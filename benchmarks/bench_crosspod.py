"""Beyond-paper headline: cross-pod traffic, GA-SGD vs MA-SGD/DiLoCo(+int8).

Reads the §Perf records produced by scripts/hillclimb.py (experiments/perf);
if absent, emits the statically-known result set from EXPERIMENTS.md §4.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

PERF = Path(__file__).resolve().parents[1] / "experiments" / "perf"

# measured on stablelm-3b x train_4k x 2x16x16 (see EXPERIMENTS.md §4)
FALLBACK = [
    ("ga_sgd_baseline", 0.699e9, 1.0),
    ("diloco_h50", 0.0140e9, 50.0),
    ("diloco_h50_int8_ef", 0.0036e9, 196.0),
]


def run(quick: bool = True):
    rows = []
    recs = []
    for p in sorted(PERF.glob("stablelm-3b__train_4k__2x16x16__P*.json")):
        d = json.loads(p.read_text())
        xb = d.get("cross_pod_bytes_per_step", d.get("cross_pod_bytes"))
        if xb is not None:
            recs.append((d["tag"], float(xb)))
    if recs:
        base = max(xb for _, xb in recs)
        for tag, xb in recs:
            rows.append({"name": f"crosspod_{tag}",
                         "us_per_call": xb / 50e9 * 1e6,  # ICI-model seconds
                         "cross_pod_bytes": xb,
                         "derived": f"GB_per_step={xb / 1e9:.4f};"
                                    f"reduction={base / max(xb, 1e-9):.0f}x"})
    else:
        for tag, xb, red in FALLBACK:
            rows.append({"name": f"crosspod_{tag}",
                         "us_per_call": xb / 50e9 * 1e6,
                         "cross_pod_bytes": xb,
                         "derived": f"GB_per_step={xb / 1e9:.4f};"
                                    f"reduction={red:.0f}x"})
    return emit(rows, "bench_crosspod")


if __name__ == "__main__":
    run()
