"""Paper Fig 7: distributed optimization algorithms (GA-SGD / MA-SGD / ADMM)
on LR and SVM -- convergence vs simulated wall-clock and vs rounds."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.algorithms import make_algorithm
from repro.core.mlmodels import make_study_model
from repro.core.runtimes import FaaSRuntime
from repro.data.synthetic import make_dataset, train_val_split


def run(quick: bool = True):
    rows = []
    rows_n = 40_000 if quick else 400_000
    workers = 10 if quick else 50
    ds = make_dataset("higgs", rows=rows_n)
    tr, va = train_val_split(ds)
    for mdl in ("lr", "svm"):
        model = make_study_model(mdl, tr)
        for alg, kw in [("ga_sgd", dict(lr=0.3, batch_size=1024)),
                        ("ma_sgd", dict(lr=0.3, batch_size=1024)),
                        ("admm", dict(lr=0.1, local_epochs=10))]:
            algo = make_algorithm(alg, **kw)
            r = FaaSRuntime(workers=workers, channel="memcached").train(
                model, algo, tr, va, max_epochs=5)
            rows.append({
                "name": f"fig7_{mdl}_{alg}", "model": mdl, "algorithm": alg,
                "us_per_call": r.sim_time * 1e6 / max(r.rounds, 1),
                "sim_time_s": r.sim_time, "rounds": r.rounds,
                "final_loss": r.final_loss,
                "derived": f"loss={r.final_loss:.4f};rounds={r.rounds}",
            })
    return emit(rows, "bench_algorithms")


if __name__ == "__main__":
    run()
