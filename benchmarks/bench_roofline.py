"""Roofline table (beyond paper): per (arch x shape x mesh) three-term
roofline from the dry-run artifacts in experiments/dryrun/.

Joins the committed repo-root perf trajectory (``BENCH_roofline.json``,
schema ``repro.bench.roofline/v1``): the committed full-arch dry-run
artifact (the measured-MFU cell, see ``bench_kernels``) keeps the file
populated on a fresh checkout; regenerate more cells with
``python -m repro.launch.dryrun``."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit, emit_root

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run(quick: bool = True):
    rows = []
    for p in sorted(DRYRUN.glob("*.json")):
        d = json.loads(p.read_text())
        if not d.get("ok") or d.get("skipped") or d.get("reduced"):
            continue
        rows.append({
            "name": f"roofline_{d['arch']}_{d['shape']}_{d['mesh']}",
            "us_per_call": d["t_compute_s"] * 1e6,
            "t_compute_s": d["t_compute_s"], "t_memory_s": d["t_memory_s"],
            "t_collective_s": d["t_collective_s"],
            "bottleneck": d["bottleneck"],
            "roofline_fraction": d["roofline_fraction"],
            "flops_ratio": d["flops_ratio"],
            "derived": (f"bound={d['bottleneck']};"
                        f"frac={d['roofline_fraction']:.3f};"
                        f"useful_flops_ratio={d['flops_ratio']:.2f}"),
        })
    if not rows:
        rows.append({"name": "roofline_missing", "us_per_call": 0,
                     "derived": "run `python -m repro.launch.dryrun` first"})
    emit_root("roofline", rows, quick=quick)
    return emit(rows, "bench_roofline")


if __name__ == "__main__":
    run()
