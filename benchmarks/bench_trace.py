"""Trace subsystem (DESIGN.md §18): recorder overhead and conservation.

Replays the pinned PR-9 parity cases (``tests/fixtures/trace_parity_pr9``)
twice each -- recorder off, recorder on -- and asserts the acceptance
story: disabled tracing is byte-identical to the pinned pre-trace metered
outputs (overhead == 0 in the simulated domain), enabled tracing perturbs
nothing while the three conservation gates (clock tiling, $ ledger, byte
census) all hold, and the Chrome exporter round-trips every span.  Rows
record span/mark volume, event rate, and the wall-clock cost of carrying
the recorder.  Writes ``BENCH_trace.json`` at the repo root
(schema ``repro.bench.trace/v1``).
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import ROOT, emit, emit_root, timeit
from repro.core.trace import assert_invariants, derive_breakdown, export_chrome
from repro.experiments.spec import ExperimentSpec

FIXTURE = ROOT / "tests" / "fixtures" / "trace_parity_pr9.json"

#: metered RunResult fields pinned by the fixture (exact == comparison)
PINNED = ("sim_time", "cost", "comm_bytes", "comm_cost",
          "ckpt_bytes", "ckpt_time", "ckpt_cost")


def _run(spec: ExperimentSpec, trace: bool):
    model, algo, tr, va = spec.build_workload()
    return spec.build_runtime().train(model, algo, tr, va,
                                      max_epochs=spec.max_epochs,
                                      trace=trace)


def run(quick: bool = True):
    rows = []
    cases = json.loads(FIXTURE.read_text())["cases"]
    reps = 3 if quick else 7

    for case in cases:
        spec = ExperimentSpec.from_dict(case["spec"])
        exp = case["result"]

        # -- disabled: byte-identical to the pinned pre-trace outputs ------
        off = _run(spec, trace=False)
        assert off.trace is None
        for f in PINNED:
            assert getattr(off, f) == exp[f], f
        assert off.breakdown == exp["breakdown"]

        # -- enabled: same meters + the three conservation gates -----------
        on = _run(spec, trace=True)
        for f in PINNED:
            assert getattr(on, f) == exp[f], f
        inv = assert_invariants(on)
        assert inv["ok"]
        assert on.trace.meters == on.breakdown
        events = export_chrome(on.trace)["traceEvents"]
        assert sum(e["ph"] == "X" for e in events) == len(on.trace.spans)
        bd = derive_breakdown(on.trace)

        # -- wall-clock cost of carrying the recorder ----------------------
        t_off = timeit(_run, spec, False, reps=reps)
        t_on = timeit(_run, spec, True, reps=reps)
        n_ev = on.trace.n_events
        rows.append({
            "name": f"trace[{spec.name}]",
            "us_per_call": t_on * 1e6,
            "kind": "parity", "platform": spec.platform,
            "spans": len(on.trace.spans), "marks": len(on.trace.marks),
            "events": n_ev,
            "wall_off_s": t_off, "wall_on_s": t_on,
            "overhead_x": t_on / t_off,
            "us_per_event": t_on * 1e6 / n_ev,
            "sim_time_s": on.sim_time,
            "traced_wall_s": bd["wall"],
            "metered_overhead": 0.0,    # asserted byte-identical above
            "derived": (f"ev={n_ev};"
                        f"over={t_on / t_off:.2f}x;"
                        f"sim={on.sim_time:.2f}s"),
        })

    emit_root("trace", rows, fixture=str(FIXTURE.relative_to(ROOT)),
              pinned_fields=list(PINNED), reps=reps)
    return emit(rows, "bench_trace")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=True)
    ap.add_argument("--full", dest="quick", action="store_false")
    run(quick=ap.parse_args().quick)
