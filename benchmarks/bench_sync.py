"""Paper Fig 8 extended: Synchronous (BSP) vs Asynchronous (SIREN-style
S-ASP) vs Stale-Synchronous (SSP, staleness bound s) -- plus a spot-instance
IaaS scenario with injected preemptions (DESIGN.md §6-§7).

Since the declarative-API redesign (DESIGN.md §10) this driver is a thin
view over the ``fig8_sync`` and ``spot_vs_ondemand`` presets: the trial
definitions live in :mod:`repro.experiments.presets`, shared with
``python -m repro run fig8_sync``.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.experiments import get_preset, run_experiment


def run(quick: bool = True):
    rows = []
    for rec in (run_experiment(s) for s in
                get_preset("fig8_sync").build(quick)):
        r = rec.result
        rows.append({
            "name": rec.spec.name,
            "us_per_call": r["sim_time_s"] * 1e6 / max(r["rounds"], 1),
            "sim_time_s": r["sim_time_s"], "rounds": r["rounds"],
            "final_loss": r["final_loss"],
            "max_staleness": r["max_staleness"],
            "derived": (f"loss={r['final_loss']:.4f};rounds={r['rounds']};"
                        f"stale={r['max_staleness']}"),
        })

    # ---- spot-instance IaaS: preemption + restart-from-checkpoint ----------
    demand, spot = (run_experiment(s) for s in
                    get_preset("spot_vs_ondemand").build(quick))
    assert spot.result["preemptions"] >= 1, \
        "spot scenario must see a preemption"
    rows.append({
        "name": "spot_iaas_vs_ondemand",
        "us_per_call": spot.result["sim_time_s"] * 1e6,
        "sim_time_s": spot.result["sim_time_s"],
        "cost_usd": spot.result["cost_usd"],
        "preemptions": spot.result["preemptions"],
        "derived": (f"preempt={spot.result['preemptions']};"
                    f"spot=${spot.result['cost_usd']:.4f}"
                    f"@{spot.result['sim_time_s']:.0f}s;"
                    f"ondemand=${demand.result['cost_usd']:.4f}"
                    f"@{demand.result['sim_time_s']:.0f}s"),
    })
    return emit(rows, "bench_sync")


if __name__ == "__main__":
    run()
