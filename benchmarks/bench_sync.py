"""Paper Fig 8: Synchronous (BSP) vs Asynchronous (SIREN-style S-ASP)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.algorithms import make_algorithm
from repro.core.mlmodels import make_study_model
from repro.core.runtimes import FaaSRuntime
from repro.data.synthetic import make_dataset, train_val_split


def run(quick: bool = True):
    rows = []
    for dsname in (("higgs",) if quick else ("higgs", "rcv1")):
        ds = make_dataset(dsname, rows=30_000 if quick else 200_000)
        tr, va = train_val_split(ds)
        model = make_study_model("lr", tr)
        for sync in ("bsp", "asp"):
            # high lr + strong straggler: the regime where stale SIREN-style
            # overwrites destabilize (paper Fig 8); at low lr ASP's extra
            # update count wins instead
            algo = make_algorithm("ga_sgd", lr=1.0, batch_size=2048)
            r = FaaSRuntime(workers=16, sync=sync, straggler=6.0).train(
                model, algo, tr, va, max_epochs=4)
            rows.append({
                "name": f"fig8_{dsname}_{sync}",
                "us_per_call": r.sim_time * 1e6 / max(r.rounds, 1),
                "sim_time_s": r.sim_time, "rounds": r.rounds,
                "final_loss": r.final_loss,
                "derived": f"loss={r.final_loss:.4f};rounds={r.rounds}",
            })
    return emit(rows, "bench_sync")


if __name__ == "__main__":
    run()
