"""Paper Fig 8 extended: Synchronous (BSP) vs Asynchronous (SIREN-style
S-ASP) vs Stale-Synchronous (SSP, staleness bound s) -- all three through
the shared discrete-event engine -- plus a spot-instance IaaS scenario with
injected preemptions (DESIGN.md §6-§7)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.algorithms import make_algorithm
from repro.core.mlmodels import make_study_model
from repro.core.runtimes import IaaSRuntime, FaaSRuntime
from repro.data.synthetic import make_dataset, train_val_split


def run(quick: bool = True):
    rows = []
    for dsname in (("higgs",) if quick else ("higgs", "rcv1")):
        ds = make_dataset(dsname, rows=30_000 if quick else 200_000)
        tr, va = train_val_split(ds)
        model = make_study_model("lr", tr)
        for sync in ("bsp", "asp", "ssp:2"):
            # high lr + strong straggler: the regime where stale SIREN-style
            # overwrites destabilize (paper Fig 8); at low lr ASP's extra
            # update count wins instead; SSP's bound caps the damage
            algo = make_algorithm("ga_sgd", lr=1.0, batch_size=2048)
            r = FaaSRuntime(workers=16, sync=sync, straggler=6.0).train(
                model, algo, tr, va, max_epochs=4)
            tag = sync.replace(":", "")
            rows.append({
                "name": f"fig8_{dsname}_{tag}",
                "us_per_call": r.sim_time * 1e6 / max(r.rounds, 1),
                "sim_time_s": r.sim_time, "rounds": r.rounds,
                "final_loss": r.final_loss,
                "max_staleness": r.max_staleness,
                "derived": (f"loss={r.final_loss:.4f};rounds={r.rounds};"
                            f"stale={r.max_staleness}"),
            })

    # ---- spot-instance IaaS: preemption + restart-from-checkpoint ----------
    ds = make_dataset("higgs", rows=30_000 if quick else 200_000)
    tr, va = train_val_split(ds)
    model = make_study_model("lr", tr)
    algo = lambda: make_algorithm("ga_sgd", lr=0.3, batch_size=2048)  # noqa
    demand = IaaSRuntime(workers=8).train(model, algo(), tr, va, max_epochs=3)
    t0 = demand.breakdown["startup"]
    spot = IaaSRuntime(workers=8, spot=True,
                       preempt_at=((1, t0 + 2.0), (5, t0 + 6.0))).train(
        model, algo(), tr, va, max_epochs=3)
    assert spot.preemptions >= 1, "spot scenario must see a preemption"
    rows.append({
        "name": "spot_iaas_vs_ondemand",
        "us_per_call": spot.sim_time * 1e6,
        "sim_time_s": spot.sim_time, "cost_usd": spot.cost,
        "preemptions": spot.preemptions,
        "derived": (f"preempt={spot.preemptions};"
                    f"spot=${spot.cost:.4f}@{spot.sim_time:.0f}s;"
                    f"ondemand=${demand.cost:.4f}@{demand.sim_time:.0f}s"),
    })
    return emit(rows, "bench_sync")


if __name__ == "__main__":
    run()
