"""Paper Fig 10: runtime breakdown (startup / data loading / computation /
communication) for LR on Higgs, w=10, 10 epochs: FaaS vs IaaS vs hybrid."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.algorithms import make_algorithm
from repro.core.mlmodels import make_study_model
from repro.core.runtimes import FaaSRuntime, IaaSRuntime
from repro.data.synthetic import make_dataset, train_val_split


def run(quick: bool = True):
    rows = []
    ds = make_dataset("higgs", rows=30_000 if quick else 500_000)
    tr, va = train_val_split(ds)
    model = make_study_model("lr", tr)
    algo = lambda: make_algorithm("ga_sgd", lr=0.3, batch_size=2048)  # noqa

    systems = {
        "faas_s3": lambda: FaaSRuntime(workers=10, channel="s3"),
        "faas_memcached": lambda: FaaSRuntime(workers=10, channel="memcached"),
        "hybridps": lambda: FaaSRuntime(workers=10, channel="vmps"),
        "iaas": lambda: IaaSRuntime(workers=10),
    }
    for name, mk in systems.items():
        r = mk().train(model, algo(), tr, va, max_epochs=10)
        bd = r.breakdown
        rows.append({
            "name": f"fig10_{name}", "us_per_call": r.sim_time * 1e6,
            "sim_time_s": r.sim_time, "breakdown": bd,
            "derived": (f"startup={bd['startup']:.1f}s;"
                        f"load={bd['load']:.2f}s;"
                        f"compute={bd['compute']:.2f}s;"
                        f"comm={bd['comm']:.2f}s"),
        })
    return emit(rows, "bench_breakdown")


if __name__ == "__main__":
    run()
