"""DESIGN.md §12: the composable comm axis -- Transport x Collective x
Codec grid on a CNN-sized (12 MB) update.

Covers Table 3 (allreduce vs scatter-reduce), the FSD-Inference-style
hierarchical two-level tree, the MLLess-style reduced-communication codecs
(int8 + error feedback, top-k sparsification), the DynamoDB 400 KB rule
(spec-time "N/A" exactly like Table 1 -- note how scatter-reduce or a
sparsifying codec flips cells back to feasible), and the same codecs on
the IaaS NIC ring / pod DCN ring / hybrid VM-PS push-pull.
"""
from __future__ import annotations

from benchmarks.common import emit, emit_root
from repro.core.comm import ChannelItemTooLarge
from repro.experiments import ExperimentSpec, run_experiment
from repro.experiments.spec import FleetSpec


def _base(quick: bool, platform: str = "faas") -> ExperimentSpec:
    return ExperimentSpec(
        platform=platform, model="mobilenet", dataset="cifar10",
        rows=2_000 if quick else 20_000, algorithm="ga_sgd",
        algo_args={"lr": 0.05, "batch_size": 512}, max_epochs=1,
        fleet=FleetSpec(workers=8))


def run(quick: bool = True):
    rows = []
    channels = ("s3", "dynamodb") if quick else (
        "s3", "memcached", "redis", "dynamodb")
    collectives = ("allreduce", "scatter_reduce", "hierarchical")
    codecs = ("fp32", "int8", "topk:0.01")
    grid = [("faas", f"{ch}/{co}/{cd}")
            for ch in channels for co in collectives for cd in codecs]
    # one row per non-store collective: NIC ring (IaaS), DCN ring (pod),
    # hybrid VM-PS push-pull -- same codecs, same metering
    grid += [("iaas", "nic/ring/fp32"), ("iaas", "nic/ring/int8"),
             ("pod", "dcn/ring/fp32"), ("pod", "dcn/ring/topk:0.01"),
             ("faas", "vmps/pushpull/fp32")]

    fp32_bytes: dict[tuple, float] = {}
    for platform, stack in grid:
        name = "comm_" + platform + "_" + stack.replace("/", "_").replace(
            ":", "")
        try:
            spec = _base(quick, platform).with_(name=name, comm=stack)
        except ChannelItemTooLarge as e:
            # the spec-time Table 1 "N/A" cell (DynamoDB 400 KB limit)
            rows.append({"name": name, "us_per_call": 0.0,
                         "derived": "N/A:" + str(e).split(";")[0]})
            continue
        r = run_experiment(spec, cache_dir=None).result
        if r.get("error"):
            rows.append({"name": name, "us_per_call": 0.0,
                         "derived": "N/A:" + r["error"]})
            continue
        key = (platform, stack.rsplit("/", 1)[0])
        if stack.endswith("/fp32"):
            fp32_bytes[key] = r["comm_bytes"]
        base = fp32_bytes.get(key)
        ratio = (r["comm_bytes"] / base) if base else float("nan")
        rows.append({
            "name": name,
            "us_per_call": r["sim_time_s"] * 1e6 / max(r["rounds"], 1),
            "sim_time_s": r["sim_time_s"], "cost_usd": r["cost_usd"],
            "comm_bytes": r["comm_bytes"],
            "comm_time_s": r.get("comm_time_s", 0.0),
            "derived": (f"bytes={r['comm_bytes']:.0f};"
                        f"ratio_vs_fp32={ratio:.4f}"),
        })
    emit_root("comm", rows, quick=quick,
              grid={"channels": list(channels),
                    "collectives": list(collectives),
                    "codecs": list(codecs)})
    return emit(rows, "bench_comm")


if __name__ == "__main__":
    run()
