"""Paper Table 1 (+Table 2): communication channels -- S3 vs Memcached vs
DynamoDB vs hybrid VM-PS: relative slowdown and relative cost vs S3."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.algorithms import make_algorithm
from repro.core.mlmodels import make_study_model
from repro.core.runtimes import FaaSRuntime
from repro.data.synthetic import make_dataset, train_val_split


def run(quick: bool = True):
    rows = []
    ds = make_dataset("higgs", rows=30_000 if quick else 200_000)
    tr, va = train_val_split(ds)
    cds = make_dataset("cifar10", rows=4_000 if quick else 20_000)
    ctr, cva = train_val_split(cds)
    workloads = [
        ("lr_higgs", make_study_model("lr", tr),
         lambda: make_algorithm("admm", lr=0.1, local_epochs=5), tr, va, 3),
        ("kmeans_higgs", make_study_model("kmeans", tr, k=10),
         lambda: make_algorithm("kmeans_em"), tr, va, 3),
        ("mobilenet_cifar10", make_study_model("mobilenet", ctr),
         lambda: make_algorithm("ga_sgd", lr=0.05, batch_size=512), ctr, cva, 1),
    ]
    for wname, model, algo, dtr, dva, ep in workloads:
        base = None
        for chan in ("s3", "memcached", "redis", "dynamodb", "vmps"):
            r = FaaSRuntime(workers=10, channel=chan).train(
                model, algo(), dtr, dva, max_epochs=ep)
            if r.error:
                rows.append({"name": f"table1_{wname}_{chan}",
                             "us_per_call": 0.0, "derived": "N/A:" + r.error})
                continue
            if chan == "s3":
                base = r
            slow = r.sim_time / base.sim_time if base else 1.0
            rel_cost = r.cost / base.cost if base and base.cost else 1.0
            rows.append({
                "name": f"table1_{wname}_{chan}",
                "us_per_call": r.sim_time * 1e6 / max(r.rounds, 1),
                "sim_time_s": r.sim_time, "cost_usd": r.cost,
                "derived": f"slowdown={slow:.2f};rel_cost={rel_cost:.2f}",
            })
    return emit(rows, "bench_channels")


if __name__ == "__main__":
    run()
