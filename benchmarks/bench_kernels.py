"""Kernel microbenchmarks.

CPU container: we time the pure-jnp oracle paths (the CPU execution baseline)
and report the model bytes each kernel must stream, i.e. the TPU roofline
floor time = bytes / 819 GB/s.  The Pallas kernels themselves are validated
in interpret mode (tests/test_kernels.py) -- interpret-mode timing is not
meaningful, so `derived` reports the v5e roofline floor instead.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_root, timeit
from repro.distributed.roofline import HBM_BW, PEAK_FLOPS
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quant8.ref import quantize8_ref
from repro.models.ssm import ssd_scan


def run(quick: bool = True):
    rows = []
    rng = np.random.default_rng(0)

    # flash attention fwd: b*h=8, s=2048, d=128
    bh, s, d = 8, 2048, 128
    q = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True,
                                              sm_scale=d ** -0.5))
    t = timeit(lambda: jax.block_until_ready(f(q, q, q)))
    flops = 4 * bh * s * s * d
    rows.append({"name": "kern_flash_attention_ref", "us_per_call": t * 1e6,
                 "derived": f"cpu_gflops={flops / t / 1e9:.1f};"
                            f"tpu_floor_us={flops / PEAK_FLOPS * 1e6:.1f}"})

    # decode attention: b*m=16, S=32768, d=128, g=8
    bm, g, S = 16, 8, 32768 if not quick else 8192
    qd = jnp.asarray(rng.standard_normal((bm, g, d)), jnp.bfloat16)
    kd = jnp.asarray(rng.standard_normal((bm, S, d)), jnp.bfloat16)
    fd = jax.jit(lambda q, k, v: decode_attention_ref(q, k, v, S,
                                                      sm_scale=d ** -0.5))
    t = timeit(lambda: jax.block_until_ready(fd(qd, kd, kd)))
    bytes_ = 2 * bm * S * d * 2
    rows.append({"name": "kern_decode_attention_ref", "us_per_call": t * 1e6,
                 "derived": f"cache_GB={bytes_ / 1e9:.3f};"
                            f"tpu_floor_us={bytes_ / HBM_BW * 1e6:.1f}"})

    # ssd scan: b=2, s=2048, h=16, p=64, n=64
    b, s2, h, p, n = 2, 2048, 16, 64, 64
    x = jnp.asarray(rng.standard_normal((b, s2, h, p)), jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.standard_normal((b, s2, h)), jnp.float32))
    alog = jnp.asarray(rng.standard_normal(h) * 0.3, jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s2, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s2, n)), jnp.float32)
    fs = jax.jit(lambda *a: ssd_scan(*a, 256)[0])
    t = timeit(lambda: jax.block_until_ready(fs(x, dt, alog, B, C)))
    ssd_flops = 2 * b * s2 * 256 * h * p + 4 * b * s2 * h * p * n
    rows.append({"name": "kern_ssd_scan_ref", "us_per_call": t * 1e6,
                 "derived": f"tpu_floor_us={ssd_flops / PEAK_FLOPS * 1e6:.2f}"})

    # quant8: 64 MB tensor
    nq = 16_000_000 if not quick else 4_000_000
    xq = jnp.asarray(rng.standard_normal((nq // 256, 256)), jnp.float32)
    fq = jax.jit(quantize8_ref)
    t = timeit(lambda: jax.block_until_ready(fq(xq)))
    bytes_q = nq * 5  # read fp32 + write int8
    rows.append({"name": "kern_quant8_ref", "us_per_call": t * 1e6,
                 "derived": f"cpu_GBps={bytes_q / t / 1e9:.1f};"
                            f"tpu_floor_us={bytes_q / HBM_BW * 1e6:.1f}"})
    emit_root("kernels", rows, quick=quick,
              peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW)
    return emit(rows, "bench_kernels")


if __name__ == "__main__":
    run()
