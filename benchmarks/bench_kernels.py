"""Kernel microbenchmarks.

CPU container: we time the pure-jnp oracle paths (the CPU execution baseline)
and report the model bytes each kernel must stream, i.e. the TPU roofline
floor time = bytes / 819 GB/s.  The Pallas kernels themselves are validated
in interpret mode (tests/test_kernels.py) -- interpret-mode timing is not
meaningful, so `derived` reports the v5e roofline floor instead.

This bench also closes the measured-MFU loop (DESIGN.md §16): it compiles
the full smollm-360m train_4k step on a 2x4 host mesh in a subprocess
(``repro.launch.dryrun`` -- jax pins the device count at first init) and
emits the compute-bound roofline fraction into the committed
``BENCH_kernels.json``, which ``PodPlatform(mfu="measured")`` and the
analytic planner's pod rows read (:mod:`repro.core.calibration`).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, emit_root, timeit
from repro.distributed.roofline import HBM_BW, PEAK_FLOPS
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quant8.ops import int8_roundtrip
from repro.kernels.quant8.ref import quantize8_ref
from repro.kernels.topk_ef.ops import topk_ef
from repro.models.ssm import ssd_scan

#: the measured-MFU dry-run cell: full (non-reduced) arch so the useful-FLOPs
#: share reflects the real model, host mesh small enough to compile in ~5 s
MFU_ARCH, MFU_SHAPE, MFU_MESH = "smollm-360m", "train_4k", "2x4"
DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def measure_roofline_fraction() -> dict:
    """Run the MFU dry-run cell in a subprocess and return
    ``{"roofline_fraction": ..., "roofline_source": ...}`` (empty dict if
    the compile fails -- the committed snapshot then remains authoritative)."""
    from repro.core.calibration import compute_measured_mfu

    env = dict(os.environ,
               REPRO_XLA_FLAGS="--xla_force_host_platform_device_count=8")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", MFU_ARCH,
         "--shape", MFU_SHAPE, "--mesh", MFU_MESH],
        env=env, capture_output=True, text=True)
    artifact = DRYRUN_DIR / f"{MFU_ARCH}__{MFU_SHAPE}__{MFU_MESH}.json"
    if proc.returncode != 0 or not artifact.exists():
        print(f"# measured-MFU dryrun failed:\n{proc.stderr[-2000:]}",
              file=sys.stderr)
        return {}
    d = json.loads(artifact.read_text())
    if not d.get("ok") or d.get("skipped"):
        return {}
    frac = compute_measured_mfu(d)
    return {
        "roofline_fraction": frac,
        "roofline_source": {
            "arch": MFU_ARCH, "shape": MFU_SHAPE, "mesh": MFU_MESH,
            "chips": d["chips"],
            "model_flops_global": d["model_flops_global"],
            "t_compute_s": d["t_compute_s"],
        },
    }


def run(quick: bool = True):
    rows = []
    rng = np.random.default_rng(0)

    # flash attention fwd: b*h=8, s=2048, d=128
    bh, s, d = 8, 2048, 128
    q = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.bfloat16)
    f = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True,
                                              sm_scale=d ** -0.5))
    t = timeit(lambda: jax.block_until_ready(f(q, q, q)))
    flops = 4 * bh * s * s * d
    rows.append({"name": "kern_flash_attention_ref", "us_per_call": t * 1e6,
                 "derived": f"cpu_gflops={flops / t / 1e9:.1f};"
                            f"tpu_floor_us={flops / PEAK_FLOPS * 1e6:.1f}"})

    # decode attention: b*m=16, S=32768, d=128, g=8
    bm, g, S = 16, 8, 32768 if not quick else 8192
    qd = jnp.asarray(rng.standard_normal((bm, g, d)), jnp.bfloat16)
    kd = jnp.asarray(rng.standard_normal((bm, S, d)), jnp.bfloat16)
    fd = jax.jit(lambda q, k, v: decode_attention_ref(q, k, v, S,
                                                      sm_scale=d ** -0.5))
    t = timeit(lambda: jax.block_until_ready(fd(qd, kd, kd)))
    bytes_ = 2 * bm * S * d * 2
    rows.append({"name": "kern_decode_attention_ref", "us_per_call": t * 1e6,
                 "derived": f"cache_GB={bytes_ / 1e9:.3f};"
                            f"tpu_floor_us={bytes_ / HBM_BW * 1e6:.1f}"})

    # ssd scan: b=2, s=2048, h=16, p=64, n=64
    b, s2, h, p, n = 2, 2048, 16, 64, 64
    x = jnp.asarray(rng.standard_normal((b, s2, h, p)), jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.standard_normal((b, s2, h)), jnp.float32))
    alog = jnp.asarray(rng.standard_normal(h) * 0.3, jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s2, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s2, n)), jnp.float32)
    fs = jax.jit(lambda *a: ssd_scan(*a, 256)[0])
    t = timeit(lambda: jax.block_until_ready(fs(x, dt, alog, B, C)))
    ssd_flops = 2 * b * s2 * 256 * h * p + 4 * b * s2 * h * p * n
    rows.append({"name": "kern_ssd_scan_ref", "us_per_call": t * 1e6,
                 "derived": f"tpu_floor_us={ssd_flops / PEAK_FLOPS * 1e6:.2f}"})

    # quant8: 64 MB tensor
    nq = 16_000_000 if not quick else 4_000_000
    xq = jnp.asarray(rng.standard_normal((nq // 256, 256)), jnp.float32)
    fq = jax.jit(quantize8_ref)
    t = timeit(lambda: jax.block_until_ready(fq(xq)))
    bytes_q = nq * 5  # read fp32 + write int8
    rows.append({"name": "kern_quant8_ref", "us_per_call": t * 1e6,
                 "derived": f"cpu_GBps={bytes_q / t / 1e9:.1f};"
                            f"tpu_floor_us={bytes_q / HBM_BW * 1e6:.1f}"})

    # codec hot paths: the fused EF roundtrip and the topk filter exactly as
    # Int8EFCodec / TopKCodec execute them (ref backend = the CPU baseline
    # of the same padded-tile plumbing the Pallas kernels run on TPU)
    xc = jnp.asarray(rng.standard_normal((nq,)), jnp.float32)
    fr = lambda: jax.block_until_ready(int8_roundtrip(xc, backend="ref")[2])
    t = timeit(fr)
    # read fp32 + write int8 codes + fp32 scales + fp32 deq + fp32 err
    bytes_r = nq * (4 + 1 + 4 / 256 + 4 + 4)
    rows.append({"name": "kern_int8_roundtrip_ref", "us_per_call": t * 1e6,
                 "derived": f"cpu_GBps={bytes_r / t / 1e9:.1f};"
                            f"tpu_floor_us={bytes_r / HBM_BW * 1e6:.1f}"})

    kt = max(1, nq // 100)
    ft = lambda: jax.block_until_ready(topk_ef(xc, kt, backend="ref")[0])
    t = timeit(ft)
    bytes_t = nq * 12  # read fp32 + write kept + residual
    rows.append({"name": "kern_topk_ef_ref", "us_per_call": t * 1e6,
                 "derived": f"cpu_GBps={bytes_t / t / 1e9:.1f};"
                            f"tpu_floor_us={bytes_t / HBM_BW * 1e6:.1f}"})

    mfu = measure_roofline_fraction()
    emit_root("kernels", rows, quick=quick,
              peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW, **mfu)
    return emit(rows, "bench_kernels")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller tensors (CI smoke)")
    ap.add_argument("--full", action="store_true",
                    help="full-size tensors (overrides --quick)")
    args = ap.parse_args()
    run(quick=not args.full)
