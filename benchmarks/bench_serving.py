"""Serving frontier (DESIGN.md §14): cost vs p99 for FaaS / IaaS / pod
across arrival shapes — trickle, sustained, flash crowd.

Runs the same grid as ``python -m repro serve --grid`` (provisioned fleets
analytically sized per shape via ``provision_for``) and asserts the
acceptance story: FaaS wins the trickle and flash cells on $ (scale to
zero), provisioned fleets win sustained traffic on both $ and p99.  Also
writes ``BENCH_serving.json`` at the repo root with the full frontier.
"""
from __future__ import annotations

from benchmarks.common import emit, emit_root
from repro.experiments import frontier
from repro.experiments.serving import FRONTIER_ARRIVALS


def run(quick: bool = True):
    duration = 300.0 if quick else 3600.0
    recs = frontier(duration_s=duration)
    rows = []
    for rec in recs:
        r = rec.result
        rows.append({
            "name": rec.spec.name,
            "us_per_call": r["p99_ms"] * 1e3,          # p99 as the latency col
            "platform": rec.spec.platform, "arrival": rec.spec.arrival,
            "workers": r["workers0"], "requests": r["requests"],
            "completed": r["completed"], "cold_starts": r["cold_starts"],
            "p50_ms": r["p50_ms"], "p99_ms": r["p99_ms"],
            "cost_usd": r["cost_usd"], "usd_per_1k": r["usd_per_1k"],
            "derived": (f"w={r['workers0']};req={r['requests']};"
                        f"cold={r['cold_starts']};p99={r['p99_ms']:.1f}ms;"
                        f"cost=${r['cost_usd']:.5f}"),
        })
        assert r["completed"] + r["rejected"] + r["dropped"] == r["requests"]

    cell = {(row["platform"], row["arrival"]): row for row in rows}
    trickle, sustained, flash = FRONTIER_ARRIVALS
    # scale-to-zero wins the sparse and bursty cells on $
    for shape in (trickle, flash):
        for fat in ("iaas", "pod"):
            assert cell[("faas", shape)]["cost_usd"] < \
                cell[(fat, shape)]["cost_usd"], (shape, fat)
    # provisioned + batched wins sustained traffic on $ AND p99
    assert cell[("iaas", sustained)]["cost_usd"] < \
        cell[("faas", sustained)]["cost_usd"]
    assert min(cell[("iaas", sustained)]["p99_ms"],
               cell[("pod", sustained)]["p99_ms"]) < \
        cell[("faas", sustained)]["p99_ms"]

    emit_root("serving", rows, duration_s=duration,
              arrivals=list(FRONTIER_ARRIVALS))
    return emit(rows, "bench_serving")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=True)
    ap.add_argument("--full", dest="quick", action="store_false")
    run(quick=ap.parse_args().quick)
