"""Paper Fig 11/12 + §5.1.1 COST check: end-to-end runtime vs cost profiles
as a function of worker count, FaaS vs IaaS (+GPU for the NN model).

The Fig 11 and heterogeneous-fleet rows come straight from the
``fig11_end2end`` and ``hetero_fleet`` presets (DESIGN.md §10); the Fig 12
MobileNet sweep and the COST check are expressed as inline
:class:`~repro.experiments.ExperimentSpec` grids over the same API.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.experiments import (
    ExperimentSpec, FleetSpec, get_preset, run_experiment, sweep,
)


def _row(rec, name=None, **extra):
    r = rec.result
    return {"name": name or rec.spec.name, "us_per_call": r["sim_time_s"] * 1e6,
            "sim_time_s": r["sim_time_s"], "cost_usd": r["cost_usd"],
            "derived": f"cost=${r['cost_usd']:.4f};loss={r['final_loss']:.4f}",
            **extra}


def run(quick: bool = True):
    rows = []

    # ---- LR (communication-efficient via ADMM), Fig 11 ----------------------
    for rec in (run_experiment(s) for s in
                get_preset("fig11_end2end").build(quick)):
        rows.append(_row(rec))

    # ---- MobileNet (communication-heavy GA-SGD), Fig 12 ---------------------
    mn = ExperimentSpec(
        model="mobilenet", dataset="cifar10", rows=4_000 if quick else 50_000,
        algorithm="ga_sgd", algo_args={"lr": 0.05, "batch_size": 512},
        max_epochs=1)
    counts = [5, 10] if quick else [5, 10, 25]
    faas = sweep(mn.with_(name="fig12_mn_faas", platform="faas",
                          **{"comm.channel": "memcached"}),
                 {"fleet.workers": counts})
    iaas = sweep(mn.with_(name="fig12_mn_iaasgpu", platform="iaas",
                          **{"fleet.instance": "g3s.xlarge",
                             "fleet.gpu": True}),
                 {"fleet.workers": counts})
    for rec in faas + iaas:
        w = rec.spec.fleet.workers
        base = rec.spec.name.split("[")[0]
        rows.append(_row(rec, name=f"{base}_w{w}"))

    # ---- heterogeneous fleets (engine scenario, DESIGN.md §7.2) ------------
    for rec in (run_experiment(s) for s in
                get_preset("hetero_fleet").build(quick)):
        rows.append(_row(rec))

    # ---- COST sanity check (§5.1.1): same statistical work (5 EM epochs),
    # compute-heavy k-means, single machine vs 10 workers --------------------
    km = ExperimentSpec(
        model="kmeans", model_args={"k": 250 if quick else 1000},
        dataset="higgs", rows=400_000 if quick else 2_000_000,
        algorithm="kmeans_em", max_epochs=5)
    single = run_experiment(km.with_(name="cost_single", platform="iaas",
                                     fleet=FleetSpec(workers=1)))
    f10 = run_experiment(km.with_(name="cost_faas10", platform="faas"))
    i10 = run_experiment(km.with_(name="cost_iaas10", platform="iaas"))

    # warm-cluster convention (paper §5.1.1 reports IaaS-10 at 98 s, below
    # its own 132 s cluster-start -- i.e. measured from job start)
    def warm(rec):
        return rec.result["sim_time_s"] - rec.result["breakdown"]["startup"]
    rows.append({"name": "cost_check_kmeans",
                 "us_per_call": single.result["sim_time_s"] * 1e6,
                 "single_s": warm(single), "faas10_s": warm(f10),
                 "iaas10_s": warm(i10),
                 "derived": (f"faas10_speedup={warm(single) / warm(f10):.1f}x;"
                             f"iaas10_speedup={warm(single) / warm(i10):.1f}x")})
    return emit(rows, "bench_end2end")


if __name__ == "__main__":
    run()
