"""Paper Fig 11/12 + §5.1.1 COST check: end-to-end runtime vs cost profiles
as a function of worker count, FaaS vs IaaS (+GPU for the NN model)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.algorithms import make_algorithm
from repro.core.mlmodels import make_study_model
from repro.core.runtimes import FaaSRuntime, IaaSRuntime
from repro.data.synthetic import make_dataset, train_val_split


def run(quick: bool = True):
    rows = []
    ds = make_dataset("higgs", rows=30_000 if quick else 400_000)
    tr, va = train_val_split(ds)
    lr_model = make_study_model("lr", tr)
    worker_counts = (1, 5, 10) if quick else (1, 5, 10, 25, 50, 100)

    # ---- LR (communication-efficient via ADMM) ------------------------------
    for w in worker_counts:
        algo = make_algorithm("admm", lr=0.1, local_epochs=5)
        f = FaaSRuntime(workers=w).train(lr_model, algo, tr, va, max_epochs=3)
        algo = make_algorithm("admm", lr=0.1, local_epochs=5)
        i = IaaSRuntime(workers=w).train(lr_model, algo, tr, va, max_epochs=3)
        rows.append({"name": f"fig11_lr_faas_w{w}", "us_per_call": f.sim_time * 1e6,
                     "sim_time_s": f.sim_time, "cost_usd": f.cost,
                     "derived": f"cost=${f.cost:.4f};loss={f.final_loss:.4f}"})
        rows.append({"name": f"fig11_lr_iaas_w{w}", "us_per_call": i.sim_time * 1e6,
                     "sim_time_s": i.sim_time, "cost_usd": i.cost,
                     "derived": f"cost=${i.cost:.4f};loss={i.final_loss:.4f}"})

    # ---- MobileNet (communication-heavy GA-SGD) ------------------------------
    cds = make_dataset("cifar10", rows=4_000 if quick else 50_000)
    ctr, cva = train_val_split(cds)
    mn = make_study_model("mobilenet", ctr)
    for w in ((5, 10) if quick else (5, 10, 25)):
        algo = make_algorithm("ga_sgd", lr=0.05, batch_size=512)
        f = FaaSRuntime(workers=w, channel="memcached").train(
            mn, algo, ctr, cva, max_epochs=1)
        algo = make_algorithm("ga_sgd", lr=0.05, batch_size=512)
        i = IaaSRuntime(workers=w, instance="g3s.xlarge", gpu=True).train(
            mn, algo, ctr, cva, max_epochs=1)
        rows.append({"name": f"fig12_mn_faas_w{w}", "us_per_call": f.sim_time * 1e6,
                     "sim_time_s": f.sim_time, "cost_usd": f.cost,
                     "derived": f"cost=${f.cost:.4f}"})
        rows.append({"name": f"fig12_mn_iaasgpu_w{w}", "us_per_call": i.sim_time * 1e6,
                     "sim_time_s": i.sim_time, "cost_usd": i.cost,
                     "derived": f"cost=${i.cost:.4f}"})

    # ---- heterogeneous fleets (engine scenario, DESIGN.md §7.2) ------------
    algo = make_algorithm("ga_sgd", lr=0.05, batch_size=512)
    het_f = FaaSRuntime(workers=6, lambda_gb=(3.0, 3.0, 3.0, 3.0, 1.0, 1.0),
                        channel="memcached").train(mn, algo, ctr, cva,
                                                   max_epochs=1)
    rows.append({"name": "hetero_faas_mixed_gb",
                 "us_per_call": het_f.sim_time * 1e6,
                 "sim_time_s": het_f.sim_time, "cost_usd": het_f.cost,
                 "derived": f"cost=${het_f.cost:.4f};loss={het_f.final_loss:.4f}"})
    algo = make_algorithm("admm", lr=0.1, local_epochs=5)
    het_i = IaaSRuntime(workers=4, instance=("c5.large", "c5.large",
                                             "t2.medium", "t2.medium")).train(
        lr_model, algo, tr, va, max_epochs=3)
    rows.append({"name": "hetero_iaas_mixed_instances",
                 "us_per_call": het_i.sim_time * 1e6,
                 "sim_time_s": het_i.sim_time, "cost_usd": het_i.cost,
                 "derived": f"cost=${het_i.cost:.4f};loss={het_i.final_loss:.4f}"})

    # ---- COST sanity check (§5.1.1): same statistical work (5 EM epochs),
    # compute-heavy k-means, single machine vs 10 workers --------------------
    kds = make_dataset("higgs", rows=400_000 if quick else 2_000_000)
    ktr, kva = train_val_split(kds)
    km = make_study_model("kmeans", ktr, k=250 if quick else 1000)
    single = IaaSRuntime(workers=1).train(km, make_algorithm("kmeans_em"),
                                          ktr, kva, max_epochs=5)
    f10 = FaaSRuntime(workers=10).train(km, make_algorithm("kmeans_em"),
                                        ktr, kva, max_epochs=5)
    i10 = IaaSRuntime(workers=10).train(km, make_algorithm("kmeans_em"),
                                        ktr, kva, max_epochs=5)
    # warm-cluster convention (paper §5.1.1 reports IaaS-10 at 98 s, below
    # its own 132 s cluster-start -- i.e. measured from job start)
    def warm(r):
        return r.sim_time - r.breakdown["startup"]
    rows.append({"name": "cost_check_kmeans",
                 "us_per_call": single.sim_time * 1e6,
                 "single_s": warm(single), "faas10_s": warm(f10),
                 "iaas10_s": warm(i10),
                 "derived": (f"faas10_speedup={warm(single) / warm(f10):.1f}x;"
                             f"iaas10_speedup={warm(single) / warm(i10):.1f}x")})
    return emit(rows, "bench_end2end")


if __name__ == "__main__":
    run()
