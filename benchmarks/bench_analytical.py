"""Paper Fig 13/14/15: analytical model validation + what-if simulations."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.algorithms import make_algorithm
from repro.core.analytical import (
    CostInputs, estimate_epochs, faas_time, iaas_time, q1_fast_hybrid,
    q2_hot_data,
)
from repro.core.mlmodels import make_study_model, model_bytes
from repro.core.runtimes import FaaSRuntime
from repro.data.synthetic import make_dataset, train_val_split


def run(quick: bool = True):
    rows = []
    ds = make_dataset("higgs", rows=30_000 if quick else 400_000)
    tr, va = train_val_split(ds)
    model = make_study_model("lr", tr)
    mbytes = model_bytes(model.init(jax.random.key(0)))

    # ---- Fig 13a: model vs emulated runtime across epoch counts -------------
    errs = []
    for epochs in (1, 3, 10) if quick else (1, 3, 10, 30, 100):
        algo = make_algorithm("ga_sgd", lr=0.3, batch_size=2048)
        r = FaaSRuntime(workers=10).train(model, algo, tr, va,
                                          max_epochs=epochs)
        wl = CostInputs(s_bytes=tr.nbytes, m_bytes=mbytes, R=r.rounds, C=0.001)
        t_pred = faas_time(wl, 10)
        ratio = r.sim_time / t_pred
        errs.append(ratio)
        rows.append({"name": f"fig13a_epochs{epochs}",
                     "us_per_call": r.sim_time * 1e6,
                     "pred_s": t_pred, "actual_s": r.sim_time,
                     "derived": f"actual/pred={ratio:.2f}"})

    # ---- Fig 13b: sampling-based epoch estimator -----------------------------
    algo = make_algorithm("ma_sgd", lr=0.3, batch_size=1024)
    est = estimate_epochs(model, algo, tr, target_loss=0.55, max_epochs=20)
    algo = make_algorithm("ma_sgd", lr=0.3, batch_size=1024)
    real = FaaSRuntime(workers=1).train(model, algo, tr, va,
                                        target_loss=0.55, max_epochs=20)
    rows.append({"name": "fig13b_estimator", "us_per_call": est * 1e6,
                 "derived": f"est_epochs={est};actual={real.rounds}"})

    # ---- Fig 14 (Q1): faster FaaS-IaaS link ----------------------------------
    wl_lr = CostInputs(s_bytes=16e9, m_bytes=16e3, R=20, C=60.0)
    wl_mn = CostInputs(s_bytes=220e6, m_bytes=12e6, R=500, C=400.0)
    for wname, wl in (("lr_yfcc", wl_lr), ("mn_cifar", wl_mn)):
        q1 = q1_fast_hybrid(wl, 10)
        rows.append({"name": f"fig14_{wname}", "us_per_call": q1["hybrid_now"] * 1e6,
                     **{k: v for k, v in q1.items()},
                     "derived": ";".join(f"{k}={v:.0f}s" for k, v in q1.items())})

    # ---- Fig 15 (Q2): hot data ------------------------------------------------
    q2 = q2_hot_data(wl_lr, 10)
    rows.append({"name": "fig15_hot_data", "us_per_call": q2["iaas_hot"] * 1e6,
                 **q2, "derived": f"iaas={q2['iaas_hot']:.0f}s;"
                                  f"faas={q2['faas_hot']:.0f}s"})
    return emit(rows, "bench_analytical")


if __name__ == "__main__":
    run()
