"""The three-infrastructure study (DESIGN.md §11): a real smollm-360m-config
workload (genuine JAX fwd/bwd) on FaaS vs IaaS vs accelerator pods, plus the
pod-platform communication-interval sweep (BSP GA-SGD vs LocalSGD(H) vs
DiLoCo vs int8-compressed deltas).

Thin view over the ``faas_vs_pod`` and ``pod_local_sgd`` presets, shared
with ``python -m repro run faas_vs_pod``.
"""
from __future__ import annotations

from benchmarks.common import emit, emit_root
from repro.experiments import get_preset, run_experiment


def _row(rec):
    r = rec.result
    return {
        "name": rec.spec.name,
        "us_per_call": r["sim_time_s"] * 1e6 / max(r["rounds"], 1),
        "sim_time_s": r["sim_time_s"], "cost_usd": r["cost_usd"],
        "rounds": r["rounds"], "final_loss": r["final_loss"],
        "comm_s": r["breakdown"].get("comm", 0.0),
        "comm_bytes": r.get("comm_bytes", 0.0),
        "derived": (f"loss={r['final_loss']:.4f};"
                    f"comm={r['breakdown'].get('comm', 0.0):.4f}s;"
                    f"bytes={r.get('comm_bytes', 0.0):.0f};"
                    f"cost=${r['cost_usd']:.4f}"),
    }


def run(quick: bool = True):
    rows = [_row(run_experiment(s))
            for s in get_preset("faas_vs_pod").build(quick)]

    by_name = {r["name"]: r for r in rows}
    bsp, loc8 = by_name["pods_pod_bsp"], by_name["pods_pod_local8"]
    assert loc8["comm_s"] * 4 <= bsp["comm_s"], \
        "LocalSGD(H=8) must cut metered pod comm seconds >= 4x vs BSP"

    sweep_rows = [_row(run_experiment(s))
                  for s in get_preset("pod_local_sgd").build(quick)]
    sweep = {r["name"]: r for r in sweep_rows}
    assert sweep["podsgd_local8_c8"]["comm_bytes"] < \
        sweep["podsgd_local8"]["comm_bytes"] / 3.9, \
        "int8 deltas must cut metered bytes ~4x on top of the H x"
    emit_root("pods", rows + sweep_rows, quick=quick)
    return emit(rows + sweep_rows, "bench_pods")


if __name__ == "__main__":
    run()
