"""Benchmark suite entry point: one module per paper table/figure.

``python -m benchmarks.run [--full]`` prints ``name,us_per_call,derived``
CSV (the scaffold contract) and writes JSON rows to experiments/bench/.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "bench_patterns",      # Table 3
    "bench_algorithms",    # Fig 7
    "bench_channels",      # Tables 1-2
    "bench_sync",          # Fig 8
    "bench_breakdown",     # Fig 10
    "bench_end2end",       # Fig 11/12 + COST check
    "bench_pipeline",      # Table 5
    "bench_analytical",    # Fig 13/14/15
    "bench_roofline",      # §Roofline (dry-run derived)
    "bench_crosspod",      # §Perf paper-technique headline
    "bench_kernels",       # kernel microbench
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slower)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    for mod in MODULES:
        if args.only and args.only not in mod:
            continue
        t0 = time.time()
        try:
            m = __import__(f"benchmarks.{mod}", fromlist=["run"])
            m.run(quick=not args.full)
            print(f"# {mod} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {mod} FAILED", file=sys.stderr)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
