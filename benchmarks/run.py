"""Benchmark suite entry point: one module per paper table/figure.

``python -m benchmarks.run [--full]`` prints ``name,us_per_call,derived``
CSV (the scaffold contract) and writes JSON rows to experiments/bench/.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "bench_patterns",      # Table 3
    "bench_algorithms",    # Fig 7
    "bench_channels",      # Tables 1-2
    "bench_comm",          # §12 Transport x Collective x Codec grid
    "bench_sync",          # Fig 8
    "bench_breakdown",     # Fig 10
    "bench_end2end",       # Fig 11/12 + COST check
    "bench_pipeline",      # Table 5
    "bench_analytical",    # Fig 13/14/15
    "bench_pods",          # §11 three-infrastructure study + LocalSGD sweep
    "bench_elastic",       # §13 elastic fleets: w(t) per policy + planner
    "bench_serving",       # §14 serving frontier: cost vs p99 per arrival
    "bench_ckpt",          # §17 checkpoint cadence grid + derived restart
    "bench_trace",         # §18 recorder overhead + conservation gates
    "bench_roofline",      # §Roofline (dry-run derived)
    "bench_crosspod",      # §Perf paper-technique headline
    "bench_kernels",       # kernel microbench
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slower)")
    ap.add_argument("--only", default=None, metavar="MODULE",
                    help="run exactly one module, e.g. bench_sync "
                         "(the bench_ prefix may be omitted)")
    args = ap.parse_args()

    if args.only:
        name = (args.only if args.only.startswith("bench_")
                else f"bench_{args.only}")
        if name not in MODULES:
            print(f"error: unknown benchmark module {args.only!r}; "
                  f"valid modules: {', '.join(MODULES)}", file=sys.stderr)
            return 2
        modules = [name]
    else:
        modules = MODULES

    print("name,us_per_call,derived")
    failures = 0
    for mod in modules:
        t0 = time.time()
        try:
            m = __import__(f"benchmarks.{mod}", fromlist=["run"])
            m.run(quick=not args.full)
            print(f"# {mod} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {mod} FAILED", file=sys.stderr)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
