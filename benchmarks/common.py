"""Benchmark harness utilities: CSV rows in the required
``name,us_per_call,derived`` format, JSON dumps under experiments/bench/,
and the committed repo-root ``BENCH_<name>.json`` trajectory files."""
from __future__ import annotations

import json
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
OUT = ROOT / "experiments" / "bench"


def emit(rows: list[dict], bench: str):
    OUT.mkdir(parents=True, exist_ok=True)
    (OUT / f"{bench}.json").write_text(json.dumps(rows, indent=1, default=float))
    for r in rows:
        name = r.get("name", bench)
        us = r.get("us_per_call", r.get("sim_time_s", 0) * 1e6)
        derived = r.get("derived", "")
        print(f"{name},{us:.1f},{derived}")
    return rows


def emit_root(bench: str, rows: list[dict], **extra):
    """Write the committed ``BENCH_<bench>.json`` perf-trajectory file at
    the repo root (schema ``repro.bench.<bench>/v1``, same envelope as
    ``BENCH_serving.json``) so speedups stay verifiable across PRs."""
    payload = {"schema": f"repro.bench.{bench}/v1", **extra, "rows": rows}
    (ROOT / f"BENCH_{bench}.json").write_text(
        json.dumps(payload, indent=1, default=float))
    return payload


def timeit(fn, *args, reps: int = 3, warmup: int = 1, **kw) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn(*args, **kw)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]
