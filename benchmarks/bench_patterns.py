"""Paper Table 3: AllReduce vs ScatterReduce communication time for LR
(224 B), MobileNet (12 MB) and ResNet50 (89 MB) sized updates over S3."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.channels import StorageChannel
from repro.core.patterns import allreduce, scatter_reduce


def run(quick: bool = True):
    rows = []
    sizes = {"lr_224B": 56, "mobilenet_12MB": 3_000_000,
             "resnet50_89MB": 22_250_000}
    w = 10
    rng = np.random.default_rng(0)
    for name, n in sizes.items():
        if quick and n > 5_000_000:
            n = 11_000_000  # keep the 2x regime but fit RAM quickly
        ups = [rng.standard_normal(n).astype(np.float32) for _ in range(w)]
        _, t_ar = allreduce(StorageChannel("s3"), ups, "a")
        _, t_sr = scatter_reduce(StorageChannel("s3"), ups, "b")
        ar, sr = float(np.max(t_ar)), float(np.max(t_sr))
        rows.append({"name": f"table3_{name}_allreduce",
                     "us_per_call": ar * 1e6, "sim_time_s": ar,
                     "derived": f"ratio_ar_over_sr={ar / sr:.2f}"})
        rows.append({"name": f"table3_{name}_scatterreduce",
                     "us_per_call": sr * 1e6, "sim_time_s": sr,
                     "derived": f"workers={w}"})
    return emit(rows, "bench_patterns")


if __name__ == "__main__":
    run()
