"""Elastic fleet control (DESIGN.md §13): static vs schedule vs SMLT vs
cost-capped scaling on the Fig-11 workload, emitting the ``w(t)`` timeline.

A thin view over the ``elastic_axis`` preset (shared with ``python -m
repro run elastic_axis``), plus one analytic-planner row per paper
workload showing the crossover the planner reproduces (FaaS for LR/Higgs,
IaaS for the comm-heavy CNNs).
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.experiments import get_preset, run_experiment


def _w_of_t(rec) -> str:
    """Render the scaling timeline as ``w@round`` hops (the plot's data)."""
    tl = rec.result.get("scaling_timeline", [])
    if not tl:
        return f"{rec.result['workers']}@0"
    return " ".join(f"{w}@{r}" for r, w, _s, _c in tl)


def run(quick: bool = True):
    rows = []
    for rec in (run_experiment(s) for s in
                get_preset("elastic_axis").build(quick)):
        r = rec.result
        tl = r.get("scaling_timeline", [])
        resize_s = sum(s for _r, _w, s, _c in tl)
        resize_usd = sum(c for _r, _w, _s, c in tl)
        rows.append({
            "name": rec.spec.name,
            "us_per_call": r["sim_time_s"] * 1e6 / max(r["rounds"], 1),
            "sim_time_s": r["sim_time_s"], "cost_usd": r["cost_usd"],
            "rounds": r["rounds"], "timeline": tl,
            "derived": (f"w(t)={_w_of_t(rec)};rounds={r['rounds']};"
                        f"cost=${r['cost_usd']:.4f};"
                        f"resize={resize_s:.1f}s/${resize_usd:.5f}"),
        })
        assert not r.get("error"), (rec.spec.name, r["error"])

    by_name = {r["name"]: r for r in rows}
    sched = by_name["elastic_schedule"]
    widths = {w for _r, w, _s, _c in sched["timeline"]}
    assert len(widths) >= 2, \
        f"schedule policy must actually change w, got timeline {sched}"
    static = by_name["elastic_static"]
    assert not static["timeline"], "static fleets must emit no timeline"
    cap = by_name["elastic_cost_cap"]
    assert cap["cost_usd"] <= static["cost_usd"] or cap["timeline"], \
        "cost_cap should shed/stop or at least log its decisions"

    # ---- analytic planner: the paper's FaaS/IaaS crossover ------------------
    from repro.core.elastic import PAPER_WORKLOADS, plan
    for name in sorted(PAPER_WORKLOADS):
        best = plan(name, "cheapest")[0]
        rows.append({
            "name": f"plan_{name}",
            "us_per_call": best.time_s * 1e6,
            "derived": (f"pick={best.platform}@w{best.workers};"
                        f"time={best.time_s:.0f}s;"
                        f"cost=${best.cost_usd:.4f}"),
        })
    picks = {r["name"]: r["derived"] for r in rows
             if r["name"].startswith("plan_")}
    assert picks["plan_lr_higgs"].startswith("pick=faas"), picks
    assert picks["plan_mobilenet_cifar10"].startswith("pick=iaas"), picks
    return emit(rows, "bench_elastic")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=True)
    ap.add_argument("--full", dest="quick", action="store_false")
    run(quick=ap.parse_args().quick)
