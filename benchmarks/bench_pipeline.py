"""Paper Table 5: ML pipeline (preprocessing + hyperparameter grid search)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import cost as pricing
from repro.core.algorithms import make_algorithm
from repro.core.mlmodels import make_study_model
from repro.core.runtimes import B_S3, FaaSRuntime, IaaSRuntime, interp_startup, _T_FAAS, _T_IAAS
from repro.data.synthetic import Dataset, make_dataset, train_val_split


def _normalize(ds: Dataset) -> Dataset:
    x = ds.x
    lo, hi = x.min(0, keepdims=True), x.max(0, keepdims=True)
    return Dataset(ds.name, (2 * (x - lo) / np.maximum(hi - lo, 1e-9) - 1)
                   .astype(np.float32), ds.y, ds.idx, ds.dim, ds.n_classes)


def run(quick: bool = True):
    rows = []
    ds = make_dataset("higgs", rows=20_000 if quick else 200_000)
    grid = [0.02, 0.05, 0.1] if quick else [round(0.01 * i, 2)
                                            for i in range(1, 11)]
    for system in ("faas", "iaas"):
        # preprocessing job (10 workers): dominated by S3 read+write
        pre_io = 2 * ds.nbytes / 10 / B_S3
        pre = (interp_startup(_T_FAAS, 10) if system == "faas"
               else interp_startup(_T_IAAS, 10)) + pre_io
        nds = _normalize(ds)
        tr, va = train_val_split(nds)
        model = make_study_model("lr", tr)
        total, cost, best = pre, 0.0, (None, 1e9)
        for lr in grid:
            algo = make_algorithm("ga_sgd", lr=lr, batch_size=2048)
            rt = (FaaSRuntime(workers=10) if system == "faas"
                  else IaaSRuntime(workers=10))
            r = rt.train(model, algo, tr, va, max_epochs=2)
            cost += r.cost
            if system == "faas":
                total = max(total, pre + r.sim_time)   # jobs run in parallel
            else:
                total += r.sim_time - r.breakdown["startup"]  # reuse cluster
            if r.final_loss < best[1]:
                best = (lr, r.final_loss)
        if system == "faas":
            cost += pricing.lambda_cost(3.0, pre * 10, 10)
        else:
            cost += pricing.ec2_cost("t2.medium", total, 10)
        rows.append({"name": f"table5_{system}",
                     "us_per_call": total * 1e6, "sim_time_s": total,
                     "cost_usd": cost,
                     "derived": f"cost=${cost:.4f};best_lr={best[0]};"
                                f"loss={best[1]:.4f}"})
    return emit(rows, "bench_pipeline")


if __name__ == "__main__":
    run()
