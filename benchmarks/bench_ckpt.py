"""Checkpoint subsystem (DESIGN.md §17): cadence grid under a recorded
spot-preemption trace, per-transport save/restore costs, and derived
restart times.

Runs the ``spot_trace`` preset's scenario directly (IaaS spot fleet, the
bundled ``spot_burst`` trace) across checkpoint cadences, plus a
transport sweep of the closed-form save/restore price for a 100 MB model,
and asserts the acceptance story: every trial sees the same recorded
preemptions, cadence checkpointing moves nonzero metered bytes/$, and the
platforms' derived ``restart_time(model_bytes)`` equals cold start + the
metered restore.  Writes ``BENCH_ckpt.json`` at the repo root
(schema ``repro.bench.ckpt/v1``).
"""
from __future__ import annotations

from benchmarks.common import emit, emit_root
from repro.core.algorithms import make_algorithm
from repro.core.ckpt import CKPT_TRANSPORTS, make_ckpt, shard_sizes
from repro.core.comm.transports import xfer_seconds
from repro.core.mlmodels import make_study_model
from repro.core.platform import FailureSpec
from repro.core.runtimes import FaaSRuntime, IaaSRuntime, PodPlatform
from repro.data.synthetic import make_dataset, train_val_split

CADENCES = ("", "s3:every=2", "s3:every=8", "s3:every=2:sharded")
MODEL_BYTES = 100_000_000          # transport-sweep payload (100 MB fp32)


def run(quick: bool = True):
    rows = []
    tr, va = train_val_split(make_dataset("higgs",
                                          rows=20_000 if quick else 200_000))
    model = make_study_model("lr", tr)
    fail = FailureSpec(spot=True, trace="spot_burst")

    # -- cadence grid under the recorded trace -----------------------------
    grid = {}
    for ck in CADENCES:
        ga = make_algorithm("ga_sgd", lr=0.2, batch_size=2048)
        res = IaaSRuntime(workers=8, failure=fail, ckpt=ck).train(
            model, ga, tr, va, max_epochs=3 if quick else 6)
        grid[ck] = res
        rows.append({
            "name": f"trace[{ck or 'every=0'}]",
            "us_per_call": res.sim_time * 1e6,
            "kind": "trace_grid", "ckpt": ck,
            "sim_time_s": res.sim_time, "cost_usd": res.cost,
            "preemptions": res.preemptions,
            "ckpt_bytes": res.ckpt_bytes, "ckpt_time_s": res.ckpt_time,
            "ckpt_cost_usd": res.ckpt_cost,
            "derived": (f"pre={res.preemptions};"
                        f"ckptB={res.ckpt_bytes:.0f};"
                        f"ckpt_s={res.ckpt_time:.3f}"),
        })
    # same recorded trace -> same kills, regardless of checkpoint policy
    assert len({r.preemptions for r in grid.values()}) == 1
    assert grid[""].preemptions > 0
    # cadence checkpointing moves real metered traffic, denser > sparser
    assert grid["s3:every=2"].ckpt_bytes > grid["s3:every=8"].ckpt_bytes > 0
    assert grid["s3:every=2"].ckpt_cost > 0

    # -- per-transport closed-form save+restore for a 100 MB model ---------
    for name, ch in sorted(CKPT_TRANSPORTS.items()):
        for sharded in (False, True):
            spec = make_ckpt(f"{name}:every=1" + (":sharded" if sharded else ""))
            sizes = shard_sizes(MODEL_BYTES, spec.shards(8))
            if ch.max_item is not None and max(sizes) > ch.max_item:
                continue                    # infeasible cell (Table 1 "N/A")
            dt = sum(xfer_seconds(ch, s) for s in sizes)
            rows.append({
                "name": f"xfer[{name}{':sharded' if sharded else ''}]",
                "us_per_call": dt * 1e6,
                "kind": "transport", "transport": name, "sharded": sharded,
                "shards": len(sizes), "bytes": sum(sizes),
                "save_s": dt, "restore_s": spec.restore_seconds(
                    MODEL_BYTES, ch, 8),
                "derived": f"shards={len(sizes)};s={dt:.3f}",
            })

    # -- derived restart per platform --------------------------------------
    for pname, rt in (("faas", FaaSRuntime(workers=8)),
                      ("iaas", IaaSRuntime(workers=8)),
                      ("pod", PodPlatform(pods=2, chips_per_pod=4))):
        bare, loaded = rt.restart_time(), rt.restart_time(MODEL_BYTES)
        assert loaded == bare + rt.ckpt.restore_seconds(
            MODEL_BYTES, rt.ckpt_channel_spec(), rt.workers)
        rows.append({
            "name": f"restart[{pname}]", "us_per_call": loaded * 1e6,
            "kind": "restart", "platform": pname,
            "bare_s": bare, "loaded_s": loaded,
            "derived": f"bare={bare:.2f}s;with_100MB={loaded:.2f}s",
        })

    emit_root("ckpt", rows, model_bytes=MODEL_BYTES, trace="spot_burst",
              cadences=list(CADENCES))
    return emit(rows, "bench_ckpt")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=True)
    ap.add_argument("--full", dest="quick", action="store_false")
    run(quick=ap.parse_args().quick)
