"""Step builders: jitted train / prefill / serve steps with explicit shardings.

The communication pattern follows the paper's AllReduce-vs-ScatterReduce
design axis, mapped to TPU-native collectives:

- ``allreduce``      -> pure data parallel: params replicated over "data",
                        gradients all-reduced (the paper's AllReduce, whose
                        leader bottleneck becomes the single all-reduce ring).
- ``scatter_reduce`` -> FSDP via GSPMD: params sharded over "data", grads
                        reduce-scattered + params all-gathered on use (the
                        paper's ScatterReduce: every worker reduces its own
                        partition).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig
from repro.distributed.sharding import ShardingCtx, use_sharding
from repro.models import build_model
from repro.optim import make_optimizer


def _is_axes(x):
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)


def resolve_shardings(ctx: ShardingCtx, axes_tree, abstract_tree):
    """axes pytree (+ matching abstract tree) -> NamedSharding pytree."""
    return jax.tree.map(
        lambda ax, sds: ctx.param_sharding(sds.shape, ax),
        axes_tree, abstract_tree, is_leaf=_is_axes)


def _value_pspec(ctx: ShardingCtx, shape, axes):
    mesh_axes = [ctx.map.get(a, None) for a in axes]
    mesh_axes = [ctx.fit_axes(shape[i], m) for i, m in enumerate(mesh_axes)]
    return NamedSharding(ctx.mesh, P(*ctx._dedup(mesh_axes)))


def batch_shardings(ctx: ShardingCtx, batch_specs: dict) -> dict:
    axes_by_key = {
        "tokens": ("batch", "seq"),
        "labels": ("batch", "seq"),
        "mask": ("batch", "seq"),
        "frames": ("batch", "seq", "embed"),
        "image_embeds": ("batch", "img_seq", "embed"),
    }
    return {k: _value_pspec(ctx, v.shape, axes_by_key[k])
            for k, v in batch_specs.items()}


@dataclass
class BuiltStep:
    fn: Callable                      # jitted
    in_specs: tuple                   # abstract inputs, positional
    ctx: ShardingCtx
    arch: ArchConfig
    kind: str

    def lower(self):
        return self.fn.lower(*self.in_specs)


def _effective_ctx(arch: ArchConfig, mesh: Mesh, kind: str = "train",
                   global_batch: int | None = None) -> ShardingCtx:
    rules = arch.sharding
    if arch.train.comm_pattern == "allreduce":
        rules = dataclasses.replace(rules, fsdp_axis=None)
    if rules.dp_over_model:
        n_dp = 1
        for a in mesh.axis_names:
            n_dp *= mesh.shape[a]
        if kind != "train" or (global_batch is not None
                               and global_batch % n_dp != 0):
            # pure DP needs batch % (all mesh axes) == 0; inference batches
            # (32/128/1) and multi-pod 256-batch train don't divide -- keep
            # the arch's TP layout instead
            rules = dataclasses.replace(rules, dp_over_model=False)
    return ShardingCtx(mesh, rules)


# ------------------------------------------------------------- train ---------

def build_train_step(arch: ArchConfig, mesh: Mesh, shape: ShapeConfig | str,
                     batch_specs: dict | None = None) -> BuiltStep:
    sh = SHAPES[shape] if isinstance(shape, str) else shape
    model = build_model(arch)
    tc = arch.train
    opt = make_optimizer(tc)
    ctx = _effective_ctx(arch, mesh, "train", sh.global_batch)

    params_abs = model.abstract()
    param_sh = resolve_shardings(ctx, model.axes(), params_abs)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    opt_sh = resolve_shardings(ctx, opt.state_axes(model.axes()), opt_abs)

    if batch_specs is None:
        from repro.launch.specs import input_specs
        batch_specs = input_specs(arch, sh)["batch"]
    batch_sh = batch_shardings(ctx, batch_specs)

    def loss_of(p, b):
        return model.loss(p, b, remat=tc.remat, scan_layers=tc.scan_layers)

    def train_step(params, opt_state, batch):
        with use_sharding(ctx):
            k = tc.micro_batches
            if k > 1:
                mb = jax.tree.map(
                    lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)
                acc0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def micro(acc, b):
                    (l, m), g = jax.value_and_grad(loss_of, has_aux=True)(params, b)
                    return jax.tree.map(lambda a, gg: a + gg.astype(jnp.float32),
                                        acc, g), (l, m)
                grads, (ls, ms) = jax.lax.scan(micro, acc0, mb)
                grads = jax.tree.map(lambda g: g / k, grads)
                metrics = jax.tree.map(jnp.mean, ms)
            else:
                (_, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(params, batch)
            new_p, new_s, stats = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(stats)
        return new_p, new_s, metrics

    fn = jax.jit(
        train_step,
        in_shardings=(param_sh, opt_sh, batch_sh),
        out_shardings=(param_sh, opt_sh, None),
        donate_argnums=(0, 1),
    )
    return BuiltStep(fn, (params_abs, opt_abs, batch_specs), ctx, arch, "train")


# ------------------------------------------------------------ prefill --------

def build_prefill_step(arch: ArchConfig, mesh: Mesh, shape: ShapeConfig | str,
                       batch_specs: dict | None = None) -> BuiltStep:
    sh = SHAPES[shape] if isinstance(shape, str) else shape
    model = build_model(arch)
    ctx = _effective_ctx(arch, mesh, "prefill")
    params_abs = model.abstract()
    param_sh = resolve_shardings(ctx, model.axes(), params_abs)
    if batch_specs is None:
        from repro.launch.specs import input_specs
        batch_specs = input_specs(arch, sh)["batch"]
    batch_sh = batch_shardings(ctx, batch_specs)

    def prefill_step(params, batch):
        with use_sharding(ctx):
            logits, _ = model.forward(params, batch, last_only=True,
                                      scan_layers=arch.train.scan_layers)
        return logits

    fn = jax.jit(prefill_step, in_shardings=(param_sh, batch_sh),
                 out_shardings=None)
    return BuiltStep(fn, (params_abs, batch_specs), ctx, arch, "prefill")


# ------------------------------------------------------------- serve ---------

def build_serve_step(arch: ArchConfig, mesh: Mesh, shape: ShapeConfig | str) -> BuiltStep:
    sh = SHAPES[shape] if isinstance(shape, str) else shape
    model = build_model(arch)
    ctx = _effective_ctx(arch, mesh, "decode")
    params_abs = model.abstract()
    param_sh = resolve_shardings(ctx, model.axes(), params_abs)
    cache_abs = model.init_cache(sh.global_batch, sh.seq_len, abstract=True)
    cache_sh = resolve_shardings(ctx, model.cache_axes(), cache_abs)
    tok_abs = jax.ShapeDtypeStruct((sh.global_batch,), jnp.int32)
    tok_sh = _value_pspec(ctx, tok_abs.shape, ("batch",))
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = NamedSharding(mesh, P())

    def serve_step(params, cache, token, pos):
        with use_sharding(ctx):
            logits, cache = model.decode_step(params, cache, token, pos)
        return logits, cache

    fn = jax.jit(serve_step,
                 in_shardings=(param_sh, cache_sh, tok_sh, pos_sh),
                 out_shardings=(None, cache_sh),
                 donate_argnums=(1,))
    return BuiltStep(fn, (params_abs, cache_abs, tok_abs, pos_abs), ctx, arch,
                     "decode")


def build_step(arch: ArchConfig, mesh: Mesh, shape: ShapeConfig | str) -> BuiltStep:
    sh = SHAPES[shape] if isinstance(shape, str) else shape
    if sh.kind == "train":
        return build_train_step(arch, mesh, sh)
    if sh.kind == "prefill":
        return build_prefill_step(arch, mesh, sh)
    return build_serve_step(arch, mesh, sh)
