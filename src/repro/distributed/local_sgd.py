"""MA-SGD on pods: local-SGD / DiLoCo across the "pod" mesh axis.

This is the paper's central insight mapped to multi-pod TPU training.  In
LambdaML, MA-SGD beats GA-SGD exactly when the communication channel is slow
relative to compute (§4.2): workers train locally and average models every H
steps instead of averaging gradients every step.  On a multi-pod mesh the
slow channel is the inter-pod DCN, so:

- inner step:  a normal train step whose collectives span ONLY the intra-pod
  ("data","model") axes -- realized with shard_map(manual="pod",
  auto={"data","model"}) so GSPMD provably cannot emit cross-pod collectives
  (verifiable in the dry-run HLO);
- outer step (every H inner steps): average the per-pod model replicas over
  "pod" (MA-SGD), or apply a Nesterov outer optimizer to the average delta
  (DiLoCo), optionally with 8-bit + error-feedback compression of the delta
  (cross-pod bytes /4 on top of the H x reduction).

Cross-pod bytes per inner step drop from every-step gradient all-reduce to
(model_bytes [/4 if compressed]) / H.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig
from repro.core.comm.codecs import dequantize_int8, quantize_int8_ef
from repro.core.sync import DiLoCoOuter
from repro.distributed.sharding import ShardingCtx, use_sharding
from repro.distributed.step import batch_shardings, resolve_shardings, _is_axes
from repro.models import build_model
from repro.optim import make_optimizer


def _shard_map(f, *, mesh, in_specs, out_specs, axis_names, check_vma=False):
    """shard_map across jax API generations: ``jax.shard_map`` (>= 0.5,
    ``axis_names`` = manual axes) when available, else the legacy
    ``jax.experimental.shard_map`` (``auto`` = complement, ``check_rep``).
    NOTE: on the legacy API, *partial*-manual mode (axis_names a strict
    subset) is known to abort in the XLA SPMD partitioner for this model --
    tests gate on ``hasattr(jax, "shard_map")`` for those paths."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy
    auto = frozenset(mesh.axis_names) - set(axis_names)
    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False, auto=auto)


def _inner_ctx(arch: ArchConfig, mesh: Mesh) -> ShardingCtx:
    """Sharding ctx for use INSIDE shard_map(manual='pod'): batch maps to
    'data' only and nothing may reference 'pod'."""
    rules = arch.sharding
    if arch.train.comm_pattern == "allreduce":
        rules = dataclasses.replace(rules, fsdp_axis=None)
    ctx = ShardingCtx(mesh, rules)
    ctx.map["batch"] = ("data",) if "data" in mesh.axis_names else None
    ctx.map["group"] = ctx.map["batch"]
    return ctx


def _stack_sharding(mesh: Mesh, inner: NamedSharding) -> NamedSharding:
    return NamedSharding(mesh, P(*(("pod",) + tuple(inner.spec))))


@dataclass
class LocalSGDStep:
    """inner_fn(params_st, opt_st, batch) -> (params_st, opt_st, metrics)
    outer_fn(params_st, outer_state) -> (params_st, outer_state)
    run H inner steps, then one outer step."""
    inner_fn: Callable
    outer_fn: Callable
    inner_inputs: tuple
    outer_inputs: tuple
    init_outer_fn: Callable = None
    n_pods: int = 1
    sync_period: int = 1

    def lower_inner(self):
        return self.inner_fn.lower(*self.inner_inputs)

    def lower_outer(self):
        return self.outer_fn.lower(*self.outer_inputs)


def build_local_sgd(arch: ArchConfig, mesh: Mesh, shape: ShapeConfig | str,
                    batch_specs: dict | None = None) -> LocalSGDStep:
    assert "pod" in mesh.axis_names, "local-SGD needs the multi-pod mesh"
    sh = SHAPES[shape] if isinstance(shape, str) else shape
    n_pods = mesh.shape["pod"]
    model = build_model(arch)
    tc = arch.train
    opt = make_optimizer(tc)
    ctx = _inner_ctx(arch, mesh)

    params_abs = model.abstract()
    param_sh_in = resolve_shardings(ctx, model.axes(), params_abs)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    opt_sh_in = resolve_shardings(ctx, opt.state_axes(model.axes()), opt_abs)

    def stack_abs(t):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_pods,) + s.shape, s.dtype), t)

    params_st_abs = stack_abs(params_abs)
    opt_st_abs = stack_abs(opt_abs)
    params_st_sh = jax.tree.map(partial(_stack_sharding, mesh), param_sh_in)
    opt_st_sh = jax.tree.map(partial(_stack_sharding, mesh), opt_sh_in)

    if batch_specs is None:
        from repro.launch.specs import input_specs
        batch_specs = input_specs(arch, sh)["batch"]
    # batch leading dim sharded over pod (outer) then data (inner)
    batch_sh = {k: NamedSharding(mesh, P(("pod", "data"),
                                         *([None] * (len(v.shape) - 1))))
                for k, v in batch_specs.items()}

    # ---------------------------------------------------------- inner -------
    def inner_body(params, opt_state, batch):
        # leading pod dim of size 1 inside shard_map
        params = jax.tree.map(lambda x: x[0], params)
        opt_state = jax.tree.map(lambda x: x[0], opt_state)
        with use_sharding(ctx):
            def loss_of(p, b):
                return model.loss(p, b, remat=tc.remat,
                                  scan_layers=tc.scan_layers)
            (_, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
            new_p, new_s, stats = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(stats)
        # NO pmean over "pod": the inner step must emit ZERO cross-pod
        # collectives (asserted in tests); metrics come back per-pod (P,)
        add_pod = lambda t: jax.tree.map(lambda x: x[None], t)  # noqa: E731
        metrics = jax.tree.map(lambda m: m[None], metrics)
        return add_pod(new_p), add_pod(new_s), metrics

    pod_leading = lambda t: jax.tree.map(lambda _: P("pod"), t)  # noqa: E731
    inner_sm = _shard_map(
        inner_body, mesh=mesh,
        in_specs=(pod_leading(params_st_abs), pod_leading(opt_st_abs),
                  jax.tree.map(lambda _: P(("pod",)), batch_specs)),
        out_specs=(pod_leading(params_st_abs), pod_leading(opt_st_abs),
                   P("pod")),
        axis_names={"pod"},   # "pod" manual; "data"/"model" stay auto (GSPMD)
        check_vma=False)

    inner_fn = jax.jit(inner_sm,
                       in_shardings=(params_st_sh, opt_st_sh, batch_sh),
                       out_shardings=(params_st_sh, opt_st_sh, None),
                       donate_argnums=(0, 1))

    # ---------------------------------------------------------- outer -------
    algo = tc.algorithm  # ma_sgd | diloco
    compress = tc.compress_cross_pod

    def outer_init(params_st):
        p0 = jax.tree.map(lambda x: x[0], params_st)
        state = {"outer_params": jax.tree.map(
            lambda x: x.astype(jnp.float32), p0)}
        if algo == "diloco":
            state["momentum"] = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), p0)
        if compress:
            state["residual"] = jax.tree.map(
                lambda x: jnp.zeros((n_pods,) + x.shape, jnp.float32),
                p0)
        return state

    def _compressed_mean(x, res, pspec):
        """Cross-pod mean with int8 on the wire + error feedback.

        FULLY-MANUAL shard_map (all mesh axes, explicit per-leaf specs): each
        device quantizes its own shard per-channel (one fp32 scale per local
        row -- no reshape, so sharding never degrades), all-gathers the int8
        codes over 'pod' ONLY (4x fewer cross-pod wire bytes than fp32,
        verified in the dry-run HLO), dequantizes and averages locally.  The
        quantization error is carried per-pod in `res` (error feedback).

        Two earlier versions were refuted by measurement (§Perf P2): (a)
        256-block quantization reshapes TP-sharded dims and GSPMD replicated
        the codes; (b) pod-only-manual shard_map let GSPMD all-gather the
        codes over (data, model) before the pod exchange.
        """
        full_in = P(*(("pod",) + tuple(pspec)))

        def body(xl, rl):
            # one quantizer implementation for the whole repo: this helper
            # delegates to kernels/quant8/ref.py, the same formula the
            # Int8EF wire codec's fused Pallas kernel is validated against
            # -- only the scale LAYOUT differs (per-channel here, see above)
            q, scale, new_res = quantize_int8_ef(
                xl[0].astype(jnp.float32) + rl[0])
            qs = jax.lax.all_gather(q, "pod")          # int8 over the wire
            ss = jax.lax.all_gather(scale, "pod")
            return jnp.mean(dequantize_int8(qs, ss), axis=0), new_res[None]

        mean, new_res = _shard_map(
            body, mesh=mesh, in_specs=(full_in, full_in),
            out_specs=(P(*pspec), full_in),
            axis_names=set(mesh.axis_names), check_vma=False)(x, res)
        return mean, new_res

    leaf_pspecs = [sh.spec for sh in jax.tree.leaves(param_sh_in)]

    def outer_step(params_st, state):
        """Average replicas over 'pod' (MA) or Nesterov-outer-step (DiLoCo)."""
        def mean_pods(x, res=None, pspec=None):
            if not compress:
                return jnp.mean(x, axis=0), None
            return _compressed_mean(x, res, pspec)

        if algo != "diloco":  # ma_sgd (ga_sgd uses the same averaging outer)
            res_st = state.get("residual")
            leaves, tdef = jax.tree.flatten(params_st)
            res_leaves = (tdef.flatten_up_to(res_st) if compress
                          else [None] * len(leaves))
            outs = [mean_pods(x.astype(jnp.float32), r, sp)
                    for x, r, sp in zip(leaves, res_leaves, leaf_pspecs)]
            mean = jax.tree.unflatten(tdef, [o[0] for o in outs])
            new_p = jax.tree.map(
                lambda ps, m: jnp.broadcast_to(
                    m.astype(ps.dtype)[None], ps.shape), params_st, mean)
            new_state = dict(state)
            new_state["outer_params"] = mean
            if compress:
                new_state["residual"] = jax.tree.unflatten(
                    tdef, [o[1] for o in outs])
            return new_p, new_state

        # DiLoCo: delta = outer - mean(inner); Nesterov on outer params --
        # the same DiLoCoOuter math the simulator's LocalSGD protocol uses
        outer_opt = DiLoCoOuter(tc.outer_lr, tc.outer_momentum)
        res_st = state.get("residual")
        leaves, tdef = jax.tree.flatten(params_st)
        o_leaves = tdef.flatten_up_to(state["outer_params"])
        m_leaves = tdef.flatten_up_to(state["momentum"])
        res_leaves = (tdef.flatten_up_to(res_st) if compress
                      else [None] * len(leaves))
        new_p, new_o, new_m, new_r = [], [], [], []
        for x, o, m, r, sp in zip(leaves, o_leaves, m_leaves, res_leaves,
                                  leaf_pspecs):
            delta_pods = o[None] - x.astype(jnp.float32)     # (P, ...)
            mean_delta, nr = mean_pods(delta_pods, r, sp)
            no, nm = outer_opt.step(o, m, mean_delta)
            new_p.append(jnp.broadcast_to(no.astype(x.dtype)[None], x.shape))
            new_o.append(no)
            new_m.append(nm)
            new_r.append(nr)
        out_state = {"outer_params": jax.tree.unflatten(tdef, new_o),
                     "momentum": jax.tree.unflatten(tdef, new_m)}
        if compress:
            out_state["residual"] = jax.tree.unflatten(tdef, new_r)
        return jax.tree.unflatten(tdef, new_p), out_state

    outer_abs = jax.eval_shape(outer_init, params_st_abs)
    outer_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, P()), outer_abs)  # refined below

    def _outer_leaf_sh(path_is_residual, inner_sh):
        return (_stack_sharding(mesh, inner_sh) if path_is_residual
                else inner_sh)

    # outer params/momentum share the per-param (non-stacked) shardings
    o_sh = {"outer_params": jax.tree.map(
        lambda s: NamedSharding(mesh, s.spec), param_sh_in)}
    if algo == "diloco":
        o_sh["momentum"] = o_sh["outer_params"]
    if compress:
        o_sh["residual"] = jax.tree.map(partial(_stack_sharding, mesh),
                                        jax.tree.map(
                                            lambda s: NamedSharding(mesh, s.spec),
                                            param_sh_in))
    outer_sh = o_sh

    outer_fn = jax.jit(outer_step,
                       in_shardings=(params_st_sh, outer_sh),
                       out_shardings=(params_st_sh, outer_sh),
                       donate_argnums=(0, 1))
    init_outer_fn = jax.jit(outer_init, in_shardings=(params_st_sh,),
                            out_shardings=outer_sh)

    return LocalSGDStep(
        inner_fn=inner_fn, outer_fn=outer_fn,
        inner_inputs=(params_st_abs, opt_st_abs, batch_specs),
        outer_inputs=(params_st_abs, outer_abs),
        init_outer_fn=init_outer_fn,
        n_pods=n_pods, sync_period=tc.sync_period)
