"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms, per (arch x shape x mesh):

    compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory     = HLO_bytes / (chips * HBM_BW)
    collective = collective_bytes / (chips * ICI_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.  Collective
bytes are NOT in cost_analysis: we parse the post-SPMD HLO text and sum the
operand sizes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute.  Hardware model: TPU v5e.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# --- TPU v5e per-chip constants (per the assignment) -------------------------
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 0.5, "u4": 0.5,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# `bf16[128,1024]{1,0}` or scalar `f32[]`
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# result-shape(s) = op-name(args)
_OP_RE = re.compile(
    r"=\s+(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LEGACY_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LEGACY_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind from (post-SPMD) HLO text.

    Final HLO references operands by name only, so operand sizes are derived
    from the *result* shape and the replica-group size: all-gather operands
    are result/S, reduce-scatter operands are result*S, everything else 1:1.
    """
    out = {k: {"count": 0, "operand_bytes": 0.0} for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        if m.group(3) == "-done":
            continue  # async pair: count the -start only
        result_bytes = sum(_shape_bytes(d, s)
                           for d, s in _SHAPE_RE.findall(m.group(1)))
        s = _group_size(line)
        if kind == "all-gather":
            b = result_bytes / max(s, 1)
        elif kind == "reduce-scatter":
            b = result_bytes * s
        else:
            b = result_bytes
        out[kind]["count"] += 1
        out[kind]["operand_bytes"] += b
    out["total_operand_bytes"] = sum(
        v["operand_bytes"] for k, v in out.items() if isinstance(v, dict))
    # wire-cost model: all-reduce moves ~2x its operand (reduce-scatter +
    # all-gather phases); everything else ~1x
    out["wire_bytes"] = sum(
        v["operand_bytes"] * (2.0 if k == "all-reduce" else 1.0)
        for k, v in out.items() if isinstance(v, dict))
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per-device flops from cost_analysis
    hlo_bytes: float            # per-device bytes accessed
    collective_bytes: float     # per-device collective operand bytes
    model_flops: float          # 6*N*D (train) / 2*N*D (decode), global
    collectives: dict = field(default_factory=dict)
    extra: dict = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_time(self) -> float:
        return self.model_flops / (self.chips * PEAK_FLOPS)

    @property
    def roofline_fraction(self) -> float:
        return self.useful_time / self.bound_time if self.bound_time else 0.0

    @property
    def flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO flops ('useful compute' share)."""
        tot = self.hlo_flops * self.chips
        return self.model_flops / tot if tot else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_device": self.hlo_flops,
            "hlo_bytes_per_device": self.hlo_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "model_flops_global": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "roofline_fraction": self.roofline_fraction,
            "flops_ratio": self.flops_ratio,
            "collectives": self.collectives,
            **self.extra,
        }


def model_flops(arch, shape, n_params: int, n_active: int) -> float:
    """6*N*D for train, 2*N*D for inference; D = processed tokens."""
    from repro.configs.base import SHAPES
    sh = SHAPES[shape] if isinstance(shape, str) else shape
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n_active * tokens
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n_active * tokens
    tokens = sh.global_batch  # decode: 1 new token per sequence
    return 2.0 * n_active * tokens


def active_params(arch) -> tuple[int, int]:
    """(total, active) parameter counts; active discounts unrouted experts."""
    import numpy as np
    from repro.models import build_model
    from repro.models.common import _is_spec
    import jax

    model = build_model(arch)
    spec = model.spec
    cfg = arch.model
    total = 0
    active = 0
    frac = 1.0
    if cfg.num_experts:
        frac = cfg.experts_per_token / cfg.num_experts

    def walk2(tree, path):
        nonlocal total, active
        if _is_spec(tree):
            n = int(np.prod(tree[0]))
            total += n
            routed = ("moe" in path) and path[-1] in ("w_gate", "w_up", "w_down") \
                and "shared" not in path
            active += int(n * frac) if routed else n
            return
        for k, v in tree.items():
            walk2(v, path + (k,))

    walk2(spec, ())
    return total, active


def analyze(compiled, lowered_text: str, *, arch_name: str, shape: str,
            mesh_desc: str, chips: int, mflops: float,
            extra: dict | None = None,
            pod_size: int | None = None) -> RooflineReport:
    """Primary numbers come from the scan-aware HLO analyzer
    (distributed/hlo_analysis.py); raw cost_analysis() is recorded for
    reference -- it does NOT multiply while-loop bodies by their trip count,
    so it undercounts scanned models by ~num_layers x (validated in
    tests/test_hlo_analysis.py)."""
    from repro.distributed.hlo_analysis import analyze_hlo

    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older API returned [dict]
        ca = ca[0]
    r = analyze_hlo(lowered_text, pod_size=pod_size)
    coll = dict(r["coll"])
    ex = dict(extra or {})
    ex["cost_analysis_flops_raw"] = float(ca.get("flops", 0.0))
    ex["cost_analysis_bytes_raw"] = float(ca.get("bytes accessed", 0.0))
    # flash-kernel-adjusted memory: bytes inside the named attention region
    # are replaced by the Pallas kernel's I/O, which is compute-bound at
    # these sequence lengths (intensity >> 240 flop/B) -- so the adjusted
    # memory term simply excludes the region (its time lives in t_compute).
    ex["scope_bytes"] = r.get("scope_bytes", 0.0)
    ex["scope_flops"] = r.get("scope_flops", 0.0)
    ex["convert_bytes"] = r.get("convert_bytes", 0.0)
    if pod_size:
        ex["cross_pod_bytes"] = r.get("cross_pod_bytes", 0.0)
    ex["t_memory_kernel_adj_s"] = (r["bytes"] - r.get("scope_bytes", 0.0)) / HBM_BW
    # TPU-dtype adjustment: convert/layout fusions are CPU-backend artifacts
    # (no native bf16 dot on CPU); on TPU they fuse away entirely.
    ex["t_memory_tpu_adj_s"] = (r["bytes"] - r.get("scope_bytes", 0.0)
                                - r.get("convert_bytes", 0.0)) / HBM_BW
    return RooflineReport(
        arch=arch_name, shape=shape, mesh=mesh_desc, chips=chips,
        hlo_flops=r["flops"], hlo_bytes=r["bytes"],
        collective_bytes=coll["total_operand_bytes"],
        model_flops=mflops, collectives=coll, extra=ex)
