"""Post-SPMD HLO cost analyzer with while-loop (scan) trip-count accounting.

Why this exists: ``compiled.cost_analysis()`` visits each HLO instruction
ONCE -- a model whose layers live inside a ``lax.scan`` (as all ours do, to
keep 512-device compiles fast) under-counts FLOPs/bytes/collectives by the
layer count.  This module parses the partitioned HLO text into a computation
graph and evaluates costs with:

- while bodies multiplied by their (statically parsed) trip count,
- fusion-aware byte accounting (only fusion operands/results touch HBM;
  internal instructions are free),
- dot FLOPs recomputed exactly from operand shapes + contraction dims,
- collective operand bytes per kind (all-gather/-reduce/reduce-scatter/
  all-to-all/collective-permute), with reduce-scatter counted at its
  pre-scatter size and all-gather at its per-shard input size,
- in-place dynamic-update-slice (counts the updated slice, not the buffer).

Shapes in post-SPMD HLO are per-device, so every number is per-device.
Validated in tests against unrolled compiles of the same model.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16, "f32": 4, "s32": 4,
    "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "s8": 1, "u8": 1, "pred": 1, "s4": 0.5,
    "u4": 0.5, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^()]*\)|[a-z0-9]+\[[0-9,]*\]\S*))\s+"
    r"([a-z0-9\-]+)\((.*)$")
_CALLED_RE = {
    "calls": re.compile(r"calls=%?([\w\.\-]+)"),
    "body": re.compile(r"body=%?([\w\.\-]+)"),
    "condition": re.compile(r"condition=%?([\w\.\-]+)"),
}
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_GROUPS_LEGACY_FULL_RE = re.compile(r"replica_groups=\{(\{[0-9,\{\} ]+\})\}")
_GROUPS_LEGACY_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_CONST_RE = re.compile(r"constant\((-?\d+)\)")
_DIMS_RE = {
    "lc": re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}"),
    "rc": re.compile(r"rhs_contracting_dims=\{([0-9,]*)\}"),
    "lb": re.compile(r"lhs_batch_dims=\{([0-9,]*)\}"),
    "rb": re.compile(r"rhs_batch_dims=\{([0-9,]*)\}"),
}

# elementwise-ish opcodes we charge 1 flop / output element
_ARITH = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "compare",
    "select", "and", "or", "xor", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "clamp", "power",
}
_TRANSCEND = {"exponential", "log", "rsqrt", "sqrt", "tanh", "logistic",
              "sine", "cosine", "exponential-minus-one", "log-plus-one",
              "atan2", "cbrt", "erf"}
_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator"}


def _shape_elems_bytes(shape_str: str) -> tuple[float, float]:
    """Total (elements, bytes) over all array shapes in a (maybe tuple) shape."""
    elems = 0.0
    bts = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bts += n * _DTYPE_BYTES[dt]
    return elems, bts


@dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str                      # operand list + attrs (raw tail)
    operands: list = field(default_factory=list)


def _split_operands(rest: str) -> tuple[list[str], str]:
    """Split 'op1, op2, ...), attrs' -> ([operand names], attrs)."""
    depth = 1
    buf, ops = [], []
    i = 0
    for i, ch in enumerate(rest):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
            if depth == 0:
                break
        if ch == "," and depth == 1:
            ops.append("".join(buf).strip())
            buf = []
        else:
            buf.append(ch)
    if buf:
        ops.append("".join(buf).strip())
    attrs = rest[i + 1:]
    names = []
    for o in ops:
        m = re.search(r"%?([\w\.\-]+)\s*$", o)
        names.append(m.group(1) if m else o)
    return names, attrs


class HloCost:
    def __init__(self, hlo_text: str, pod_size: int | None = None):
        """pod_size: devices per pod (leading mesh axis); enables cross-pod
        collective classification (bytes moved over the inter-pod DCN)."""
        self.comps: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self.pod_size = pod_size
        self._parse(hlo_text)
        self._memo_flops_only: dict[str, float] = {}
        self._memo_full: dict[str, dict] = {}

    def _spans_pods(self, ins: Instr) -> bool:
        """True if any replica group mixes devices from different pods."""
        if not self.pod_size:
            return False
        P = self.pod_size
        m = _GROUPS_IOTA_RE.search(ins.rest)
        if m:
            import numpy as _np
            g, s_ = int(m.group(1)), int(m.group(2))
            dims = [int(x) for x in m.group(3).split(",")]
            ids = _np.arange(int(_np.prod(dims))).reshape(dims)
            if m.group(4):
                ids = ids.transpose([int(x) for x in m.group(4).split(",")])
            groups = ids.reshape(g, s_)
            pods = groups // P
            return bool((pods != pods[:, :1]).any())
        m = _GROUPS_LEGACY_FULL_RE.search(ins.rest)
        if m:
            for grp in m.group(1).split("},{"):
                ids = [int(x) for x in grp.replace("{", "").replace("}", "")
                       .split(",") if x.strip()]
                if len({i // P for i in ids}) > 1:
                    return True
            return False
        m = re.search(r"source_target_pairs=\{(.+?)\}\}", ins.rest)
        if m:  # collective-permute: spans pods iff any (src, dst) pair does
            for pair in (m.group(1) + "}").split("},{"):
                ids = [int(x) for x in pair.replace("{", "").replace("}", "")
                       .split(",") if x.strip()]
                if len(ids) == 2 and ids[0] // P != ids[1] // P:
                    return True
            return False
        return True  # unknown format: be conservative

    # ------------------------------------------------------------ parsing ----
    def _parse(self, text: str):
        cur: list[Instr] | None = None
        cur_name = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                m = _COMP_HDR_RE.match(line.strip())
                if m and line.rstrip().endswith("{"):
                    cur_name = m.group(1)
                    cur = []
                    if raw.lstrip().startswith("ENTRY"):
                        self.entry = cur_name
                continue
            if line.strip() == "}":
                self.comps[cur_name] = cur
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                name, shape, opcode, rest = m.groups()
                ops, attrs = _split_operands(rest)
                ins = Instr(name, shape, opcode, rest, ops)
                cur.append(ins)

    def _instr_map(self, comp: str) -> dict[str, Instr]:
        return {i.name: i for i in self.comps.get(comp, [])}

    # --------------------------------------------------------- primitives ----
    def _operand_shape(self, comp: str, opname: str) -> str:
        ins = self._instr_map(comp).get(opname)
        return ins.shape if ins else ""

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        lhs = self._operand_shape(comp, ins.operands[0])
        m = _SHAPE_RE.search(lhs)
        if not m:
            return 0.0
        ldims = [int(d) for d in m.group(2).split(",") if d]
        dims = {}
        for k, rx in _DIMS_RE.items():
            mm = rx.search(ins.rest)
            dims[k] = [int(d) for d in mm.group(1).split(",") if d] if mm else []
        contract = 1
        for d in dims["lc"]:
            if d < len(ldims):
                contract *= ldims[d]
        out_elems, _ = _shape_elems_bytes(ins.shape)
        return 2.0 * out_elems * contract

    def _trip_count(self, cond_comp: str) -> int:
        """Parse the loop bound from a scan-style condition computation.

        lax.scan lowers to ``while(cond: i < L)``; post-optimization the
        compare is usually fused, so we take the max scalar s32 constant in
        the condition computation (the only constants there are loop bounds).
        Validated against known layer counts in tests.
        """
        best = None
        for ins in self.comps.get(cond_comp, []):
            if ins.opcode == "constant" and ins.shape.startswith("s32[]"):
                mc = _CONST_RE.search("constant(" + ins.rest)
                if mc:
                    v = int(mc.group(1))
                    if v > 0:
                        best = v if best is None else max(best, v)
        return best if best else 1

    def _group_size(self, ins: Instr) -> int:
        m = _GROUPS_RE.search(ins.rest)
        if m:
            return int(m.group(2))
        m = _GROUPS_LEGACY_RE.search(ins.rest)
        if m:
            return len(m.group(1).split(","))
        return 1

    _LAYOUT_ONLY = {"parameter", "convert", "transpose", "copy", "reshape",
                    "broadcast", "bitcast"}

    def _is_convert_fusion(self, comp: str) -> bool:
        """True if the fused computation only converts/relayouts (no math).

        XLA-CPU has no native bf16 matmul: every dot's operands/results are
        wrapped in convert fusions that would NOT exist on TPU.  These are
        tracked separately so the roofline can report a TPU-dtype-adjusted
        memory term (raw numbers are always reported too)."""
        instrs = self.comps.get(comp, [])
        if not instrs:
            return False
        return all(i.opcode in self._LAYOUT_ONLY for i in instrs)

    # ------------------------------------------------------- flops-only ------
    def _flops_only(self, comp: str) -> float:
        """FLOPs inside a fused computation (no bytes)."""
        if comp in self._memo_flops_only:
            return self._memo_flops_only[comp]
        total = 0.0
        for ins in self.comps.get(comp, []):
            if ins.opcode == "dot":
                total += self._dot_flops(comp, ins)
            elif ins.opcode in _ARITH:
                e, _ = _shape_elems_bytes(ins.shape)
                total += e
            elif ins.opcode in _TRANSCEND:
                e, _ = _shape_elems_bytes(ins.shape)
                total += 4 * e
            elif ins.opcode == "fusion":
                m = _CALLED_RE["calls"].search(ins.rest)
                if m:
                    total += self._flops_only(m.group(1))
            elif ins.opcode == "reduce":
                e, _ = _shape_elems_bytes(
                    self._operand_shape(comp, ins.operands[0]))
                total += e
        self._memo_flops_only[comp] = total
        return total

    # ------------------------------------------------------------- full ------
    def _operand_bytes(self, comp: str, ins: Instr) -> float:
        b = 0.0
        imap = self._instr_map(comp)
        for op in ins.operands:
            src = imap.get(op)
            if src is not None:
                _, ob = _shape_elems_bytes(src.shape)
                b += ob
        return b

    def comp_cost(self, comp: str) -> dict:
        if comp in self._memo_full:
            return self._memo_full[comp]
        c = {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0,
             "scope_bytes": 0.0, "scope_flops": 0.0, "convert_bytes": 0.0,
             "cross_pod_bytes": 0.0,
             "coll": {k: {"count": 0, "operand_bytes": 0.0} for k in COLLECTIVES}}
        for ins in self.comps.get(comp, []):
            op = ins.opcode
            base = op.replace("-start", "").replace("-done", "")
            if op in _FREE:
                continue
            if op.endswith("-done"):
                continue
            if base in COLLECTIVES:
                _, rb = _shape_elems_bytes(ins.shape)
                s = self._group_size(ins)
                if base == "all-gather":
                    b = rb / max(s, 1)
                elif base == "reduce-scatter":
                    b = rb * s
                else:
                    b = rb
                c["coll"][base]["count"] += 1
                c["coll"][base]["operand_bytes"] += b
                c["coll_bytes"] += b
                if self._spans_pods(ins):
                    c["cross_pod_bytes"] += b
                c["bytes"] += rb  # it also touches memory
                continue
            if op == "while":
                body = _CALLED_RE["body"].search(ins.rest)
                cond = _CALLED_RE["condition"].search(ins.rest)
                trips = self._trip_count(cond.group(1)) if cond else 1
                if body:
                    sub = self.comp_cost(body.group(1))
                    for k in ("flops", "bytes", "coll_bytes", "scope_bytes",
                              "scope_flops", "convert_bytes",
                              "cross_pod_bytes"):
                        c[k] += trips * sub[k]
                    for kk, vv in sub["coll"].items():
                        c["coll"][kk]["count"] += trips * vv["count"]
                        c["coll"][kk]["operand_bytes"] += trips * vv["operand_bytes"]
                continue
            if op in ("call", "conditional", "async-start"):
                m = _CALLED_RE["calls"].search(ins.rest)
                if m:
                    sub = self.comp_cost(m.group(1))
                    for k in ("flops", "bytes", "coll_bytes", "scope_bytes",
                              "scope_flops", "convert_bytes",
                              "cross_pod_bytes"):
                        c[k] += sub[k]
                    for kk, vv in sub["coll"].items():
                        c["coll"][kk]["count"] += vv["count"]
                        c["coll"][kk]["operand_bytes"] += vv["operand_bytes"]
                continue
            if op == "fusion":
                m = _CALLED_RE["calls"].search(ins.rest)
                fl = self._flops_only(m.group(1)) if m else 0.0
                c["flops"] += fl
                _, rb = _shape_elems_bytes(ins.shape)
                bb = rb + self._operand_bytes(comp, ins)
                c["bytes"] += bb
                if m and self._is_convert_fusion(m.group(1)):
                    c["convert_bytes"] += bb
                if "flashrgn" in ins.rest:
                    c["scope_bytes"] += bb
                    c["scope_flops"] += fl
                continue
            if op == "dot":
                fl = self._dot_flops(comp, ins)
                c["flops"] += fl
                _, rb = _shape_elems_bytes(ins.shape)
                bb = rb + self._operand_bytes(comp, ins)
                c["bytes"] += bb
                if "flashrgn" in ins.rest:
                    c["scope_bytes"] += bb
                    c["scope_flops"] += fl
                continue
            if op == "dynamic-update-slice":
                # in-place: read+write the updated slice only
                upd = (self._operand_shape(comp, ins.operands[1])
                       if len(ins.operands) > 1 else ins.shape)
                _, ub = _shape_elems_bytes(upd)
                c["bytes"] += 2 * ub
                continue
            if op == "dynamic-slice":
                # reads only the extracted slice (result), writes it
                _, rb = _shape_elems_bytes(ins.shape)
                c["bytes"] += 2 * rb
                continue
            if op in _ARITH or op in _TRANSCEND:
                e, rb = _shape_elems_bytes(ins.shape)
                fl = 4 * e if op in _TRANSCEND else e
                bb = rb + self._operand_bytes(comp, ins)
                c["flops"] += fl
                c["bytes"] += bb
                if "flashrgn" in ins.rest:
                    c["scope_bytes"] += bb
                    c["scope_flops"] += fl
                continue
            if op == "reduce":
                e, _ = _shape_elems_bytes(
                    self._operand_shape(comp, ins.operands[0]))
                c["flops"] += e
                _, rb = _shape_elems_bytes(ins.shape)
                c["bytes"] += rb + self._operand_bytes(comp, ins)
                continue
            # default: memory-touching op (copy, reshape-materialize, gather,
            # scatter, dynamic-slice, convert, transpose, pad, concatenate...)
            _, rb = _shape_elems_bytes(ins.shape)
            bb = rb + self._operand_bytes(comp, ins)
            c["bytes"] += bb
            if op in ("convert", "copy", "transpose"):
                c["convert_bytes"] += bb
            if "flashrgn" in ins.rest:
                c["scope_bytes"] += bb
        self._memo_full[comp] = c
        return c

    def cost(self) -> dict:
        if self.entry is None:
            raise ValueError("no ENTRY computation found")
        out = dict(self.comp_cost(self.entry))
        out["coll"]["total_operand_bytes"] = sum(
            v["operand_bytes"] for v in out["coll"].values())
        out["coll"]["wire_bytes"] = sum(
            v["operand_bytes"] * (2.0 if k == "all-reduce" else 1.0)
            for k, v in out["coll"].items() if isinstance(v, dict))
        return out


def analyze_hlo(hlo_text: str, pod_size: int | None = None) -> dict:
    return HloCost(hlo_text, pod_size=pod_size).cost()
