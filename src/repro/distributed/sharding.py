"""Logical-axis sharding: resolve ('embed','heads',...) -> mesh axes.

Models are written against *logical* axis names; a `ShardingCtx` installed by
the step builder maps them to physical mesh axes and applies
``with_sharding_constraint`` hints.  Outside a ctx (CPU smoke tests) every
hint is the identity, so the same model code runs anywhere.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ShardingRules

_TLS = threading.local()


def _logical_map(rules: ShardingRules, mesh: Mesh) -> dict:
    axes = set(mesh.axis_names)
    batch_axes = ("pod", "data", "model") if rules.dp_over_model \
        else ("pod", "data")
    batch = tuple(a for a in batch_axes if a in axes)
    m = {
        "batch": batch or None,
        "group": batch or None,          # MoE dispatch groups track data shards
        "seq": rules.seq,
        "embed": rules.embed,
        "heads": rules.heads,
        "kv_heads": rules.heads,         # same axis family as heads
        "head_dim": None,
        "ff": rules.ff,
        "vocab": rules.vocab,
        "experts": rules.experts,
        "kv_seq": rules.kv_seq,
        "img_seq": None,
        "layers": None,
        "lora": None,
        "state": None,
        "conv": None,
        None: None,
    }
    if rules.dp_over_model:
        # pure DP: weight TP mappings would fight the batch sharding
        for k in ("heads", "kv_heads", "ff", "vocab", "experts", "seq",
                  "kv_seq"):
            m[k] = None
    return {k: (v if v in axes or v is None or isinstance(v, tuple) else None)
            for k, v in m.items()}


class ShardingCtx:
    def __init__(self, mesh: Mesh, rules: ShardingRules):
        self.mesh = mesh
        self.rules = rules
        self.map = _logical_map(rules, mesh)

    def pspec(self, axes: tuple) -> P:
        return P(*self._dedup([self.map.get(a, None) for a in axes]))

    @staticmethod
    def _dedup(mesh_axes: list) -> list:
        """A mesh axis may shard at most one dim -- first occurrence wins
        (e.g. EP keeps "model" on the experts dim; the per-expert ff falls
        back to replication)."""
        seen: set = set()
        out = []
        for m in mesh_axes:
            ms = (m,) if isinstance(m, str) else (m or ())
            if any(a in seen for a in ms):
                out.append(None)
            else:
                seen.update(ms)
                out.append(m)
        return out

    def sharding(self, axes: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(axes))

    def _axis_size(self, m) -> int:
        if m is None:
            return 1
        axes = (m,) if isinstance(m, str) else m
        return int(np.prod([self.mesh.shape[a] for a in axes]))

    def fit_axes(self, dim: int, m):
        """Trim trailing axes of a tuple mapping until it divides `dim`
        (e.g. global_batch=256 with batch axes (pod,data,model)=512 shards
        falls back to (pod,data)=32)."""
        if m is None or isinstance(m, str):
            return m if dim % self._axis_size(m) == 0 else None
        axes = tuple(m)
        while axes and dim % self._axis_size(axes) != 0:
            axes = axes[:-1]
        return axes or None

    # ---- parameter sharding with FSDP fill-in -------------------------------
    def param_pspec(self, shape: tuple, axes: tuple) -> P:
        mesh_axes = [self.map.get(a, None) for a in axes]
        # drop mappings that do not divide the dim (tiny smoke shapes, scales)
        mesh_axes = [m if shape[i] % self._axis_size(m) == 0 else None
                     for i, m in enumerate(mesh_axes)]
        mesh_axes = self._dedup(mesh_axes)
        fsdp = self.rules.fsdp_axis
        if isinstance(fsdp, str):
            fsdp = (fsdp,) if fsdp in self.mesh.axis_names else ()
        else:
            fsdp = tuple(a for a in (fsdp or ()) if a in self.mesh.axis_names)
        if fsdp:
            fsdp_size = int(np.prod([self.mesh.shape[a] for a in fsdp]))
            used = set()
            for x in mesh_axes:
                used.update((x,) if isinstance(x, str) else (x or ()))
            if not used & set(fsdp):
                # shard the largest still-replicated, divisible dim over fsdp axis
                cands = [(shape[i], i) for i, m in enumerate(mesh_axes)
                         if m is None and axes[i] != "layers"
                         and shape[i] % fsdp_size == 0 and shape[i] >= fsdp_size]
                if cands:
                    _, i = max(cands)
                    mesh_axes[i] = fsdp if len(fsdp) > 1 else fsdp[0]
        return P(*mesh_axes)

    def param_sharding(self, shape: tuple, axes: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.param_pspec(shape, axes))


def current() -> Optional[ShardingCtx]:
    return getattr(_TLS, "ctx", None)


@contextmanager
def use_sharding(ctx: Optional[ShardingCtx]):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = ctx
    try:
        yield ctx
    finally:
        _TLS.ctx = prev


def hint(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain activation sharding by logical axis names (no-op w/o ctx).

    Per-dim mappings that don't divide are trimmed (tuple mappings lose
    trailing axes first) rather than dropping the whole constraint."""
    ctx = current()
    if ctx is None:
        return x
    if x.ndim != len(axes):
        raise ValueError(f"rank mismatch: {x.shape} vs {axes}")
    ps = ctx.pspec(axes)
    fitted = [ctx.fit_axes(dim, m) for dim, m in zip(x.shape, ps)]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*fitted)))
