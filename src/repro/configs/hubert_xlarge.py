"""hubert-xlarge [audio] — 48L d_model=1280 16H d_ff=5120 vocab=504.
Encoder-only (bidirectional); same backbone as wav2vec2. [arXiv:2106.07447]

Modality frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings of shape (batch, seq, d_model); training objective is 504-class
masked-frame prediction (HuBERT cluster targets). No decode shapes.
"""
from repro.configs.base import ArchConfig, ModelConfig, ShardingRules, TrainConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="hubert-xlarge",
        family="encoder",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        is_encoder=True,
        act="gelu",
        rope_theta=10_000.0,
    ),
    sharding=ShardingRules(heads="model", ff="model", vocab=None,
                           fsdp_axis="data", dp_over_model=True),
    train=TrainConfig(remat="full"),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(model=CONFIG.model.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=32))
