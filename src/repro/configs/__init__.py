"""Architecture registry: ``get_arch(name)`` / ``list_archs()``.

Each assigned architecture lives in its own module with two entry points:
``CONFIG`` (the exact published configuration) and ``reduced()`` (a tiny
same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ArchConfig,
    ModelConfig,
    ShapeConfig,
    ShardingRules,
    SHAPES,
    TrainConfig,
)

ARCH_IDS = [
    "grok-1-314b",
    "deepseek-v2-lite-16b",
    "hubert-xlarge",
    "phi3-medium-14b",
    "llama3-405b",
    "stablelm-3b",
    "smollm-360m",
    "zamba2-2.7b",
    "mamba2-370m",
    "llama-3.2-vision-90b",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name])


def get_arch(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_reduced(name: str) -> ArchConfig:
    return _module(name).reduced()


def list_archs() -> list[str]:
    return list(ARCH_IDS)
