"""zamba2-2.7b [hybrid] — 54 Mamba2 layers d_model=2560 + shared attention
block (32H MHA, d_ff=10240) applied every 6 layers; ssm_state=64; vocab=32000.
[arXiv:2411.15242; hf]

The attention block's weights are SHARED across all 9 applications (Zamba2's
defining trick); we scan over 9 groups of (6 mamba layers + 1 shared-attn
application).  Hybrid -> runs long_500k.
"""
from repro.configs.base import ArchConfig, ModelConfig, ShardingRules, TrainConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab_size=32000,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        attn_every=6,
        rope_theta=10_000.0,
    ),
    sharding=ShardingRules(heads="model", ff="model", vocab="model",
                           fsdp_axis="data", kv_seq=None,
                           dp_over_model=True),  # §Perf M1 pattern
    train=TrainConfig(remat="full"),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(model=CONFIG.model.replace(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, ssm_state=16, ssm_head_dim=16, attn_every=2))
