"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408 vocab=102400,
MoE 64 routed experts top-6 + 2 shared, MLA kv_lora=512, first layer dense.
[arXiv:2405.04434; hf]

64 experts divide model=16 -> expert-parallel (4 experts/shard).
MLA: KV compressed to a 512-dim latent + 64-dim decoupled RoPE key; the decode
cache stores the latent (per token), not per-head K/V.
"""
from repro.configs.base import ArchConfig, ModelConfig, ShardingRules, TrainConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        num_layers=27,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,          # nope part; v_head_dim below
        d_ff=1408,
        moe_d_ff=1408,
        dense_d_ff=10944,
        first_k_dense=1,
        vocab_size=102400,
        num_experts=64,
        experts_per_token=6,
        num_shared_experts=2,
        use_mla=True,
        kv_lora_rank=512,
        qk_rope_head_dim=64,
        qk_nope_head_dim=128,
        v_head_dim=128,
        capacity_factor=1.0,
        rope_theta=10_000.0,
    ),
    # §Perf D4/D5: 16B on 256 chips trains fastest as pure FSDP-DP (2.4x
    # fraction, 6.9x fewer collective bytes than TP+EP); EP/TP layout is
    # kept for prefill/decode shapes automatically.
    sharding=ShardingRules(heads="model", ff="model", vocab="model",
                           experts="model", seq="model",
                           fsdp_axis=("data", "model"), kv_seq="model",
                           dp_over_model=True),
    train=TrainConfig(remat="full", comm_pattern="scatter_reduce"),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(model=CONFIG.model.replace(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=64, moe_d_ff=64, dense_d_ff=128, vocab_size=256,
        num_experts=8, experts_per_token=2, num_shared_experts=1,
        kv_lora_rank=32, qk_rope_head_dim=8, qk_nope_head_dim=16, v_head_dim=16))
