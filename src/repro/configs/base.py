"""Config dataclasses for the model zoo, input shapes and distribution.

Every assigned architecture is a `ModelConfig`; every assigned input shape is
a `ShapeConfig`.  `ArchConfig = ModelConfig + ShardingRules + training knobs`
is what the launcher consumes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encoder | vlm
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    vocab_size: int
    num_kv_heads: int = 0          # 0 -> = num_heads (MHA)
    head_dim: int = 0              # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0           # routed experts; 0 -> dense
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0              # per-expert ff width (0 -> d_ff)
    first_k_dense: int = 0         # leading dense layers (deepseek)
    dense_d_ff: int = 0            # ff width of those dense layers
    capacity_factor: float = 1.25  # lint: ignore[C001] -- MoE capacity, not a price
    # --- MLA (DeepSeek latent attention) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    # --- SSM (Mamba2 SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    # --- hybrid (Zamba2) ---
    attn_every: int = 0            # shared attention block every k SSM layers
    # --- VLM ---
    cross_attn_every: int = 0      # cross-attn layer every k self-attn layers
    num_image_tokens: int = 1024
    # --- encoder-only ---
    is_encoder: bool = False
    # --- misc ---
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    act: str = "swiglu"            # swiglu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ---- derived ----
    @property
    def kv_heads(self) -> int:
        return self.num_kv_heads or self.num_heads

    @property
    def hdim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_decode(self) -> bool:
        return not self.is_encoder

    @property
    def subquadratic(self) -> bool:
        """True if the arch can run 500k-token contexts (SSM state or hybrid)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


# The four assigned LM shape cells.
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ShardingRules:
    """Logical-axis -> mesh-axis map. None = replicate.

    `fsdp_axis` additionally shards the largest parameter dim over the data
    axis (classic FSDP-via-GSPMD) when divisible.
    """
    heads: Optional[str] = "model"       # attention head axis
    ff: Optional[str] = "model"          # mlp hidden axis
    vocab: Optional[str] = "model"       # embedding/unembedding vocab axis
    experts: Optional[str] = None        # MoE expert axis (EP)
    embed: Optional[str] = None          # d_model axis of activations
    seq: Optional[str] = None            # activation seq axis (Megatron-style
                                         # sequence parallelism when = "model")
    fsdp_axis: object = "data"           # parameter FSDP axis (str or tuple)
    kv_seq: Optional[str] = None         # decode KV-cache sequence axis
    dp_over_model: bool = False          # small archs: batch over "model" too
                                         # (pure DP; TP mappings ignored)


@dataclass(frozen=True)
class TrainConfig:
    """Training-loop knobs (distribution + optimization)."""
    optimizer: str = "adamw"             # adamw | adamw8bit | sgd
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    micro_batches: int = 1               # grad accumulation
    remat: str = "dots"                  # none | dots | full
    comm_pattern: str = "allreduce"      # allreduce | scatter_reduce
    # paper technique (MA-SGD -> local-SGD / DiLoCo across pods):
    algorithm: str = "ga_sgd"            # ga_sgd | ma_sgd (local sgd) | diloco
    sync_period: int = 1                 # H: inner steps between cross-pod syncs
    outer_lr: float = 0.7                # DiLoCo outer Nesterov lr
    outer_momentum: float = 0.9
    compress_cross_pod: bool = False     # 8-bit gradient/delta compression
    scan_layers: bool = True
    logits_fp32: bool = True


@dataclass(frozen=True)
class ArchConfig:
    model: ModelConfig
    sharding: ShardingRules = field(default_factory=ShardingRules)
    train: TrainConfig = field(default_factory=TrainConfig)

    @property
    def name(self) -> str:
        return self.model.name

    def shapes(self) -> list[str]:
        """Runnable shape cells for this arch (documented skips applied)."""
        out = ["train_4k", "prefill_32k"]
        if self.model.supports_decode:
            out.append("decode_32k")
            if self.model.subquadratic:
                out.append("long_500k")
        return out

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)
