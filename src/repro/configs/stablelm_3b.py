"""stablelm-3b [dense] — 32L d_model=2560 32H (MHA, kv=32) d_ff=6912
vocab=50304. [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs.base import ArchConfig, ModelConfig, ShardingRules, TrainConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="stablelm-3b",
        family="dense",
        num_layers=32,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=6912,
        vocab_size=50304,
        rope_theta=10_000.0,
    ),
    sharding=ShardingRules(heads="model", ff="model", vocab="model",
                           fsdp_axis="data", kv_seq=None,
                           dp_over_model=True),  # §Perf M1 pattern
    train=TrainConfig(remat="full"),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(model=CONFIG.model.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256))
