"""phi3-medium-14b [dense] — 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352. RoPE + SwiGLU + GQA. [arXiv:2404.14219; unverified]"""
from repro.configs.base import ArchConfig, ModelConfig, ShardingRules, TrainConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="phi3-medium-14b",
        family="dense",
        num_layers=40,
        d_model=5120,
        num_heads=40,
        num_kv_heads=10,
        head_dim=128,
        d_ff=17920,
        vocab_size=100352,
        rope_theta=10_000.0,
    ),
    sharding=ShardingRules(heads="model", ff="model", vocab="model",
                           seq="model", fsdp_axis="data", kv_seq="model"),
    train=TrainConfig(remat="full", comm_pattern="scatter_reduce"),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(model=CONFIG.model.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256))
