"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, cross-attention image layers. [hf:meta-llama/Llama-3.2-11B-Vision]

100 layers = 20 groups of (4 self-attn + 1 cross-attn).  The vision frontend
is a STUB: ``input_specs()`` provides precomputed patch embeddings of shape
(batch, num_image_tokens=1024, d_model) that the cross-attn layers attend to.
"""
from repro.configs.base import ArchConfig, ModelConfig, ShardingRules, TrainConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        num_layers=100,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128256,
        cross_attn_every=5,
        num_image_tokens=1024,
        rope_theta=500_000.0,
    ),
    sharding=ShardingRules(heads="model", ff="model", vocab="model",
                           seq="model", fsdp_axis="data", kv_seq="model"),
    train=TrainConfig(remat="full", comm_pattern="scatter_reduce",
                      micro_batches=4),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(model=CONFIG.model.replace(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, cross_attn_every=2, num_image_tokens=16))
