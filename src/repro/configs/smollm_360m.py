"""smollm-360m [dense] — 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
llama-arch small. [hf:HuggingFaceTB/SmolLM-135M; hf]

15 heads do not divide model=16: heads replicated, ff/vocab TP-sharded
(2560/16=160, 49152/16=3072).
"""
from repro.configs.base import ArchConfig, ModelConfig, ShardingRules, TrainConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="smollm-360m",
        family="dense",
        num_layers=32,
        d_model=960,
        num_heads=15,
        num_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab_size=49152,
        rope_theta=10_000.0,
    ),
    sharding=ShardingRules(heads=None, ff="model", vocab="model",
                           fsdp_axis="data", kv_seq="model",
                           dp_over_model=True),  # §Perf M1 pattern
    train=TrainConfig(remat="full"),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(model=CONFIG.model.replace(
        num_layers=2, d_model=60, num_heads=3, num_kv_heads=1, head_dim=20,
        d_ff=128, vocab_size=256))
