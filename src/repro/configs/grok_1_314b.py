"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]

8 experts do not divide the model=16 mesh axis, so experts are replicated and
each expert's d_ff is tensor-parallel sharded (32768/16 = 2048/shard).
"""
from repro.configs.base import ArchConfig, ModelConfig, ShardingRules, TrainConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="grok-1-314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=32768,
        moe_d_ff=32768,
        vocab_size=131072,
        num_experts=8,
        experts_per_token=2,
        rope_theta=10_000.0,
    ),
    sharding=ShardingRules(heads="model", ff="model", vocab="model",
                           experts=None, seq="model", fsdp_axis="data",
                           kv_seq="model"),
    train=TrainConfig(optimizer="adamw8bit", remat="full",
                      comm_pattern="scatter_reduce", micro_batches=4),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(model=CONFIG.model.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, moe_d_ff=128, vocab_size=256, num_experts=4, experts_per_token=2))
