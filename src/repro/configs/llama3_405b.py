"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256. [arXiv:2407.21783; unverified]

At 405B params the single-pod (256-chip) HBM budget forces 8-bit Adam moment
states (2+4+4 -> 2+1+1 bytes/param for p/m/v) — see optim/adamw8bit.
"""
from repro.configs.base import ArchConfig, ModelConfig, ShardingRules, TrainConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="llama3-405b",
        family="dense",
        num_layers=126,
        d_model=16384,
        num_heads=128,
        num_kv_heads=8,
        head_dim=128,
        d_ff=53248,
        vocab_size=128256,
        rope_theta=500_000.0,
    ),
    sharding=ShardingRules(heads="model", ff="model", vocab="model",
                           seq="model", fsdp_axis="data", kv_seq="model"),
    train=TrainConfig(optimizer="adamw8bit", remat="full",
                      comm_pattern="scatter_reduce", micro_batches=4),  # §Perf L6
)


def reduced() -> ArchConfig:
    return CONFIG.replace(model=CONFIG.model.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=256))
