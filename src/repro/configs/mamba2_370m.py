"""mamba2-370m [ssm] — 48L d_model=1024, attention-free, ssm_state=128,
vocab=50280. SSD (state-space duality) chunked scan. [arXiv:2405.21060]

d_inner = 2*1024 = 2048, 32 SSD heads of dim 64.  Attention-free -> runs all
four shapes including long_500k.
"""
from repro.configs.base import ArchConfig, ModelConfig, ShardingRules, TrainConfig

CONFIG = ArchConfig(
    model=ModelConfig(
        name="mamba2-370m",
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
    ),
    # §Perf M1: 370M params over 256 chips is pure-DP territory -- batch
    # over BOTH mesh axes for train (2.4x roofline fraction, 18x fewer
    # collective bytes); TP layout kept for decode shapes automatically.
    sharding=ShardingRules(heads="model", ff="model", vocab="model",
                           fsdp_axis="data", dp_over_model=True),
    train=TrainConfig(remat="full"),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(model=CONFIG.model.replace(
        num_layers=2, d_model=64, vocab_size=256, ssm_state=16, ssm_head_dim=16))
