"""Fault tolerance: atomic checkpoints + deadline-aware preemption guard.

The tree-flatten / bf16-encode / atomic-commit machinery that used to be
implemented here (a second, disconnected copy of the checkpoint path) now
lives in :mod:`repro.core.ckpt.localfs` as the ``local`` backend of the
metered checkpoint subsystem (DESIGN.md §17); this module re-exports it
unchanged, so the seed-era import path -- ``from repro import checkpoint``
-- keeps working with bit-exact bf16 roundtrips.

:class:`PreemptionGuard` stays HERE on purpose: it reads the real wall
clock (``time.monotonic``), which the simulated core (``repro/core``) is
lint-forbidden (D001) from touching.  It is the real-hardware realization
of LambdaML's hierarchical invocation (§3.3.1): checkpoint while there is
still (margin + one step) of the lease left, resume after re-invocation.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.core.ckpt.localfs import (  # noqa: F401
    _BF16_TAG, _SEP, _decode, _encode, _flatten, _unflatten, list_steps,
    load, load_latest, retain, save,
)


@dataclass
class PreemptionGuard:
    """Deadline-aware checkpoint trigger (the 15-minute-Lambda analogue)."""
    lifetime_s: float = 900.0
    margin_s: float = 30.0
    _t0: float = field(default_factory=time.monotonic)
    _ema: float = 0.0

    def record_step(self, dt: float):
        self._ema = dt if self._ema == 0 else 0.9 * self._ema + 0.1 * dt

    @property
    def elapsed(self) -> float:
        return time.monotonic() - self._t0

    def should_checkpoint(self) -> bool:
        budget = self.lifetime_s - self.margin_s - self.elapsed
        return budget < 2 * max(self._ema, 1e-3)

    def renew(self):
        """Call after re-invocation (new lifetime lease)."""
        self._t0 = time.monotonic()
