"""Declarative experiment specifications (DESIGN.md §10).

An :class:`ExperimentSpec` is a frozen, JSON-round-trippable description of
one point in the paper's design space: platform x fleet x failure scenario x
communication x sync protocol x algorithm x model x dataset x stopping rule.
It is the unit the sweep runner expands, hashes (for the on-disk result
cache), and records next to every result, so any row in any table can be
re-run from its JSON alone:

    spec = ExperimentSpec(platform="faas", sync="ssp:2",
                          fleet=FleetSpec(workers=16, straggler=6.0))
    assert ExperimentSpec.from_json(spec.to_json()) == spec

``build_runtime()`` / ``build_workload()`` turn a spec into the exact same
objects a hand-written ``FaaSRuntime(...).train(...)`` call would construct,
which is what makes ``run_experiment(spec)`` byte-identical to the legacy
entry points for the same seed.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field, fields, replace

from repro.core.platform import CommSpec, FailureSpec, FleetSpec
from repro.core.runtimes import LIFETIME, FaaSRuntime, IaaSRuntime
from repro.core.sync import sync_name

PLATFORMS = ("faas", "iaas")


@dataclass(frozen=True)
class ExperimentSpec:
    """One fully-determined experiment.  Every field is JSON-serializable;
    ``name`` is a human label and does NOT enter the spec hash."""
    name: str = ""
    platform: str = "faas"                 # faas | iaas
    fleet: FleetSpec = field(default_factory=FleetSpec)
    failure: FailureSpec = field(default_factory=FailureSpec)
    comm: CommSpec = field(default_factory=CommSpec)
    sync: str = "bsp"                      # bsp | asp | ssp:<s>
    model: str = "lr"                      # make_study_model name
    model_args: dict = field(default_factory=dict)
    algorithm: str = "ga_sgd"              # make_algorithm name
    algo_args: dict = field(default_factory=dict)
    dataset: str = "higgs"                 # make_dataset name
    rows: int = 30_000
    data_seed: int = 0
    val_frac: float = 0.1
    seed: int = 0                          # params init + stragglers + failures
    max_epochs: int = 3
    eval_every: int = 1
    target_loss: float | None = None
    data_local: bool = False               # IaaS: load from peer VMs, not S3
    lifetime: float | None = None          # FaaS: worker lease override (s)

    def __post_init__(self):
        if self.platform not in PLATFORMS:
            raise ValueError(f"platform must be one of {PLATFORMS}, "
                             f"got {self.platform!r}")
        object.__setattr__(self, "sync", sync_name(self.sync))
        for f in ("fleet", "failure", "comm"):
            v = getattr(self, f)
            if isinstance(v, dict):
                cls = {"fleet": FleetSpec, "failure": FailureSpec,
                       "comm": CommSpec}[f]
                object.__setattr__(self, f, cls(**v))

    # ---- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        return asdict(self)

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        d = dict(d)
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise KeyError(f"unknown ExperimentSpec fields {sorted(unknown)}; "
                           f"valid fields: {sorted(known)}")
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    def spec_hash(self) -> str:
        """Stable content hash (cache key).  ``name`` is excluded: renaming
        a trial must still hit the cache."""
        d = self.to_dict()
        d.pop("name")
        canon = json.dumps(d, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(canon.encode()).hexdigest()[:16]

    def with_(self, **overrides) -> "ExperimentSpec":
        """`replace` that also reaches into nested specs via dotted keys:
        ``spec.with_(**{"fleet.workers": 8, "sync": "asp"})``."""
        out = self
        for key, value in overrides.items():
            out = _apply_override(out, key, value)
        return out

    # ---- builders -----------------------------------------------------------
    def build_runtime(self):
        """The platform object a hand-written call would construct."""
        if self.platform == "faas":
            return FaaSRuntime(
                fleet=self.fleet, failure=self.failure, comm=self.comm,
                sync=self.sync, seed=self.seed,
                lifetime=LIFETIME if self.lifetime is None else self.lifetime)
        return IaaSRuntime(fleet=self.fleet, failure=self.failure,
                           comm=self.comm, sync=self.sync, seed=self.seed)

    def build_workload(self):
        """(model, algo, ds_train, ds_val) exactly as the legacy scripts
        build them -- deterministic in (dataset, rows, data_seed, val_frac,
        model, algorithm)."""
        from repro.core.algorithms import make_algorithm
        from repro.core.mlmodels import make_study_model
        from repro.data.synthetic import make_dataset, train_val_split
        ds = make_dataset(self.dataset, rows=self.rows, seed=self.data_seed)
        tr, va = train_val_split(ds, val_frac=self.val_frac)
        model = make_study_model(self.model, tr, **self.model_args)
        algo = make_algorithm(self.algorithm, **self.algo_args)
        return model, algo, tr, va


def _apply_override(spec, path: str, value):
    head, _, rest = path.partition(".")
    valid = {f.name for f in fields(spec)}
    if head not in valid:
        raise KeyError(f"unknown spec field {head!r} in override {path!r}; "
                       f"valid fields: {sorted(valid)}")
    if rest:
        return replace(spec, **{head: _apply_override(getattr(spec, head),
                                                      rest, value)})
    return replace(spec, **{head: value})
