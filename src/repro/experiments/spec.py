"""Declarative experiment specifications (DESIGN.md §10).

An :class:`ExperimentSpec` is a frozen, JSON-round-trippable description of
one point in the paper's design space: platform x fleet x failure scenario x
communication x sync protocol x algorithm x model x dataset x stopping rule.
It is the unit the sweep runner expands, hashes (for the on-disk result
cache), and records next to every result, so any row in any table can be
re-run from its JSON alone:

    spec = ExperimentSpec(platform="faas", sync="ssp:2",
                          fleet=FleetSpec(workers=16, straggler=6.0))
    assert ExperimentSpec.from_json(spec.to_json()) == spec

``build_runtime()`` / ``build_workload()`` turn a spec into the exact same
objects a hand-written ``FaaSRuntime(...).train(...)`` call would construct,
which is what makes ``run_experiment(spec)`` byte-identical to the legacy
entry points for the same seed.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field, fields, replace

from repro.core.ckpt import CheckpointSpec
from repro.core.platform import CommSpec, FailureSpec, FleetSpec
from repro.core.runtimes import (
    LIFETIME, FaaSRuntime, IaaSRuntime, PodPlatform,
)
from repro.core.sync import sync_name

PLATFORMS = ("faas", "iaas", "pod")

#: salt for :meth:`ExperimentSpec.spec_hash`.  Bump whenever a spec field's
#: DEFAULT VALUE changes (defaults are elided from the hash, so an old
#: record would otherwise alias the new semantics); adding fields needs no
#: bump.  h3: the elastic-fleet fields (``scaling`` on the spec,
#: ``min_workers``/``max_workers`` on FleetSpec) landed together with the
#: ``scaling_timeline`` RunResult key, so pre-elastic records are re-keyed
#: rather than served with the old result schema.  h4: int8 wire accounting
#: went blockwise (``int8_wire_floats = ceil(n/4) + ceil(n/256)``, one fp32
#: scale per 256-element block -- the form the quant8 Pallas kernel ships)
#: and the codecs now execute the kernels, so cached ``comm_bytes``/loss
#: histories from the per-vector-scale era must not alias the new numbers.
#: h5: the metered checkpoint subsystem (DESIGN.md §17) landed -- restarts
#: route real shard bytes through the transport, ``RunResult`` grew the
#: ``ckpt_*`` meters, and the FaaS planner time gained the lifetime-rotation
#: term -- so pre-checkpoint records must not alias runs that now bill
#: checkpoint traffic (``FailureSpec.trace`` / ``ExperimentSpec.ckpt`` are
#: new fields and elide from the hash when defaulted).  h6: the structured
#: trace subsystem (DESIGN.md §18) landed -- ``ExperimentSpec.trace`` asks
#: the engine for a span recorder, and recorded results moved to full
#: precision (``repro.experiment/v2``: ``sim_time_s``/``cost_usd``/... are
#: no longer rounded at record time, and traced records carry a ``trace``
#: section) -- so h5-era rounded records must not alias the full-precision
#: schema.
HASH_SCHEMA = "h6"


@dataclass(frozen=True)
class ExperimentSpec:
    """One fully-determined experiment.  Every field is JSON-serializable;
    ``name`` is a human label and does NOT enter the spec hash."""
    name: str = ""
    platform: str = "faas"                 # faas | iaas | pod
    fleet: FleetSpec = field(default_factory=FleetSpec)
    failure: FailureSpec = field(default_factory=FailureSpec)
    comm: CommSpec = field(default_factory=CommSpec)
                                           # also accepts the string grammar
                                           # "transport/collective/codec",
                                           # e.g. "s3/scatter_reduce/int8"
    ckpt: CheckpointSpec = field(default_factory=CheckpointSpec)
                                           # also accepts the string grammar
                                           # "<transport>[:every=<N>][:sharded]",
                                           # e.g. "s3:every=5:sharded" (§17)
    sync: str = "bsp"                      # bsp | asp | ssp:<s>
                                           #   | local:<H>[:c8] | diloco:<H>[:c8]
    scaling: str = "static"                # elastic fleet policy (§13):
                                           # static | schedule:<w@round,...>
                                           #   | smlt[:<f>] | cost_cap:<$>
                                           #   | plan[:cheapest|fastest]
    model: str = "lr"                      # any core.workloads name: a study
                                           # stand-in (lr/svm/...) or a real
                                           # arch (smollm_360m, mamba2_370m...)
    model_args: dict = field(default_factory=dict)
    algorithm: str = "ga_sgd"              # make_algorithm name
    algo_args: dict = field(default_factory=dict)
    dataset: str = "higgs"                 # make_dataset name
    rows: int = 30_000
    data_seed: int = 0
    val_frac: float = 0.1
    seed: int = 0                          # params init + stragglers + failures
    max_epochs: int = 3
    eval_every: int = 1
    target_loss: float | None = None
    data_local: bool = False               # IaaS/pod: peer-to-peer data load
    trace: bool = False                    # record per-event spans (§18);
                                           # metered results are byte-equal
                                           # either way (property-tested)
    lifetime: float | None = None          # FaaS: worker lease override (s)
    platform_args: dict = field(default_factory=dict)
                                           # pod: chips_per_pod, mfu,
                                           # dcn_bandwidth, chip_hourly, ...

    def __post_init__(self):
        if self.platform not in PLATFORMS:
            raise ValueError(f"platform must be one of {PLATFORMS}, "
                             f"got {self.platform!r}")
        if self.platform_args and self.platform != "pod":
            raise ValueError(
                f"platform_args only apply to platform='pod' "
                f"(got {sorted(self.platform_args)} on {self.platform!r}); "
                f"faas/iaas knobs live in fleet/failure/comm/lifetime")
        bad = set(self.platform_args) - PodPlatform.SPEC_TUNABLES
        if bad:
            raise KeyError(
                f"unknown platform_args {sorted(bad)}; tunable via spec: "
                f"{sorted(PodPlatform.SPEC_TUNABLES)} (worker/pod count and "
                f"failure scenario come from fleet/failure)")
        # fail the workload/dataset pairing eagerly (a sweep should reject
        # at expansion, not crash mid-batch inside build_workload)
        from repro.core.workloads import TOKEN_DATASET, is_arch_workload
        if is_arch_workload(self.model):
            if self.dataset != TOKEN_DATASET:
                raise ValueError(
                    f"architecture workload {self.model!r} trains on the "
                    f"synthetic LM corpus; set dataset={TOKEN_DATASET!r} "
                    f"(got {self.dataset!r})")
        elif self.dataset == TOKEN_DATASET:
            raise ValueError(
                f"dataset={TOKEN_DATASET!r} is the architecture workloads' "
                f"corpus; model {self.model!r} is a study stand-in -- pick "
                f"one of the feature datasets (higgs, rcv1, ...)")
        object.__setattr__(self, "sync", sync_name(self.sync))
        if isinstance(self.comm, str):     # "transport/collective/codec"
            object.__setattr__(self, "comm", CommSpec.parse(self.comm))
        if isinstance(self.ckpt, str) or self.ckpt is None:
            object.__setattr__(self, "ckpt", CheckpointSpec.parse(self.ckpt))
        for f in ("fleet", "failure", "comm", "ckpt"):
            v = getattr(self, f)
            if isinstance(v, dict):
                cls = {"fleet": FleetSpec, "failure": FailureSpec,
                       "comm": CommSpec, "ckpt": CheckpointSpec}[f]
                object.__setattr__(self, f, cls(**v))
        # the comm stack fails HERE, not mid-simulation: pairing/platform
        # rules and per-item limits (DynamoDB 400 KB x the estimated model
        # update size -> ChannelItemTooLarge, Table 1's "N/A" cells).  The
        # size estimate is lazy -- only transports with item limits pay it.
        from repro.core.workloads import estimate_update_bytes
        self.comm.validate(
            platform=self.platform,
            model_bytes=lambda: estimate_update_bytes(
                self.model, self.dataset, self.model_args),
            workers=self.fleet.workers)
        # checkpoint feasibility fails here too: every shard must fit the
        # ckpt transport's per-item limit (DynamoDB 400 KB), same lazy
        # size estimate as the comm check (§17)
        self.ckpt.validate(
            model_bytes=lambda: estimate_update_bytes(
                self.model, self.dataset, self.model_args),
            workers=self.fleet.workers)
        # a preemption trace must exist and parse before a sweep starts
        if self.failure.trace:
            from repro.core.failures import load_trace, resolve_trace
            load_trace(resolve_trace(self.failure.trace))
        # lossy codecs only act on collective reduces; reject the ASP/SSP
        # pairing eagerly (it would silently run fp32)
        from repro.core.platform import check_sync_codec
        from repro.core.sync import make_sync
        check_sync_codec(make_sync(self.sync), self.comm.codec)
        # elastic scaling (§13): parse the policy grammar eagerly, reject
        # sync protocols without a resize path and heterogeneous fleets
        from repro.core.elastic import build_controller, validate_scaling
        if not isinstance(self.scaling, str):
            raise ValueError(
                f"ExperimentSpec.scaling must be a policy string (specs are "
                f"JSON-round-trippable); pass policy INSTANCES to the "
                f"platform classes directly (got {type(self.scaling)})")
        validate_scaling(self.scaling)
        if self.scaling.startswith("plan"):
            if self.platform not in ("faas", "iaas"):
                raise ValueError(
                    f"scaling='plan' covers the analytic model's platforms "
                    f"(faas/iaas), not {self.platform!r}")
        else:
            controller = build_controller(self.scaling, self.fleet)
            if controller is not None and not make_sync(
                    self.sync).supports_resize:
                raise ValueError(
                    f"scaling={self.scaling!r} resizes the fleet mid-run, "
                    f"which sync={self.sync!r} does not support "
                    f"(supports_resize=False)")
            # a declarative schedule names every width it will run at --
            # validate the comm stack against each one NOW (a round-0 pin
            # to a width whose scatter-reduce chunk busts a per-item
            # transport limit should fail here, not mid-simulation)
            from repro.core.elastic import SchedulePolicy
            if controller is not None and isinstance(controller.policy,
                                                     SchedulePolicy):
                for _rnd, w in controller.policy.plan:
                    self.comm.validate(
                        platform=self.platform,
                        model_bytes=lambda: estimate_update_bytes(
                            self.model, self.dataset, self.model_args),
                        workers=max(controller.min_w,
                                    min(controller.max_w, w)))

    # ---- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        return asdict(self)

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        d = dict(d)
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise KeyError(f"unknown ExperimentSpec fields {sorted(unknown)}; "
                           f"valid fields: {sorted(known)}")
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    def spec_hash(self) -> str:
        """Stable content hash (cache key).  ``name`` is excluded (renaming
        a trial must still hit the cache), and so is every field still at
        its default value -- so ADDING a spec field in a future schema
        revision does not orphan the whole on-disk record cache (only specs
        that actually use the new field hash differently).  The flip side:
        because defaults are elided, CHANGING a field's default changes
        what an elided field means -- whoever changes a default MUST bump
        ``HASH_SCHEMA`` (and may re-key ``experiments/runs/``), otherwise
        old records alias the new semantics."""
        d = self.to_dict()
        d.pop("name")
        defaults = _spec_defaults()
        canon = {k: v for k, v in d.items() if v != defaults[k]}
        payload = HASH_SCHEMA + json.dumps(canon, sort_keys=True,
                                           separators=(",", ":"))
        return hashlib.sha256(payload.encode()).hexdigest()[:16]

    def with_(self, **overrides) -> "ExperimentSpec":
        """`replace` that also reaches into nested specs via dotted keys:
        ``spec.with_(**{"fleet.workers": 8, "sync": "asp"})``."""
        out = self
        for key, value in overrides.items():
            out = _apply_override(out, key, value)
        return out

    # ---- builders -----------------------------------------------------------
    def build_runtime(self):
        """The platform object a hand-written call would construct.
        ``scaling="plan[:objective]"`` resolves HERE: the analytic planner
        picks the initial width for this spec's platform, and the run
        itself is static (DESIGN.md §13)."""
        fleet, scaling = self.fleet, self.scaling
        if scaling.startswith("plan"):
            from repro.core.elastic import plan_initial_workers
            _, _, objective = scaling.partition(":")
            fleet = replace(fleet, workers=plan_initial_workers(
                self, objective or "cheapest"))
            scaling = "static"
        if self.platform == "faas":
            return FaaSRuntime(
                fleet=fleet, failure=self.failure, comm=self.comm,
                sync=self.sync, seed=self.seed, scaling=scaling,
                ckpt=self.ckpt,
                lifetime=LIFETIME if self.lifetime is None else self.lifetime)
        if self.platform == "pod":
            return PodPlatform(fleet=fleet, failure=self.failure,
                               comm=self.comm, sync=self.sync,
                               seed=self.seed, scaling=scaling,
                               ckpt=self.ckpt, **self.platform_args)
        return IaaSRuntime(fleet=fleet, failure=self.failure,
                           comm=self.comm, sync=self.sync, seed=self.seed,
                           scaling=scaling, ckpt=self.ckpt)

    def build_workload(self):
        """(workload, algo, ds_train, ds_val) via the unified
        :func:`repro.core.workloads.make_workload` -- study stand-ins keep
        the exact legacy construction (byte-identical histories),
        architecture names build the real JAX model.  Deterministic in
        (dataset, rows, data_seed, val_frac, model, algorithm)."""
        from repro.core.algorithms import make_algorithm
        from repro.core.workloads import make_workload
        wl, tr, va = make_workload(
            self.model, dataset=self.dataset, rows=self.rows,
            data_seed=self.data_seed, val_frac=self.val_frac,
            **self.model_args)
        algo = make_algorithm(self.algorithm, **self.algo_args)
        return wl, algo, tr, va


_DEFAULTS: dict | None = None


def _spec_defaults() -> dict:
    """asdict of a default ExperimentSpec (computed once) -- the reference
    ``spec_hash`` diffs against."""
    global _DEFAULTS
    if _DEFAULTS is None:
        _DEFAULTS = ExperimentSpec().to_dict()
    return _DEFAULTS


def _apply_override(spec, path: str, value):
    head, _, rest = path.partition(".")
    valid = {f.name for f in fields(spec)}
    if head not in valid:
        raise KeyError(f"unknown spec field {head!r} in override {path!r}; "
                       f"valid fields: {sorted(valid)}")
    if rest:
        return replace(spec, **{head: _apply_override(getattr(spec, head),
                                                      rest, value)})
    return replace(spec, **{head: value})
