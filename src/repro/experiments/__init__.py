"""Declarative experiment layer (DESIGN.md §10).

One way to run a study, three entry points:

- :class:`ExperimentSpec` -- frozen, JSON-round-trippable description of a
  single trial (platform x fleet x failure x comm x sync x algorithm x
  model x dataset x stopping rule), composed from the same
  FleetSpec/FailureSpec/CommSpec objects the platforms consume.
- :func:`run_experiment` / :func:`sweep` -- execute a spec (or a cartesian
  grid of overrides over one) into stable-schema :class:`RunRecord` JSON,
  with an on-disk cache keyed by spec hash.
- :data:`PRESETS` -- the paper's figures as named spec bundles
  (``fig10_breakdown``, ``fig11_end2end``, ``fig8_sync``,
  ``spot_vs_ondemand``, ``hetero_fleet``), consumed by both the
  ``python -m repro`` CLI and the benchmark drivers.
"""
from repro.core.platform import CommSpec, FailureSpec, FleetSpec  # noqa: F401
from repro.experiments.presets import PRESETS, Preset, get_preset  # noqa: F401
from repro.experiments.runner import (  # noqa: F401
    SCHEMA, RunRecord, expand_grid, run_experiment, sweep,
)
from repro.experiments.serving import (  # noqa: F401
    SERVE_SCHEMA, ServeRecord, ServingSpec, frontier, run_serving,
)
from repro.experiments.spec import ExperimentSpec  # noqa: F401
