"""Named experiment presets: the paper's figures as specs, not scripts.

Each preset is a declarative bundle of :class:`ExperimentSpec` trials (plus
a canonical ``base`` spec for sweeps).  ``python -m repro run <name>`` and
the benchmark drivers both consume these, so there is exactly one
definition of what e.g. "Fig 10" means.

Every preset takes ``quick`` (small row counts, CI-friendly) vs full
paper-scale sizes -- the same knob the benchmark suite always had.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from repro.core.runtimes import _T_IAAS, interp_startup
from repro.experiments.spec import (
    CommSpec, ExperimentSpec, FailureSpec, FleetSpec,
)


@dataclass(frozen=True)
class Preset:
    """A named, parameter-free study: ``build(quick)`` yields its trials."""
    name: str
    description: str
    build: Callable[[bool], list[ExperimentSpec]] = field(repr=False)

    def base(self, quick: bool = True) -> ExperimentSpec:
        """Canonical single spec for sweeping (the first trial)."""
        return self.build(quick)[0]


_GA = {"lr": 0.3, "batch_size": 2048}
_ADMM = {"lr": 0.1, "local_epochs": 5}


def _fig10_breakdown(quick: bool) -> list[ExperimentSpec]:
    base = ExperimentSpec(
        model="lr", dataset="higgs", rows=30_000 if quick else 500_000,
        algorithm="ga_sgd", algo_args=dict(_GA), max_epochs=10,
        fleet=FleetSpec(workers=10))
    return [
        base.with_(name="fig10_faas_s3", platform="faas",
                   comm=CommSpec(channel="s3")),
        base.with_(name="fig10_faas_memcached", platform="faas",
                   comm=CommSpec(channel="memcached")),
        base.with_(name="fig10_hybridps", platform="faas",
                   comm=CommSpec(channel="vmps")),
        base.with_(name="fig10_iaas", platform="iaas"),
    ]


def _fig10_trace(quick: bool) -> list[ExperimentSpec]:
    """The Fig-10 trials with the span recorder on (§18): the breakdown
    table is re-derived from spans alone and gated on the conservation
    invariants (``repro trace fig10_trace``)."""
    return [s.with_(name=s.name.replace("fig10_", "fig10_trace_"),
                    trace=True)
            for s in _fig10_breakdown(quick)]


def _fig11_end2end(quick: bool) -> list[ExperimentSpec]:
    base = ExperimentSpec(
        model="lr", dataset="higgs", rows=30_000 if quick else 400_000,
        algorithm="admm", algo_args=dict(_ADMM), max_epochs=3)
    counts = (1, 5, 10) if quick else (1, 5, 10, 25, 50, 100)
    specs = []
    for w in counts:
        for plat in ("faas", "iaas"):
            specs.append(base.with_(
                name=f"fig11_lr_{plat}_w{w}", platform=plat,
                **{"fleet.workers": w}))
    return specs


def _fig8_sync(quick: bool) -> list[ExperimentSpec]:
    # high lr + strong straggler: the regime where stale SIREN-style
    # overwrites destabilize (paper Fig 8); SSP's bound caps the damage
    base = ExperimentSpec(
        platform="faas", model="lr", algorithm="ga_sgd",
        algo_args={"lr": 1.0, "batch_size": 2048}, max_epochs=4,
        fleet=FleetSpec(workers=16, straggler=6.0))
    datasets = ("higgs",) if quick else ("higgs", "rcv1")
    rows = 30_000 if quick else 200_000
    return [
        base.with_(name=f"fig8_{ds}_{sync.replace(':', '')}", dataset=ds,
                   rows=rows, sync=sync)
        for ds in datasets for sync in ("bsp", "asp", "ssp:2")
    ]


def _spot_vs_ondemand(quick: bool) -> list[ExperimentSpec]:
    w = 8
    t0 = interp_startup(_T_IAAS, w)       # kills land after cluster startup
    base = ExperimentSpec(
        platform="iaas", model="lr", dataset="higgs",
        rows=30_000 if quick else 200_000, algorithm="ga_sgd",
        algo_args=dict(_GA), max_epochs=3, fleet=FleetSpec(workers=w))
    return [
        base.with_(name="spot_ondemand"),
        base.with_(name="spot_preempted",
                   failure=FailureSpec(spot=True,
                                       inject=((1, t0 + 2.0), (5, t0 + 6.0)))),
    ]


def _spot_trace(quick: bool) -> list[ExperimentSpec]:
    # trace-driven spot failures x checkpoint cadence (DESIGN.md §17): the
    # recorded spot_burst reclaim wave replayed against a no-cadence fleet
    # (save-at-kill seed semantics) and three checkpoint policies -- the
    # cadence grid shows the rework-vs-overhead trade the derived restart
    # term prices
    base = ExperimentSpec(
        platform="iaas", model="lr", dataset="higgs",
        rows=30_000 if quick else 200_000, algorithm="ga_sgd",
        algo_args=dict(_GA), max_epochs=3, fleet=FleetSpec(workers=8),
        failure=FailureSpec(spot=True, trace="spot_burst"))
    return [
        base.with_(name="spot_trace_nockpt"),
        base.with_(name="spot_trace_every2", ckpt="s3:every=2"),
        base.with_(name="spot_trace_every8", ckpt="s3:every=8"),
        base.with_(name="spot_trace_sharded", ckpt="s3:every=2:sharded"),
    ]


def _hetero_fleet(quick: bool) -> list[ExperimentSpec]:
    return [
        ExperimentSpec(
            name="hetero_faas_mixed_gb", platform="faas", model="mobilenet",
            dataset="cifar10", rows=4_000 if quick else 50_000,
            algorithm="ga_sgd", algo_args={"lr": 0.05, "batch_size": 512},
            max_epochs=1, comm=CommSpec(channel="memcached"),
            fleet=FleetSpec(workers=6,
                            lambda_gb=(3.0, 3.0, 3.0, 3.0, 1.0, 1.0))),
        ExperimentSpec(
            name="hetero_iaas_mixed_instances", platform="iaas", model="lr",
            dataset="higgs", rows=30_000 if quick else 400_000,
            algorithm="admm", algo_args=dict(_ADMM), max_epochs=3,
            fleet=FleetSpec(workers=4,
                            instance=("c5.large", "c5.large",
                                      "t2.medium", "t2.medium"))),
    ]


def _faas_vs_pod(quick: bool) -> list[ExperimentSpec]:
    # a REAL smollm-360m-config workload running genuine JAX fwd/bwd
    # numerics through the engine on all three infrastructures (the CPU-
    # sized reduced() config; --set model_args={"reduced":false} builds the
    # published 360M shapes -- same code path).  LocalSGD(H=8) on the pod
    # platform is the paper's reduced-communication regime: ~8x fewer
    # metered cross-pod comm seconds/bytes at matching statistical
    # efficiency (loss histories agree at the averaging boundaries).
    base = ExperimentSpec(
        model="smollm_360m", dataset="tokens",
        rows=256 if quick else 16_384,
        algorithm="ga_sgd", algo_args={"lr": 0.05, "batch_size": 8},
        max_epochs=2, fleet=FleetSpec(workers=4))
    return [
        base.with_(name="pods_faas_bsp", platform="faas",
                   comm=CommSpec(channel="memcached")),
        base.with_(name="pods_iaas_bsp", platform="iaas"),
        base.with_(name="pods_pod_bsp", platform="pod"),
        base.with_(name="pods_pod_local8", platform="pod", sync="local:8"),
    ]


def _comm_axis(quick: bool) -> list[ExperimentSpec]:
    # the Transport x Collective x Codec axis (DESIGN.md §12) on one
    # CNN-sized workload: Table 3's allreduce-vs-scatter-reduce, the
    # FSD-Inference-style hierarchical tree, and the MLLess-style
    # reduced-communication codecs that change the FaaS verdict -- plus
    # the same codecs riding the IaaS NIC ring and the pod DCN.
    base = ExperimentSpec(
        platform="faas", model="mobilenet", dataset="cifar10",
        rows=2_000 if quick else 20_000, algorithm="ga_sgd",
        algo_args={"lr": 0.05, "batch_size": 512}, max_epochs=1,
        fleet=FleetSpec(workers=8))
    stacks = [
        "s3/allreduce/fp32",
        "s3/scatter_reduce/fp32",
        "s3/hierarchical/fp32",
        "s3/scatter_reduce/int8",
        "s3/scatter_reduce/topk:0.01",
        "memcached/allreduce/fp32",
        "vmps/pushpull/fp32",
    ]
    specs = [base.with_(name="comm_" + s.replace("/", "_").replace(":", ""),
                        comm=s)
             for s in stacks]
    specs.append(base.with_(name="comm_iaas_nic_ring_int8", platform="iaas",
                            comm="nic/ring/int8"))
    specs.append(base.with_(name="comm_pod_dcn_ring_int8", platform="pod",
                            comm="dcn/ring/int8"))
    return specs


def _pod_local_sgd(quick: bool) -> list[ExperimentSpec]:
    # communication-interval sweep on the pod platform: BSP GA-SGD vs
    # LocalSGD(H) vs DiLoCo, with and without int8 delta compression
    base = ExperimentSpec(
        platform="pod", model="smollm_360m", dataset="tokens",
        rows=256 if quick else 16_384,
        algorithm="ga_sgd", algo_args={"lr": 0.05, "batch_size": 8},
        max_epochs=2, fleet=FleetSpec(workers=4))
    return [
        base.with_(name="podsgd_bsp"),
        base.with_(name="podsgd_local1", sync="local:1"),
        base.with_(name="podsgd_local8", sync="local:8"),
        base.with_(name="podsgd_local8_c8", sync="local:8:c8"),
        base.with_(name="podsgd_diloco8", sync="diloco:8"),
    ]


def _elastic_axis(quick: bool) -> list[ExperimentSpec]:
    # the elastic-fleet axis (DESIGN.md §13) on the Fig-11 workload: a
    # fixed fleet vs a declarative resize plan vs SMLT-style adaptive
    # scaling vs an MLLess-style cost cap, all emitting w(t) in
    # RunResult.scaling_timeline
    base = ExperimentSpec(
        platform="faas", model="lr", dataset="higgs",
        rows=30_000 if quick else 400_000, algorithm="ga_sgd",
        algo_args=dict(_GA), max_epochs=6,
        fleet=FleetSpec(workers=4, min_workers=2, max_workers=16))
    return [
        base.with_(name="elastic_static"),
        base.with_(name="elastic_schedule", scaling="schedule:2@0,8@5"),
        base.with_(name="elastic_smlt", scaling="smlt"),
        base.with_(name="elastic_cost_cap",
                   scaling="cost_cap:0.01" if quick else "cost_cap:0.25"),
        base.with_(name="elastic_iaas_schedule", platform="iaas",
                   scaling="schedule:4@0,2@3"),
    ]


PRESETS: dict[str, Preset] = {p.name: p for p in [
    Preset("fig10_breakdown",
           "Fig 10: startup/load/compute/comm breakdown, FaaS channels vs "
           "hybrid VM-PS vs IaaS (LR on Higgs, w=10)", _fig10_breakdown),
    Preset("fig10_trace",
           "Fig 10 re-derived from spans (§18): the same four trials with "
           "trace=True, phase table from the recorder + conservation gates",
           _fig10_trace),
    Preset("fig11_end2end",
           "Fig 11: end-to-end runtime+cost vs worker count, FaaS vs IaaS "
           "(LR+ADMM on Higgs)", _fig11_end2end),
    Preset("fig8_sync",
           "Fig 8: BSP vs ASP vs SSP(s=2) under a 6x straggler "
           "(GA-SGD, w=16)", _fig8_sync),
    Preset("spot_vs_ondemand",
           "Spot IaaS with injected preemptions + restart-from-checkpoint "
           "vs the on-demand fleet", _spot_vs_ondemand),
    Preset("spot_trace",
           "Recorded spot-preemption trace (spot_burst) x checkpoint "
           "cadence grid: no cadence vs s3:every=2/8 vs sharded (§17)",
           _spot_trace),
    Preset("hetero_fleet",
           "Heterogeneous fleets: mixed 1/3 GB Lambdas and mixed instance "
           "types", _hetero_fleet),
    Preset("faas_vs_pod",
           "Real smollm-360m workload (genuine JAX fwd/bwd) on all three "
           "infrastructures: FaaS vs IaaS vs accelerator pods, + "
           "LocalSGD(H=8) on pods", _faas_vs_pod),
    Preset("pod_local_sgd",
           "Pod platform comm-interval sweep: BSP vs LocalSGD(H) vs DiLoCo "
           "vs int8-compressed deltas (MA-SGD insight on pod meshes)",
           _pod_local_sgd),
    Preset("comm_axis",
           "Transport x Collective x Codec axis (§12): S3/Memcached/VM-PS, "
           "allreduce vs scatter-reduce vs hierarchical, fp32 vs int8 vs "
           "top-k, + NIC/DCN ring rows", _comm_axis),
    Preset("elastic_axis",
           "Elastic fleets (§13): static vs schedule vs SMLT-adaptive vs "
           "cost-capped scaling on the Fig-11 workload, w(t) in the "
           "scaling timeline", _elastic_axis),
]}


def get_preset(name: str) -> Preset:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown preset {name!r}; available: "
                       f"{', '.join(sorted(PRESETS))}") from None
