"""Experiment runner + sweep driver (DESIGN.md §10).

``run_experiment(spec)`` executes one :class:`ExperimentSpec` through the
discrete-event engine and returns a :class:`RunRecord` -- the stable JSON
schema every study emits (schema ``repro.experiment/v2``; v2 records full-
precision metered values and, when ``spec.trace`` is set, a ``trace``
section with the span list and Figure-10 phase breakdown, DESIGN.md §18):

    {
      "schema":    "repro.experiment/v2",
      "name":      "<human label>",
      "spec_hash": "<16-hex content hash of the spec, name excluded>",
      "spec":      { ...ExperimentSpec.to_dict()... },
      "result": {
        ...RunResult.to_dict()...,      # sim_time_s, cost_usd, breakdown, ...
        "history": [[sim_time_s, loss], ...]
      }
    }

Records are cached on disk keyed by ``spec_hash`` (pass ``cache_dir``), so
re-running a study only executes the trials whose specs changed.
``sweep()`` expands a cartesian grid of dotted-path overrides over a base
spec, dedupes identical expansions, and optionally fans trials out over a
thread pool.
"""
from __future__ import annotations

import itertools
import json
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from pathlib import Path

from repro.experiments.spec import ExperimentSpec

SCHEMA = "repro.experiment/v2"
DEFAULT_CACHE = Path(__file__).resolve().parents[3] / "experiments" / "runs"


@dataclass
class RunRecord:
    """One executed (or cache-recalled) experiment, spec included."""
    spec: ExperimentSpec
    result: dict
    spec_hash: str = ""
    schema: str = SCHEMA
    cached: bool = False          # served from the on-disk cache?
    path: str = ""                # cache file, when one was used

    def __post_init__(self):
        if not self.spec_hash:
            self.spec_hash = self.spec.spec_hash()

    def to_dict(self) -> dict:
        return {"schema": self.schema, "name": self.spec.name,
                "spec_hash": self.spec_hash, "spec": self.spec.to_dict(),
                "result": self.result}

    @classmethod
    def from_dict(cls, d: dict, **kw) -> "RunRecord":
        return cls(spec=ExperimentSpec.from_dict(d["spec"]),
                   result=d["result"], spec_hash=d["spec_hash"],
                   schema=d.get("schema", SCHEMA), **kw)

    @property
    def history(self) -> list:
        return self.result.get("history", [])

    @property
    def final_loss(self) -> float:
        return self.result.get("final_loss", float("nan"))


def _result_dict(res) -> dict:
    d = res.to_dict()
    d["history"] = [[float(t), float(l)] for t, l in res.history]
    return d


def run_experiment(spec: ExperimentSpec, cache_dir: str | Path | None = None,
                   force: bool = False) -> RunRecord:
    """Execute one spec (or recall it from ``cache_dir``).

    The workload and runtime are built exactly as the legacy hand-written
    scripts build them, so the loss history is byte-identical to a direct
    ``FaaSRuntime(...).train(...)`` call with the same seed.
    """
    cache_file = None
    if cache_dir is not None:
        cache_file = Path(cache_dir) / f"{spec.spec_hash()}.json"
        if cache_file.exists() and not force:
            rec = RunRecord.from_dict(json.loads(cache_file.read_text()),
                                      cached=True, path=str(cache_file))
            # keep the caller's label: the hash ignores names on purpose
            rec.spec = spec
            return rec

    model, algo, tr, va = spec.build_workload()
    res = spec.build_runtime().train(
        model, algo, tr, va, target_loss=spec.target_loss,
        max_epochs=spec.max_epochs, eval_every=spec.eval_every,
        data_local=spec.data_local, trace=spec.trace)
    rec = RunRecord(spec=spec, result=_result_dict(res))

    if cache_file is not None:
        cache_file.parent.mkdir(parents=True, exist_ok=True)
        cache_file.write_text(json.dumps(rec.to_dict(), indent=1))
        rec.path = str(cache_file)
    return rec


def expand_grid(base: ExperimentSpec, grid: dict) -> list[ExperimentSpec]:
    """Cartesian expansion of ``{dotted.field: [values...]}`` over ``base``.
    Each expansion is named ``base.name[k=v,...]`` for traceability."""
    if not grid:
        return [base]
    keys = sorted(grid)
    specs = []
    for combo in itertools.product(*(grid[k] for k in keys)):
        over = dict(zip(keys, combo))
        label = ",".join(f"{k.split('.')[-1]}={v}" for k, v in over.items())
        s = base.with_(**over)
        specs.append(s.with_(name=f"{base.name or 'sweep'}[{label}]"))
    return specs


def sweep(base: ExperimentSpec, grid: dict | None = None,
          cache_dir: str | Path | None = None, max_workers: int = 0,
          force: bool = False) -> list[RunRecord]:
    """Run every point of ``grid`` over ``base`` (see :func:`expand_grid`).

    Trials whose specs hash identically are executed once and the record is
    shared; ``max_workers > 1`` fans independent trials out over a thread
    pool (the simulation is numpy/JAX-bound, so threads overlap usefully).
    Results come back in grid order regardless of execution order.
    """
    specs = expand_grid(base, grid or {})
    unique: dict[str, ExperimentSpec] = {}
    for s in specs:
        unique.setdefault(s.spec_hash(), s)

    def _run(s: ExperimentSpec) -> RunRecord:
        return run_experiment(s, cache_dir=cache_dir, force=force)

    if max_workers and max_workers > 1 and len(unique) > 1:
        with ThreadPoolExecutor(max_workers=max_workers) as pool:
            done = dict(zip(unique, pool.map(_run, unique.values())))
    else:
        done = {h: _run(s) for h, s in unique.items()}

    out = []
    for s in specs:
        rec = done[s.spec_hash()]
        if rec.spec.name != s.name:      # shared record, caller's label wins
            rec = RunRecord(spec=s, result=rec.result,
                            spec_hash=rec.spec_hash, cached=rec.cached,
                            path=rec.path)
        out.append(rec)
    return out
