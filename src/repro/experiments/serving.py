"""Declarative serving trials (DESIGN.md §14): spec, cache, frontier.

The serving mirror of :mod:`repro.experiments.spec`/``runner``: a
:class:`ServingSpec` is a frozen, JSON-round-trippable description of one
serving trial (platform x fleet x arrival process x request shape x
autoscaler), hashed with the same default-elision scheme as
``ExperimentSpec`` and cached on disk as schema ``repro.serving/v1``
records (``experiments/runs/serve_<hash>.json``).

:func:`frontier` is the deliverable grid: FaaS vs IaaS vs pod across
arrival shapes, with provisioned fleets sized analytically for each shape's
peak (``provision_for``) — the inference-side Table 6.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass, field, fields
from pathlib import Path

from repro.core.platform import FleetSpec
from repro.core.runtimes import FaaSRuntime, IaaSRuntime, PodPlatform
from repro.experiments.spec import PLATFORMS, _apply_override
from repro.serving.arrivals import make_arrivals
from repro.serving.latency import LatencyModel
from repro.serving.sim import make_autoscaler, provision_for, serve

SERVE_SCHEMA = "repro.serving/v1"

#: hash salt, same contract as ``spec.HASH_SCHEMA``: defaults are elided
#: from the hash, so bump this whenever a ServingSpec default changes.
SERVE_HASH_SCHEMA = "s1"

DEFAULT_CACHE = Path(__file__).resolve().parents[3] / "experiments" / "runs"

#: the frontier's arrival shapes: trickle, sustained, flash crowd.  The
#: trickle sits below the FaaS/IaaS break-even (~0.01 qps: one always-on
#: t2.medium costs what ~36 cold-started Lambda requests/hour cost); the
#: flash is sharp, so a provisioned fleet sized for its peak idles >90% of
#: the run and even one always-on pod costs more than cold-starting every
#: spike request — the two regimes where scale-to-zero wins.
FRONTIER_ARRIVALS = ("poisson:0.005", "poisson:5", "flash:0.05,10,60,30")


@dataclass(frozen=True)
class ServingSpec:
    """One fully-determined serving trial.  ``name`` is a human label and
    does not enter the spec hash (same rule as ExperimentSpec)."""

    name: str = ""
    platform: str = "faas"                # faas | iaas | pod
    fleet: FleetSpec = field(default_factory=FleetSpec)
    arrival: str = "poisson:1"            # arrivals registry grammar
    model: str = "smollm_360m"            # a decode-capable zoo arch
    reduced: bool = False                 # serve the CPU-sized variant
    duration_s: float = 300.0
    prompt_len: int = 32
    new_tokens: int = 32
    window_s: float = 15.0
    scaling: str = "static"               # core.elastic grammar (smlt re-read
                                          # on serving signals)
    max_batch: int = 32
    prewarm: int = 0                      # FaaS warm-pool seed
    seed: int = 0
    platform_args: dict = field(default_factory=dict)   # pod tunables

    def __post_init__(self):
        if self.platform not in PLATFORMS:
            raise ValueError(f"platform must be one of {PLATFORMS}, "
                             f"got {self.platform!r}")
        if self.platform_args and self.platform != "pod":
            raise ValueError("platform_args only apply to platform='pod'")
        from repro.core.workloads import _arch_key
        if _arch_key(self.model) is None:
            raise ValueError(f"model {self.model!r} is not a zoo arch; "
                             f"serving needs a decode-capable architecture")
        if isinstance(self.fleet, dict):
            object.__setattr__(self, "fleet", FleetSpec(**self.fleet))
        if not isinstance(self.scaling, str):
            raise ValueError("ServingSpec.scaling must be a policy string "
                             "(specs are JSON-round-trippable)")
        make_autoscaler(self.scaling)     # reject bad grammar eagerly
        head = str(self.arrival).partition(":")[0]
        from repro.serving.arrivals import ARRIVALS
        if head not in ARRIVALS:
            raise ValueError(f"unknown arrival process {head!r}; known: "
                             f"{', '.join(sorted(ARRIVALS))}")

    # ---- serialization (same contract as ExperimentSpec) --------------------
    def to_dict(self) -> dict:
        return asdict(self)

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "ServingSpec":
        d = dict(d)
        known = {f.name for f in fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise KeyError(f"unknown ServingSpec fields {sorted(unknown)}; "
                           f"valid fields: {sorted(known)}")
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "ServingSpec":
        return cls.from_dict(json.loads(s))

    def spec_hash(self) -> str:
        """Content hash with name excluded and defaults elided -- see
        ``ExperimentSpec.spec_hash`` for the schema-evolution contract."""
        d = self.to_dict()
        d.pop("name")
        defaults = _serving_defaults()
        canon = {k: v for k, v in d.items() if v != defaults[k]}
        payload = SERVE_HASH_SCHEMA + json.dumps(canon, sort_keys=True,
                                                 separators=(",", ":"))
        return hashlib.sha256(payload.encode()).hexdigest()[:16]

    def with_(self, **overrides) -> "ServingSpec":
        out = self
        for key, value in overrides.items():
            out = _apply_override(out, key, value)
        return out

    # ---- builders -----------------------------------------------------------
    def build_platform(self):
        if self.platform == "faas":
            return FaaSRuntime(fleet=self.fleet, seed=self.seed)
        if self.platform == "pod":
            return PodPlatform(fleet=self.fleet, seed=self.seed,
                               **self.platform_args)
        return IaaSRuntime(fleet=self.fleet, seed=self.seed)

    def run(self):
        return serve(self.build_platform(), self.model, self.arrival,
                     duration_s=self.duration_s, prompt_len=self.prompt_len,
                     new_tokens=self.new_tokens, window_s=self.window_s,
                     scaling=self.scaling, max_batch=self.max_batch,
                     prewarm=self.prewarm, reduced=self.reduced,
                     seed=self.seed)


_SERVING_DEFAULTS: dict | None = None


def _serving_defaults() -> dict:
    global _SERVING_DEFAULTS
    if _SERVING_DEFAULTS is None:
        _SERVING_DEFAULTS = ServingSpec().to_dict()
    return _SERVING_DEFAULTS


# ------------------------------------------------------------------ runner --

@dataclass
class ServeRecord:
    """One executed (or cache-recalled) serving trial, spec included."""

    spec: ServingSpec
    result: dict
    spec_hash: str = ""
    schema: str = SERVE_SCHEMA
    cached: bool = False
    path: str = ""

    def __post_init__(self):
        if not self.spec_hash:
            self.spec_hash = self.spec.spec_hash()

    def to_dict(self) -> dict:
        return {"schema": self.schema, "name": self.spec.name,
                "spec_hash": self.spec_hash, "spec": self.spec.to_dict(),
                "result": self.result}

    @classmethod
    def from_dict(cls, d: dict, **kw) -> "ServeRecord":
        return cls(spec=ServingSpec.from_dict(d["spec"]), result=d["result"],
                   spec_hash=d["spec_hash"],
                   schema=d.get("schema", SERVE_SCHEMA), **kw)


def run_serving(spec: ServingSpec, cache_dir: str | Path | None = None,
                force: bool = False) -> ServeRecord:
    """Execute one serving spec (or recall it from ``cache_dir``); cache
    files are ``serve_<hash>.json`` so they sit next to training records
    without colliding."""
    cache_file = None
    if cache_dir is not None:
        cache_file = Path(cache_dir) / f"serve_{spec.spec_hash()}.json"
        if cache_file.exists() and not force:
            rec = ServeRecord.from_dict(json.loads(cache_file.read_text()),
                                        cached=True, path=str(cache_file))
            rec.spec = spec          # caller's label wins (hash ignores it)
            return rec

    rec = ServeRecord(spec=spec, result=spec.run().to_dict())
    if cache_file is not None:
        cache_file.parent.mkdir(parents=True, exist_ok=True)
        cache_file.write_text(json.dumps(rec.to_dict(), indent=1))
        rec.path = str(cache_file)
    return rec


# ---------------------------------------------------------------- frontier --

def _sized_spec(platform: str, arrival: str, model: str, duration_s: float,
                reduced: bool, seed: int) -> ServingSpec:
    """Provisioned platforms get an analytically-sized static fleet for the
    arrival's peak; FaaS gets a generous concurrency cap (it scales per
    request anyway — the cap only guards runaway fan-out)."""
    if platform == "faas":
        return ServingSpec(name=f"faas/{arrival}", platform="faas",
                           fleet=FleetSpec(workers=256, lambda_gb=3.0),
                           arrival=arrival, model=model, reduced=reduced,
                           duration_s=duration_s, seed=seed)
    probe = (IaaSRuntime(workers=1) if platform == "iaas"
             else PodPlatform(pods=1))
    hooks = probe.serving_hooks()
    lat = LatencyModel.from_arch(model, flops=hooks.flops,
                                 mem_bandwidth=hooks.mem_bandwidth,
                                 reduced=reduced)
    w = provision_for(arrival, lat, hooks)
    return ServingSpec(name=f"{platform}/{arrival}", platform=platform,
                       fleet=FleetSpec(workers=w), arrival=arrival,
                       model=model, reduced=reduced, duration_s=duration_s,
                       seed=seed)


def frontier(arrivals=FRONTIER_ARRIVALS, model: str = "smollm_360m",
             duration_s: float = 300.0, reduced: bool = False, seed: int = 0,
             cache_dir: str | Path | None = None,
             force: bool = False) -> list:
    """The cost-vs-p99 frontier: every platform against every arrival shape.
    FaaS wins the trickle/bursty cells on $ (scale-to-zero); provisioned
    fleets win sustained throughput on both $ and p99 — the paper's training
    verdict, inverted per request shape."""
    recs = []
    for arrival in arrivals:
        for platform in ("faas", "iaas", "pod"):
            spec = _sized_spec(platform, arrival, model, duration_s,
                               reduced, seed)
            recs.append(run_serving(spec, cache_dir=cache_dir, force=force))
    return recs
