"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

``input_specs`` mirrors the shannon/kernels pattern: weak-type-correct,
shardable, and allocation-free -- the dry-run lowers against these.
``make_batch`` produces small *concrete* batches for smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig
from repro.models import build_model


def _train_like_specs(arch: ArchConfig, batch: int, seq: int) -> dict:
    m = arch.model
    i32 = jnp.int32
    if m.is_encoder:
        return {
            "frames": jax.ShapeDtypeStruct((batch, seq, m.d_model), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32),
            "mask": jax.ShapeDtypeStruct((batch, seq), jnp.bool_),
        }
    out = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
        "labels": jax.ShapeDtypeStruct((batch, seq), i32),
    }
    if m.family == "vlm":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, m.num_image_tokens, m.d_model), jnp.bfloat16)
    return out


def input_specs(arch: ArchConfig, shape: ShapeConfig | str) -> dict:
    """Inputs for the step that this shape lowers (train/prefill -> batch;
    decode -> token + pos + cache)."""
    sh = SHAPES[shape] if isinstance(shape, str) else shape
    m = arch.model
    if sh.kind in ("train", "prefill"):
        return {"batch": _train_like_specs(arch, sh.global_batch, sh.seq_len)}
    # decode: one new token against a cache of seq_len
    model = build_model(arch)
    cache = model.init_cache(sh.global_batch, sh.seq_len, abstract=True)
    return {
        "cache": cache,
        "token": jax.ShapeDtypeStruct((sh.global_batch,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def make_batch(arch: ArchConfig, batch: int, seq: int, seed: int = 0) -> dict:
    """Concrete random batch (smoke tests)."""
    m = arch.model
    rng = np.random.default_rng(seed)
    if m.is_encoder:
        return {
            "frames": jnp.asarray(
                rng.standard_normal((batch, seq, m.d_model)), jnp.bfloat16),
            "labels": jnp.asarray(
                rng.integers(0, m.vocab_size, (batch, seq)), jnp.int32),
            "mask": jnp.asarray(rng.random((batch, seq)) < 0.5),
        }
    out = {
        "tokens": jnp.asarray(
            rng.integers(0, m.vocab_size, (batch, seq)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, m.vocab_size, (batch, seq)), jnp.int32),
    }
    if m.family == "vlm":
        out["image_embeds"] = jnp.asarray(
            rng.standard_normal((batch, m.num_image_tokens, m.d_model)),
            jnp.bfloat16)
    return out
