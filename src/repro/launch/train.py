"""Distributed training launcher: compose mesh + steps + data + checkpoints.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
        --steps 50 --batch 8 --seq 128 --mesh 1x1

On real hardware, run without --mesh to get the production 16x16 pod (or
--multi-pod for 2x16x16 with --algorithm diloco for the cross-pod-efficient
MA-SGD path).  Fault tolerance: deadline-aware checkpointing via
PreemptionGuard; rerun the same command to resume (elastic: change
--data-workers between runs).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_arch, get_reduced
from repro.configs.base import ShapeConfig
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.launch.specs import make_batch


def _mesh_from_arg(arg: str | None, multi_pod: bool):
    if arg:
        dims = tuple(int(x) for x in arg.split("x"))
        names = (("data", "model") if len(dims) == 2
                 else ("pod", "data", "model"))
        return make_mesh(dims, names)
    return make_production_mesh(multi_pod=multi_pod)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=None,
                    help="global batch (default: arch shape train_4k)")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--mesh", default=None, help="e.g. 1x1, 2x4, 2x2x2")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--algorithm", default=None,
                    choices=[None, "ga_sgd", "ma_sgd", "diloco"])
    ap.add_argument("--sync-period", type=int, default=None)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lifetime", type=float, default=900.0)
    ap.add_argument("--data-workers", type=int, default=1)
    ap.add_argument("--data-worker", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    arch = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    tc = arch.train
    if args.algorithm:
        tc = dataclasses.replace(tc, algorithm=args.algorithm)
    if args.sync_period:
        tc = dataclasses.replace(tc, sync_period=args.sync_period)
    if args.compress:
        tc = dataclasses.replace(tc, compress_cross_pod=True)
    # micro-batching needs batch % micro == 0 on arbitrary CLI batches
    if args.batch and args.batch % max(tc.micro_batches, 1) != 0:
        tc = dataclasses.replace(tc, micro_batches=1)
    arch = arch.replace(train=tc)

    mesh = _mesh_from_arg(args.mesh, args.multi_pod)
    batch_size = args.batch or 8
    seq = args.seq or 128
    shape = ShapeConfig("cli", seq, batch_size, "train")
    local_sgd = (tc.algorithm in ("ma_sgd", "diloco")
                 and "pod" in mesh.axis_names)

    from repro.models import build_model
    from repro.optim import make_optimizer
    model = build_model(arch)
    opt = make_optimizer(tc)
    stream = TokenStream(arch.model.vocab_size, seed=0,
                         worker=args.data_worker,
                         num_workers=args.data_workers)

    print(f"arch={arch.name} ({model.param_count():,} params) "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"algo={tc.algorithm} local_sgd={local_sgd}")

    with mesh:
        if local_sgd:
            from repro.distributed.local_sgd import build_local_sgd
            ls = build_local_sgd(arch, mesh, shape)
            P = ls.n_pods
            params = model.init(jax.random.key(0))
            params_st = jax.tree.map(lambda x: jnp.stack([x] * P), params)
            opt_st = jax.tree.map(lambda x: jnp.stack([x] * P),
                                  opt.init(params))
            outer = ls.init_outer_fn(params_st)
        else:
            from repro.distributed.step import build_train_step
            from repro.launch.specs import input_specs
            specs = {
                k: jax.ShapeDtypeStruct((batch_size,) + v.shape[1:], v.dtype)
                for k, v in input_specs(arch, ShapeConfig(
                    "x", seq, batch_size, "train"))["batch"].items()}
            step = build_train_step(arch, mesh, shape, batch_specs=specs)
            params = model.init(jax.random.key(0))
            opt_state = opt.init(params)

        # resume
        step0 = 0
        if args.ckpt_dir:
            restored, meta = ckpt.load_latest(args.ckpt_dir)
            if restored is not None:
                step0 = int(meta["step"])
                stream.restore(meta["stream"], args.data_worker,
                               args.data_workers)
                if local_sgd:
                    params_st = jax.tree.map(jnp.asarray, restored["params"])
                    opt_st = jax.tree.map(jnp.asarray, restored["opt"])
                else:
                    params = jax.tree.map(jnp.asarray, restored["params"])
                    opt_state = jax.tree.map(jnp.asarray, restored["opt"])
                print(f"resumed from step {step0}")

        guard = ckpt.PreemptionGuard(lifetime_s=args.lifetime)
        t0 = time.time()
        loss = float("nan")
        for it in range(step0, args.steps):
            b = jax.tree.map(jnp.asarray, stream.batch(batch_size, seq))
            ts = time.time()
            if local_sgd:
                params_st, opt_st, m = ls.inner_fn(params_st, opt_st, b)
                loss = float(np.asarray(m["loss"]).mean())
                if (it + 1) % ls.sync_period == 0:
                    params_st, outer = ls.outer_fn(params_st, outer)
            else:
                params, opt_state, m = step.fn(params, opt_state, b)
                loss = float(m["loss"])
            guard.record_step(time.time() - ts)
            if it % args.log_every == 0 or it == args.steps - 1:
                print(f"step {it:5d}  loss {loss:.4f}  "
                      f"{time.time() - t0:6.1f}s")
            if args.ckpt_dir and ((it and it % args.ckpt_every == 0)
                                  or guard.should_checkpoint()):
                tree = ({"params": params_st, "opt": opt_st} if local_sgd
                        else {"params": params, "opt": opt_state})
                ckpt.save(args.ckpt_dir, it + 1, tree,
                          {"stream": stream.state()})
                ckpt.retain(args.ckpt_dir, keep=2)
                if guard.should_checkpoint():
                    print(f"step {it}: lifetime deadline -- checkpointed; "
                          "re-invoke to resume")
                    guard.renew()
        if args.ckpt_dir:
            tree = ({"params": params_st, "opt": opt_st} if local_sgd
                    else {"params": params, "opt": opt_state})
            ckpt.save(args.ckpt_dir, args.steps, tree,
                      {"stream": stream.state()})
        print(f"done: step {args.steps}, loss {loss:.4f}")


if __name__ == "__main__":
    main()
