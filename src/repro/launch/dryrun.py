import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (``python -m repro.launch.dryrun``): the first
two lines force 512 host placeholder devices BEFORE any jax import -- jax
locks the device count on first init.  Tests override the count via
REPRO_XLA_FLAGS.

For each cell we record: memory_analysis (proves it fits), cost_analysis
(FLOPs/bytes for the roofline), and the collective-bytes breakdown parsed
from the partitioned HLO.  Results land in experiments/dryrun/*.json.
"""
import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402
from pathlib import Path  # noqa: E402

import jax            # noqa: E402

from repro.configs import SHAPES, get_arch, get_reduced, list_archs  # noqa: E402
from repro.distributed import roofline as rl                         # noqa: E402
from repro.distributed.step import build_step                        # noqa: E402
from repro.launch.mesh import make_mesh, make_production_mesh        # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _mem_dict(mem) -> dict:
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v() if callable(v) else v)
    return out


def run_cell(arch_name: str, shape_name: str, mesh, mesh_desc: str,
             *, reduced: bool = False, save: bool = True) -> dict:
    arch = get_reduced(arch_name) if reduced else get_arch(arch_name)
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_desc,
           "reduced": reduced, "ok": False}
    t0 = time.time()
    try:
        if shape_name not in arch.shapes():
            rec["skipped"] = True
            rec["reason"] = ("encoder has no decode" if arch.model.is_encoder
                            else "full attention cannot run 500k context")
            rec["ok"] = True
            return rec
        step = build_step(arch, mesh, shape_name)
        with mesh:
            lowered = step.lower()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
        chips = mesh.devices.size
        total, active = rl.active_params(arch)
        mflops = rl.model_flops(arch, shape_name, total, active)
        rep = rl.analyze(compiled, hlo, arch_name=arch_name, shape=shape_name,
                         mesh_desc=mesh_desc, chips=chips, mflops=mflops,
                         extra={"t_lower_s": round(t_lower, 2),
                                "t_compile_s": round(t_compile, 2),
                                "params_total": total, "params_active": active})
        rec.update(rep.to_dict())
        rec["memory_analysis"] = _mem_dict(mem)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    finally:
        rec["t_total_s"] = round(time.time() - t0, 2)
    if save:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        tag = "reduced-" if reduced else ""
        p = OUT_DIR / f"{tag}{arch_name}__{shape_name}__{mesh_desc}.json"
        p.write_text(json.dumps(rec, indent=1, default=float))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--pods", default="both", choices=["1", "2", "both"])
    ap.add_argument("--mesh", default=None,
                    help="override mesh, e.g. '2x4' or '2x2x2' (test use)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = []
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        meshes.append((make_mesh(dims, names), args.mesh))
    else:
        if args.pods in ("1", "both"):
            meshes.append((make_production_mesh(), "16x16"))
        if args.pods in ("2", "both"):
            meshes.append((make_production_mesh(multi_pod=True), "2x16x16"))

    n_fail = 0
    for mesh, desc in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, mesh, desc, reduced=args.reduced,
                               save=not args.no_save)
                if rec.get("skipped"):
                    status = "SKIP " + rec["reason"]
                elif rec["ok"]:
                    status = (f"ok  comp={rec['t_compute_s']:.3e}s "
                              f"mem={rec['t_memory_s']:.3e}s "
                              f"coll={rec['t_collective_s']:.3e}s "
                              f"bound={rec['bottleneck']} "
                              f"frac={rec['roofline_fraction']:.3f}")
                else:
                    n_fail += 1
                    status = "FAIL " + rec.get("error", "?")
                print(f"[{desc}] {a} x {s}: {status}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
