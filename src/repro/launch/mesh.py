"""Production mesh builders.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  The single-pod production mesh is 16x16 = 256
chips over ("data","model"); multi-pod prepends a "pod" axis (2x16x16 = 512
chips).  The dry-run launcher forces 512 host devices via XLA_FLAGS before
any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small ones, e.g. (2,4) on 8 host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_local_mesh():
    """1x1 mesh on whatever single device exists (CPU smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
