"""Serving launcher: batched generation against any zoo arch.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
        --batch 4 --prompt-len 16 --new-tokens 32

Uses the same decode_step the dry-run's decode_32k/long_500k cells lower;
on hardware, pass --mesh/--multi-pod like the train launcher.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, get_reduced
from repro.models import build_model
from repro.serving import Generator, perplexity


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--requests", type=int, default=2,
                    help="number of batched requests to serve")
    args = ap.parse_args()

    arch = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    arch = arch.replace(model=arch.model.replace(dtype="float32"))
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    gen = Generator(arch, params,
                    max_seq=args.prompt_len + args.new_tokens + 1)
    rng = np.random.default_rng(0)
    total_tok, total_t = 0, 0.0
    for r in range(args.requests):
        prompts = rng.integers(0, arch.model.vocab_size,
                               (args.batch, args.prompt_len)).astype(np.int32)
        t0 = time.time()
        out = gen.generate(prompts, max_new_tokens=args.new_tokens,
                           temperature=args.temperature, seed=r)
        dt = time.time() - t0
        total_tok += args.batch * args.new_tokens
        total_t += dt
        print(f"request {r}: {args.batch}x{args.new_tokens} tokens in "
              f"{dt:.2f}s  ppl={perplexity(model, params, out):.1f}")
    print(f"served {total_tok} tokens @ {total_tok / total_t:.1f} tok/s")


if __name__ == "__main__":
    main()
