"""Data pipeline: paper-study synthetic datasets + LM token streams."""
from repro.data.synthetic import (  # noqa: F401
    DATASETS, Dataset, make_dataset, partition,
)
from repro.data.tokens import TokenStream, lm_batches  # noqa: F401
