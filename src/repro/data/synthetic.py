"""Synthetic stand-ins for the paper's datasets (paper-exact dimensionality,
scaled row counts so the full study runs on CPU; row counts configurable).

| paper dataset | dims      | here (default rows) | label model            |
|---------------|-----------|---------------------|------------------------|
| Higgs         | 28        | 100k (of 11M)       | logistic teacher + noise |
| RCV1          | 47,236 sparse | 20k, nnz=64     | sparse logistic teacher  |
| Cifar10       | 3,072     | 20k                 | 10-class linear teacher  |
| YFCC100M     | 4,096     | 20k (of 4M sample)  | binary, 7.5% positive    |
| Criteo        | 1M sparse | 10k, nnz=39         | sparse logistic teacher  |

Sparse datasets are (indices, values) pairs with fixed nnz per row -- models
consume them with gather-style dot products, which is also how LambdaML's
sparse LR worked.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class Dataset:
    name: str
    x: np.ndarray                     # (n, d) dense OR (n, nnz) values
    y: np.ndarray                     # (n,) float {-1,+1} or int class
    idx: Optional[np.ndarray] = None  # (n, nnz) int32 for sparse
    dim: int = 0                      # full feature dim (sparse)
    n_classes: int = 2

    @property
    def n(self) -> int:
        return self.x.shape[0]

    @property
    def d(self) -> int:
        return self.dim or self.x.shape[1]

    @property
    def sparse(self) -> bool:
        return self.idx is not None

    @property
    def nbytes(self) -> int:
        return self.x.nbytes + self.y.nbytes + (self.idx.nbytes if self.sparse else 0)


def _teacher_labels(rng, z):
    p = 1.0 / (1.0 + np.exp(-z))
    return np.where(rng.random(z.shape) < p, 1.0, -1.0).astype(np.float32)


def make_dataset(name: str, rows: int | None = None, seed: int = 0) -> Dataset:
    rng = np.random.default_rng(seed)
    if name == "higgs":
        n = rows or 100_000
        x = rng.standard_normal((n, 28)).astype(np.float32)
        w = rng.standard_normal(28).astype(np.float32)
        return Dataset("higgs", x, _teacher_labels(rng, 1.5 * x @ w), n_classes=2)
    if name == "rcv1":
        n = rows or 20_000
        d, nnz = 47_236, 64
        idx = rng.integers(0, d, (n, nnz)).astype(np.int32)
        val = np.abs(rng.standard_normal((n, nnz))).astype(np.float32)
        val /= np.linalg.norm(val, axis=1, keepdims=True)  # TF-IDF-normalized
        w = rng.standard_normal(d).astype(np.float32)
        z = (val * w[idx]).sum(1)
        return Dataset("rcv1", val, _teacher_labels(rng, 4.0 * z), idx=idx, dim=d)
    if name == "cifar10":
        n = rows or 20_000
        x = rng.standard_normal((n, 3072)).astype(np.float32)
        w = rng.standard_normal((3072, 10)).astype(np.float32) / 50.0
        y = np.argmax(x @ w + rng.standard_normal((n, 10)), axis=1)
        return Dataset("cifar10", x, y.astype(np.int32), n_classes=10)
    if name == "yfcc100m":
        n = rows or 20_000
        x = rng.standard_normal((n, 4096)).astype(np.float32)
        w = rng.standard_normal(4096).astype(np.float32)
        z = x @ w / 64.0 - 2.5  # ~7.5% positives, like the 'animal' tag
        return Dataset("yfcc100m", x, _teacher_labels(rng, z), n_classes=2)
    if name == "criteo":
        n = rows or 10_000
        d, nnz = 1_000_000, 39
        idx = rng.integers(0, d, (n, nnz)).astype(np.int32)
        val = np.ones((n, nnz), np.float32)
        w = (rng.standard_normal(d) / 6.0).astype(np.float32)
        z = (val * w[idx]).sum(1)
        return Dataset("criteo", val, _teacher_labels(rng, z), idx=idx, dim=d)
    raise KeyError(name)


DATASETS = ("higgs", "rcv1", "cifar10", "yfcc100m", "criteo")


def partition(ds: Dataset, w: int) -> list[Dataset]:
    """Even row partition over w workers (paper: data parallelism)."""
    out = []
    bounds = np.linspace(0, ds.n, w + 1, dtype=int)
    for i in range(w):
        s = slice(bounds[i], bounds[i + 1])
        out.append(Dataset(ds.name, ds.x[s], ds.y[s],
                           None if ds.idx is None else ds.idx[s],
                           ds.dim, ds.n_classes))
    return out


def train_val_split(ds: Dataset, val_frac: float = 0.1, seed: int = 1):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(ds.n)
    nv = int(ds.n * val_frac)
    vi, ti = perm[:nv], perm[nv:]

    def take(sel):
        return Dataset(ds.name, ds.x[sel], ds.y[sel],
                       None if ds.idx is None else ds.idx[sel],
                       ds.dim, ds.n_classes)
    return take(ti), take(vi)
