"""LM token pipeline: deterministic synthetic corpus + sharded batch iterator.

The corpus is a Zipf-distributed token stream with short-range structure (a
bigram mixture), enough for a real next-token-loss signal on CPU-scale runs.
The loader is elastic: ``TokenStream(worker, num_workers)`` re-shards
deterministically when the worker count changes (checkpoint/elastic-resume
carries only ``position``).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TokenStream:
    vocab_size: int
    seed: int = 0
    worker: int = 0
    num_workers: int = 1
    position: int = 0  # global sample counter (for elastic resume)

    def _sample_doc(self, rng: np.random.Generator, length: int) -> np.ndarray:
        v = self.vocab_size
        base = rng.zipf(1.3, size=length).astype(np.int64) % v
        # bigram structure: with p=0.5 the next token is a fixed hash of prev
        follow = (base * 2654435761 + 12345) % v
        coin = rng.random(length) < 0.5
        out = np.where(coin, np.roll(follow, 1), base)
        return out.astype(np.int32)

    def batch(self, batch_size: int, seq_len: int) -> dict:
        """Deterministic batch for (position, worker); advances position."""
        tokens = np.empty((batch_size, seq_len + 1), np.int32)
        for i in range(batch_size):
            gidx = self.position + i * self.num_workers + self.worker
            rng = np.random.default_rng((self.seed, gidx))
            tokens[i] = self._sample_doc(rng, seq_len + 1)
        self.position += batch_size * self.num_workers
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def state(self) -> dict:
        return {"position": self.position, "seed": self.seed}

    def restore(self, state: dict, worker: int, num_workers: int):
        self.position = int(state["position"])
        self.seed = int(state["seed"])
        self.worker = worker
        self.num_workers = num_workers


def lm_batches(vocab_size: int, batch_size: int, seq_len: int, steps: int,
               seed: int = 0):
    ts = TokenStream(vocab_size, seed)
    for _ in range(steps):
        yield ts.batch(batch_size, seq_len)
