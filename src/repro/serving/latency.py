"""Analytic per-step serving latency from zoo arch dims + platform constants.

One ``LatencyModel`` is shared by the discrete-event simulator (`sim.py`)
and the real ``Generator`` instrumentation, so there is exactly one place
where "how long does a decode step take" is written down — the same
"two implementations of one cost" discipline the training engine follows.

The model is the standard decode roofline: a step over a batch of B
requests costs

    step_s(B) = max( B * 2 * n_params / flops,        # compute-bound
                     model_bytes / mem_bandwidth )    # weight-streaming floor

and a request of (prompt_len, new_tokens) runs ``prompt_len + new_tokens``
decode steps — exactly the loop ``Generator._prefill_loop`` + ``generate``
executes, which is what the parity test pins.

KV-cache footprint (the continuous-batching packing constraint) comes from
the config dims: per-token K+V bytes for attention families, the MLA latent
for DeepSeek, and a constant per-request SSM state for mamba-style archs.
"""
from __future__ import annotations

from dataclasses import dataclass

__all__ = ["LatencyModel"]

_DTYPE_BYTES = {"bfloat16": 2, "float16": 2, "float32": 4}


@dataclass(frozen=True)
class LatencyModel:
    arch: str                 # spec-friendly name, e.g. "smollm_360m"
    n_params: int
    flops: float              # replica FLOP/s (platform serving hook)
    mem_bandwidth: float      # replica bytes/s   (platform serving hook)
    kv_bytes_token: int       # per token, across all layers
    kv_bytes_const: int = 0   # per request (SSM/conv state)
    param_bytes: int = 2      # serving dtype width

    # ------------------------------------------------------------- sizing --
    @property
    def model_bytes(self) -> int:
        return self.n_params * self.param_bytes

    def kv_bytes(self, tokens: int) -> int:
        """Cache bytes one request holds after ``tokens`` positions."""
        return self.kv_bytes_const + self.kv_bytes_token * tokens

    # ------------------------------------------------------------- timing --
    def step_s(self, batch: int = 1) -> float:
        """One decode step over a batch: compute vs weight-streaming roofline."""
        if batch < 1:
            raise ValueError("batch must be >= 1")
        compute = batch * 2.0 * self.n_params / self.flops
        streaming = self.model_bytes / self.mem_bandwidth
        return max(compute, streaming)

    def request_steps(self, prompt_len: int, new_tokens: int) -> int:
        """Decode-step count for one request — mirrors Generator's loop
        (token-by-token prefill + new_tokens decode steps)."""
        return prompt_len + new_tokens

    def service_s(self, prompt_len: int, new_tokens: int,
                  batch: int = 1) -> float:
        return self.request_steps(prompt_len, new_tokens) * self.step_s(batch)

    # -------------------------------------------------------- construction --
    @classmethod
    def from_arch(cls, name: str, *, flops: float, mem_bandwidth: float,
                  reduced: bool = False) -> "LatencyModel":
        """Build from a zoo arch (accepts ``smollm_360m`` or ``smollm-360m``)."""
        from repro.configs import get_arch, get_reduced
        from repro.core.workloads import _arch_key
        from repro.models import build_model

        arch_id = _arch_key(name) or name
        arch = get_reduced(arch_id) if reduced else get_arch(arch_id)
        m = arch.model
        if not m.supports_decode:
            raise ValueError(f"{name!r} is encoder-only; it cannot serve decode")
        dtype_b = _DTYPE_BYTES.get(m.dtype, 2)

        per_token, const = 0, 0
        if m.family == "ssm":
            const = m.num_layers * (m.d_inner * (m.ssm_state + m.conv_width)) * dtype_b
        else:
            attn_layers = m.num_layers
            if m.family == "hybrid" and m.attn_every:
                attn_layers = m.num_layers // m.attn_every
                const = m.num_layers * (m.d_inner * (m.ssm_state + m.conv_width)) * dtype_b
            if m.use_mla:
                per_layer = m.kv_lora_rank + m.qk_rope_head_dim
            else:
                per_layer = 2 * m.kv_heads * m.hdim
            per_token = attn_layers * per_layer * dtype_b

        return cls(arch=name.replace("-", "_").replace(".", "_"),
                   n_params=int(build_model(arch).param_count()),
                   flops=float(flops), mem_bandwidth=float(mem_bandwidth),
                   kv_bytes_token=per_token, kv_bytes_const=const,
                   param_bytes=dtype_b)
