"""Batched serving: prefill + decode loop against the model zoo's cache API.

``Generator`` serves a batch of prompts: one prefill (cache capture for the
dense family; token-by-token warm-up fallback otherwise) followed by greedy
or temperature sampling through ``decode_step``.  The same ``serve_step`` is
what the decode_32k / long_500k dry-run shapes lower, so everything here
runs identically under `jit` on the production mesh.

Traffic-scale serving lives next door (DESIGN.md §14): open-loop arrival
processes in :mod:`repro.serving.arrivals`, the analytic per-step
:class:`~repro.serving.latency.LatencyModel`, and the request-driven
discrete-event simulator in :mod:`repro.serving.sim`.  ``Generator`` counts
its ``decode_step`` calls so the parity suite can pin the simulator's
timing byte-identically to this real path (``simulated_latency_s``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import Model, build_model
from repro.serving.arrivals import (  # noqa: F401
    ARRIVALS, ArrivalProcess, list_arrivals, make_arrivals,
)
from repro.serving.latency import LatencyModel  # noqa: F401
from repro.serving.sim import (  # noqa: F401
    ServingResult, ServingSMLT, make_autoscaler, provision_for, serve,
)


@dataclass
class Generator:
    arch: ArchConfig
    params: object
    max_seq: int = 512

    def __post_init__(self):
        self.model: Model = build_model(self.arch)
        assert self.model.cfg.supports_decode, "encoder models cannot decode"
        self._decode_fn = jax.jit(self.model.decode_step)
        self.decode_steps = 0     # calls to decode_step (parity with sim)

    def _decode(self, *args):
        self.decode_steps += 1
        return self._decode_fn(*args)

    def simulated_latency_s(self, lat: LatencyModel) -> float:
        """Simulated seconds for the decode steps this Generator actually
        executed, under ``lat``'s per-step roofline -- the bridge the parity
        test pins against :func:`repro.serving.sim.serve`."""
        return self.decode_steps * lat.step_s(1)

    def _prefill_loop(self, tokens: np.ndarray):
        """Generic prefill: feed prompt tokens through decode_step."""
        b, s = tokens.shape
        cache = self.model.init_cache(b, self.max_seq)
        logits = None
        for pos in range(s):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(tokens[:, pos]),
                                         jnp.int32(pos))
        return logits, cache, s

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """prompts (b, s) int32 -> (b, s + max_new_tokens)."""
        prompts = np.asarray(prompts, np.int32)
        b, s = prompts.shape
        assert s + max_new_tokens <= self.max_seq
        logits, cache, pos = self._prefill_loop(prompts)
        out = [prompts]
        key = jax.random.key(seed)
        tok = None
        for i in range(max_new_tokens):
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                tok = jnp.argmax(logits, axis=-1)
            out.append(np.asarray(tok, np.int32)[:, None])
            logits, cache = self._decode(self.params, cache,
                                         tok.astype(jnp.int32),
                                         jnp.int32(pos + i))
        return np.concatenate(out, axis=1)


def perplexity(model: Model, params, tokens: np.ndarray) -> float:
    """Teacher-forced ppl via the training forward (consistency checks)."""
    batch = {"tokens": jnp.asarray(tokens[:, :-1]),
             "labels": jnp.asarray(tokens[:, 1:])}
    loss, _ = model.loss(params, batch)
    return float(jnp.exp(loss))
