"""Batched serving: prefill + decode loop against the model zoo's cache API.

``Generator`` serves a batch of prompts: one prefill (cache capture for the
dense family; token-by-token warm-up fallback otherwise) followed by greedy
or temperature sampling through ``decode_step``.  The same ``serve_step`` is
what the decode_32k / long_500k dry-run shapes lower, so everything here
runs identically under `jit` on the production mesh.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import Model, build_model


@dataclass
class Generator:
    arch: ArchConfig
    params: object
    max_seq: int = 512

    def __post_init__(self):
        self.model: Model = build_model(self.arch)
        assert self.model.cfg.supports_decode, "encoder models cannot decode"
        self._decode = jax.jit(self.model.decode_step)

    def _prefill_loop(self, tokens: np.ndarray):
        """Generic prefill: feed prompt tokens through decode_step."""
        b, s = tokens.shape
        cache = self.model.init_cache(b, self.max_seq)
        logits = None
        for pos in range(s):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(tokens[:, pos]),
                                         jnp.int32(pos))
        return logits, cache, s

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """prompts (b, s) int32 -> (b, s + max_new_tokens)."""
        prompts = np.asarray(prompts, np.int32)
        b, s = prompts.shape
        assert s + max_new_tokens <= self.max_seq
        logits, cache, pos = self._prefill_loop(prompts)
        out = [prompts]
        key = jax.random.key(seed)
        tok = None
        for i in range(max_new_tokens):
            if temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                tok = jnp.argmax(logits, axis=-1)
            out.append(np.asarray(tok, np.int32)[:, None])
            logits, cache = self._decode(self.params, cache,
                                         tok.astype(jnp.int32),
                                         jnp.int32(pos + i))
        return np.concatenate(out, axis=1)


def perplexity(model: Model, params, tokens: np.ndarray) -> float:
    """Teacher-forced ppl via the training forward (consistency checks)."""
    batch = {"tokens": jnp.asarray(tokens[:, :-1]),
             "labels": jnp.asarray(tokens[:, 1:])}
    loss, _ = model.loss(params, batch)
    return float(jnp.exp(loss))
