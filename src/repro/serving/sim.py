"""Request-driven serving simulator: open-loop traffic against metered fleets.

The inference-side mirror of :mod:`repro.core.engine` (DESIGN.md §14): a
discrete-event loop on the same clock/metering discipline — simulated
seconds and dollars are derived from the same measured constants the
training engine bills against; nothing here touches a wall clock.

Two money models, selected by the platform's :class:`ServingHooks`:

- ``"request"`` (FaaS): one Lambda per in-flight request.  A request that
  finds no warm sandbox pays the measured invoke curve **plus** pulling the
  weights from S3; finished sandboxes stay warm for ``keep_warm_s``.  The
  bill is Σ per-request ``gb × billed_s × $/GB-s + invocation fee`` — and
  scale-to-zero is structural: zero traffic costs exactly $0.
- ``"provisioned"`` (IaaS / pods): hourly-billed replicas that run a
  continuously-batched decode loop — at every step boundary, waiting
  requests are packed into the batch as long as reserved KV-cache bytes fit
  the replica's memory budget.  The bill is Σ replica (provision→retire)
  spans × hourly; an idle fleet costs exactly its idle floor.

Both loops observe per-window :class:`~repro.core.elastic.ServingTelemetry`
and hand it to an autoscaler from the ``core.elastic`` policy registry
(``schedule:`` and ``cost_cap:`` work unchanged; ``smlt`` is re-read on
queue depth + utilization via :class:`ServingSMLT`).  Scale-ups pay the same
Table 6 provisioning curves as elastic training; scale-downs drain.

Latency/service times come from one shared :class:`LatencyModel`, which the
parity test pins byte-identically to the real ``Generator`` decode loop.
"""
from __future__ import annotations

import heapq
import math
from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core.elastic import MAX_FLEET, SMLTPolicy, StaticPolicy, make_policy
from repro.core.elastic.telemetry import ServingTelemetry
from repro.core.trace import TraceRecorder
from repro.serving.arrivals import ArrivalProcess, make_arrivals
from repro.serving.latency import LatencyModel

__all__ = ["ServingResult", "ServingSMLT", "make_autoscaler", "serve",
           "provision_for"]


# ------------------------------------------------------------ autoscaler ----

class ServingSMLT:
    """The SMLT widen/hold/narrow loop re-read on serving signals.

    Training SMLT sheds workers when the marginal loss drop stops paying for
    them; serving has no loss, so the "is the fleet earning its keep" signal
    becomes load: widen while requests queue or the fleet runs hot, narrow
    once it idles.  Same ×/÷ ``factor`` geometry as the training policy.
    """

    name = "smlt"

    def __init__(self, factor: int = 2, util_hi: float = 0.85,
                 util_lo: float = 0.30, cooldown_s: float = 120.0):
        if int(factor) < 2:
            raise ValueError(f"smlt step factor must be >= 2, got {factor}")
        self.factor = int(factor)
        self.util_hi = float(util_hi)
        self.util_lo = float(util_lo)
        # ordered capacity takes a Table 6 provisioning curve to come online;
        # widening again before then just re-reacts to the same backlog
        self.cooldown_s = float(cooldown_s)
        self._last_widen: float | None = None

    def initial_workers(self, w0: int) -> int:
        return w0

    def observe(self, t: ServingTelemetry) -> int:
        if t.queue_depth > 0 or t.utilization >= self.util_hi:
            if (self._last_widen is not None
                    and t.sim_time - self._last_widen < self.cooldown_s):
                return t.workers
            self._last_widen = t.sim_time
            return min(t.workers * self.factor, t.max_workers)
        if t.utilization <= self.util_lo:
            return max(t.workers // self.factor, t.min_workers)
        return t.workers


def make_autoscaler(spec):
    """Resolve a ``scaling`` spec against the ``core.elastic`` registry.

    ``static`` (or None) means no autoscaler; ``smlt[:<factor>]`` maps to
    :class:`ServingSMLT`; every other grammar entry (``schedule:…``,
    ``cost_cap:…``) is the training policy unchanged — their ``observe``
    only reads fields :class:`ServingTelemetry` provides.
    """
    if spec is None:
        return None
    if isinstance(spec, str):
        head, _, arg = spec.partition(":")
        if head == "static":
            return None
        if head == "smlt":
            return ServingSMLT(int(arg)) if arg else ServingSMLT()
        if head == "plan":
            raise ValueError("scaling='plan' is the training-side planner; "
                             "size a serving fleet with provision_for()")
        return make_policy(spec)
    if isinstance(spec, SMLTPolicy):
        return ServingSMLT(spec.factor)
    if isinstance(spec, StaticPolicy):
        return None
    return spec


def provision_for(arrivals, lat: LatencyModel, hooks, *,
                  prompt_len: int = 32, new_tokens: int = 32,
                  max_batch: int = 32, util_target: float = 0.8) -> int:
    """Analytic fleet sizing: replicas needed to carry the arrival peak at
    ``util_target`` utilization with continuous batching at the best
    feasible batch.  The serving mirror of ``plan_initial_workers``."""
    arrivals = make_arrivals(arrivals)
    kv_req = lat.kv_bytes(prompt_len + new_tokens)
    kv_budget = hooks.memory_bytes - lat.model_bytes
    b = max(1, min(max_batch, int(kv_budget // kv_req) if kv_req else max_batch))
    per_replica_qps = b / (lat.step_s(b) * lat.request_steps(prompt_len,
                                                             new_tokens))
    return max(1, math.ceil(arrivals.peak_qps / (per_replica_qps
                                                 * util_target)))


# --------------------------------------------------------------- result -----

@dataclass
class ServingResult:
    """Everything a serving run produced, with the bill decomposed so every
    dollar is recomputable from the parts (property-tested)."""

    system: str
    arrival: str
    duration_s: float
    workers0: int
    requests: int = 0            # arrivals seen
    completed: int = 0
    rejected: int = 0            # could never fit replica memory
    dropped: int = 0             # shed by a stop/scale-to-zero
    cold_starts: int = 0
    latencies: List[float] = field(default_factory=list)
    per_request_usd: List[float] = field(default_factory=list)   # FaaS
    provisioned: List[tuple] = field(default_factory=list)       # (t0,t1,$/h)
    cost: float = 0.0
    peak_kv_bytes: int = 0
    kv_budget_bytes: float = 0.0
    peak_batch: int = 0
    scaling_timeline: List[tuple] = field(default_factory=list)  # (win,w,t)
    windows: List[dict] = field(default_factory=list)
    sim_time: float = 0.0
    trace: object = field(default=None, repr=False)
                                 # TraceRecorder when serve(trace=True)
                                 # (DESIGN.md §18); None otherwise

    def _pct(self, q: float) -> float:
        if not self.latencies:
            return float("nan")
        return float(np.percentile(np.asarray(self.latencies), q))

    @property
    def p50_s(self) -> float:
        return self._pct(50)

    @property
    def p99_s(self) -> float:
        return self._pct(99)

    @property
    def mean_s(self) -> float:
        return float(np.mean(self.latencies)) if self.latencies else float("nan")

    @property
    def usd_per_1k(self) -> float:
        if not self.completed:
            return float("nan")
        return self.cost / self.completed * 1e3

    def to_dict(self) -> dict:
        return {
            "system": self.system, "arrival": self.arrival,
            "duration_s": self.duration_s, "workers0": self.workers0,
            "requests": self.requests, "completed": self.completed,
            "rejected": self.rejected, "dropped": self.dropped,
            "cold_starts": self.cold_starts,
            "p50_ms": round(self.p50_s * 1e3, 3) if self.latencies else None,
            "p99_ms": round(self.p99_s * 1e3, 3) if self.latencies else None,
            "mean_ms": round(self.mean_s * 1e3, 3) if self.latencies else None,
            "cost_usd": self.cost,
            "usd_per_1k": (round(self.usd_per_1k, 6)
                           if self.completed else None),
            "peak_batch": self.peak_batch,
            "peak_kv_bytes": self.peak_kv_bytes,
            "kv_budget_bytes": self.kv_budget_bytes,
            "scaling_timeline": [list(x) for x in self.scaling_timeline],
            "sim_time": round(self.sim_time, 3),
            "breakdown": self.breakdown(),
        }

    def breakdown(self) -> dict:
        """Span-derived phase seconds (queue wait, cold start, prefill,
        decode) -- {} when the run was not traced."""
        if self.trace is None:
            return {}
        out: dict = {}
        for s in self.trace.spans:
            out[s.kind] = out.get(s.kind, 0.0) + (s.t1 - s.t0)
        return out


# ------------------------------------------------------------- internals ----

@dataclass
class _Req:
    rid: int
    t_arr: float
    steps_left: int
    kv_bytes: int
    t_admit: Optional[float] = None
    cost: float = 0.0


class _Replica:
    __slots__ = ("rid", "t_ready", "t_bill0", "t_bill1", "active",
                 "draining", "scheduled", "kv")

    def __init__(self, rid: int, t_ready: float, t_bill0: float):
        self.rid = rid
        self.t_ready = t_ready
        self.t_bill0 = t_bill0
        self.t_bill1: Optional[float] = None   # None = still billing
        self.active: List[_Req] = []
        self.draining = False
        self.scheduled = False
        self.kv = 0

    @property
    def alive(self) -> bool:
        return self.t_bill1 is None


def _fleet_bounds(platform) -> tuple:
    lo = 1 if platform.fleet.min_workers is None else int(platform.fleet.min_workers)
    hi = (MAX_FLEET if platform.fleet.max_workers is None
          else int(platform.fleet.max_workers))
    return lo, hi


class _WindowMeter:
    """Per-window telemetry, built once for both billing loops (they used
    to duplicate this block).  One source of truth for
    :class:`ServingTelemetry` and the ``res.windows`` record; when tracing,
    each window also lands a ``serve.window`` mark on the recorder."""

    def __init__(self, rec, res, window_s: float, lo: int, hi: int):
        self.rec = rec
        self.res = res
        self.window_s = window_s
        self.lo = lo
        self.hi = hi
        self.arr = 0                # arrivals this window
        self.lat: list = []         # completion latencies this window
        self.prev_busy = 0.0        # busy_integral at the last boundary

    def observe(self, widx: int, t: float, workers: int,
                busy_integral: float, queue_depth: int,
                cost_now: float) -> ServingTelemetry:
        util = (busy_integral - self.prev_busy) / (max(workers, 1)
                                                   * self.window_s)
        tele = ServingTelemetry(
            round=widx, workers=workers, qps=self.arr / self.window_s,
            queue_depth=queue_depth,
            p50_ms=(float(np.percentile(self.lat, 50)) * 1e3
                    if self.lat else None),
            p99_ms=(float(np.percentile(self.lat, 99)) * 1e3
                    if self.lat else None),
            utilization=min(1.0, util), cost_so_far=cost_now,
            sim_time=t, min_workers=self.lo, max_workers=self.hi)
        self.res.windows.append({"t": t, "qps": tele.qps,
                                 "queue": tele.queue_depth,
                                 "p50_ms": tele.p50_ms,
                                 "p99_ms": tele.p99_ms,
                                 "util": round(tele.utilization, 4),
                                 "workers": workers, "cost": cost_now})
        if self.rec is not None:
            self.rec.mark("serve.window", t, workers=workers, qps=tele.qps,
                          queue=queue_depth, util=tele.utilization,
                          cost_usd=cost_now)
        self.prev_busy = busy_integral
        self.arr = 0
        self.lat = []
        return tele


# ------------------------------------------------------------------ serve ---

def serve(platform, lat, arrivals, *, duration_s: float = 300.0,
          prompt_len: int = 32, new_tokens: int = 32,
          window_s: float = 15.0, scaling=None, max_batch: int = 32,
          prewarm: int = 0, reduced: bool = False,
          seed: int = 0, trace: bool = False) -> ServingResult:
    """Serve an open-loop arrival process on ``platform``.

    ``lat`` is a :class:`LatencyModel` or an arch name (resolved against the
    platform's serving hooks); ``arrivals`` is a process or grammar string;
    ``scaling`` is a ``core.elastic`` grammar string / policy instance
    (default: the platform's own ``scaling`` spec, ``static`` = fixed).
    ``prewarm`` seeds the FaaS warm pool (ignored on provisioned platforms,
    whose initial fleet is warm by construction).  ``trace=True`` records
    the request lifecycle (queue wait, cold start, prefill, decode slices)
    on a :class:`~repro.core.trace.TraceRecorder` (DESIGN.md §18) without
    perturbing any metered value.
    """
    hooks = platform.serving_hooks()
    if isinstance(lat, str):
        lat = LatencyModel.from_arch(lat, flops=hooks.flops,
                                     mem_bandwidth=hooks.mem_bandwidth,
                                     reduced=reduced)
    arrivals = make_arrivals(arrivals)
    if prompt_len < 1 or new_tokens < 1:
        raise ValueError("prompt_len and new_tokens must be >= 1")
    if window_s <= 0 or duration_s <= 0:
        raise ValueError("window_s and duration_s must be > 0")
    if lat.model_bytes >= hooks.memory_bytes:
        raise ValueError(
            f"weights ({lat.model_bytes / 1e9:.2f} GB) do not fit a "
            f"{hooks.system} replica ({hooks.memory_bytes / 1e9:.2f} GB)")

    if scaling is None:
        scaling = getattr(platform, "scaling", None)
    policy = make_autoscaler(scaling)
    lo, hi = _fleet_bounds(platform)
    w0 = int(platform.workers)
    if policy is not None:
        w0 = max(lo, min(hi, int(policy.initial_workers(w0))))

    times = arrivals.times(duration_s, seed)
    res = ServingResult(system=hooks.system, arrival=arrivals.name,
                        duration_s=float(duration_s), workers0=w0,
                        kv_budget_bytes=hooks.memory_bytes - lat.model_bytes)
    res.trace = TraceRecorder("serve") if trace else None
    if policy is not None:
        res.scaling_timeline.append((0, w0, 0.0))

    kv_req = lat.kv_bytes(prompt_len + new_tokens)
    args = (platform, hooks, lat, policy, res, times, kv_req, lo, hi, w0,
            duration_s, prompt_len, new_tokens, window_s, max_batch)
    if hooks.billing == "request":
        _serve_request_billed(*args, prewarm=prewarm)
    else:
        _serve_provisioned(*args)
    return res


# ------------------------------------------------------ FaaS (per-request) --

def _serve_request_billed(platform, hooks, lat, policy, res, times, kv_req,
                          lo, hi, w0, duration_s, prompt_len, new_tokens,
                          window_s, max_batch, *, prewarm: int = 0):
    """One Lambda per in-flight request; the autoscaler moves the
    concurrency cap.  Fees accrue when a request starts executing (its
    billed duration is known then), so ``cost_cap`` windows always observe
    every admitted dollar."""
    heap: list = []
    seq = 0
    for i, t in enumerate(times):
        heap.append((float(t), seq, "arr", i))
        seq += 1
    heapq.heapify(heap)
    heapq.heappush(heap, (window_s, seq, "win", 0))
    seq += 1

    rec = res.trace
    win = _WindowMeter(rec, res, window_s, lo, hi)
    service_s = lat.service_s(prompt_len, new_tokens, batch=1)
    cold_extra = hooks.cold_start_total_s(lat.model_bytes)
    warm: list = [hooks.keep_warm_s] * max(0, int(prewarm))
    queue: deque = deque()
    cap = w0
    busy = 0
    stopped = False
    last_t = 0.0
    busy_integral = 0.0
    last_done = 0.0

    def advance(t: float):
        nonlocal busy_integral, last_t
        busy_integral += busy * (t - last_t)
        last_t = t

    def start(req: _Req, t: float):
        nonlocal busy, seq
        warm[:] = [e for e in warm if e > t]
        cold = not warm
        if warm:
            warm.pop()
        delay = cold_extra if cold else 0.0
        res.cold_starts += int(cold)
        billed = delay + service_s
        req.cost = (hooks.gb * billed * hooks.gb_s_usd
                    + hooks.request_fee_usd)
        req.t_admit = t
        res.cost += req.cost
        res.per_request_usd.append(req.cost)
        if rec is not None:
            # invariant 2: one ledger entry per admitted dollar, in the
            # exact order res.cost accumulates them
            rec.cost("request", req.cost)
            rec.span(req.rid, "serve.queue", "stall", req.t_arr, t)
            if cold:
                rec.span(req.rid, "serve.coldstart", "startup", t, t + delay)
            t_exec = t + delay
            t_pf = t_exec + prompt_len * lat.step_s(1)
            rec.span(req.rid, "serve.prefill", "compute", t_exec, t_pf,
                     usd=req.cost)
            rec.span(req.rid, "serve.decode", "compute", t_pf,
                     t_exec + service_s)
        res.peak_kv_bytes = max(res.peak_kv_bytes, req.kv_bytes)
        res.peak_batch = max(res.peak_batch, 1)
        busy += 1
        heapq.heappush(heap, (t + delay + service_s, seq, "done", req))
        seq += 1

    while heap:
        t, _, kind, payload = heapq.heappop(heap)
        advance(t)
        if kind == "arr":
            res.requests += 1
            win.arr += 1
            if stopped or cap == 0:
                res.dropped += 1
                continue
            if lat.model_bytes + kv_req > hooks.memory_bytes:
                res.rejected += 1
                continue
            req = _Req(rid=payload, t_arr=t,
                       steps_left=lat.request_steps(prompt_len, new_tokens),
                       kv_bytes=kv_req)
            if busy < cap:
                start(req, t)
            else:
                queue.append(req)
        elif kind == "done":
            req = payload
            busy -= 1
            res.completed += 1
            delay = t - req.t_arr
            res.latencies.append(delay)
            win.lat.append(delay)
            last_done = max(last_done, t)
            warm.append(t + hooks.keep_warm_s)
            if queue and not stopped and busy < cap:
                start(queue.popleft(), t)
        elif kind == "win":
            widx = payload
            # when tracing, the window's cost snapshot is the recorder's
            # ledger sum -- bitwise-equal to res.cost by construction
            cost_now = res.cost if rec is None else rec.cost_total()
            tele = win.observe(widx, t, cap, busy_integral, len(queue),
                               cost_now)
            if policy is not None:
                target = int(policy.observe(tele))
                if target == 0:
                    stopped = True
                    res.dropped += len(queue)
                    queue.clear()
                    cap = 0
                    res.scaling_timeline.append((widx, 0, t))
                else:
                    target = max(lo, min(hi, target))
                    if target != cap:
                        res.scaling_timeline.append((widx, target, t))
                        if target > cap:  # drain the queue into the new room
                            cap = target
                            while queue and busy < cap:
                                start(queue.popleft(), t)
                        cap = target
            if not stopped and (t < duration_s or queue or busy > 0):
                heapq.heappush(heap, (t + window_s, seq, "win", widx + 1))
                seq += 1

    res.sim_time = max(duration_s, last_done)


# ------------------------------------------- IaaS / pods (provisioned) ------

def _serve_provisioned(platform, hooks, lat, policy, res, times, kv_req,
                       lo, hi, w0, duration_s, prompt_len, new_tokens,
                       window_s, max_batch):
    """Hourly-billed replicas running a continuously-batched decode loop.

    Each replica advances its batch in fast-forwarded chunks: ``n`` decode
    steps at the current batch's step time, where ``n`` is capped by the
    soonest batch-changing event (a member finishing, the next arrival, the
    next autoscaler window) — so wall-clock work is proportional to
    batch-composition changes, not to tokens."""
    heap: list = []
    seq = 0
    for i, t in enumerate(times):
        heap.append((float(t), seq, "arr", i))
        seq += 1
    heapq.heapify(heap)
    heapq.heappush(heap, (window_s, seq, "win", 0))
    seq += 1

    kv_budget = hooks.memory_bytes - lat.model_bytes
    steps_per_req = lat.request_steps(prompt_len, new_tokens)
    # the initial fleet is provisioned and warmed before t=0; it bills
    # from t=0 (that IS the idle-fleet floor the zero-traffic test pins)
    replicas: List[_Replica] = [_Replica(i, 0.0, 0.0) for i in range(w0)]
    queue: deque = deque()
    width = w0
    stopped = False
    arr_idx = 0                 # next unseen arrival (horizon lookahead)
    next_win = window_s
    busy_integral = 0.0
    rec = res.trace
    win = _WindowMeter(rec, res, window_s, lo, hi)
    last_done = 0.0

    def cost_at(t: float) -> float:
        total = 0.0
        for r in replicas:
            end = r.t_bill1 if r.t_bill1 is not None else t
            total += (end - r.t_bill0) * hooks.hourly_usd / 3600.0
        return total

    def schedule(r: _Replica, t: float):
        nonlocal seq
        if not r.scheduled and r.alive:
            r.scheduled = True
            heapq.heappush(heap, (max(t, r.t_ready), seq, "step", r.rid))
            seq += 1

    def admit(r: _Replica, t: float):
        while (queue and len(r.active) < max_batch
               and r.kv + queue[0].kv_bytes <= kv_budget):
            req = queue.popleft()
            req.t_admit = t
            if rec is not None:
                rec.span(req.rid, "serve.queue", "stall", req.t_arr, t,
                         meta={"replica": r.rid})
            r.active.append(req)
            r.kv += req.kv_bytes
            res.peak_kv_bytes = max(res.peak_kv_bytes, r.kv)
        res.peak_batch = max(res.peak_batch, len(r.active))

    def retire(r: _Replica, t: float):
        r.t_bill1 = t
        res.provisioned.append((r.t_bill0, t, hooks.hourly_usd))

    while heap:
        t, _, kind, payload = heapq.heappop(heap)
        if kind == "arr":
            arr_idx = payload + 1
            res.requests += 1
            win.arr += 1
            if stopped:
                res.dropped += 1
                continue
            if kv_req > kv_budget:
                res.rejected += 1
                continue
            queue.append(_Req(rid=payload, t_arr=t, steps_left=steps_per_req,
                              kv_bytes=kv_req))
            for r in replicas:
                if r.alive and not r.draining and not r.active:
                    schedule(r, t)
        elif kind == "step":
            r = replicas[payload]
            if not r.alive:
                continue
            r.scheduled = False
            for req in [q for q in r.active if q.steps_left <= 0]:
                r.active.remove(req)
                r.kv -= req.kv_bytes
                res.completed += 1
                delay = t - req.t_arr
                res.latencies.append(delay)
                win.lat.append(delay)
                last_done = max(last_done, t)
            if r.draining:
                if not r.active:
                    retire(r, t)
                    continue
            else:
                admit(r, t)
            if not r.active:
                continue            # idle; the next arrival wakes it
            b = len(r.active)
            step = lat.step_s(b)
            n = min(q.steps_left for q in r.active)
            horizon = next_win
            if queue or arr_idx < len(times):
                nxt = times[arr_idx] if arr_idx < len(times) else horizon
                horizon = min(horizon, nxt)
            if math.isfinite(horizon) and horizon > t + step:
                n = min(n, max(1, int((horizon - t) / step)))
            for q in r.active:
                q.steps_left -= n
            busy_integral += n * step
            if rec is not None:
                # one continuous-batching decode slice per fast-forwarded
                # chunk, on the replica's timeline
                rec.span(r.rid, "serve.decode", "compute", t, t + n * step,
                         meta={"batch": b, "steps": n})
            r.scheduled = True
            heapq.heappush(heap, (t + n * step, seq, "step", r.rid))
            seq += 1
        elif kind == "win":
            widx = payload
            tele = win.observe(widx, t, width, busy_integral, len(queue),
                               cost_at(t))
            if policy is not None and not stopped:
                target = int(policy.observe(tele))
                if target == 0:
                    stopped = True
                    res.dropped += len(queue)
                    queue.clear()
                    width = 0
                    res.scaling_timeline.append((widx, 0, t))
                    for r in replicas:
                        if r.alive:
                            if r.active:
                                r.draining = True
                            else:
                                retire(r, t)
                else:
                    target = max(lo, min(hi, target))
                    if target != width:
                        res.scaling_timeline.append((widx, target, t))
                    if target > width:
                        need = target - width
                        for r in replicas:   # un-drain before provisioning
                            if need and r.alive and r.draining:
                                r.draining = False
                                need -= 1
                                schedule(r, t)
                        if need:
                            t_ready = (t + hooks.provision_s(need)
                                       + hooks.model_load_s(lat.model_bytes))
                            res.cold_starts += need
                            for _ in range(need):
                                r = _Replica(len(replicas), t_ready, t)
                                replicas.append(r)
                                if rec is not None:
                                    rec.span(r.rid, "serve.coldstart",
                                             "startup", t, t_ready,
                                             meta={"ordered": need})
                                if queue:
                                    schedule(r, t_ready)
                        width = target
                    elif target < width:
                        shed = width - target
                        live = [r for r in replicas
                                if r.alive and not r.draining]
                        live.sort(key=lambda r: len(r.active))
                        for r in live[:shed]:
                            if r.active:
                                r.draining = True
                            else:
                                retire(r, t)
                        width = target
            if not stopped and (t < duration_s or queue
                                or any(r.active for r in replicas if r.alive)):
                next_win = t + window_s
                heapq.heappush(heap, (next_win, seq, "win", widx + 1))
                seq += 1
            else:
                next_win = float("inf")

    sim_end = max(duration_s, last_done,
                  max((r.t_bill1 or 0.0 for r in replicas), default=0.0))
    for r in replicas:
        if r.alive:
            retire(r, sim_end)
    res.sim_time = sim_end
    res.cost = sum((t1 - t0) * hourly / 3600.0
                   for t0, t1, hourly in res.provisioned)
    if rec is not None:
        # invariant 2 for provisioned billing: one ledger entry per replica
        # span, same terms in the same order as the sum above, so the
        # ledger total is bitwise-equal to res.cost
        rec.cost_reset()
        for t0, t1, hourly in res.provisioned:
            rec.cost("replica", (t1 - t0) * hourly / 3600.0)
