"""Open-loop arrival processes behind the string-grammar registry.

Serving load is *open-loop*: requests arrive on the process's clock whether
or not the fleet keeps up, which is what makes queueing (and therefore p99)
an output of the simulator instead of an input.  Four shapes cover the
paper-style design space:

  ``poisson:<qps>``            homogeneous Poisson at a nominal rate
  ``diurnal:<qps@hour,...>``   piecewise-linear daily rate curve, sampled by
                               thinning; optional ``day=<s>`` rescales the
                               24 h period onto ``<s>`` simulated seconds
  ``flash:<base,spike,at[,dur]>``  flash crowd: base rate with a ``spike``
                               qps plateau starting at ``at`` seconds
                               (default duration: rest of the run)
  ``trace:<file>``             replay recorded arrival timestamps (seconds,
                               one per line, or a JSON list)

Every process exposes ``times(duration_s, seed)`` (sorted arrival instants),
``rate(t)`` (instantaneous qps, used by the analytic sizing helper) and
``peak_qps`` (used to provision IaaS/pod fleets for the frontier grid).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, Sequence

import numpy as np

__all__ = ["ArrivalProcess", "PoissonArrivals", "DiurnalArrivals",
           "FlashArrivals", "TraceArrivals", "ARRIVALS", "make_arrivals",
           "list_arrivals"]


class ArrivalProcess:
    """Protocol: open-loop request arrival instants on the simulated clock."""

    name: str = "?"

    def times(self, duration_s: float, seed: int = 0) -> np.ndarray:
        raise NotImplementedError

    def rate(self, t: float) -> float:
        raise NotImplementedError

    @property
    def peak_qps(self) -> float:
        raise NotImplementedError


def _thin(rate: Callable[[float], float], rate_max: float,
          duration_s: float, seed: int) -> np.ndarray:
    """Sample an inhomogeneous Poisson process by thinning at ``rate_max``."""
    if rate_max <= 0 or duration_s <= 0:
        return np.zeros(0)
    rng = np.random.default_rng(seed)
    # Candidate count ~ Poisson(rate_max * T); draw with headroom, extend if
    # the tail is unlucky.
    t, out = 0.0, []
    while True:
        gaps = rng.exponential(1.0 / rate_max, size=max(16, int(rate_max * duration_s)))
        for g in gaps:
            t += g
            if t >= duration_s:
                return np.asarray(out)
            if rng.random() * rate_max < rate(t):
                out.append(t)


@dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    qps: float

    def __post_init__(self):
        if self.qps < 0:
            raise ValueError(f"poisson qps must be >= 0, got {self.qps}")

    @property
    def name(self) -> str:
        return f"poisson:{self.qps:g}"

    def times(self, duration_s: float, seed: int = 0) -> np.ndarray:
        if self.qps == 0 or duration_s <= 0:
            return np.zeros(0)
        rng = np.random.default_rng(seed)
        n = int(np.ceil(self.qps * duration_s + 6 * np.sqrt(self.qps * duration_s) + 16))
        t = np.cumsum(rng.exponential(1.0 / self.qps, size=n))
        while t.size and t[-1] < duration_s:      # pragma: no cover - headroom
            t = np.concatenate([t, t[-1] + np.cumsum(
                rng.exponential(1.0 / self.qps, size=n))])
        return t[t < duration_s]

    def rate(self, t: float) -> float:
        return self.qps

    @property
    def peak_qps(self) -> float:
        return self.qps


@dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Piecewise-linear rate over a wrapped 24 h cycle, mapped onto ``day_s``
    simulated seconds (so a 300 s run can sweep a full synthetic day)."""

    points: tuple  # ((hour, qps), ...) sorted by hour in [0, 24)
    day_s: float = 86400.0

    def __post_init__(self):
        if not self.points:
            raise ValueError("diurnal needs at least one qps@hour point")
        if any(q < 0 for _, q in self.points):
            raise ValueError("diurnal qps must be >= 0")

    @property
    def name(self) -> str:
        pts = ",".join(f"{q:g}@{h:g}" for h, q in self.points)
        return f"diurnal:{pts}" + ("" if self.day_s == 86400.0 else f",day={self.day_s:g}")

    def rate(self, t: float) -> float:
        hour = (t / self.day_s * 24.0) % 24.0
        pts = list(self.points) + [(self.points[0][0] + 24.0, self.points[0][1])]
        if hour < pts[0][0]:
            hour += 24.0
        for (h0, q0), (h1, q1) in zip(pts, pts[1:]):
            if h0 <= hour <= h1:
                f = 0.0 if h1 == h0 else (hour - h0) / (h1 - h0)
                return q0 + f * (q1 - q0)
        return pts[0][1]

    def times(self, duration_s: float, seed: int = 0) -> np.ndarray:
        return _thin(self.rate, self.peak_qps, duration_s, seed)

    @property
    def peak_qps(self) -> float:
        return max(q for _, q in self.points)


@dataclass(frozen=True)
class FlashArrivals(ArrivalProcess):
    base: float
    spike: float
    at: float
    dur: float = float("inf")

    def __post_init__(self):
        if self.base < 0 or self.spike < 0 or self.at < 0:
            raise ValueError("flash parameters must be >= 0")

    @property
    def name(self) -> str:
        tail = "" if self.dur == float("inf") else f",{self.dur:g}"
        return f"flash:{self.base:g},{self.spike:g},{self.at:g}{tail}"

    def rate(self, t: float) -> float:
        return self.spike if self.at <= t < self.at + self.dur else self.base

    def times(self, duration_s: float, seed: int = 0) -> np.ndarray:
        return _thin(self.rate, self.peak_qps, duration_s, seed)

    @property
    def peak_qps(self) -> float:
        return max(self.base, self.spike)


@dataclass(frozen=True)
class TraceArrivals(ArrivalProcess):
    """Replay a recorded trace: arrival seconds, one float per line (or a
    JSON list).  ``times`` clips to the run duration; the seed is ignored."""

    path: str = ""
    _times: tuple = ()

    @classmethod
    def from_file(cls, path: str) -> "TraceArrivals":
        text = Path(path).read_text().strip()
        if text.startswith("["):
            vals = json.loads(text)
        else:
            vals = [float(x) for x in text.split()]
        return cls(path=path, _times=tuple(sorted(float(v) for v in vals)))

    @classmethod
    def from_times(cls, times: Sequence[float]) -> "TraceArrivals":
        return cls(path="<inline>", _times=tuple(sorted(float(v) for v in times)))

    @property
    def name(self) -> str:
        return f"trace:{self.path}"

    def times(self, duration_s: float, seed: int = 0) -> np.ndarray:
        t = np.asarray(self._times)
        return t[t < duration_s]

    def rate(self, t: float) -> float:
        if not self._times:
            return 0.0
        span = max(self._times[-1], 1e-9)
        return len(self._times) / span

    @property
    def peak_qps(self) -> float:
        t = np.asarray(self._times)
        if t.size < 2:
            return float(t.size)
        # max arrivals in any sliding 1 s window
        best = 1
        j = 0
        for i in range(t.size):
            while t[i] - t[j] > 1.0:
                j += 1
            best = max(best, i - j + 1)
        return float(best)


def _parse_poisson(arg: str) -> PoissonArrivals:
    return PoissonArrivals(qps=float(arg))


def _parse_diurnal(arg: str) -> DiurnalArrivals:
    pts, day_s = [], 86400.0
    for part in arg.split(","):
        part = part.strip()
        if not part:
            continue
        if part.startswith("day="):
            day_s = float(part[4:])
        else:
            q, _, h = part.partition("@")
            pts.append((float(h), float(q)))
    return DiurnalArrivals(points=tuple(sorted(pts)), day_s=day_s)


def _parse_flash(arg: str) -> FlashArrivals:
    parts = [float(x) for x in arg.split(",")]
    if len(parts) not in (3, 4):
        raise ValueError("flash:<base,spike,at[,dur]>")
    return FlashArrivals(*parts)


def _parse_trace(arg: str) -> TraceArrivals:
    return TraceArrivals.from_file(arg)


ARRIVALS: Dict[str, Callable[[str], ArrivalProcess]] = {
    "poisson": _parse_poisson,
    "diurnal": _parse_diurnal,
    "flash": _parse_flash,
    "trace": _parse_trace,
}


def make_arrivals(spec) -> ArrivalProcess:
    """``'poisson:5'`` / ``'flash:0.2,8,60'`` / an ArrivalProcess passthrough."""
    if isinstance(spec, ArrivalProcess):
        return spec
    head, _, arg = str(spec).partition(":")
    if head not in ARRIVALS:
        raise ValueError(f"unknown arrival process {head!r}; known: "
                         f"{', '.join(sorted(ARRIVALS))}")
    if not arg:
        raise ValueError(f"arrival process {head!r} needs an argument, e.g. "
                         "'poisson:5'")
    return ARRIVALS[head](arg)


def list_arrivals() -> Dict[str, str]:
    """name -> grammar line, for ``repro list``."""
    return {
        "poisson": "poisson:<qps> - homogeneous Poisson arrivals",
        "diurnal": "diurnal:<qps@hour,...>[,day=<s>] - daily rate curve (thinning)",
        "flash": "flash:<base,spike,at[,dur]> - flash crowd plateau",
        "trace": "trace:<file> - replay recorded arrival seconds",
    }
