"""``python -m repro`` -- the one way to run a study (DESIGN.md §10).

    python -m repro list                    # available presets
    python -m repro run fig10_breakdown     # run a preset (quick sizes)
    python -m repro run spec.json --set max_epochs=5
    python -m repro sweep fig8_sync --grid fleet.workers=4,8 --grid sync=bsp,asp

``run`` executes a preset (or a single-spec JSON file) and ``sweep``
expands a cartesian ``--grid`` over the preset's base spec; both write
``repro.experiment/v1`` records (see :mod:`repro.experiments.runner`) into
the spec-hash cache directory (default ``experiments/runs/``) and print a
summary table.  ``--set field=value`` tweaks every trial (dotted paths
reach nested specs), which is how CI keeps the smoke runs small.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.experiments import (
    PRESETS, ExperimentSpec, RunRecord, get_preset, run_experiment, sweep,
)
from repro.experiments.runner import DEFAULT_CACHE


def _parse_value(text: str):
    """JSON if it parses, bare string otherwise (so ``sync=asp`` works)."""
    try:
        return json.loads(text)
    except json.JSONDecodeError:
        return text


def _parse_set(items: list[str]) -> dict:
    over = {}
    for item in items:
        key, eq, value = item.partition("=")
        if not eq:
            raise SystemExit(f"--set expects field=value, got {item!r}")
        over[key] = _parse_value(value)
    return over


def _parse_grid(items: list[str]) -> dict:
    grid = {}
    for item in items:
        key, eq, values = item.partition("=")
        if not eq:
            raise SystemExit(f"--grid expects field=v1,v2,..., got {item!r}")
        grid[key] = [_parse_value(v) for v in values.split(",")]
    return grid


def _unwrap(d: dict) -> dict:
    """Accept a bare spec dict OR a full run-record envelope (the
    ``repro.experiment/v1`` files under experiments/runs/ and ``--out``)."""
    return d["spec"] if isinstance(d.get("spec"), dict) else d


def _load_specs(target: str, quick: bool) -> list[ExperimentSpec]:
    """A preset name, or a JSON file holding a spec / record / list of
    either."""
    if target in PRESETS:
        return get_preset(target).build(quick)
    path = Path(target)
    if path.suffix == ".json" or path.exists():
        if not path.exists():
            raise SystemExit(f"spec file not found: {target}")
        data = json.loads(path.read_text())
        items = data if isinstance(data, list) else [data]
        if not items:
            raise SystemExit(f"no specs in {target}")
        return [ExperimentSpec.from_dict(_unwrap(d)) for d in items]
    raise SystemExit(f"unknown preset or spec file {target!r}; "
                     f"presets: {', '.join(sorted(PRESETS))}")


def _print_records(records: list[RunRecord]) -> None:
    if not records:
        print("no records")
        return
    wname = max(len(r.spec.name) for r in records)
    print(f"{'name':<{wname}s} {'time_s':>9s} {'cost_$':>9s} {'loss':>9s} "
          f"{'rounds':>6s}  note")
    for r in records:
        res = r.result
        note = "cached" if r.cached else ""
        if res.get("error"):
            note = f"ERROR: {res['error']}"
        print(f"{r.spec.name:<{wname}s} {res.get('sim_time_s', 0):9.1f} "
              f"{res.get('cost_usd', 0):9.4f} {res.get('final_loss', 0):9.4f} "
              f"{res.get('rounds', 0):6d}  {note}")


def _finish(records: list[RunRecord], out: str | None) -> None:
    _print_records(records)
    if out:
        Path(out).parent.mkdir(parents=True, exist_ok=True)
        Path(out).write_text(
            json.dumps([r.to_dict() for r in records], indent=1))
        print(f"# {len(records)} record(s) -> {out}", file=sys.stderr)


def cmd_list(args) -> int:
    for name in sorted(PRESETS):
        p = PRESETS[name]
        n = len(p.build(True))
        print(f"{name:<18s} {n:2d} trial(s)  {p.description}")
    from repro.core.comm import list_codecs, list_collectives, list_transports
    from repro.core.elastic import list_policies
    from repro.core.workloads import list_workloads
    from repro.experiments.spec import PLATFORMS
    print(f"\nplatforms: {', '.join(PLATFORMS)}")
    print(f"models:    {', '.join(list_workloads())}")
    from repro.core.sync import list_syncs
    print(f"\nsync protocols (--set sync=..., DESIGN.md §3):")
    print(f"  {', '.join(list_syncs())}")
    print(f"\ncomm stacks (--set comm=transport/collective/codec, "
          f"DESIGN.md §12):")
    print(f"  transports:  {', '.join(list_transports())}")
    print(f"  collectives: {', '.join(list_collectives())}")
    print(f"  codecs:      {', '.join(list_codecs())}")
    print(f"\nscaling policies (--set scaling=..., DESIGN.md §13):")
    print(f"  {', '.join(list_policies())}")
    from repro.core.ckpt import list_ckpts
    print(f"\ncheckpoint transports (--set ckpt=..., DESIGN.md §17):")
    print(f"  {', '.join(list_ckpts().values())}")
    from repro.core.failures import list_failures
    print(f"\nfailure processes (--set failure.trace=... / failure.rate=..., "
          f"DESIGN.md §17):")
    for line in list_failures().values():
        print(f"  {line}")
    from repro.serving.arrivals import list_arrivals
    print(f"\narrival processes (repro serve --arrival ..., DESIGN.md §14):")
    for line in list_arrivals().values():
        print(f"  {line}")
    from repro.analysis import list_checkers
    print(f"\nlint checkers (repro lint --select ..., DESIGN.md §15):")
    for line in list_checkers():
        print(f"  {line}")
    from repro.core.trace import list_exporters
    print(f"\ntrace exporters (repro trace --export ..., DESIGN.md §18):")
    print(f"  {', '.join(list_exporters())}")
    return 0


def cmd_lint(args) -> int:
    """Static project-invariant checks (DESIGN.md §15)."""
    from repro.analysis import (
        ModuleCache, render_json, render_text, run_lint, write_manifest)
    if args.write_manifest:
        try:
            path = write_manifest(ModuleCache())
        except ValueError as e:
            print(e, file=sys.stderr)
            return 1
        print(f"# spec-hash manifest -> {path}")
        return 0
    select = ([s.strip() for s in args.select.split(",") if s.strip()]
              if args.select else None)
    paths = [Path(p) for p in args.paths] or None
    try:
        findings, n_files = run_lint(paths=paths, select=select)
    except KeyError as e:
        raise SystemExit(str(e.args[0]) if e.args else str(e))
    render = render_json if args.format == "json" else render_text
    print(render(findings, n_files))
    return 1 if findings else 0


def cmd_plan(args) -> int:
    """Analytic fleet planner (DESIGN.md §13): rank platform x width for a
    workload by the §5.3 cost model."""
    from repro.core.elastic import PAPER_WORKLOADS, plan
    if args.target in PAPER_WORKLOADS:
        target, label = PAPER_WORKLOADS[args.target], args.target
    else:
        spec = _load_specs(args.target, quick=not args.full)[0]
        overrides = _parse_set(args.set or [])
        if overrides:
            spec = spec.with_(**overrides)
        target, label = spec, spec.name or args.target
    workers = ([int(w) for w in args.workers.split(",")]
               if args.workers else None)
    kw = {} if workers is None else {"workers": workers}
    platforms = tuple(p.strip() for p in args.platforms.split(",")
                      if p.strip())
    mfu = args.mfu if args.mfu == "measured" else float(args.mfu)
    options = plan(target, args.objective, deadline_s=args.deadline_s,
                   budget_usd=args.budget_usd, platforms=platforms,
                   mfu=mfu, **kw)
    print(f"# plan for {label} (objective={args.objective})")
    print(f"{'rank':>4s} {'platform':<8s} {'w':>4s} {'time_s':>10s} "
          f"{'cost_$':>9s}  note")
    for i, o in enumerate(options, 1):
        note = o.note if o.note else ("" if i > 1 else "<- pick")
        print(f"{i:4d} {o.platform:<8s} {o.workers:4d} {o.time_s:10.1f} "
              f"{o.cost_usd:9.4f}  {note}")
    # the restart term behind the ranking: startup + metered restore of
    # the model's actual bytes through the checkpoint transport (§17)
    from repro.core.analytical import restart_seconds
    from repro.core.elastic.planner import as_cost_inputs
    ci = as_cost_inputs(target)
    per = ", ".join(f"{p}={restart_seconds(p, ci.m_bytes):.1f}s"
                    for p in platforms)
    print(f"# derived restart ({ci.m_bytes / 1e6:.3f} MB model): {per}")
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(
            json.dumps([o.to_dict() for o in options], indent=1))
    if not options or not options[0].feasible:
        print("# no feasible option under the given constraints",
              file=sys.stderr)
        return 1
    return 0


def _print_serve_records(records) -> None:
    wname = max(len(r.spec.name or r.spec.platform) for r in records)
    print(f"{'name':<{wname}s} {'w':>5s} {'req':>6s} {'done':>6s} "
          f"{'cold':>5s} {'p50_ms':>10s} {'p99_ms':>10s} {'cost_$':>10s} "
          f"{'$/1k':>9s}  note")
    for r in records:
        d = r.result
        name = r.spec.name or r.spec.platform
        p50 = d.get("p50_ms")
        p99 = d.get("p99_ms")
        perk = d.get("usd_per_1k")
        print(f"{name:<{wname}s} {d.get('workers0', 0):5d} "
              f"{d.get('requests', 0):6d} {d.get('completed', 0):6d} "
              f"{d.get('cold_starts', 0):5d} "
              f"{p50 if p50 is not None else float('nan'):10.1f} "
              f"{p99 if p99 is not None else float('nan'):10.1f} "
              f"{d.get('cost_usd', 0):10.5f} "
              f"{perk if perk is not None else float('nan'):9.4f}  "
              f"{'cached' if r.cached else ''}")


def cmd_serve(args) -> int:
    """Request-driven serving simulator (DESIGN.md §14)."""
    from repro.experiments.serving import (
        ServingSpec, frontier, run_serving)
    cache = None if args.no_cache else args.cache
    overrides = _parse_set(args.set or [])
    if args.grid:
        records = frontier(duration_s=args.duration_s, reduced=args.reduced,
                           cache_dir=cache, force=args.force)
        print("# cost-vs-p99 frontier: FaaS vs IaaS vs pod x arrival shape")
    else:
        if args.target:
            path = Path(args.target)
            if not path.exists():
                raise SystemExit(f"spec file not found: {args.target}")
            data = json.loads(path.read_text())
            items = data if isinstance(data, list) else [data]
            specs = [ServingSpec.from_dict(_unwrap(d)) for d in items]
        else:
            specs = [ServingSpec(name="serve", arrival=args.arrival,
                                 duration_s=args.duration_s,
                                 reduced=args.reduced)]
        if overrides:
            specs = [s.with_(**overrides) for s in specs]
        records = [run_serving(s, cache_dir=cache, force=args.force)
                   for s in specs]
    _print_serve_records(records)
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(
            json.dumps([r.to_dict() for r in records], indent=1))
        print(f"# {len(records)} record(s) -> {args.out}", file=sys.stderr)
    return 0


def cmd_trace(args) -> int:
    """Span-level observability (DESIGN.md §18): run a preset with the
    recorder on, print the Figure-10 phase breakdown and the three
    conservation gates, optionally export a Chrome/Perfetto trace."""
    from repro.core.trace import (
        check_invariants, make_exporter, render_breakdown, render_invariants)
    exporter = make_exporter(args.export) if args.export else None
    specs = _load_specs(args.target, quick=not args.full)
    overrides = _parse_set(args.set or [])
    if overrides:
        specs = [s.with_(**overrides) for s in specs]
    rc = 0
    for k, spec in enumerate(specs):
        spec = spec.with_(trace=True)
        model, algo, tr, va = spec.build_workload()
        res = spec.build_runtime().train(
            model, algo, tr, va, target_loss=spec.target_loss,
            max_epochs=spec.max_epochs, eval_every=spec.eval_every,
            data_local=spec.data_local, trace=True)
        if res.error:
            print(f"# {spec.name or args.target}: ERROR {res.error}",
                  file=sys.stderr)
            rc = 1
            continue
        print(render_breakdown(res.trace, title=spec.name or args.target))
        inv = check_invariants(res)
        print(render_invariants(inv))
        print()
        if not inv["ok"]:
            rc = 1
        if exporter is not None:
            path = Path(args.out or f"{spec.name or 'trace'}"
                                    f".{args.export}.json")
            if len(specs) > 1 and args.out:
                path = path.with_name(f"{path.stem}.{k}{path.suffix}")
            path.parent.mkdir(parents=True, exist_ok=True)
            path.write_text(json.dumps(exporter(res.trace)))
            print(f"# {args.export} trace -> {path}", file=sys.stderr)
    return rc


def cmd_run(args) -> int:
    specs = _load_specs(args.target, quick=not args.full)
    overrides = _parse_set(args.set or [])
    if overrides:
        specs = [s.with_(**overrides) for s in specs]
    cache = None if args.no_cache else args.cache
    records = [run_experiment(s, cache_dir=cache, force=args.force)
               for s in specs]
    _finish(records, args.out)
    return 1 if any(r.result.get("error") for r in records) else 0


def cmd_sweep(args) -> int:
    quick = not args.full
    base = (get_preset(args.target).base(quick) if args.target in PRESETS
            else _load_specs(args.target, quick)[0])
    base = base.with_(**_parse_set(args.set or []))
    grid = _parse_grid(args.grid or [])
    if not grid:
        raise SystemExit("sweep needs at least one --grid field=v1,v2,...")
    cache = None if args.no_cache else args.cache
    records = sweep(base, grid, cache_dir=cache,
                    max_workers=args.workers, force=args.force)
    _finish(records, args.out)
    return 1 if any(r.result.get("error") for r in records) else 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="Declarative experiment runner for the LambdaML "
                    "reproduction (see DESIGN.md §10).")
    sub = ap.add_subparsers(dest="command", required=True)

    sub.add_parser("list", help="list available presets").set_defaults(
        fn=cmd_list)

    lint_p = sub.add_parser(
        "lint",
        help="static project-invariant checks (DESIGN.md §15): "
             "determinism, spec-hash drift, registries, units, metering, "
             "constant duplication")
    lint_p.add_argument("paths", nargs="*", default=[],
                        help="files to lint (default: src/repro + "
                             "benchmarks; explicit paths skip the "
                             "tree-level checkers unless --select'ed)")
    lint_p.add_argument("--select", default=None, metavar="A,B",
                        help="comma-separated checker names (see `list`)")
    lint_p.add_argument("--format", default="text",
                        choices=("text", "json"),
                        help="finding output format")
    lint_p.add_argument("--write-manifest", action="store_true",
                        help="regenerate the spec-hash manifest (refuses "
                             "over an unbumped schema change)")
    lint_p.set_defaults(fn=cmd_lint)

    common = argparse.ArgumentParser(add_help=False)
    common.add_argument("target",
                        help="preset name (see `list`) or spec JSON file")
    size = common.add_mutually_exclusive_group()
    size.add_argument("--quick", action="store_true",
                      help="small CI-friendly sizes (the default)")
    size.add_argument("--full", action="store_true",
                      help="paper-scale sizes")
    common.add_argument("--set", action="append", metavar="FIELD=VALUE",
                        help="override a spec field on every trial "
                             "(dotted paths reach nested specs)")
    common.add_argument("--cache", default=str(DEFAULT_CACHE),
                        help="record cache dir (default experiments/runs/)")
    common.add_argument("--no-cache", action="store_true",
                        help="do not read or write the record cache")
    common.add_argument("--force", action="store_true",
                        help="re-run even on a cache hit")
    common.add_argument("--out", default=None,
                        help="also write all records to this JSON file")

    run_p = sub.add_parser("run", parents=[common],
                           help="run a preset or spec file")
    run_p.set_defaults(fn=cmd_run)

    sweep_p = sub.add_parser(
        "sweep", parents=[common],
        help="cartesian sweep over a preset's base spec")
    sweep_p.add_argument("--grid", action="append", metavar="FIELD=V1,V2",
                         help="one sweep axis (repeatable)")
    sweep_p.add_argument("--workers", type=int, default=0,
                         help="thread-pool size for independent trials")
    sweep_p.set_defaults(fn=cmd_sweep)

    plan_p = sub.add_parser(
        "plan", parents=[common],
        help="rank platform x fleet width for a workload via the §5.3 "
             "analytic model (DESIGN.md §13); target is a preset, a spec "
             "JSON, or a named paper workload (lr_higgs, "
             "mobilenet_cifar10, ...)")
    plan_p.add_argument("--objective", default="cheapest",
                        choices=("cheapest", "fastest"))
    plan_p.add_argument("--deadline-s", type=float, default=None,
                        help="only options finishing within this many "
                             "simulated seconds are feasible (default for "
                             "'cheapest': 1.25x the fastest option)")
    plan_p.add_argument("--budget-usd", type=float, default=None,
                        help="only options under this $ are feasible")
    plan_p.add_argument("--workers", default=None, metavar="W1,W2,...",
                        help="fleet widths to sweep (default: the Fig-11 "
                             "axis 1..300)")
    plan_p.add_argument("--platforms", default="faas,iaas",
                        metavar="P1,P2,...",
                        help="platforms to sweep (faas, iaas, pod; "
                             "default: faas,iaas)")
    plan_p.add_argument("--mfu", default="0.4",
                        help="pod MFU: a fraction in (0, 1], or 'measured' "
                             "to read the benchmarked roofline fraction "
                             "from BENCH_kernels.json")
    plan_p.set_defaults(fn=cmd_plan)

    trace_p = sub.add_parser(
        "trace",
        help="run a preset with the span recorder on (DESIGN.md §18): "
             "Figure-10 phase breakdown, conservation gates, Chrome export")
    trace_p.add_argument("target",
                         help="preset name (see `list`) or spec JSON file")
    tsize = trace_p.add_mutually_exclusive_group()
    tsize.add_argument("--quick", action="store_true",
                       help="small CI-friendly sizes (the default)")
    tsize.add_argument("--full", action="store_true",
                       help="paper-scale sizes")
    trace_p.add_argument("--set", action="append", metavar="FIELD=VALUE",
                         help="override a spec field on every trial")
    trace_p.add_argument("--export", default=None,
                         choices=("chrome", "perfetto"),
                         help="also write a trace-event JSON file")
    trace_p.add_argument("--out", default=None,
                         help="export file name (default <name>.chrome.json)")
    trace_p.set_defaults(fn=cmd_trace)

    serve_p = sub.add_parser(
        "serve",
        help="request-driven serving simulator (DESIGN.md §14): open-loop "
             "traffic, cold starts, continuous batching")
    serve_p.add_argument("target", nargs="?", default=None,
                         help="ServingSpec JSON file (default: a single "
                              "built-in spec shaped by --arrival)")
    serve_p.add_argument("--grid", action="store_true",
                         help="run the cost-vs-p99 frontier: faas/iaas/pod "
                              "x trickle/sustained/flash")
    serve_p.add_argument("--arrival", default="poisson:1",
                         metavar="PROCESS",
                         help="arrival grammar for the default spec "
                              "(poisson:<qps> | diurnal:... | flash:... | "
                              "trace:<file>)")
    serve_p.add_argument("--duration-s", type=float, default=300.0,
                         help="simulated traffic window (default 300)")
    serve_p.add_argument("--reduced", action="store_true",
                         help="serve the CPU-sized reduced arch variant")
    serve_p.add_argument("--set", action="append", metavar="FIELD=VALUE",
                         help="override a ServingSpec field (dotted paths "
                              "reach the fleet)")
    serve_p.add_argument("--cache", default=str(DEFAULT_CACHE),
                         help="record cache dir (default experiments/runs/)")
    serve_p.add_argument("--no-cache", action="store_true",
                         help="do not read or write the record cache")
    serve_p.add_argument("--force", action="store_true",
                         help="re-run even on a cache hit")
    serve_p.add_argument("--out", default=None,
                         help="also write all records to this JSON file")
    serve_p.set_defaults(fn=cmd_serve)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
