"""Failure-process registry + trace-driven spot preemptions (DESIGN.md §17).

The engine's :class:`~repro.core.engine.FailureProcess` hierarchy gets the
same registry treatment as arrivals/scaling/sync: a string grammar per
process, printed by ``repro list`` (R001), parse round-trip covered by the
registry checker (R002).

:class:`TracePreemptions` replays a RECORDED spot-preemption trace --
SMLT's (arXiv 2205.01853) point that real spot markets are bursty and
correlated, not a single Poisson rate.  A trace file is either whitespace
lines ``<sim_seconds> [<worker>]`` (``#`` comments allowed) or a JSON list
of times / ``[t, worker]`` pairs; events without a worker are assigned
round-robin over the fleet (deterministic -- no RNG is ever consumed, so
an EMPTY trace is byte-identical to a no-failure run).  Three recorded
fixtures ship under ``repro/core/traces/`` and resolve by bare name.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.engine import (
    FailureProcess, InjectedPreemptions, PoissonPreemptions,
)

#: bundled recorded traces, resolvable as ``trace:<name>``
TRACE_DIR = Path(__file__).parent / "traces"


def trace_fixtures() -> list[str]:
    """Names of the bundled preemption traces."""
    return sorted(p.stem for p in TRACE_DIR.glob("*.txt"))


def resolve_trace(name_or_path: str) -> Path:
    """A bare fixture name resolves to the bundled trace; anything else is
    treated as a filesystem path."""
    bundled = TRACE_DIR / f"{name_or_path}.txt"
    if "/" not in name_or_path and bundled.exists():
        return bundled
    return Path(name_or_path)


def load_trace(path: str | Path) -> tuple:
    """-> ``((sim_seconds, worker_or_None), ...)`` sorted by time.

    Accepts the whitespace line format (``t [worker]``, ``#`` comments) or
    a JSON list of times / ``[t, worker]`` pairs.
    """
    text = Path(path).read_text().strip()
    events = []
    if text.startswith("["):
        for item in json.loads(text):
            if isinstance(item, (list, tuple)):
                t, w = item[0], (int(item[1]) if len(item) > 1 else None)
            else:
                t, w = item, None
            events.append((float(t), w))
    else:
        for line in text.splitlines():
            line = line.split("#", 1)[0].strip()
            if not line:
                continue
            parts = line.split()
            events.append((float(parts[0]),
                           int(parts[1]) if len(parts) > 1 else None))
    return tuple(sorted(events, key=lambda e: e[0]))


class TracePreemptions(InjectedPreemptions):
    """Replay a recorded preemption trace against a ``workers``-wide fleet.

    Events that name a worker kill that stable worker id; events without
    one round-robin over the initial fleet in time order (event ``k`` ->
    worker ``k % workers``), so the same trace spreads proportionally over
    any fleet width.  Replay semantics are exactly
    :class:`InjectedPreemptions`: a kill recorded before a worker's current
    clock fires clamped to the present -- a scripted event never silently
    vanishes."""

    def __init__(self, events, workers: int):
        w = max(int(workers), 1)
        inject = tuple(
            ((wid if wid is not None else k % w), t)
            for k, (t, wid) in enumerate(events))
        super().__init__(inject)

    @classmethod
    def from_spec(cls, spec: str, workers: int) -> "TracePreemptions":
        """``"<fixture|path>"`` (an optional ``trace:`` head is stripped)."""
        head, _, arg = str(spec).partition(":")
        name = arg if head == "trace" and arg else str(spec)
        return cls(load_trace(resolve_trace(name)), workers)


#: the failure-process grammars, printed by ``repro list`` (R001); keep in
#: step with :func:`make_failure`
FAILURES: dict[str, str] = {
    "poisson": "poisson:<rate> -- memoryless kills, <rate> per worker-hour "
               "of healthy runtime",
    "inject": "inject:<w>@<t>[,<w>@<t>...] -- scripted kills at exact sim "
              "seconds",
    "trace": "trace:<fixture|path> -- replay a recorded preemption trace",
}


def make_failure(spec: str, *, workers: int, seed: int = 0) -> FailureProcess:
    """Build a failure process from its grammar string (the registry
    constructor; :meth:`repro.core.platform.FailureSpec.process` is the
    spec-driven path the platforms use)."""
    if isinstance(spec, FailureProcess):
        return spec
    head, _, arg = str(spec).partition(":")
    if head == "poisson":
        if not arg:
            raise ValueError("poisson needs a rate: poisson:<per-hour>")
        return PoissonPreemptions(float(arg), workers, seed)
    if head == "inject":
        if not arg:
            raise ValueError("inject needs kills: inject:<w>@<t>[,...]")
        at = []
        for item in arg.split(","):
            w, _, t = item.partition("@")
            at.append((int(w), float(t)))
        return InjectedPreemptions(tuple(at))
    if head == "trace":
        if not arg:
            raise ValueError(
                f"trace needs a file or fixture name: trace:<file> "
                f"(fixtures: {', '.join(trace_fixtures())})")
        return TracePreemptions(load_trace(resolve_trace(arg)), workers)
    raise KeyError(f"unknown failure process {spec!r}; available: "
                   f"{', '.join(sorted(FAILURES))}")


def list_failures() -> dict[str, str]:
    """name -> grammar line, printed by ``repro list`` (R001)."""
    out = dict(FAILURES)
    out["trace"] += f" (fixtures: {', '.join(trace_fixtures())})"
    return out
