"""``repro.core.ckpt``: the metered checkpoint subsystem (DESIGN.md §17).

Three pieces, one set of constants:

- :class:`CheckpointSpec` -- frozen spec + string grammar
  (``"s3:every=5:sharded"``) selecting a transport from the comm registry's
  storage channels (plus the EBS-backed ``local`` disk), a save cadence,
  and a sharding layout.  Printed by ``repro list``; parse/name round-trip
  under R002.
- :class:`Checkpointer` -- routes real shard bytes through the metered
  store so checkpoint seconds, wire bytes and request $ land in
  :class:`~repro.core.engine.RunResult` alongside the comm meters.
- :mod:`repro.core.ckpt.localfs` -- the ``local`` backend's atomic on-disk
  npz format (re-exported by :mod:`repro.checkpoint` for the seed-era
  import path).

``Platform.restart_time(model_bytes)`` derives from the same
:class:`ChannelSpec` constants via :meth:`CheckpointSpec.restore_seconds`,
so the engine's metered restarts, the planner's crossover and serving's
cold-start weight pulls can never disagree.
"""
from repro.core.ckpt.spec import (  # noqa: F401
    CKPT_TRANSPORTS, LOCAL_SPEC, CheckpointSpec, ckpt_transport_constants,
    list_ckpts, make_ckpt, make_ckpt_transport, shard_sizes,
)
from repro.core.ckpt.store import Checkpointer  # noqa: F401
