"""The checkpoint axis: :class:`CheckpointSpec` + its transport registry.

A checkpoint spec picks *where* checkpoints live (a metered transport from
the comm registry's storage channels, or the instance-local EBS disk), *how
often* the fleet saves (``every=N`` sync rounds; 0 keeps the save-at-kill
semantics of the seed engine), and *how* the model is laid out (``sharded``
splits it one shard per worker -- which is also what makes models larger
than a transport's per-item limit feasible, e.g. DynamoDB's 400 KB).

String grammar (same registry conventions as comm/sync/scaling/arrivals,
``repro list`` prints it, parse/name round-trip under R002)::

    <transport>[:every=<N>][:sharded]      e.g. "s3:every=5:sharded"
    every=<N>[:sharded]                    platform-default store + cadence

Everything downstream -- the engine's metered save/restore
(:class:`repro.core.ckpt.store.Checkpointer`), the platforms' derived
``restart_time(model_bytes)``, the planner's restart term, serving's
scale-up weight pulls -- reads the SAME :class:`ChannelSpec` constants, so
a checkpoint second is traceable to the same Table 6 sources as a comm
second.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.comm.transports import (
    CHANNEL_SPECS, EBS_BANDWIDTH, EBS_LATENCY, ChannelItemTooLarge,
    ChannelSpec, StorageChannel, xfer_seconds,
)

#: the "local" backend: instance-attached EBS disk (the B_EBS/L_EBS row the
#: analytical model always used).  Registered ONLY here -- a local disk is
#: not a fleet-wide comm substrate, so ``CommSpec(channel="local")`` stays
#: invalid while ``ckpt="local:every=5"`` works on every platform.
LOCAL_SPEC = ChannelSpec("local", EBS_BANDWIDTH, EBS_LATENCY, 0.0)

#: every selectable checkpoint transport: the comm registry's storage
#: channels plus the local-disk backend (one source of truth -- no second
#: copy of the Table 6 constants)
CKPT_TRANSPORTS: dict[str, ChannelSpec] = {**CHANNEL_SPECS,
                                           "local": LOCAL_SPEC}

_GRAMMAR = "[:every=<N>][:sharded]"


def shard_sizes(model_bytes: int, shards: int) -> list[int]:
    """Byte size of each checkpoint shard.  This is the SAME split the
    metered save/restore ships (fp32 words, last shard takes the
    remainder), so closed-form restart times equal metered ones exactly."""
    words = max(int(model_bytes) // 4, 1)
    if shards <= 1:
        return [4 * words]
    per = -(-words // shards)          # ceil-divide
    out = []
    for j in range(shards):
        n = min(per, words - j * per)
        if n <= 0:
            break
        out.append(4 * n)
    return out


@dataclass(frozen=True)
class CheckpointSpec:
    """One point of the checkpoint design space (frozen, hashable,
    JSON-round-trippable through :meth:`parse`/:attr:`name`).

    The default spec (``CheckpointSpec()``) reproduces the seed engine
    byte-for-byte: checkpoints ride the platform's default store (FaaS: the
    comm channel itself; IaaS/pod: ``CommSpec.ckpt_channel``) and a worker
    saves exactly when it is killed or rotates out of its lease.
    """
    transport: str | None = None   # None = the platform's default store
    every: int = 0                 # fleet checkpoint every N sync rounds;
                                   #   0 = save-at-kill (seed semantics)
    sharded: bool = False          # one shard per worker (fixed at start)

    def __post_init__(self):
        if (self.transport is not None
                and self.transport not in CKPT_TRANSPORTS):
            raise KeyError(
                f"unknown checkpoint transport {self.transport!r}; "
                f"available: {', '.join(sorted(CKPT_TRANSPORTS))}")
        if int(self.every) < 0:
            raise ValueError(f"every must be >= 0, got {self.every}")
        object.__setattr__(self, "every", int(self.every))
        object.__setattr__(self, "sharded", bool(self.sharded))

    # ---- the string grammar -------------------------------------------------
    @classmethod
    def parse(cls, text) -> "CheckpointSpec":
        """``"<transport>[:every=<N>][:sharded]"`` -> CheckpointSpec; the
        empty string (or None) is the default spec."""
        if isinstance(text, cls):
            return text
        if not text:
            return cls()
        transport, every, sharded = None, 0, False
        for idx, part in enumerate(str(text).split(":")):
            if part.startswith("every="):
                every = int(part[len("every="):])
            elif part == "sharded":
                sharded = True
            elif idx == 0:
                transport = part
            else:
                raise ValueError(
                    f"bad checkpoint spec segment {part!r} in {text!r} "
                    f"(grammar: <transport>{_GRAMMAR})")
        return cls(transport=transport, every=every, sharded=sharded)

    @property
    def name(self) -> str:
        """Canonical grammar string; ``parse(name)`` round-trips (R002) and
        the default spec serializes to ``""``."""
        parts = []
        if self.transport is not None:
            parts.append(self.transport)
        if self.every:
            parts.append(f"every={self.every}")
        if self.sharded:
            parts.append("sharded")
        return ":".join(parts)

    # ---- layout + feasibility -----------------------------------------------
    def shards(self, workers: int) -> int:
        return max(int(workers), 1) if self.sharded else 1

    def validate(self, *, model_bytes=None, workers: int | None = None) -> None:
        """Spec-time feasibility: every shard must fit the transport's
        per-item limit (DynamoDB's 400 KB -> an eager
        :class:`ChannelItemTooLarge`, the checkpoint mirror of Table 1's
        "N/A" cells).  ``model_bytes`` may be a callable for lazy
        estimation, mirroring :meth:`CommSpec.validate`."""
        if self.transport is None or model_bytes is None:
            return
        ch = CKPT_TRANSPORTS[self.transport]
        if ch.max_item is None:
            return
        mb = model_bytes() if callable(model_bytes) else model_bytes
        biggest = max(shard_sizes(int(mb), self.shards(workers or 1)))
        if biggest > ch.max_item:
            hint = ("" if self.sharded
                    else " -- shard it (ckpt='...:sharded') or pick a "
                         "transport without a per-item limit")
            raise ChannelItemTooLarge(
                f"checkpoint shard ({biggest} B) exceeds {ch.name}'s "
                f"per-item limit ({ch.max_item} B){hint}")

    # ---- derived restart ----------------------------------------------------
    def restore_seconds(self, model_bytes: int, channel: ChannelSpec,
                        workers: int = 1) -> float:
        """Closed-form seconds to pull a ``model_bytes`` checkpoint through
        ``channel``: the SAME per-shard transfer arithmetic the metered
        store charges (:func:`xfer_seconds` over :func:`shard_sizes`), so
        the planner's derived restart equals the engine's metered one to
        the last bit."""
        return sum(xfer_seconds(channel, s)
                   for s in shard_sizes(model_bytes, self.shards(workers)))


def make_ckpt(spec) -> CheckpointSpec:
    """Registry-style constructor: string grammar, dict, CheckpointSpec or
    None -> CheckpointSpec."""
    if isinstance(spec, CheckpointSpec):
        return spec
    if isinstance(spec, dict):
        return CheckpointSpec(**spec)
    return CheckpointSpec.parse(spec)


def ckpt_transport_constants(name: str) -> ChannelSpec:
    """Constants for any name a checkpoint may ride -- the registry's own
    transports first, then the comm registry (platform defaults like vmps
    resolve here)."""
    try:
        return CKPT_TRANSPORTS[name]
    except KeyError:
        from repro.core.comm.transports import transport_constants
        return transport_constants(name)


def make_ckpt_transport(name: str) -> StorageChannel:
    """A metered store for a checkpoint-transport registry name (the
    storage services, or the EBS-constant ``local`` channel)."""
    try:
        return StorageChannel(CKPT_TRANSPORTS[name])
    except KeyError:
        raise KeyError(
            f"unknown checkpoint transport {name!r}; available: "
            f"{', '.join(sorted(CKPT_TRANSPORTS))}") from None


def list_ckpts() -> dict[str, str]:
    """name -> grammar line, printed by ``repro list`` (R001)."""
    return {name: f"{name}{_GRAMMAR}" for name in CKPT_TRANSPORTS}
