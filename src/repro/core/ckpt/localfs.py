"""The ``local`` backend's on-disk format: atomic npz tree checkpoints.

This is the file machinery that used to live (duplicated from the metered
path) in :mod:`repro.checkpoint`: flatten a pytree to flat npz keys
(``a//b//#0``), encode bf16 leaves as uint16 views (npz cannot store
ml_dtypes), commit atomically (tmp + fsync + rename) so a preemption
mid-write never corrupts the latest checkpoint, and resume from
``load_latest``.  The metered side of the same backend is
:data:`repro.core.ckpt.LOCAL_SPEC` (EBS constants) -- one flatten/manifest
format for both the simulator's accounting and real on-disk saves.

:mod:`repro.checkpoint` re-exports everything here unchanged (plus the
wall-clock :class:`~repro.checkpoint.PreemptionGuard`, which must stay
outside ``repro/core`` -- the simulated core is lint-forbidden, D001, from
reading real time).
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Optional

import numpy as np

_SEP = "//"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{_SEP}{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{_SEP}#{i}" if prefix else f"#{i}"))
    else:
        out[prefix] = np.asarray(tree)
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, v in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("#") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][1:]))
            return [fix(v) for _, v in items]
        return {k: fix(v) for k, v in node.items()}
    return fix(root)


_BF16_TAG = "@bf16"


def _encode(arr: np.ndarray):
    """npz cannot store ml_dtypes.bfloat16 -- save as a uint16 view."""
    if arr.dtype.name == "bfloat16":
        return arr.view(np.uint16), True
    return arr, False


def _decode(arr: np.ndarray, is_bf16: bool):
    if is_bf16:
        import ml_dtypes  # ships with jax
        return arr.view(ml_dtypes.bfloat16)
    return arr


def save(directory: str | Path, step: int, tree: Any,
         metadata: Optional[dict] = None) -> Path:
    """Atomic checkpoint commit: write tmp, fsync, rename."""
    import jax

    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    flat = {}
    for k, v in _flatten(jax.tree.map(np.asarray, tree)).items():
        enc, is_bf16 = _encode(v)
        flat[k + _BF16_TAG if is_bf16 else k] = enc
    tmp = directory / f".tmp-{step}-{os.getpid()}.npz"
    final = directory / f"step_{step:010d}.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)  # atomic on POSIX
    meta = dict(metadata or {})
    meta["step"] = step
    mtmp = directory / f".tmp-meta-{step}.json"
    mtmp.write_text(json.dumps(meta))
    os.replace(mtmp, directory / f"step_{step:010d}.json")
    return final


def list_steps(directory: str | Path) -> list[int]:
    directory = Path(directory)
    if not directory.exists():
        return []
    return sorted(int(p.stem.split("_")[1]) for p in directory.glob("step_*.npz"))


def load(directory: str | Path, step: int):
    directory = Path(directory)
    with np.load(directory / f"step_{step:010d}.npz") as z:
        flat = {}
        for k in z.files:
            if k.endswith(_BF16_TAG):
                flat[k[: -len(_BF16_TAG)]] = _decode(z[k], True)
            else:
                flat[k] = z[k]
    meta_p = directory / f"step_{step:010d}.json"
    meta = json.loads(meta_p.read_text()) if meta_p.exists() else {"step": step}
    return _unflatten(flat), meta


def load_latest(directory: str | Path):
    steps = list_steps(directory)
    if not steps:
        return None, None
    return load(directory, steps[-1])


def retain(directory: str | Path, keep: int = 3):
    steps = list_steps(directory)
    for s in steps[:-keep]:
        (Path(directory) / f"step_{s:010d}.npz").unlink(missing_ok=True)
        (Path(directory) / f"step_{s:010d}.json").unlink(missing_ok=True)
