"""The :class:`Checkpointer`: metered, sharded checkpoint save/restore.

One object per simulated run, wrapping the platform's checkpoint store
(the comm channel itself on FaaS, a dedicated :class:`StorageChannel` on
IaaS/pods or whenever ``CheckpointSpec.transport`` pins one).  Every save
and restore ships REAL shard payloads through the store's metered
``put``/``get`` -- so checkpoint seconds land on the worker clocks, wire
bytes and request $ accumulate here for :class:`RunResult`, and per-item
limits fire exactly like comm traffic does.

Default-spec parity contract: with ``CheckpointSpec()`` and one shard the
op sequence (keys, payload sizes, put/get order) is byte-identical to the
seed engine's inline rotate path, so no-failure fixed-seed runs reproduce
PR 8 exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np

from repro.core.ckpt.spec import CheckpointSpec, shard_sizes


@dataclass
class Checkpointer:
    """Routes checkpoint bytes through a metered transport and accounts
    for them (wire bytes, transfer seconds, request $) separately from the
    comm meters -- the FaaS default store is SHARED with comm traffic, so
    the split has to happen at this layer."""
    spec: CheckpointSpec
    store: Any                # metered put/get with a .spec ChannelSpec
    mbytes: int               # model checkpoint payload (fp32 bytes)
    shards: int = 1           # fixed at run start (initial fleet width)
    wire_bytes: float = 0.0   # checkpoint bytes moved (puts + gets)
    time_s: float = 0.0       # simulated transfer seconds (puts + gets)
    op_usd: float = 0.0       # request $ (puts + gets)
    puts: int = 0
    gets: int = 0
    last_ckpt_t: float = 0.0  # sim time of the last fleet checkpoint
    _last_save_rnd: int = 0
    rec: Any = None           # TraceRecorder (DESIGN.md §18): every shard
                              # put/get lands one "ckpt" byte event, in the
                              # exact order wire_bytes accumulates

    @property
    def every(self) -> int:
        return self.spec.every

    def _op_price(self, kind: str) -> float:
        ch = getattr(self.store, "spec", None)
        return float(getattr(ch, f"{kind}_cost", 0.0)) if ch else 0.0

    def _blobs(self, key: str) -> list:
        sizes = shard_sizes(self.mbytes, self.shards)
        if len(sizes) == 1:
            return [(key, np.zeros(sizes[0] // 4, np.float32))]
        return [(f"{key}/s{j}", np.zeros(n // 4, np.float32))
                for j, n in enumerate(sizes)]

    def save(self, key: str) -> float:
        """Put every shard under ``key``; returns the (sequential-stream)
        simulated seconds the saving worker stalls."""
        dt = 0.0
        for k, blob in self._blobs(key):
            dt += self.store.put(k, blob)
            self.wire_bytes += blob.nbytes
            self.op_usd += self._op_price("put")
            self.puts += 1
            if self.rec is not None:
                self.rec.bytes_event("ckpt", blob.nbytes,
                                     meta={"op": "put", "key": k})
        self.time_s += dt
        return dt

    def restore(self, key: str) -> float:
        """Get every shard back; returns the simulated restore seconds."""
        dt = 0.0
        for k, blob in self._blobs(key):
            _, d = self.store.get(k)
            dt += d
            self.wire_bytes += blob.nbytes
            self.op_usd += self._op_price("get")
            self.gets += 1
            if self.rec is not None:
                self.rec.bytes_event("ckpt", blob.nbytes,
                                     meta={"op": "get", "key": k})
        self.time_s += dt
        return dt

    # ---- cadence (CheckpointSpec.every) -------------------------------------
    def due(self, rnd: int) -> bool:
        """True when a periodic fleet save is owed at sync round ``rnd``
        (rounds-since-last-save accounting, so LocalSGD's sparse boundaries
        still checkpoint at roughly the requested cadence)."""
        return self.every > 0 and (rnd - self._last_save_rnd) >= self.every

    def mark(self, rnd: int, t: float) -> None:
        """Record that a fleet checkpoint landed at round ``rnd``, sim
        time ``t`` (what preemption rework is measured against)."""
        self._last_save_rnd = int(rnd)
        self.last_ckpt_t = float(t)
