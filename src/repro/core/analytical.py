"""The paper's analytical cost/performance model (§5.3, Table 6).

    FaaS(w) = t_F(w) + s/B_S3
              + R_F * f_F(w) * [ (3w-2) * (m/w/B_ch + L_ch) + C_F/w ]
    IaaS(w) = t_I(w) + s/min(B_S3, B_n)
              + R_I * f_I(w) * [ (2w-2) * (m/w/B_n + L_n) + C_I/w ]

(s = dataset MB, m = model MB, R = epochs to converge on one worker, f(w) =
convergence scaling factor, C = single-worker epoch compute seconds.)

Includes the Table 6 constants, a sampling-based epoch estimator (Kaoudi et
al. [54], 10% sample), and the Q1/Q2 what-if studies (faster FaaS-IaaS
link / GPU-FaaS pricing; hot data).

The ``(s, m, R, C)`` constants are ONE derivation away from the simulator:
:meth:`CostInputs.from_workload` computes them from any
:class:`repro.core.workloads.Workload`, so the analytic curves and the
discrete-event sweeps describe the same workload by construction
(cross-checked in ``tests/test_workloads.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.comm.codecs import make_codec
from repro.core.comm.transports import (
    CHANNEL_SPECS, EBS_BANDWIDTH, EBS_LATENCY, VMParameterServer,
    transport_constants, xfer_seconds)
from repro.core.runtimes import (
    _T_FAAS, _T_IAAS, _T_POD, B_NET, L_NET, LIFETIME, LIFETIME_MARGIN,
    POD_DCN_BANDWIDTH, POD_DCN_LATENCY, interp_startup,
)

# ------------------------------- Table 6 -------------------------------------
# Derived from the SAME Transport constants the simulator meters with
# (repro.core.comm.transports.CHANNEL_SPECS and the runtimes' NIC tables):
# the analytic curves and the discrete-event sweeps read one source of
# truth by construction -- Table 6 is a *view*, not a second copy.
TABLE6 = {
    "t_F": dict(_T_FAAS),
    "t_I": dict(_T_IAAS),
    "B_S3": CHANNEL_SPECS["s3"].bandwidth, "B_EBS": EBS_BANDWIDTH,
    "B_n": {k: B_NET[k] for k in ("t2.medium", "c5.large")},
    "B_EC": {"cache.t3.medium": CHANNEL_SPECS["memcached"].bandwidth,
             "cache.m5.large": CHANNEL_SPECS["memcached_large"].bandwidth},
    "L_S3": CHANNEL_SPECS["s3"].latency, "L_EBS": EBS_LATENCY,
    "L_n": {k: L_NET[k] for k in ("t2.medium", "c5.large")},
    "L_EC": {"cache.t3.medium": CHANNEL_SPECS["memcached"].latency},
}


@dataclass
class CostInputs:
    """The analytic model's ``(s, m, R, C)`` constants for one workload.

    Historically this class was also called ``Workload``, colliding with
    the engine-facing :class:`repro.core.workloads.Workload` protocol; that
    protocol is now the one source of truth and :meth:`from_workload`
    derives these constants from it (``Workload`` remains as a
    backwards-compatible alias here).
    """
    s_bytes: float          # dataset size
    m_bytes: float          # model size
    R: float                # single-worker epochs to target loss
    C: float                # single-worker seconds per epoch
    f: callable = field(default=lambda w: 1.0)  # convergence scaling

    @classmethod
    def from_workload(cls, workload, ds_train, *, R: float | None = None,
                      algo=None, target_loss: float | None = None,
                      worker_flops: float | None = None, params=None,
                      f=None) -> "CostInputs":
        """Derive the constants from an engine workload (study stand-in or
        real architecture): ``s`` = the training partition's bytes, ``m`` =
        the fp32 update-vector bytes
        (:func:`repro.core.workloads.update_vector_bytes`), ``C`` = dataset
        rows x ``flops_per_row`` over one worker's FLOP/s (default: the
        t2.medium CPU model, matching the paper's C^F ~= C^I calibration),
        and ``R`` either given explicitly or measured with the sampling
        estimator [54] (needs ``algo`` + ``target_loss``)."""
        from repro.core import cost as pricing
        from repro.core.workloads import update_vector_bytes

        if worker_flops is None:
            worker_flops = pricing.VM_CPU_FLOPS
        if R is None:
            if algo is None or target_loss is None:
                raise ValueError("pass R= explicitly, or algo= and "
                                 "target_loss= for the sampling estimator")
            R = estimate_epochs(workload, algo, ds_train, target_loss)
        kw = {} if f is None else {"f": f}
        return cls(s_bytes=float(ds_train.nbytes),
                   m_bytes=float(update_vector_bytes(workload, params)),
                   R=float(R),
                   C=ds_train.n * workload.flops_per_row / worker_flops,
                   **kw)


def __getattr__(name: str):
    """Deprecated alias: ``Workload`` was the pre-§11 name of
    :class:`CostInputs` and now collides with the engine-facing
    :class:`repro.core.workloads.Workload` protocol.  Importing it here
    still works but warns; new code should use ``CostInputs``."""
    if name == "Workload":
        import warnings
        warnings.warn(
            "repro.core.analytical.Workload is a deprecated alias of "
            "CostInputs (the engine-facing Workload protocol lives in "
            "repro.core.workloads); import CostInputs instead",
            DeprecationWarning, stacklevel=2)
        return CostInputs
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")


def wire_bytes(m_bytes: float, codec: str = "fp32") -> float:
    """Per-round wire bytes after a :mod:`repro.core.comm.codecs` codec --
    the same ``wire_floats`` the simulator meters ``comm_bytes`` with, so
    the analytic what-ifs (sparsified updates flipping the FaaS verdict,
    MLLess-style) use the exact simulator ratios."""
    c = make_codec(codec)
    if c.is_identity:
        return float(m_bytes)
    n = max(int(m_bytes) // 4, 1)
    return float(c.wire_floats(n) * 4)


def restart_seconds(platform: str, m_bytes: float = 0.0, *,
                    ckpt: object = None, channel: str = "s3",
                    workers: int = 1) -> float:
    """DERIVED worker-restart seconds (DESIGN.md §17): platform startup
    for one replacement worker plus the metered restore of the model's
    actual byte size through the checkpoint transport -- the same
    :func:`~repro.core.comm.transports.xfer_seconds` /
    :meth:`~repro.core.ckpt.CheckpointSpec.restore_seconds` arithmetic the
    simulator bills, so the planner's crossover and the discrete-event
    meters cannot drift.  ``ckpt`` is a :class:`~repro.core.ckpt.
    CheckpointSpec` or grammar string; with no explicit transport the
    restore reads ``channel``'s constants (the engine's default store)."""
    from repro.core.ckpt import ckpt_transport_constants, make_ckpt
    table = {"faas": _T_FAAS, "iaas": _T_IAAS, "pod": _T_POD}[platform]
    startup = interp_startup(table, 1)
    if m_bytes <= 0:
        return startup
    spec = make_ckpt(ckpt)
    ch = ckpt_transport_constants(spec.transport or channel)
    return startup + spec.restore_seconds(m_bytes, ch, workers)


def faas_time(wl: CostInputs, w: int, *, channel: str = "s3",
              codec: str = "fp32") -> float:
    """§5.3 FaaS(w), over ANY storage transport's Table 6 constants
    (``channel`` accepts every :mod:`repro.core.comm` storage transport
    name; the legacy ``"elasticache"`` alias maps to memcached) and any
    codec's wire ratio.  Runs longer than one Lambda lease add the
    lifetime-rotation overhead: one checkpoint save + derived restart
    per elapsed lease (zero for runs shorter than a lease)."""
    spec = transport_constants(
        "memcached" if channel == "elasticache" else channel)
    b, lat = spec.bandwidth, spec.latency
    m = wire_bytes(wl.m_bytes, codec)
    t = interp_startup(TABLE6["t_F"], w) + wl.s_bytes / w / TABLE6["B_S3"]
    per_round = (3 * w - 2) * (m / w / b + lat) + wl.C / w
    train_span = wl.R * wl.f(w) * per_round
    n_rot = int(train_span // (LIFETIME - LIFETIME_MARGIN))
    if n_rot:       # ckpt save + re-invoke + restore, once per lease
        t += n_rot * (xfer_seconds(spec, wl.m_bytes)
                      + restart_seconds("faas", wl.m_bytes, channel=channel))
    return t + train_span


def iaas_time(wl: CostInputs, w: int, *, instance: str = "t2.medium") -> float:
    bn = TABLE6["B_n"][instance]
    ln = TABLE6["L_n"][instance]
    t = interp_startup(TABLE6["t_I"], w) + wl.s_bytes / w / min(TABLE6["B_S3"], bn)
    per_round = (2 * w - 2) * (wl.m_bytes / w / bn + ln) + wl.C / w
    return t + wl.R * wl.f(w) * per_round


def faas_cost(wl: CostInputs, w: int, t: float, gb: float = 3.0) -> float:
    from repro.core import cost as pricing
    return pricing.lambda_cost(gb, t * w, w)


def iaas_cost(wl: CostInputs, w: int, t: float,
              instance: str = "t2.medium") -> float:
    from repro.core import cost as pricing
    return pricing.ec2_cost(instance, t, w)


def pod_time(wl: CostInputs, w: int, *, chips_per_pod: int = 4,
             mfu: float | str = 0.4, codec: str = "fp32") -> float:
    """Pod(w): the :class:`~repro.core.runtimes.PodPlatform` analogue of
    FaaS(w)/IaaS(w) -- pod provisioning + S3 data load + ``R * f(w)``
    rounds of a cross-pod DCN ring all-reduce and roofline-discounted
    compute.  ``mfu="measured"`` reads the benchmarked compute-bound
    roofline fraction (:mod:`repro.core.calibration`), so the analytic pod
    rows derive from measurements, not the asserted 0.4."""
    from repro.core import cost as pricing
    from repro.core.calibration import resolve_mfu
    from repro.distributed.roofline import PEAK_FLOPS

    mfu = resolve_mfu(mfu)
    m = wire_bytes(wl.m_bytes, codec)
    # wl.C is single-worker epoch seconds on the t2.medium CPU model
    # (CostInputs' calibration); rescale to one slice's discounted FLOP/s
    c_pod = wl.C * pricing.VM_CPU_FLOPS / (chips_per_pod * PEAK_FLOPS * mfu)
    t = interp_startup(_T_POD, w) + wl.s_bytes / w / TABLE6["B_S3"]
    per_round = (2 * w - 2) * (m / w / POD_DCN_BANDWIDTH + POD_DCN_LATENCY) \
        + c_pod / w
    return t + wl.R * wl.f(w) * per_round


def pod_cost(wl: CostInputs, w: int, t: float,
             chips_per_pod: int = 4) -> float:
    from repro.core import cost as pricing
    return w * chips_per_pod * pricing.TPU_CHIP_HOURLY * t / 3600.0


# ----------------------------- epoch estimator --------------------------------

def estimate_epochs(model, algo, ds, target_loss: float, *, sample_frac=0.1,
                    max_epochs=100, seed=0) -> float:
    """Sampling-based estimator [54]: train on a 10% sample single-worker,
    count epochs to the target; also calibrates C (epoch seconds)."""
    import jax
    from repro.data.synthetic import Dataset

    n = max(int(ds.n * sample_frac), 64)
    sub = Dataset(ds.name, ds.x[:n], ds.y[:n],
                  None if ds.idx is None else ds.idx[:n], ds.dim, ds.n_classes)
    params = model.init(jax.random.key(seed))
    st = algo.init_worker(model, params, sub)
    for ep in range(1, max_epochs + 1):
        upd = algo.local_update(model, st, ep - 1)
        algo.apply_merged(model, st, upd, 1)
        if model.eval_loss(algo.eval_params(st), sub) <= target_loss:
            return float(ep)
    return float(max_epochs)


# ------------------------------- what-ifs (§5.3.1) ----------------------------

def hybridps_time(wl: CostInputs, w: int, *,
                  bandwidth: float = VMParameterServer.base_bw,
                  update_unit: float = VMParameterServer.update_unit) -> float:
    """Hybrid VM-PS FaaS: 2 transfers + PS update per round."""
    t = interp_startup(TABLE6["t_F"], w) + wl.s_bytes / w / TABLE6["B_S3"]
    per_round = (2 * wl.m_bytes / bandwidth
                 + update_unit * wl.m_bytes * w + wl.C / w)
    return t + wl.R * wl.f(w) * per_round


def q1_fast_hybrid(wl: CostInputs, w: int) -> dict:
    """Q1: 10 GB/s FaaS<->VM link, no serialization bottleneck."""
    return {
        "hybrid_now": hybridps_time(wl, w),
        "hybrid_10GBps": hybridps_time(wl, w, bandwidth=10e9, update_unit=0.0),
        "faas_s3": faas_time(wl, w),
        "iaas": iaas_time(wl, w),
    }


def q2_hot_data(wl: CostInputs, w: int) -> dict:
    """Q2: data pre-resident on a VM; everyone reads from that VM."""
    bn = TABLE6["B_n"]["t2.medium"]
    iaas_hot = iaas_time(wl, w) - wl.s_bytes / w / TABLE6["B_S3"] \
        + wl.s_bytes / w / bn
    # FaaS must still pull from the VM at Lambda-to-EC2 speed (~40.5 MB/s)
    faas_hot = faas_time(wl, w) - wl.s_bytes / w / TABLE6["B_S3"] \
        + wl.s_bytes / w / VMParameterServer.base_bw
    return {"iaas_hot": iaas_hot, "faas_hot": faas_hot}
