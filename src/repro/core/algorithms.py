"""Distributed optimization algorithms of the study (§3.2.1, §4.2):
GA-SGD, MA-SGD, consensus ADMM (convex models), EM k-means.

Each algorithm is a pure strategy object: the SAME implementation runs under
the FaaS and the IaaS runtime (paper principle 1), which only differ in how
they time/merge the flat update vectors.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core.mlmodels import StudyModel
from repro.data.synthetic import Dataset


def _batches(part: Dataset, batch_size: int):
    n = part.n
    for lo in range(0, n, batch_size):
        hi = min(lo + batch_size, n)
        b = {"x": jnp.asarray(part.x[lo:hi]), "y": jnp.asarray(part.y[lo:hi])}
        if part.sparse:
            b["idx"] = jnp.asarray(part.idx[lo:hi])
        yield b


@dataclass
class WorkerState:
    part: Dataset
    params: Any
    extra: dict = field(default_factory=dict)


class Algorithm:
    name = "base"
    convex_only = False
    #: True when local_update returns an additive update vector (a gradient)
    #: that can be accumulated across rounds and applied to older params --
    #: the contract repro.core.sync.LocalSGD builds on.  MA/ADMM/EM ship
    #: full params / statistics instead.
    additive_update = False

    def __init__(self, lr: float = 0.1, batch_size: int = 4096):
        self.lr = lr
        self.batch_size = batch_size

    def init_worker(self, model: StudyModel, params, part: Dataset) -> WorkerState:
        return WorkerState(part, params)

    def rounds_per_epoch(self, part: Dataset) -> int:
        raise NotImplementedError

    def rows_per_round(self, part: Dataset) -> int:
        raise NotImplementedError

    def local_update(self, model, st: WorkerState, rnd: int) -> np.ndarray:
        raise NotImplementedError

    def apply_merged(self, model, st: WorkerState, merged: np.ndarray, w: int):
        raise NotImplementedError

    def eval_params(self, st: WorkerState):
        return st.params


class GASGD(Algorithm):
    """Gradient averaging: sync every mini-batch."""
    name = "ga_sgd"
    additive_update = True

    def rounds_per_epoch(self, part):
        return max(1, -(-part.n // self.batch_size))

    def rows_per_round(self, part):
        return min(self.batch_size, part.n)

    def init_worker(self, model, params, part):
        st = WorkerState(part, params)
        st.extra["unravel"] = ravel_pytree(params)[1]
        st.extra["bi"] = 0
        return st

    def local_update(self, model, st, rnd):
        n = st.part.n
        bs = min(self.batch_size, n)
        lo = (rnd * bs) % max(n - bs + 1, 1)
        b = {"x": jnp.asarray(st.part.x[lo:lo + bs]),
             "y": jnp.asarray(st.part.y[lo:lo + bs])}
        if st.part.sparse:
            b["idx"] = jnp.asarray(st.part.idx[lo:lo + bs])
        _, g = model.grad(st.params, b)
        return np.asarray(ravel_pytree(g)[0], np.float32)

    def apply_merged(self, model, st, merged, w):
        flat, unravel = ravel_pytree(st.params)
        st.params = unravel(flat - self.lr * jnp.asarray(merged))


class MASGD(Algorithm):
    """Model averaging: local SGD for `local_epochs`, then average params
    every round (the merge pattern does the averaging).

    The generalized form -- sync every H mini-batch rounds, optional DiLoCo
    outer optimizer / int8 delta compression -- is the
    :class:`repro.core.sync.LocalSGD` protocol, which shares its outer-step
    math (`DiLoCoOuter`, `quantize_int8_ef`) with the real pod stack in
    :mod:`repro.distributed.local_sgd`; prefer ``sync="local:<H>"`` over
    stacking `local_epochs` when the sweep axis is communication interval.
    """
    name = "ma_sgd"

    def __init__(self, lr=0.1, batch_size=4096, local_epochs: int = 1):
        super().__init__(lr, batch_size)
        self.local_epochs = local_epochs

    def rounds_per_epoch(self, part):
        return 1  # one sync per local_epochs epochs; epoch accounting below

    def rows_per_round(self, part):
        return part.n * self.local_epochs

    def local_update(self, model, st, rnd):
        params = st.params
        for _ in range(self.local_epochs):
            for b in _batches(st.part, self.batch_size):
                _, g = model.grad(params, b)
                flat, unravel = ravel_pytree(params)
                params = unravel(flat - self.lr * ravel_pytree(g)[0])
        st.params = params
        return np.asarray(ravel_pytree(params)[0], np.float32)

    def apply_merged(self, model, st, merged, w):
        _, unravel = ravel_pytree(st.params)
        st.params = unravel(jnp.asarray(merged))


class ADMM(Algorithm):
    """Consensus ADMM (Boyd et al.): x-update via `local_epochs` SGD epochs on
    the augmented Lagrangian, z-update in closed form for L2, dual ascent.
    Convex models only (the paper shows it fails for NNs, §4.2)."""
    name = "admm"
    convex_only = True

    def __init__(self, lr=0.05, batch_size=4096, rho: float = 0.01,
                 local_epochs: int = 10, l2: float = 1e-4):
        super().__init__(lr, batch_size)
        self.rho = rho
        self.local_epochs = local_epochs
        self.l2 = l2

    def rounds_per_epoch(self, part):
        return 1

    def rows_per_round(self, part):
        return part.n * self.local_epochs

    def init_worker(self, model, params, part):
        st = WorkerState(part, params)
        flat = np.asarray(ravel_pytree(params)[0], np.float32)
        st.extra["x"] = flat.copy()
        st.extra["u"] = np.zeros_like(flat)
        st.extra["z"] = flat.copy()
        return st

    def local_update(self, model, st, rnd):
        _, unravel = ravel_pytree(st.params)
        x = jnp.asarray(st.extra["x"])
        zu = jnp.asarray(st.extra["z"] - st.extra["u"])
        rho = self.rho
        for _ in range(self.local_epochs):
            for b in _batches(st.part, self.batch_size):
                _, g = model.grad(unravel(x), b)
                g = ravel_pytree(g)[0] + rho * (x - zu)
                x = x - self.lr * g
        st.extra["x"] = np.asarray(x, np.float32)
        return st.extra["x"] + st.extra["u"]

    def apply_merged(self, model, st, merged, w):
        # merged = avg(x_i + u_i); z* = w*rho*merged / (l2 + w*rho)
        z = merged * (w * self.rho / (self.l2 + w * self.rho))
        st.extra["u"] = st.extra["u"] + st.extra["x"] - z
        st.extra["z"] = z
        _, unravel = ravel_pytree(st.params)
        st.params = unravel(jnp.asarray(z))


class EMKMeans(Algorithm):
    """One EM round per epoch: merge (sums, counts), recompute centroids."""
    name = "kmeans_em"

    def rounds_per_epoch(self, part):
        return 1

    def rows_per_round(self, part):
        return part.n

    def local_update(self, model, st, rnd):
        b = {"x": jnp.asarray(st.part.x), "y": jnp.asarray(st.part.y)}
        s = model.local_stats(st.params, b)
        return np.concatenate([np.asarray(s["sums"], np.float32).ravel(),
                               np.asarray(s["counts"], np.float32)])

    def apply_merged(self, model, st, merged, w):
        k, d = st.params.shape
        sums = (merged[: k * d] * w).reshape(k, d)   # undo pattern's averaging
        counts = merged[k * d:] * w
        st.params = jnp.where(counts[:, None] > 0,
                              sums / np.maximum(counts[:, None], 1.0),
                              st.params)


def make_algorithm(name: str, **kw) -> Algorithm:
    return {"ga_sgd": GASGD, "ma_sgd": MASGD, "admm": ADMM,
            "kmeans_em": EMKMeans}[name](**kw)
