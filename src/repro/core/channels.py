"""Storage-mediated communication channels -- COMPAT SHIM.

The implementations moved to :mod:`repro.core.comm.transports` when the
communication subsystem became the composable Transport x Collective x
Codec API (DESIGN.md §12).  This module re-exports the seed-era surface so
existing imports keep working; new code should import from
:mod:`repro.core.comm`.
"""
from repro.core.comm.transports import (  # noqa: F401
    CHANNEL_SPECS, ChannelItemTooLarge, ChannelSpec, StorageChannel,
    VMNetwork, VMParameterServer, nbytes,
)

__all__ = ["CHANNEL_SPECS", "ChannelItemTooLarge", "ChannelSpec",
           "StorageChannel", "VMNetwork", "VMParameterServer", "nbytes"]
