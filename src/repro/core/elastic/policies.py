"""Scaling policies and the elastic fleet controller (DESIGN.md §13).

A :class:`ScalingPolicy` observes one :class:`~repro.core.elastic.telemetry.
Telemetry` snapshot per sync boundary and returns the fleet width it wants
next (0 = stop the run).  Policies are selected by the same string-grammar
convention as sync protocols and comm stacks, via
``ExperimentSpec(scaling=...)`` / ``FaaSRuntime(scaling=...)``:

- ``static``                -- never resize (the default; parity-pinned:
  the engine takes the exact pre-elastic code path),
- ``schedule:<w@round,...>`` -- declarative resize plan
  (``"schedule:2@0,8@5"`` = 2 workers from round 0, 8 from round 5),
- ``smlt``                  -- SMLT-style adaptive scaling (Ali et al.,
  PAPERS.md): widen while the per-round progress rate (loss drop x
  throughput) keeps improving, narrow once statistical efficiency decays,
- ``cost_cap:<dollars>``    -- MLLess-style budget guard (Sarroca &
  Sánchez-Artigas, PAPERS.md): shed workers to stretch the remaining
  budget, stop before overshooting it by more than one round's spend,
- ``plan[:<objective>]``    -- use the analytic planner's pick
  (:mod:`repro.core.elastic.planner`) as the initial fleet, then run
  static.  Resolved at spec level (it needs the workload constants), so
  :func:`make_policy` refuses it with a pointer.

The :class:`ElasticController` is the engine-facing half: it builds the
telemetry from the :class:`~repro.core.engine.SimContext`, clamps the
policy's answer to the FleetSpec's ``min_workers``/``max_workers``, and
performs the resize through ``ctx.resize``.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.elastic.telemetry import Telemetry

#: hard ceiling when FleetSpec.max_workers is unset -- generous beyond the
#: paper's 300-worker measurements, but keeps a runaway policy bounded
MAX_FLEET = 1000


@runtime_checkable
class ScalingPolicy(Protocol):
    """The decision surface of elastic fleet control (DESIGN.md §13)."""

    name: str

    def initial_workers(self, w0: int) -> int:
        """Fleet width to START with (``w0`` = the FleetSpec's); lets a
        schedule's round-0 entry apply before anything is invoked."""
        ...

    def observe(self, t: Telemetry) -> int:
        """Target width for the next rounds; 0 stops the run.  The
        controller clamps the answer to ``[min_workers, max_workers]``."""
        ...


class StaticPolicy:
    """Never resize.  :func:`build_controller` maps this to *no controller
    at all*, so the engine runs the exact fixed-fleet code path -- the
    byte-identity contract the parity tests pin."""
    name = "static"

    def initial_workers(self, w0: int) -> int:
        return w0

    def observe(self, t: Telemetry) -> int:
        return t.workers


class SchedulePolicy:
    """Declarative resize plan: ``schedule:<w@round,...>``.

    Entry ``w@r`` means "run with ``w`` workers from round ``r`` on"; the
    latest entry at or before the current round wins.  A round-0 entry
    also pins the INITIAL fleet (applied before startup, so nothing is
    invoked twice)."""
    name = "schedule"

    def __init__(self, plan):
        entries = sorted((int(r), int(w)) for r, w in plan)
        if not entries:
            raise ValueError("schedule needs at least one w@round entry")
        rounds = [r for r, _ in entries]
        if len(set(rounds)) != len(rounds):
            raise ValueError(f"schedule has duplicate rounds: {rounds}")
        if rounds[0] < 0:
            raise ValueError(f"schedule rounds must be >= 0, got {rounds[0]}")
        if any(w < 1 for _, w in entries):
            raise ValueError("schedule widths must be >= 1")
        self.plan = tuple(entries)

    @classmethod
    def parse(cls, arg: str) -> "SchedulePolicy":
        """``"2@0,8@5"`` -> entries ((0, 2), (5, 8))."""
        plan = []
        for item in arg.split(","):
            w_s, sep, r_s = item.partition("@")
            if not sep:
                raise ValueError(
                    f"schedule entry {item!r} is not <workers>@<round> "
                    f"(example: scaling='schedule:2@0,8@5')")
            plan.append((int(r_s), int(w_s)))
        return cls(plan)

    def _at(self, rnd: int, default: int) -> int:
        w = default
        for r, tw in self.plan:
            if r <= rnd:
                w = tw
        return w

    def initial_workers(self, w0: int) -> int:
        return self._at(0, w0)

    def observe(self, t: Telemetry) -> int:
        return self._at(t.round, t.workers)


class SMLTPolicy:
    """SMLT-style adaptive scaling: widen while the per-round progress
    rate (loss drop x throughput) keeps improving, step back once it
    stops, and narrow when statistical efficiency decays (the late-run
    regime where extra workers buy almost no loss drop -- MLLess's
    scale-down-to-save-money observation)."""
    name = "smlt"

    def __init__(self, factor: int = 2, improve_tol: float = 0.02,
                 decay_frac: float = 0.25):
        if int(factor) < 2:
            raise ValueError(f"smlt step factor must be >= 2, got {factor}")
        self.factor = int(factor)
        self.improve_tol = float(improve_tol)
        self.decay_frac = float(decay_frac)
        self._best_rate: float | None = None
        self._peak_delta: float | None = None
        self._widening = True

    def initial_workers(self, w0: int) -> int:
        return w0

    def observe(self, t: Telemetry) -> int:
        rate = t.progress_rate
        if rate is None:
            return t.workers              # no signal yet
        delta = t.loss_delta
        if delta is not None and delta > 0:
            if self._peak_delta is None or delta > self._peak_delta:
                self._peak_delta = delta
        if delta is None or delta <= 0:
            # loss stalled or regressed: stop exploring, shed a step
            self._widening = False
            return max(t.workers // self.factor, t.min_workers)
        if self._widening:
            if (self._best_rate is None
                    or rate > self._best_rate * (1.0 + self.improve_tol)):
                self._best_rate = rate
                return min(t.workers * self.factor, t.max_workers)
            # widening stopped paying: step back and hold
            self._widening = False
            return max(t.workers // self.factor, t.min_workers)
        if (self._peak_delta is not None
                and delta < self.decay_frac * self._peak_delta):
            return max(t.workers // self.factor, t.min_workers)
        return t.workers


class CostCapPolicy:
    """MLLess-style running budget: keep the fleet only as wide as the
    remaining dollars can carry; stop (width 0) rather than bust the cap.

    Invariant (property-tested): a run under ``cost_cap:<b>`` never costs
    more than ``b`` plus ONE round's spend -- the policy only lets another
    round start while the bill is still under the budget, and sheds
    workers once the projected next-round spend would cross it."""
    name = "cost_cap"

    def __init__(self, budget_usd: float):
        budget = float(budget_usd)
        if not budget > 0.0:
            raise ValueError(f"cost_cap budget must be > 0, got {budget}")
        self.budget = budget
        self._prev_cost: float | None = None
        self.max_round_spend = 0.0       # observed, for the property test

    def initial_workers(self, w0: int) -> int:
        return w0

    def observe(self, t: Telemetry) -> int:
        spend = t.cost_so_far - (self._prev_cost or 0.0)
        self._prev_cost = t.cost_so_far
        self.max_round_spend = max(self.max_round_spend, spend)
        if t.cost_so_far >= self.budget:
            return 0
        remaining = self.budget - t.cost_so_far
        if spend <= 0.0 or spend <= remaining:
            return t.workers
        # the next round at this width busts the budget: shed workers
        # (per-round spend scales ~linearly with width on every platform)
        shrunk = max(t.min_workers,
                     min(t.workers, int(t.workers * remaining / spend)))
        if spend * shrunk / t.workers > remaining:
            return 0                     # even the floor fleet busts it
        return shrunk


# ------------------------------------------------------------- controller ---

class ElasticController:
    """Engine-side driver: telemetry in, (clamped) resize out.

    Built once per run by :func:`build_controller`; the engine calls
    :meth:`step` at every sync boundary the protocol declares safe
    (``supports_resize``).  Keeps the per-run observation state (previous
    loss/clock/rounds) so policies stay pure functions of telemetry."""

    def __init__(self, policy, min_workers: int, max_workers: int):
        self.policy = policy
        self.min_w = int(min_workers)
        self.max_w = int(max_workers)
        self.telemetry_log: list[Telemetry] = []
        self._prev_loss: float | None = None
        self._prev_time: float | None = None
        self._rounds_at_time = 0         # rounds at the last boundary
        self._rounds_at_eval = 0         # rounds at the last NEW eval
        self._prev_evals = 0             # history length last boundary

    def initial_workers(self, w0: int) -> int:
        return max(self.min_w, min(self.max_w,
                                   int(self.policy.initial_workers(w0))))

    def snapshot(self, ctx, rnd: int) -> Telemetry:
        """Build (and log) the boundary telemetry from the engine state."""
        res = ctx.res
        loss = float(res.history[-1][1]) if res.history else None
        now = float(np.max(ctx.clock))
        dr = max(res.rounds - self._rounds_at_time, 1)
        round_time = ((now - self._prev_time) / dr
                      if self._prev_time is not None else now)
        # loss_delta only when the history actually GREW since the last
        # boundary: under eval_every > 1 some boundaries see no new eval,
        # and a stale entry would read as delta == 0.0 ("stalled")
        # instead of "no signal" (None)
        fresh_eval = loss is not None and len(res.history) > self._prev_evals
        loss_delta = None
        if fresh_eval and self._prev_loss is not None:
            loss_delta = (self._prev_loss - loss) / max(
                res.rounds - self._rounds_at_eval, 1)
        # one source of truth (DESIGN.md §18): when tracing, both the comm
        # seconds and the cost snapshot come from the recorder -- its meter
        # mirror and $ ledger are bitwise-equal to the engine values by
        # construction, so policy decisions are identical either way
        from repro.core.trace import comm_seconds
        cost_now = float(ctx.platform.finalize_cost(ctx))
        if ctx.rec is not None:
            cost_now = ctx.rec.cost_total()
        tel = Telemetry(
            round=int(rnd), workers=ctx.w, loss=loss, loss_delta=loss_delta,
            round_time=round_time,
            comm_share=comm_seconds(ctx) / max(now, 1e-12),
            cost_so_far=cost_now,
            sim_time=now, min_workers=self.min_w, max_workers=self.max_w)
        self.telemetry_log.append(tel)
        if fresh_eval:
            self._prev_loss = loss
            self._rounds_at_eval = res.rounds
        self._prev_evals = len(res.history)
        self._rounds_at_time = res.rounds
        self._prev_time = now
        return tel

    def step(self, ctx, rnd: int) -> bool:
        """One boundary decision; True = the policy stopped the run."""
        tel = self.snapshot(ctx, rnd)
        target = int(self.policy.observe(tel))
        if target <= 0:
            ctx.res.scaling_timeline.append((int(rnd), 0, 0.0, 0.0))
            return True
        target = max(self.min_w, min(self.max_w, target))
        if target != ctx.w and self._comm_feasible(ctx, target):
            ctx.resize(target, rnd)
        return False

    @staticmethod
    def _comm_feasible(ctx, target: int) -> bool:
        """Spec-time comm validation, re-run for the CANDIDATE width: a
        scatter-reduce chunk grows as the fleet shrinks, so a scale-down
        can push a per-item transport limit (DynamoDB's 400 KB) that the
        original width satisfied.  An infeasible target skips the resize
        (the fleet keeps its width) instead of aborting the run mid-flight
        with ChannelItemTooLarge."""
        spec = getattr(ctx.platform, "comm", None)
        if spec is None or not hasattr(spec, "validate"):
            return True
        base = ctx.platform.system_name().partition("-")[0]
        update_bytes = ctx.last_update_nbytes or ctx.mbytes
        try:
            spec.validate(platform=base, model_bytes=update_bytes,
                          workers=target)
        except ValueError:
            return False
        return True


# ---------------------------------------------------------------- registry --

#: name -> factory(arg_str_or_None); the grammar mirror of the sync/comm
#: registries
POLICIES = {
    "static": lambda arg=None: StaticPolicy(),
    "schedule": lambda arg=None: SchedulePolicy.parse(arg or ""),
    "smlt": lambda arg=None: SMLTPolicy(int(arg) if arg else 2),
    "cost_cap": lambda arg=None: CostCapPolicy(float(arg) if arg else 0.0),
}


def make_policy(spec) -> "ScalingPolicy":
    """``"static"`` | ``"schedule:<w@round,...>"`` | ``"smlt[:<factor>]"``
    | ``"cost_cap:<dollars>"`` | a :class:`ScalingPolicy` instance."""
    if not isinstance(spec, str):
        if isinstance(spec, type):
            return spec()
        return spec
    name, _, arg = spec.partition(":")
    if name == "plan":
        raise ValueError(
            "scaling='plan' is resolved at spec level (it needs the "
            "workload's analytic constants): use "
            "ExperimentSpec(scaling='plan') or pick the width with "
            "repro.core.elastic.planner.plan() yourself")
    try:
        factory = POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown scaling policy {spec!r}; available: "
                       f"{', '.join(sorted(POLICIES))}, plan") from None
    return factory(arg or None)


def validate_scaling(spec) -> None:
    """Eager grammar check for ``ExperimentSpec.scaling`` (a sweep should
    reject at expansion, not crash mid-run): parses and discards."""
    if isinstance(spec, str):
        head, _, arg = spec.partition(":")
        if head == "plan":
            if arg not in ("", "cheapest", "fastest"):
                raise ValueError(
                    f"plan objective must be 'cheapest' or 'fastest', "
                    f"got {arg!r}")
            return
    make_policy(spec)


def build_controller(scaling, fleet) -> ElasticController | None:
    """Turn a platform's ``scaling`` spec + FleetSpec into a controller.

    Returns ``None`` for static (string or instance): the engine then runs
    the pre-elastic fixed-fleet path untouched.  Heterogeneous per-worker
    fleets (tuple ``lambda_gb``/``instance``) are rejected -- a joiner's
    shape would be ambiguous."""
    policy = make_policy(scaling)
    if isinstance(policy, StaticPolicy):
        return None
    for name in ("lambda_gb", "instance"):
        if isinstance(getattr(fleet, name, None), tuple):
            raise ValueError(
                f"elastic scaling needs a homogeneous fleet; per-worker "
                f"{name}={getattr(fleet, name)!r} cannot be resized")
    min_w = 1 if fleet.min_workers is None else int(fleet.min_workers)
    max_w = MAX_FLEET if fleet.max_workers is None else int(fleet.max_workers)
    return ElasticController(policy, min_w, max_w)


def list_policies() -> list[str]:
    """Human-oriented registry listing for ``repro list``."""
    return ["static", "schedule:<w@round,...>", "smlt[:<factor>]",
            "cost_cap:<dollars>", "plan[:cheapest|fastest]"]
