"""Elastic fleet control (DESIGN.md §13): scaling policies, mid-run
resizing, and the analytic planner.

Every run in the repo used to pin ``FleetSpec.workers`` for its whole
lifetime; this package lets the width change at sync boundaries under a
runtime-checkable :class:`ScalingPolicy` -- the SMLT/MLLess adaptive-
serverless-training axis (PAPERS.md) on top of the paper's design space:

- :mod:`repro.core.elastic.telemetry`  -- the per-boundary observation
  (:class:`Telemetry`) policies decide from,
- :mod:`repro.core.elastic.policies`   -- the policy registry
  (``static`` / ``schedule:<w@round,...>`` / ``smlt`` /
  ``cost_cap:<dollars>``) and the engine-facing
  :class:`ElasticController`,
- :mod:`repro.core.elastic.planner`    -- the §5.3 analytical model as a
  decision subsystem (:func:`plan`), behind ``python -m repro plan`` and
  ``ExperimentSpec(scaling="plan")``.

Select a policy anywhere a platform is built:
``ExperimentSpec(scaling="schedule:2@0,8@5")``,
``FaaSRuntime(scaling="smlt")``, or pass a policy instance.  The default
``scaling="static"`` maps to NO controller: the engine takes the exact
pre-elastic code path (parity-pinned in ``tests/test_elastic.py``).
"""
from repro.core.elastic.planner import (  # noqa: F401
    DEFAULT_WORKERS, PAPER_WORKLOADS, PlanOption, as_cost_inputs, plan,
    plan_initial_workers,
)
from repro.core.elastic.policies import (  # noqa: F401
    MAX_FLEET, POLICIES, CostCapPolicy, ElasticController, SchedulePolicy,
    ScalingPolicy, SMLTPolicy, StaticPolicy, build_controller, list_policies,
    make_policy, validate_scaling,
)
from repro.core.elastic.telemetry import (  # noqa: F401
    ServingTelemetry, Telemetry,
)
