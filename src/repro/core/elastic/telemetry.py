"""Per-round telemetry snapshots for elastic fleet control (DESIGN.md §13).

A :class:`Telemetry` is everything a scaling policy may observe at a sync
boundary: statistical progress (loss and per-round loss drop), system
progress (round time, the share of wall time spent in metered
communication), and money (the platform's bill so far).  Policies see
ONLY this snapshot -- they never touch the engine context -- which is
what keeps the ``static`` path byte-identical and makes policies trivially
unit-testable with hand-built snapshots.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Telemetry:
    """One sync-boundary observation handed to a scaling policy."""
    round: int                   # fleet rounds completed so far
    workers: int                 # current fleet width
    loss: float | None           # latest evaluated loss (None before any eval)
    loss_delta: float | None     # loss drop per round since the last
                                 # observation (positive = improving; None
                                 # until two evals exist)
    round_time: float            # simulated s per round since last observation
    comm_share: float            # metered comm s / total elapsed s, in [0, 1]
    cost_so_far: float           # the platform bill if the run stopped now ($)
    sim_time: float              # max worker clock (s)
    min_workers: int             # the FleetSpec's elastic floor
    max_workers: int             # the FleetSpec's elastic ceiling

    @property
    def progress_rate(self) -> float | None:
        """Loss drop per simulated second -- SMLT's widen/narrow signal.
        None until a loss delta exists; 0-time rounds report None too."""
        if self.loss_delta is None or self.round_time <= 0.0:
            return None
        return self.loss_delta / self.round_time


@dataclass(frozen=True)
class ServingTelemetry:
    """One autoscaler-window observation of a serving fleet (DESIGN.md §14).

    Field names are chosen so the training policies whose ``observe`` only
    reads scheduling state (``StaticPolicy``, ``SchedulePolicy`` via
    ``round``/``workers``) or money (``CostCapPolicy`` via ``cost_so_far``/
    ``min_workers``) work on serving snapshots unchanged -- the registry
    grammar carries over; only the load-driven policy (smlt) is re-read on
    serving signals (queue depth + utilization instead of loss deltas).
    """
    round: int                   # autoscaler windows completed so far
    workers: int                 # replicas (provisioned) or concurrency cap
    qps: float                   # arrivals/s over the window
    queue_depth: int             # requests waiting at the window boundary
    p50_ms: float | None         # window completion-latency percentiles
    p99_ms: float | None         # (None if nothing completed this window)
    utilization: float           # busy replica-seconds / capacity, in [0, 1]
    cost_so_far: float           # serving bill if traffic stopped now ($)
    sim_time: float              # window boundary on the simulated clock (s)
    min_workers: int             # elastic floor
    max_workers: int             # elastic ceiling
