"""Analytic fleet planner: the §5.3 cost model as a decision subsystem
(DESIGN.md §13).

The paper's headline result is that the profitable degree of parallelism
is workload-dependent: FaaS pays off for fast-converging, comm-light
models (LR/Higgs), IaaS for comm-heavy ones (MobileNet).  The analytical
model (:mod:`repro.core.analytical`) already encodes the whole trade-off;
:func:`plan` turns it into ranked advice by sweeping fleet width x
platform through ``faas_time``/``iaas_time`` and the pricing model.

Objectives:

- ``fastest``  -- minimize wall-clock, budget-feasible options first.
- ``cheapest`` -- minimize dollars among DEADLINE-feasible options.  With
  no explicit ``deadline_s`` the deadline defaults to ``slack`` x the
  fastest option ("no-regret": the paper's profitability question is asked
  at a competitive degree of parallelism, not at w=1-and-wait) -- pass
  ``deadline_s=math.inf`` for the unconstrained minimum, which on this
  pricing model is always a small IaaS fleet.

Entry points: ``python -m repro plan`` (CLI verb), ``ExperimentSpec(
scaling="plan")`` (use the pick as the initial fleet), or call
:func:`plan` directly with a :class:`~repro.core.analytical.CostInputs`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.analytical import (
    TABLE6, CostInputs, faas_cost, faas_time, iaas_cost, iaas_time,
    pod_cost, pod_time,
)
from repro.core.elastic.policies import MAX_FLEET

#: default fleet widths swept by the planner (the paper's Fig 11/14 axis)
DEFAULT_WORKERS = (1, 2, 5, 10, 25, 50, 100, 150, 200, 300)

#: paper-scale ``(s, m, R, C)`` constants for the Fig 11-14 workloads --
#: the crossover fixtures the planner CLI and tests reproduce: LR/Higgs
#: converges fast and ships 16 KB updates (FaaS pays off), MobileNet/
#: ResNet ship MBs for hundreds of epochs (IaaS wins outright)
PAPER_WORKLOADS = {
    "lr_higgs": CostInputs(s_bytes=16e9, m_bytes=16e3, R=10, C=30.0),
    "svm_rcv1": CostInputs(s_bytes=1.2e9, m_bytes=189e3, R=15, C=20.0),
    "kmeans_higgs": CostInputs(s_bytes=16e9, m_bytes=3.4e3, R=15, C=45.0),
    "mobilenet_cifar10": CostInputs(s_bytes=220e6, m_bytes=12e6,
                                    R=500, C=400.0),
    "resnet50_cifar10": CostInputs(s_bytes=220e6, m_bytes=89e6,
                                   R=600, C=900.0),
}

OBJECTIVES = ("cheapest", "fastest")


@dataclass(frozen=True)
class PlanOption:
    """One ranked point of the plan: platform x width -> (time, $)."""
    platform: str
    workers: int
    time_s: float
    cost_usd: float
    feasible: bool = True
    note: str = ""

    def to_dict(self) -> dict:
        return {"platform": self.platform, "workers": self.workers,
                "time_s": round(self.time_s, 1),
                "cost_usd": round(self.cost_usd, 4),
                "feasible": self.feasible, "note": self.note}


def as_cost_inputs(workload, *, R: float | None = None) -> CostInputs:
    """Coerce a plan target into :class:`CostInputs`: pass one through, a
    :data:`PAPER_WORKLOADS` name, or an ``ExperimentSpec`` (the constants
    are derived from its actual workload; ``R`` defaults to the spec's
    epoch budget)."""
    if isinstance(workload, CostInputs):
        return workload
    if isinstance(workload, str):
        try:
            return PAPER_WORKLOADS[workload]
        except KeyError:
            raise KeyError(
                f"unknown planner workload {workload!r}; named workloads: "
                f"{', '.join(sorted(PAPER_WORKLOADS))}") from None
    # duck-typed ExperimentSpec
    wl, _algo, tr, _va = workload.build_workload()
    return CostInputs.from_workload(
        wl, tr, R=workload.max_epochs if R is None else R)


def plan(workload, objective: str = "cheapest", *,
         deadline_s: float | None = None, budget_usd: float | None = None,
         workers=DEFAULT_WORKERS, platforms=("faas", "iaas"),
         channel: str = "s3", codec: str = "fp32", gb: float = 3.0,
         instance: str = "t2.medium", chips_per_pod: int = 4,
         mfu: float | str = 0.4,
         slack: float = 1.25,  # lint: ignore[C001] -- deadline slack, not a price
         R: float | None = None) -> list[PlanOption]:
    """Sweep ``workers`` x ``platforms`` through the analytic model and
    return options ranked best-first: feasible options (deadline + budget)
    before infeasible ones, then by the objective's key.  See the module
    docstring for the ``cheapest`` auto-deadline.  ``platforms`` may
    include ``"pod"`` (accelerator slices, ``pod_time``/``pod_cost``);
    ``mfu="measured"`` derives those rows from the benchmarked roofline
    fraction instead of the asserted default."""
    if objective not in OBJECTIVES:
        raise ValueError(f"objective must be one of {OBJECTIVES}, "
                         f"got {objective!r}")
    ci = as_cost_inputs(workload, R=R)
    if "pod" in platforms:
        from repro.core.calibration import resolve_mfu
        mfu = resolve_mfu(mfu)   # resolve once: one snapshot read per plan
    # the analytic NIC table (Table 6 "B_n"/"L_n") covers two instance
    # rows; for others the TIME constants fall back to t2.medium's NIC
    # (flagged in the option note) while the COST keeps the real instance
    # price -- dollars must never silently change instance type
    time_instance, nic_note = instance, ""
    if instance not in TABLE6["B_n"]:
        time_instance = "t2.medium"
        nic_note = f"NIC constants approximated from {time_instance}"
    raw = []
    for w in workers:
        w = int(w)
        if "faas" in platforms:
            t = faas_time(ci, w, channel=channel, codec=codec)
            raw.append(("faas", w, t, faas_cost(ci, w, t, gb), ""))
        if "iaas" in platforms:
            t = iaas_time(ci, w, instance=time_instance)
            raw.append(("iaas", w, t, iaas_cost(ci, w, t, instance),
                        nic_note))
        if "pod" in platforms:
            t = pod_time(ci, w, chips_per_pod=chips_per_pod, mfu=mfu,
                         codec=codec)
            raw.append(("pod", w, t, pod_cost(ci, w, t, chips_per_pod),
                        f"mfu={mfu:.3f}"))
    if not raw:
        return []
    fastest = min(t for _, _, t, _, _ in raw)
    if deadline_s is None:
        deadline_s = slack * fastest if objective == "cheapest" else math.inf
    options = []
    for plat, w, t, c, extra in raw:
        notes = []
        if t > deadline_s:
            notes.append(f"misses deadline ({t:.0f}s > {deadline_s:.0f}s)")
        if budget_usd is not None and c > budget_usd:
            notes.append(f"over budget (${c:.4f} > ${budget_usd:.4f})")
        feasible = not notes
        if extra:
            notes.append(extra)
        options.append(PlanOption(plat, w, t, c, feasible=feasible,
                                  note="; ".join(notes)))
    key = ((lambda o: o.cost_usd) if objective == "cheapest"
           else (lambda o: o.time_s))
    return sorted(options, key=lambda o: (not o.feasible, key(o)))


def plan_initial_workers(spec, objective: str = "cheapest") -> int:
    """The width ``scaling="plan"`` starts a spec's run with: the best
    feasible option for the SPEC's platform (the platform itself is fixed
    by the spec; cross-platform comparison is ``repro plan``'s job),
    clamped to the fleet's elastic bounds."""
    if spec.platform not in ("faas", "iaas"):
        raise ValueError(
            f"scaling='plan' covers the analytic model's platforms "
            f"(faas/iaas), not {spec.platform!r}; size pod fleets "
            f"explicitly or via scaling='schedule:...'")
    fleet = spec.fleet
    lo = 1 if fleet.min_workers is None else int(fleet.min_workers)
    hi = fleet.max_workers
    candidates = [w for w in DEFAULT_WORKERS
                  if lo <= w and (hi is None or w <= int(hi))]
    kw = {}
    if spec.platform == "faas":
        transport, _c, codec = spec.comm.resolved("faas")
        kw = dict(channel=transport, codec=codec,
                  gb=float(fleet.gb_array()[0]))
    else:
        kw = dict(instance=str(fleet.instances()[0]))
    options = plan(spec, objective, workers=candidates or [fleet.workers],
                   platforms=(spec.platform,), **kw)
    best = next((o for o in options if o.feasible), options[0])
    return max(lo, min(best.workers,
                       int(hi) if hi is not None else MAX_FLEET))
