"""The comm-stack string grammar and its spec-time validation
(DESIGN.md §12).

A stack is written ``"<transport>/<collective>/<codec>"``, each part
optionally parameterized with ``:``-arguments::

    s3/allreduce/fp32            # the seed-era FaaS default, byte-identical
    s3/scatter_reduce/int8       # balanced reduce, int8+error-feedback wire
    s3/hierarchical:4/topk:0.01  # two-level tree, groups of 4, top-1% sparse
    nic/ring/fp32                # the IaaS default (ring over VM NICs)
    dcn/ring/int8                # cross-pod DCN ring, compressed deltas
    vmps/pushpull/fp32           # the hybrid VM parameter server

The collective and codec may be omitted (``"s3"``, ``"s3/scatter_reduce"``)
and default per transport: store transports reduce with ``allreduce``,
``nic``/``dcn`` with ``ring``, ``vmps`` with ``pushpull``; the codec
defaults to ``fp32``.

:func:`validate_stack` is the eager half of the paper's Table 1: pairing
rules (a ring needs a network, the PS needs push/pull, FaaS workers have no
p2p NICs) are structural errors, and a transport per-item limit versus the
codec'd wire size of the model update raises
:class:`~repro.core.comm.transports.ChannelItemTooLarge` AT SPEC TIME --
reproducing the "N/A" cells (DynamoDB x models > 400 KB) before a single
simulated second elapses.  A sparsifying codec can flip a cell back to
feasible, which is exactly MLLess's point.
"""
from __future__ import annotations

from typing import Callable

from repro.core.comm.codecs import make_codec
from repro.core.comm.collectives import STORE_COLLECTIVES, make_collective
from repro.core.comm.transports import (
    ChannelItemTooLarge, NETWORK_TRANSPORTS, TRANSPORTS, transport_constants,
)

#: default collective per transport kind (when the string omits it)
_DEFAULT_COLLECTIVE = {"vmps": "pushpull", "nic": "ring", "dcn": "ring"}


def default_collective(transport: str) -> str:
    return _DEFAULT_COLLECTIVE.get(transport, "allreduce")


def parse_stack(text: str) -> tuple[str, str | None, str]:
    """``"t[/c[/d]]"`` -> ``(transport, collective_or_None, codec)`` with
    every named part checked against its registry."""
    parts = str(text).strip().split("/")
    if not 1 <= len(parts) <= 3 or not all(parts):
        raise ValueError(
            f"bad comm stack {text!r}: expected "
            f"'<transport>[/<collective>[/<codec>]]', e.g. "
            f"'s3/scatter_reduce/int8'")
    transport = parts[0]
    collective = parts[1] if len(parts) > 1 else None
    codec = parts[2] if len(parts) > 2 else "fp32"
    if transport.partition(":")[0] not in TRANSPORTS:
        raise KeyError(f"unknown transport {transport!r} in comm stack "
                       f"{text!r}; available: {', '.join(sorted(TRANSPORTS))}")
    if transport.partition(":")[2]:
        raise ValueError(f"transport {transport!r} takes no ':' arguments")
    if collective is not None:
        make_collective(collective)          # raises on unknown/bad args
    make_codec(codec)                        # raises on unknown/bad args
    return transport, collective, codec


def stack_name(transport: str, collective: str, codec: str) -> str:
    return f"{transport}/{collective}/{codec}"


def validate_stack(transport: str, collective: str, codec: str, *,
                   platform: str | None = None,
                   model_bytes: int | Callable[[], int | None] | None = None,
                   workers: int | None = None) -> None:
    """Raise on any stack that cannot run (structure) or cannot fit
    (per-item limits).  ``model_bytes`` is the fp32 update-vector size and
    may be a lazy callable -- it is only evaluated when the transport
    actually enforces an item limit."""
    spec = transport_constants(transport)          # raises on unknown name
    coll = make_collective(collective)             # raises on unknown name
    cdc = make_codec(codec)                        # raises on unknown name
    c_base = collective.partition(":")[0]
    if (transport == "vmps") != (c_base == "pushpull"):
        raise ValueError(
            f"comm stack '{stack_name(transport, collective, codec)}': "
            f"the push/pull collective and the 'vmps' transport require "
            f"each other (Table 2's hybrid PS protocol); store transports "
            f"use {'/'.join(STORE_COLLECTIVES)}, networks use 'ring'")
    if c_base == "ring" and transport not in NETWORK_TRANSPORTS:
        raise ValueError(
            f"comm stack '{stack_name(transport, collective, codec)}': "
            f"'ring' reduces over point-to-point network constants "
            f"({'/'.join(NETWORK_TRANSPORTS)}); storage services reduce "
            f"with {'/'.join(STORE_COLLECTIVES)} (paper Fig 4/Table 3)")
    if platform == "faas" and transport in NETWORK_TRANSPORTS:
        raise ValueError(
            f"comm stack '{stack_name(transport, collective, codec)}': "
            f"FaaS workers cannot talk to each other directly "
            f"(no p2p network, paper §3.2.2) -- pick a storage transport "
            f"({', '.join(n for n in sorted(TRANSPORTS) if n not in NETWORK_TRANSPORTS)})")
    if spec.max_item is None:
        return
    m = model_bytes() if callable(model_bytes) else model_bytes
    if m is None:
        return
    n = max(int(m) // 4, 1)                       # fp32 elements
    wire_bytes = cdc.wire_floats(n) * 4
    item = coll.max_item_bytes(wire_bytes, workers or 1)
    if item > spec.max_item:
        raise ChannelItemTooLarge(
            f"comm stack '{stack_name(transport, collective, codec)}': the "
            f"model update is {m / 1e6:.2f} MB ({wire_bytes / 1e6:.2f} MB "
            f"on the wire after the {cdc.name} codec), whose largest "
            f"{coll.name} item ({item / 1e3:.1f} KB) exceeds the "
            f"{spec.name} per-item limit of {spec.max_item / 1e3:.0f} KB "
            f"(paper Table 1 'N/A'); shrink the model, switch transports, "
            f"or sparsify (e.g. codec 'topk:0.01')")
