"""The **Collective** axis of the communication design space (DESIGN.md §12).

A collective is *how a fleet's update vectors are reduced to one* over a
transport.  The store-based collectives (paper §3.2.3, Fig 4) implement the
two-phase synchronous protocol of §3.2.4 (merge phase + update phase,
file-name polling) over any transport exposing the metered ``put``/``get``
surface; the network collectives reduce with the paper's closed-form ring /
push-pull models over the transport's Table 6/2 constants.

Each collective takes the workers' flat update vectors, moves them through
the transport (real payloads), and returns ``(merged_vector,
per_worker_times)`` -- AllReduce's leader bottleneck and ScatterReduce's
balanced reduce show up exactly as in Table 3, and the two-level tree of
:func:`two_level_reduce` shows the multi-level-reduction scaling of
FSD-Inference (PAPERS.md): leaders touch ``g + w/g`` objects instead of
``w``.

The :class:`Collective` protocol also carries the two facts spec-time
validation needs: ``barrier`` (does the reduce rendezvous the fleet?) and
``max_item_bytes`` (the largest single object the reduce stores -- what the
DynamoDB 400 KB limit is checked against, Table 1's "N/A" cells).
"""
from __future__ import annotations

import math
from typing import Protocol, runtime_checkable

import numpy as np

POLL = 0.01  # s between list() polls (merge-phase waiting)


def _poll_until(t_now: float, t_ready: float, latency: float) -> float:
    """Poll (list) until t_ready; each poll costs one latency."""
    if t_now >= t_ready:
        return t_now + latency
    n_polls = int((t_ready - t_now) / max(POLL, latency)) + 1
    return t_ready + latency  # arrives at ready + one confirming list


def allreduce(channel, updates: list[np.ndarray], tag: str):
    """Fig 4 left: all write; leader (worker 0) merges; all read merged."""
    w = len(updates)
    lat = channel.spec.latency
    t_put = np.zeros(w)
    for i, u in enumerate(updates):
        t_put[i] = channel.put(f"{tag}/part{i}", u)
    # merge phase: leader polls until all parts visible
    t_all_put = float(np.max(t_put))
    t_leader = _poll_until(t_put[0], t_all_put, lat)
    merged = np.zeros_like(updates[0])
    for i in range(w):
        p, dt = channel.get(f"{tag}/part{i}")
        merged += p
        t_leader += dt
    merged /= w
    t_leader += channel.put(f"{tag}/merged", merged)
    # update phase: everyone else polls for the merged file, then reads it
    times = np.zeros(w)
    for i in range(w):
        if i == 0:
            times[i] = t_leader
        else:
            t = _poll_until(t_put[i], t_leader, lat)
            _, dt = channel.get(f"{tag}/merged")
            times[i] = t + dt
    return merged, times


def scatter_reduce(channel, updates: list[np.ndarray], tag: str):
    """Fig 4 right: every worker reduces one partition of the update."""
    w = len(updates)
    lat = channel.spec.latency
    n = updates[0].size
    bounds = np.linspace(0, n, w + 1, dtype=int)
    # phase 1: each worker writes w partitions
    t_put = np.zeros(w)
    for i, u in enumerate(updates):
        t = 0.0
        for j in range(w):
            t += channel.put(f"{tag}/p{i}_{j}", u[bounds[j]: bounds[j + 1]])
        t_put[i] = t
    t_all_put = float(np.max(t_put))
    # phase 2: worker j reduces partition j
    merged = np.zeros_like(updates[0])
    t_reduced = np.zeros(w)
    for j in range(w):
        t = _poll_until(t_put[j], t_all_put, lat)
        acc = np.zeros(bounds[j + 1] - bounds[j], updates[0].dtype)
        for i in range(w):
            p, dt = channel.get(f"{tag}/p{i}_{j}")
            acc += p
            t += dt
        acc /= w
        merged[bounds[j]: bounds[j + 1]] = acc
        t += channel.put(f"{tag}/r{j}", acc)
        t_reduced[j] = t
    t_all_reduced = float(np.max(t_reduced))
    # phase 3: everyone reads the other w-1 reduced partitions
    times = np.zeros(w)
    for i in range(w):
        t = _poll_until(t_reduced[i], t_all_reduced, lat)
        for j in range(w):
            if j != i:
                _, dt = channel.get(f"{tag}/r{j}")
                t += dt
        times[i] = t
    return merged, times


def two_level_reduce(channel, updates: list[np.ndarray], tag: str,
                     group_size: int | None = None):
    """Hierarchical two-level reduction (FSD-Inference's multi-level
    scaling, PAPERS.md): workers form groups of ``group_size`` (default
    ``ceil(sqrt(w))``); each group leader reduces its group's parts into one
    partial sum, the global leader (worker 0) reduces the partial sums and
    publishes the merged vector.  Leaders read ``g + w/g`` objects instead
    of AllReduce's ``w`` -- the tree flattens the leader bottleneck for
    large fleets while every byte still crosses the metered transport."""
    w = len(updates)
    lat = channel.spec.latency
    g = int(group_size) if group_size else max(int(math.ceil(math.sqrt(w))), 1)
    groups = [list(range(s, min(s + g, w))) for s in range(0, w, g)]
    # phase 1: everyone writes its update
    t_put = np.zeros(w)
    for i, u in enumerate(updates):
        t_put[i] = channel.put(f"{tag}/part{i}", u)
    # phase 2: each group leader polls for its group's parts and writes the
    # group partial sum
    t_group = np.zeros(len(groups))
    for gi, members in enumerate(groups):
        leader = members[0]
        t = _poll_until(t_put[leader],
                        float(max(t_put[m] for m in members)), lat)
        acc = np.zeros_like(updates[0])
        for m in members:
            p, dt = channel.get(f"{tag}/part{m}")
            acc += p
            t += dt
        t += channel.put(f"{tag}/g{gi}", acc)
        t_group[gi] = t
    # phase 3: the global leader polls for all group sums and merges
    t_all_groups = float(np.max(t_group))
    t_root = _poll_until(float(t_group[0]), t_all_groups, lat)
    merged = np.zeros_like(updates[0])
    for gi in range(len(groups)):
        p, dt = channel.get(f"{tag}/g{gi}")
        merged += p
        t_root += dt
    merged /= w
    t_root += channel.put(f"{tag}/merged", merged)
    # phase 4: everyone else polls for the merged file, then reads it
    times = np.zeros(w)
    for gi, members in enumerate(groups):
        for m in members:
            if m == 0:
                times[m] = t_root
                continue
            t_done = float(t_group[gi]) if m == members[0] else float(t_put[m])
            t = _poll_until(t_done, t_root, lat)
            _, dt = channel.get(f"{tag}/merged")
            times[m] = t + dt
    return merged, times


#: legacy name -> free-function map (the seed-era ``patterns.PATTERNS``)
PATTERNS = {"allreduce": allreduce, "scatter_reduce": scatter_reduce,
            "hierarchical": two_level_reduce}


# ----------------------------------------------------------------- protocol --

@runtime_checkable
class Collective(Protocol):
    """How a fleet reduces one round of update vectors (DESIGN.md §12)."""

    name: str
    #: True: the reduce rendezvouses the fleet (clocks resync at the max);
    #: False: each worker pays the round time from its own clock (push/pull)
    barrier: bool

    def run(self, transport, updates: list[np.ndarray], tag: str):
        """-> ``(merged_vector, per_worker_times)`` (times may be scalar)."""
        ...

    def max_item_bytes(self, m_bytes: int, workers: int) -> int:
        """Largest single object this reduce stores on the transport for an
        ``m_bytes`` wire payload -- 0 when nothing is stored (ring/PS)."""
        ...


class StoreAllReduce:
    """Two-phase leader merge over a storage transport (Fig 4 left)."""
    name = "allreduce"
    barrier = True

    def run(self, transport, updates, tag):
        return allreduce(transport, updates, tag)

    def max_item_bytes(self, m_bytes, workers):
        return int(m_bytes)


class StoreScatterReduce:
    """Balanced partition reduce over a storage transport (Fig 4 right)."""
    name = "scatter_reduce"
    barrier = True

    def run(self, transport, updates, tag):
        return scatter_reduce(transport, updates, tag)

    def max_item_bytes(self, m_bytes, workers):
        n = -(-int(m_bytes) // 4)                      # fp32 elements
        return -(-n // max(int(workers), 1)) * 4       # largest partition


class TwoLevelReduce:
    """Hierarchical two-level tree reduce (FSD-Inference scaling)."""
    barrier = True

    def __init__(self, group_size: int | None = None):
        if group_size is not None and int(group_size) < 1:
            raise ValueError(f"hierarchical group size must be >= 1, "
                             f"got {group_size}")
        self.group_size = int(group_size) if group_size else None

    @property
    def name(self) -> str:
        return ("hierarchical" if self.group_size is None
                else f"hierarchical:{self.group_size}")

    def run(self, transport, updates, tag):
        return two_level_reduce(transport, updates, tag, self.group_size)

    def max_item_bytes(self, m_bytes, workers):
        return int(m_bytes)                  # full vectors + group sums


class RingAllReduce:
    """IaaS/pod ring AllReduce: the paper's closed-form ``(2w-2) *
    (m/w/Bn + Ln)`` over the transport's constants; the mean is computed
    in place (nothing is stored on the transport)."""
    name = "ring"
    barrier = True

    def run(self, transport, updates, tag):
        merged = np.mean(updates, axis=0)
        w = len(updates)
        spec = transport.spec
        if w <= 1:
            return merged, 0.0
        t = (2 * w - 2) * (updates[0].nbytes / w / spec.bandwidth
                           + spec.latency)
        return merged, t

    def max_item_bytes(self, m_bytes, workers):
        return 0


class PSPushPull:
    """Hybrid VM-PS round (Table 2): push grads + server update + pull
    model; each worker pays the round from its own clock (no barrier --
    the PS serializes, it does not rendezvous)."""
    name = "pushpull"
    barrier = False

    def run(self, transport, updates, tag):
        merged = np.mean(updates, axis=0)
        return merged, transport.push_pull_round(updates[0].nbytes,
                                                 len(updates))

    def max_item_bytes(self, m_bytes, workers):
        return 0


#: every selectable collective: name -> factory(arg_str or None)
COLLECTIVES = {
    "allreduce": lambda arg=None: StoreAllReduce(),
    "scatter_reduce": lambda arg=None: StoreScatterReduce(),
    "hierarchical": lambda arg=None: TwoLevelReduce(
        int(arg) if arg else None),
    "ring": lambda arg=None: RingAllReduce(),
    "pushpull": lambda arg=None: PSPushPull(),
}

#: collectives that store objects on the transport (need put/get; their
#: items are what per-item limits apply to)
STORE_COLLECTIVES = ("allreduce", "scatter_reduce", "hierarchical")


def make_collective(spec) -> Collective:
    """``"allreduce"`` | ``"scatter_reduce"`` | ``"hierarchical[:<g>]"`` |
    ``"ring"`` | ``"pushpull"`` | a :class:`Collective` instance."""
    if not isinstance(spec, str):
        return spec
    name, _, arg = spec.partition(":")
    try:
        factory = COLLECTIVES[name]
    except KeyError:
        raise KeyError(f"unknown collective {spec!r}; available: "
                       f"{', '.join(sorted(COLLECTIVES))}") from None
    return factory(arg or None)


def list_collectives() -> list[str]:
    return sorted(COLLECTIVES)
