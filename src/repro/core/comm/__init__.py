"""``repro.core.comm``: the communication design space as three orthogonal,
runtime-checkable protocols (DESIGN.md §12).

- :class:`Transport`  -- WHERE bytes move: S3 / Memcached / Redis /
  DynamoDB / hybrid VM-PS / VM NIC / cross-pod DCN (Table 6 constants).
- :class:`Collective` -- HOW vectors reduce: two-phase allreduce /
  scatter-reduce (Fig 4), hierarchical two-level tree (FSD-Inference),
  ring (IaaS/pods), PS push-pull (Table 2).
- :class:`Codec`      -- WHAT goes on the wire: fp32 identity, int8 +
  error feedback, top-k sparsification (MLLess).

Any triple composes through :class:`CommStack`; a stack is selected
declaratively with the ``"transport/collective/codec"`` grammar
(:func:`parse_stack`) on :class:`repro.core.platform.CommSpec` /
:class:`repro.experiments.ExperimentSpec`, validated eagerly at spec time
(:func:`validate_stack` -- the DynamoDB 400 KB limit reproduces Table 1's
"N/A" cells as a spec error), and metered uniformly into
``RunResult.comm_bytes`` / ``breakdown["comm"]`` / ``comm_cost`` on every
platform.  Codecs act on collective reduces (BSP and the LocalSGD/DiLoCo
sync boundaries); the ASP/SSP event loop exchanges the raw fp32 global
model, so a lossy codec there is rejected at spec time rather than
silently ignored.
"""
from repro.core.comm.codecs import (  # noqa: F401
    CODECS, Codec, Fp32Codec, Int8EFCodec, TopKCodec, dequantize_int8,
    int8_encode_decode, int8_wire_floats, list_codecs, make_codec,
    quantize_int8_ef,
)
from repro.core.comm.collectives import (  # noqa: F401
    COLLECTIVES, PATTERNS, Collective, PSPushPull, RingAllReduce,
    STORE_COLLECTIVES, StoreAllReduce, StoreScatterReduce, TwoLevelReduce,
    allreduce, list_collectives, make_collective, scatter_reduce,
    two_level_reduce,
)
from repro.core.comm.grammar import (  # noqa: F401
    default_collective, parse_stack, stack_name, validate_stack,
)
from repro.core.comm.stack import (  # noqa: F401
    ChannelComm, CommStack, MPIComm, PSComm, build_comm_stack,
)
from repro.core.comm.transports import (  # noqa: F401
    CHANNEL_SPECS, ChannelItemTooLarge, ChannelSpec, NETWORK_TRANSPORTS,
    STORAGE_TRANSPORTS, StorageChannel, TRANSPORTS, Transport, VMNetwork,
    VMParameterServer, list_transports, make_transport, nbytes,
    transport_constants,
)
