"""The **Transport** axis of the communication design space (DESIGN.md §12).

A transport is *where update bytes physically move*: a storage service
(S3, ElastiCache-Memcached/Redis, DynamoDB -- the FaaS channels of §3.2.2),
a VM NIC mesh, the cross-pod data-center network, or the hybrid VM-hosted
parameter server of Table 2.  Every transport moves REAL numpy payloads (so
convergence is exact) while charging *simulated* time/cost from the paper's
measured constants (Table 6) -- the same methodology as the paper's
analytical model, applied per operation.

The uniform surface (runtime-checkable :class:`Transport`):

- ``put(key, payload) -> sim_seconds`` / ``get(key) -> (payload, seconds)``
  -- a metered key-value store (collectives build reductions out of these),
- ``service_cost(seconds) -> $`` -- what the substrate itself bills,
- ``spec`` -- the :class:`ChannelSpec` constants (bandwidth, latency,
  startup, item limit, prices) that the analytical model (§5.3) reads from
  the SAME source the simulator meters with.

Transports compose with any :mod:`repro.core.comm.collectives` collective
and any :mod:`repro.core.comm.codecs` codec through
:class:`repro.core.comm.stack.CommStack`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Protocol, runtime_checkable

import numpy as np

from repro.core import cost as pricing

#: VM NIC defaults (t2.medium, Table 6 "B_n"/"L_n" row) -- per-instance
#: overrides live in repro.core.runtimes.B_NET/L_NET
NIC_BANDWIDTH = 120e6
NIC_LATENCY = 5e-4

#: cross-pod data-center network: per-pod egress bandwidth and latency
#: (intra-pod ICI is never metered -- it rides the MFU discount, §11)
DCN_BANDWIDTH = 25e9
DCN_LATENCY = 1e-3

#: instance-attached EBS (gp2) volume: sequential bandwidth and access
#: latency (Table 6 methodology) -- the measured source for BOTH the
#: analytical model's local-disk terms (B_EBS/L_EBS) and the checkpoint
#: subsystem's ``local`` backend (repro.core.ckpt)
EBS_BANDWIDTH = 1950e6
EBS_LATENCY = 3e-5


class ChannelItemTooLarge(ValueError):
    """A payload exceeds the transport's per-item limit (DynamoDB's 400 KB
    -> the "N/A" cells of Table 1).  Raised eagerly by
    :meth:`repro.core.platform.CommSpec.validate` at spec time and, as a
    backstop, by :meth:`StorageChannel.put` mid-simulation."""


@dataclass(frozen=True)
class ChannelSpec:
    """Measured constants for one communication substrate (Table 6
    methodology, DESIGN.md §3): per-op time = ``latency + size / bandwidth``.

    ``large_item_slowdown`` models a single-threaded value server: for items
    over 10 MB the effective bandwidth is divided by this factor.  The paper
    observes this for Redis (§4.3) -- one event-loop thread serializes big
    GET/SET payloads, so Redis falls behind the otherwise identically-priced
    Memcached once update vectors reach CNN sizes, while staying on par for
    the small linear models of Table 1.
    """
    name: str
    bandwidth: float                 # bytes/s per worker stream
    latency: float                   # s per op
    startup: float                   # s to provision the service
    max_item: Optional[int] = None   # bytes; None = unlimited
    hourly_cost: float = 0.0
    put_cost: float = 0.0            # $ per op
    get_cost: float = 0.0
    large_item_slowdown: float = 1.0  # >1: single-threaded server (Redis)


# Table 6 (+ §4.3 observations), row by row:
CHANNEL_SPECS = {
    # Table 6 "S3" row: B_S3 = 65 MB/s per stream, L_S3 = 80 ms per request;
    # no provisioning (always-on service), request-priced (no hourly $).
    "s3": ChannelSpec("s3", 65e6, 8e-2, 0.0, None, 0.0,
                      pricing.S3_PUT, pricing.S3_GET),
    # Table 6 "ElastiCache" row, cache.t3.medium: B_EC = 630 MB/s,
    # L_EC = 10 ms; ~2-minute cluster provisioning; hourly-priced.
    "memcached": ChannelSpec("memcached", 630e6, 1e-2, 130.0, None,
                             pricing.ELASTICACHE_HOURLY["cache.t3.medium"]),
    # Table 6 "ElastiCache" row, cache.m5.large: 2x the t3.medium bandwidth
    # (1260 MB/s) at ~2.3x the hourly price.
    "memcached_large": ChannelSpec("memcached_large", 1260e6, 1e-2, 130.0,
                                   None,
                                   pricing.ELASTICACHE_HOURLY["cache.m5.large"]),
    # Same ElastiCache constants as memcached (same service class), plus the
    # §4.3 single-threaded-server penalty on > 10 MB items (see ChannelSpec).
    "redis": ChannelSpec("redis", 630e6, 1e-2, 130.0, None,
                         pricing.ELASTICACHE_HOURLY["cache.t3.medium"],
                         large_item_slowdown=2.0),
    # Table 1 + §4.3: bandwidth/latency calibrated so small-model rounds run
    # ~20% faster than S3 (Table 1 slowdown 0.81-0.93 vs S3); the 400 KB
    # item limit makes models > 400 KB infeasible exactly as the paper
    # reports ("N/A" cells of Table 1); on-demand request pricing.
    "dynamodb": ChannelSpec("dynamodb", 81e6, 6.2e-2, 0.0, 400_000, 0.0,
                            put_cost=pricing.DYNAMODB_PER_MREQ / 1e6,
                            get_cost=pricing.DYNAMODB_PER_MREQ / 4e6),
}

def nbytes(payload) -> int:
    if isinstance(payload, np.ndarray):
        return payload.nbytes
    return sum(p.nbytes for p in payload)


def xfer_seconds(spec: ChannelSpec, size: int) -> float:
    """Per-op transfer seconds for ``size`` bytes over ``spec`` -- the ONE
    formula both the metered :class:`StorageChannel` and the closed-form
    consumers (derived restarts in :mod:`repro.core.ckpt`, the analytical
    model) evaluate, so they can never disagree."""
    bw = spec.bandwidth
    if size > 10e6 and spec.large_item_slowdown > 1:
        bw /= spec.large_item_slowdown
    return spec.latency + size / bw


@runtime_checkable
class Transport(Protocol):
    """The metering surface every substrate exposes (DESIGN.md §12)."""

    @property
    def spec(self) -> ChannelSpec: ...

    def put(self, key: str, payload) -> float:
        """Store ``payload``; return simulated seconds for the operation."""
        ...

    def get(self, key: str):
        """-> ``(payload, simulated_seconds)``."""
        ...

    def service_cost(self, seconds: float) -> float:
        """$ billed by the substrate itself over ``seconds`` of wall time."""
        ...


class StorageChannel:
    """In-memory store with a simulated (time, $) meter."""

    def __init__(self, spec: ChannelSpec | str):
        self.spec = CHANNEL_SPECS[spec] if isinstance(spec, str) else spec
        self.store: dict[str, np.ndarray] = {}
        self.op_cost = 0.0            # accumulated $ for requests
        self.ops = {"put": 0, "get": 0, "list": 0}

    # each op returns simulated seconds
    def _xfer(self, size: int) -> float:
        return xfer_seconds(self.spec, size)

    def put(self, key: str, payload: np.ndarray) -> float:
        size = nbytes(payload)
        if self.spec.max_item and size > self.spec.max_item:
            raise ChannelItemTooLarge(
                f"{self.spec.name}: item {size}B > limit {self.spec.max_item}B")
        self.store[key] = payload
        self.ops["put"] += 1
        self.op_cost += self.spec.put_cost
        return self._xfer(size)

    def get(self, key: str) -> tuple[np.ndarray, float]:
        payload = self.store[key]
        self.ops["get"] += 1
        self.op_cost += self.spec.get_cost
        return payload, self._xfer(nbytes(payload))

    def list(self, prefix: str) -> tuple[list[str], float]:
        self.ops["list"] += 1
        self.op_cost += self.spec.get_cost
        return [k for k in self.store if k.startswith(prefix)], self.spec.latency

    def delete(self, key: str) -> float:
        self.store.pop(key, None)
        return 0.0

    def service_cost(self, seconds: float) -> float:
        return self.spec.hourly_cost / 3600.0 * seconds + self.op_cost


class VMNetwork:
    """Metered point-to-point VM network + in-memory key-value host.

    Implements the same metering interface as :class:`StorageChannel`
    (``put``/``get`` return simulated seconds, op counters accumulate) so the
    discrete-event engine can treat "files on S3" and "tensors over a NIC"
    uniformly (DESIGN.md §4.3).  ``put``/``get`` model a worker exchanging a
    payload with the key-value host (worker 0) over one NIC stream;
    ``allreduce_time`` is the paper's ring model for the BSP collective.
    The network itself bills nothing -- NICs come with the instances.
    """

    def __init__(self, bandwidth: float, latency: float, name: str = "nic"):
        self.bandwidth = bandwidth
        self.latency = latency
        self.name = name
        self.store: dict[str, np.ndarray] = {}
        self.ops = {"put": 0, "get": 0}

    @property
    def spec(self) -> ChannelSpec:
        """Constants view in the shared :class:`ChannelSpec` shape."""
        return ChannelSpec(self.name, self.bandwidth, self.latency, 0.0)

    def _xfer(self, size: int) -> float:
        return self.latency + size / self.bandwidth

    def put(self, key: str, payload: np.ndarray) -> float:
        self.store[key] = payload
        self.ops["put"] += 1
        return self._xfer(nbytes(payload))

    def get(self, key: str) -> tuple[np.ndarray, float]:
        payload = self.store[key]
        self.ops["get"] += 1
        return payload, self._xfer(nbytes(payload))

    def allreduce_time(self, size: int, workers: int) -> float:
        """MPI ring AllReduce (paper model): ``(2w-2) * (m/w/Bn + Ln)``."""
        if workers <= 1:
            return 0.0
        return (2 * workers - 2) * (size / workers / self.bandwidth
                                    + self.latency)

    def service_cost(self, seconds: float) -> float:
        return 0.0


@dataclass
class VMParameterServer:
    """Hybrid design (Cirrus): a VM-hosted PS reached from Lambda via gRPC.

    Table 2 model: a 3GB Lambda moves 75 MB in ~1.85 s to c5.4xlarge (~40.5
    MB/s effective incl. serialization), with ~2x contention at 10 workers;
    the server-side model update costs ~2.7 s per worker per 75 MB (lock +
    apply), which is what bounds the hybrid design (§4.3).
    """
    instance: str = "c5.4xlarge"
    n_servers: int = 1
    startup: float = 40.0              # VM boot (no job dispatch needed)
    base_bw: float = 40.5e6
    update_unit: float = 2.7 / 75e6    # s per byte per worker

    store: dict = field(default_factory=dict)

    @property
    def spec(self) -> ChannelSpec:
        return ChannelSpec("vmps", self.base_bw, 0.0, self.startup)

    # metered single-stream kv ops (the Transport surface; the push/pull
    # round below is what the BSP collective actually uses)
    def put(self, key: str, payload: np.ndarray) -> float:
        self.store[key] = payload
        return self.transfer_time(nbytes(payload), 1)

    def get(self, key: str) -> tuple[np.ndarray, float]:
        payload = self.store[key]
        return payload, self.transfer_time(nbytes(payload), 1)

    def transfer_time(self, size: int, workers: int) -> float:
        contention = 1.0 + (workers - 1) / 9.0
        return size / self.base_bw * contention / self.n_servers

    def update_time(self, size: int, workers: int) -> float:
        # serialization/locking on the PS, scales with workers (Table 2)
        return self.update_unit * size * workers / self.n_servers

    def push_pull_round(self, size: int, workers: int) -> float:
        """push grads + server update + pull model (per worker wall time)."""
        return (2 * self.transfer_time(size, workers)
                + self.update_time(size, workers))

    def hourly_cost(self) -> float:
        return pricing.EC2_HOURLY[self.instance] * self.n_servers

    def service_cost(self, seconds: float) -> float:
        return pricing.ec2_cost(self.instance, seconds, self.n_servers)


# ----------------------------------------------------------------- registry --

#: non-storage transport constants, same ChannelSpec shape so the analytical
#: model and spec-time validation read every substrate uniformly -- derived
#: from the implementations' own defaults (no second copy of Table 2)
NETWORK_SPECS = {
    "nic": ChannelSpec("nic", NIC_BANDWIDTH, NIC_LATENCY, 0.0),
    "dcn": ChannelSpec("dcn", DCN_BANDWIDTH, DCN_LATENCY, 0.0),
    "vmps": VMParameterServer().spec,
}


def _make_nic(bandwidth: float = NIC_BANDWIDTH,
              latency: float = NIC_LATENCY) -> VMNetwork:
    return VMNetwork(bandwidth, latency, "nic")


def _make_dcn(bandwidth: float = DCN_BANDWIDTH,
              latency: float = DCN_LATENCY) -> VMNetwork:
    return VMNetwork(bandwidth, latency, "dcn")


#: every selectable transport: name -> zero-config factory
TRANSPORTS = {
    **{name: (lambda n: (lambda: StorageChannel(n)))(name)
       for name in CHANNEL_SPECS},
    "vmps": VMParameterServer,
    "nic": _make_nic,
    "dcn": _make_dcn,
}

#: transports that are storage services (FaaS channels, Tables 1/6)
STORAGE_TRANSPORTS = tuple(CHANNEL_SPECS)

#: transports that are point-to-point networks (ring collectives)
NETWORK_TRANSPORTS = ("nic", "dcn")


def make_transport(name: str, **kw) -> Transport:
    """Instantiate a transport by registry name (``s3``, ``memcached``,
    ``memcached_large``, ``redis``, ``dynamodb``, ``vmps``, ``nic``,
    ``dcn``).  ``nic``/``dcn``/``vmps`` accept constructor overrides."""
    try:
        factory = TRANSPORTS[name]
    except KeyError:
        raise KeyError(f"unknown transport {name!r}; available: "
                       f"{', '.join(sorted(TRANSPORTS))}") from None
    return factory(**kw) if kw else factory()


def transport_constants(name: str) -> ChannelSpec:
    """The Table 6 constants for any transport, WITHOUT instantiating it --
    the single source the analytical model (§5.3) and spec-time validation
    (:meth:`repro.core.platform.CommSpec.validate`) both read."""
    if name in CHANNEL_SPECS:
        return CHANNEL_SPECS[name]
    try:
        return NETWORK_SPECS[name]
    except KeyError:
        raise KeyError(f"unknown transport {name!r}; available: "
                       f"{', '.join(sorted(TRANSPORTS))}") from None


def list_transports() -> list[str]:
    return sorted(TRANSPORTS)
