"""The **Codec** axis of the communication design space (DESIGN.md §12, §16).

A codec is *what an update vector looks like on the wire*.  The paper's
core finding -- FaaS pays off only for models with *reduced* communication
-- makes payload encoding a first-class axis: MLLess (PAPERS.md) shows
significance-filtered/sparsified updates change the FaaS verdict, and
int8 + error-feedback deltas are what make DiLoCo-style outer steps cheap
across slow links.

Codecs here follow the *simulate-time, exact-numerics* contract of the
whole engine: the *merged value* is computed from the dequantized/densified
vectors (so convergence reflects the real lossy math, error feedback
included), while the *metered wire payload* is the packed form --
``wire_floats(n)`` f32 slots for an ``n``-element vector.  Metered
``comm_bytes`` therefore shrink by exactly ``wire_floats(n) / n``.

The codec math itself is NOT implemented here: :class:`Int8EFCodec` and
:class:`TopKCodec` execute the Pallas kernels in :mod:`repro.kernels.quant8`
and :mod:`repro.kernels.topk_ef` (interpret mode off-TPU, real Mosaic
lowering on TPU; ``REPRO_CODEC_BACKEND=ref`` selects the straight-line
oracle fallback).  Quantization is **blockwise**: one fp32 scale per
256-element block (= ``kernels.quant8.kernel.BLOCK``), which is what the
silicon path ships and what :func:`int8_wire_floats` meters.  The
per-channel helper trio (:func:`quantize_int8_ef` / :func:`dequantize_int8`)
delegates to the same :mod:`repro.kernels.quant8.ref` formula for the
TP-sharded in-jit path in :mod:`repro.distributed.local_sgd`, whose
per-channel (no-reshape) layout is load-bearing -- 256-block quantization
of TP-sharded dims made GSPMD replicate the codes (measured regression,
see its §Perf P2 note).
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

#: elements per quantization block == one fp32 wire scale; must equal
#: ``repro.kernels.quant8.kernel.BLOCK`` (asserted in tests) -- kept as a
#: plain int so importing the codec registry never imports jax
QUANT_BLOCK = 256


# --------------------------------------------------- shared quantizer math --

def quantize_int8_ef(xe):
    """Symmetric per-channel (last-axis) int8 quantization with the error
    returned for feedback: ``xe`` should already include the carried
    residual.  -> ``(codes int8, scales f32, error f32)``.

    Thin delegate to :func:`repro.kernels.quant8.ref.quantize8_ef_ref`
    (the one statement of the quantizer formula); jit-traceable, used
    per parameter leaf inside ``shard_map`` by
    :mod:`repro.distributed.local_sgd`.
    """
    from repro.kernels.quant8.ref import quantize8_ef_ref

    q, scale, _deq, err = quantize8_ef_ref(xe, axis=-1)
    return q, scale, err


def dequantize_int8(q, scale):
    from repro.kernels.quant8.ref import dequantize8_ref

    return dequantize8_ref(q, scale)


def int8_wire_floats(n: int) -> int:
    """f32 slots occupied by an int8-compressed n-element vector on the
    wire: packed codes (4 per float) + one fp32 scale per 256-element
    block -- the blockwise form the quant8 kernel actually ships."""
    return -(-n // 4) + -(-n // QUANT_BLOCK)


def int8_encode_decode(x, residual=None):
    """One blockwise-int8 EF wire round trip for an any-shape vector.

    -> ``(deq, new_residual)`` both shaped like ``x``.  This is THE
    simulate-time hot path: one fused Pallas pass
    (:func:`repro.kernels.quant8.ops.int8_roundtrip`) emits codes, scales,
    dequantized values and the carried error together.
    """
    x = np.asarray(x, np.float32)
    if residual is not None:
        x = x + residual
    from repro.kernels.quant8.ops import int8_roundtrip

    _q, _s, deq, err = int8_roundtrip(x)
    return np.asarray(deq, np.float32), np.asarray(err, np.float32)


# ----------------------------------------------------------------- protocol --

@runtime_checkable
class Codec(Protocol):
    """Payload encoding for one fleet's update vectors (DESIGN.md §12).

    Codecs are STATEFUL per run (error-feedback residuals are carried per
    worker across rounds), so factories hand out fresh instances.
    """

    name: str
    #: identity codecs skip the encode/decode round trip entirely, keeping
    #: the fp32 path byte-identical to the seed-era backends
    is_identity: bool

    def wire_floats(self, n: int) -> int:
        """f32 slots the encoded form of an n-element vector occupies."""
        ...

    def encode_decode(self, worker: int, vec: np.ndarray) -> np.ndarray:
        """One worker's lossy wire round trip (residual carried inside)."""
        ...

    def ratio(self, n: int) -> float:
        """Wire bytes / fp32 bytes for an n-element vector."""
        ...


class _CodecBase:
    is_identity = False

    def ratio(self, n: int) -> float:
        return self.wire_floats(n) / n


class Fp32Codec(_CodecBase):
    """Identity: fp32 vectors go on the wire untouched."""
    name = "fp32"
    is_identity = True

    def wire_floats(self, n: int) -> int:
        return n

    def encode_decode(self, worker: int, vec: np.ndarray) -> np.ndarray:
        return vec


class Int8EFCodec(_CodecBase):
    """Blockwise int8 + error feedback: ~4x fewer wire bytes; the
    quantization error is carried per worker into the next round.  Executes
    the fused quant8 EF Pallas kernel (:func:`int8_encode_decode`)."""
    name = "int8"

    def __init__(self):
        self._residual: dict[int, np.ndarray] = {}

    def wire_floats(self, n: int) -> int:
        return int8_wire_floats(n)

    def encode_decode(self, worker: int, vec: np.ndarray) -> np.ndarray:
        deq, err = int8_encode_decode(vec, self._residual.get(worker))
        self._residual[worker] = err
        return deq


class TopKCodec(_CodecBase):
    """Top-k sparsification with error feedback (MLLess-style significance
    filtering): only the ``k = max(1, round(fraction * n))`` largest-|.|
    coordinates ship each round as (value, index) pairs -- ``2k`` f32 slots
    on the wire; everything filtered is carried as residual into the next
    round, so no signal is lost, only deferred.  Executes the fused
    magnitude-threshold + residual-carry Pallas kernel
    (:func:`repro.kernels.topk_ef.topk_ef`); ties at the k-th magnitude
    are all kept."""

    def __init__(self, fraction: float = 0.01):
        fraction = float(fraction)
        if not 0.0 < fraction <= 1.0:
            raise ValueError(
                f"topk fraction must be in (0, 1], got {fraction}")
        self.fraction = fraction
        self._residual: dict[int, np.ndarray] = {}

    @property
    def name(self) -> str:
        return f"topk:{self.fraction:g}"

    def _k(self, n: int) -> int:
        return max(1, int(round(self.fraction * n)))

    def wire_floats(self, n: int) -> int:
        return 2 * self._k(n)            # values + int32 indices

    def encode_decode(self, worker: int, vec: np.ndarray) -> np.ndarray:
        from repro.kernels.topk_ef import topk_ef

        x = np.asarray(vec, np.float32)
        res = self._residual.get(worker)
        if res is not None:
            x = x + res
        out, new_res = topk_ef(x, self._k(x.size))
        self._residual[worker] = np.asarray(new_res, np.float32)
        return np.asarray(out, np.float32)


#: every selectable codec: name -> factory(arg_str or None)
CODECS = {
    "fp32": lambda arg=None: Fp32Codec(),
    "int8": lambda arg=None: Int8EFCodec(),
    "topk": lambda arg=None: TopKCodec(float(arg) if arg else 0.01),
}


def make_codec(spec) -> Codec:
    """``"fp32"`` | ``"int8"`` | ``"topk[:<fraction>]"`` | a
    :class:`Codec` instance.  Returns a FRESH instance (codecs carry
    per-run error-feedback state)."""
    if not isinstance(spec, str):
        return spec
    name, _, arg = spec.partition(":")
    try:
        factory = CODECS[name]
    except KeyError:
        raise KeyError(f"unknown codec {spec!r}; available: "
                       f"{', '.join(sorted(CODECS))}") from None
    return factory(arg or None)


def list_codecs() -> list[str]:
    return sorted(CODECS)
