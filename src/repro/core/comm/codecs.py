"""The **Codec** axis of the communication design space (DESIGN.md §12).

A codec is *what an update vector looks like on the wire*.  The paper's
core finding -- FaaS pays off only for models with *reduced* communication
-- makes payload encoding a first-class axis: MLLess (PAPERS.md) shows
significance-filtered/sparsified updates change the FaaS verdict, and
int8 + error-feedback deltas are what make DiLoCo-style outer steps cheap
across slow links.

Codecs here follow the *simulate-time, exact-numerics* contract of the
whole engine: the *merged value* is computed from the dequantized/densified
vectors (so convergence reflects the real lossy math, error feedback
included), while the *metered wire payload* is the packed form --
``wire_floats(n)`` f32 slots for an ``n``-element vector.  Metered
``comm_bytes`` therefore shrink by exactly ``wire_floats(n) / n``.

The int8 quantizer trio (:func:`quantize_int8_ef` /
:func:`dequantize_int8` / :func:`int8_wire_floats`) is the ONE
implementation shared by the whole repo: the discrete-event stack here,
the LocalSGD/DiLoCo sync protocols (:mod:`repro.core.sync`), and the real
multi-pod training stack (:mod:`repro.distributed.local_sgd`, which applies
the same functions per parameter leaf inside ``shard_map``).
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np


# --------------------------------------------------- shared quantizer math --

def quantize_int8_ef(xe):
    """Symmetric per-channel (last-axis) int8 quantization with the error
    returned for feedback: ``xe`` should already include the carried
    residual.  -> ``(codes int8, scales f32, error f32)`` with
    ``dequantize_int8(codes, scales) + error == xe``."""
    import jax.numpy as jnp

    scale = jnp.maximum(
        jnp.max(jnp.abs(xe), axis=-1, keepdims=True) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xe / scale), -127, 127).astype(jnp.int8)
    return q, scale, xe - q.astype(jnp.float32) * scale


def dequantize_int8(q, scale):
    import jax.numpy as jnp

    return q.astype(jnp.float32) * scale


def int8_wire_floats(n: int) -> int:
    """f32 slots occupied by an int8-compressed n-element vector on the
    wire: packed codes (4 per float) + one per-vector scale."""
    return -(-n // 4) + 1


# ----------------------------------------------------------------- protocol --

@runtime_checkable
class Codec(Protocol):
    """Payload encoding for one fleet's update vectors (DESIGN.md §12).

    Codecs are STATEFUL per run (error-feedback residuals are carried per
    worker across rounds), so factories hand out fresh instances.
    """

    name: str
    #: identity codecs skip the encode/decode round trip entirely, keeping
    #: the fp32 path byte-identical to the seed-era backends
    is_identity: bool

    def wire_floats(self, n: int) -> int:
        """f32 slots the encoded form of an n-element vector occupies."""
        ...

    def encode_decode(self, worker: int, vec: np.ndarray) -> np.ndarray:
        """One worker's lossy wire round trip (residual carried inside)."""
        ...

    def ratio(self, n: int) -> float:
        """Wire bytes / fp32 bytes for an n-element vector."""
        ...


class _CodecBase:
    is_identity = False

    def ratio(self, n: int) -> float:
        return self.wire_floats(n) / n


class Fp32Codec(_CodecBase):
    """Identity: fp32 vectors go on the wire untouched."""
    name = "fp32"
    is_identity = True

    def wire_floats(self, n: int) -> int:
        return n

    def encode_decode(self, worker: int, vec: np.ndarray) -> np.ndarray:
        return vec


class Int8EFCodec(_CodecBase):
    """int8 + error feedback: ~4x fewer wire bytes; the quantization error
    is carried per worker into the next round (:func:`quantize_int8_ef`)."""
    name = "int8"

    def __init__(self):
        self._residual: dict[int, np.ndarray] = {}

    def wire_floats(self, n: int) -> int:
        return int8_wire_floats(n)

    def encode_decode(self, worker: int, vec: np.ndarray) -> np.ndarray:
        res = self._residual.get(worker)
        if res is None:
            res = np.zeros_like(vec, dtype=np.float32)
        q, scale, err = quantize_int8_ef(np.asarray(vec, np.float32) + res)
        self._residual[worker] = np.asarray(err, np.float32)
        return np.asarray(dequantize_int8(q, scale), np.float32)


class TopKCodec(_CodecBase):
    """Top-k sparsification with error feedback (MLLess-style significance
    filtering): only the ``k = max(1, round(fraction * n))`` largest-|.|
    coordinates ship each round as (value, index) pairs -- ``2k`` f32 slots
    on the wire; everything filtered is carried as residual into the next
    round, so no signal is lost, only deferred."""

    def __init__(self, fraction: float = 0.01):
        fraction = float(fraction)
        if not 0.0 < fraction <= 1.0:
            raise ValueError(
                f"topk fraction must be in (0, 1], got {fraction}")
        self.fraction = fraction
        self._residual: dict[int, np.ndarray] = {}

    @property
    def name(self) -> str:
        return f"topk:{self.fraction:g}"

    def _k(self, n: int) -> int:
        return max(1, int(round(self.fraction * n)))

    def wire_floats(self, n: int) -> int:
        return 2 * self._k(n)            # values + int32 indices

    def encode_decode(self, worker: int, vec: np.ndarray) -> np.ndarray:
        x = np.asarray(vec, np.float32)
        res = self._residual.get(worker)
        if res is not None:
            x = x + res
        k = self._k(x.size)
        if k >= x.size:
            self._residual[worker] = np.zeros_like(x)
            return x
        idx = np.argpartition(np.abs(x), -k)[-k:]
        out = np.zeros_like(x)
        out[idx] = x[idx]
        self._residual[worker] = x - out
        return out


#: every selectable codec: name -> factory(arg_str or None)
CODECS = {
    "fp32": lambda arg=None: Fp32Codec(),
    "int8": lambda arg=None: Int8EFCodec(),
    "topk": lambda arg=None: TopKCodec(float(arg) if arg else 0.01),
}


def make_codec(spec) -> Codec:
    """``"fp32"`` | ``"int8"`` | ``"topk[:<fraction>]"`` | a
    :class:`Codec` instance.  Returns a FRESH instance (codecs carry
    per-run error-feedback state)."""
    if not isinstance(spec, str):
        return spec
    name, _, arg = spec.partition(":")
    try:
        factory = CODECS[name]
    except KeyError:
        raise KeyError(f"unknown codec {spec!r}; available: "
                       f"{', '.join(sorted(CODECS))}") from None
    return factory(arg or None)


def list_codecs() -> list[str]:
    return sorted(CODECS)
