"""Transport x Collective x Codec composed into one engine comm backend
(DESIGN.md §12).

:class:`CommStack` is the single implementation of the engine's
``CommBackend`` surface: it runs the collective over the transport on the
codec's wire form, advances the per-worker clocks (barrier or skew,
according to the collective), and meters time (``breakdown["comm"]``),
bytes (``RunResult.comm_bytes``: the WIRE payload, so codec compression
shows up exactly) and substrate dollars (``service_cost``) uniformly --
the three hardwired seed-era backends each re-implemented this.

``ChannelComm`` / ``PSComm`` / ``MPIComm`` remain as thin legacy adapters
over the composition (constructors unchanged, byte-identical results);
:func:`build_comm_stack` is what the platforms call to turn a resolved
``(transport, collective, codec)`` triple into a backend.
"""
from __future__ import annotations

import numpy as np

from repro.core.comm.codecs import Codec, make_codec
from repro.core.comm.collectives import Collective, make_collective
from repro.core.comm.transports import (
    DCN_BANDWIDTH, DCN_LATENCY, NETWORK_TRANSPORTS, StorageChannel, Transport,
    VMNetwork, VMParameterServer, make_transport,
)


class CommStack:
    """One composed communication stack; the engine's comm backend.

    - ``bsp_reduce(ctx, updates, tag)``: merge one BSP round, advancing
      ``ctx.clock`` and the comm meters; returns the merged vector.
    - ``kvstore()``: a metered key-value store (``put``/``get`` returning
      simulated seconds) holding the global model for ASP/SSP and the
      checkpoint blobs -- the transport itself, unless a side ``store``
      was given (the hybrid VM-PS keeps its global model on S3).
    - ``service_cost(seconds)``: $ for the communication substrate(s).
    """

    def __init__(self, transport: Transport, collective: Collective | str,
                 codec: Codec | str = "fp32", store=None):
        self.transport = transport
        self.collective = make_collective(collective)
        self.codec = make_codec(codec)
        self._store = store if store is not None else transport

    @property
    def name(self) -> str:
        """Canonical ``transport/collective/codec`` label."""
        return (f"{self.transport.spec.name}/{self.collective.name}"
                f"/{self.codec.name}")

    def bsp_reduce(self, ctx, updates, tag):
        # pre-codec update size, for elastic resize feasibility checks
        # (validate_stack re-applies the codec's wire ratio itself)
        ctx.last_update_nbytes = int(updates[0].nbytes)
        codec = self.codec
        if codec.is_identity:
            payloads, merged_lossy = updates, None
        else:
            # exact numerics from the dequantized/densified vectors; the
            # collective runs on wire-sized stand-ins for time/byte metering
            deq = [codec.encode_decode(i, u) for i, u in enumerate(updates)]
            merged_lossy = np.mean(np.stack(deq), axis=0)
            nw = codec.wire_floats(updates[0].size)
            payloads = [np.zeros(nw, np.float32) for _ in updates]
        merged, times = self.collective.run(self.transport, payloads, tag)
        times = np.asarray(times, float)
        ctx.meter_add("comm", float(np.mean(times)))
        ctx.meter_bytes(float(payloads[0].nbytes))
        rec = ctx.rec
        if rec is not None and not codec.is_identity:
            rec.mark("codec", float(np.max(ctx.clock)), codec=self.codec.name,
                     raw_bytes=int(updates[0].nbytes),
                     wire_bytes=int(payloads[0].nbytes))
        if self.collective.barrier:
            base = float(np.max(ctx.clock))
            if rec is None:
                ctx.clock[:] = base + times
            else:
                # barrier semantics: wait to the fleet max (idle), then the
                # collective's per-worker comm seconds
                before = ctx.clock.copy()
                ctx.clock[:] = base + times
                meta = {"stack": self.name}
                for i in range(len(ctx.worker_ids)):  # times may be 0-d
                    wid = int(ctx.worker_ids[i])
                    rec.span(wid, "barrier", "idle", float(before[i]), base)
                    rec.span(wid, "comm.reduce", "comm", base,
                             float(ctx.clock[i]), meta=meta)
        else:
            if rec is None:
                ctx.clock += times
            else:
                before = ctx.clock.copy()
                ctx.clock += times
                rec.tile(ctx.worker_ids, before, ctx.clock, "comm.reduce",
                         "comm", meta={"stack": self.name})
        return merged if merged_lossy is None else merged_lossy

    def kvstore(self):
        return self._store

    def rebuilt(self) -> "CommStack":
        """Re-compose this stack for a resized fleet (DESIGN.md §13): the
        collective and codec are rebuilt fresh (error-feedback residuals
        are keyed by worker position, which a resize invalidates) while the
        TRANSPORT objects -- and with them the accumulated op counters,
        per-op dollars, and the ASP/SSP kvstore contents -- carry over, so
        ``service_cost`` keeps billing the whole run."""
        return CommStack(
            self.transport, self.collective.name, self.codec.name,
            store=None if self._store is self.transport else self._store)

    def startup(self) -> float:
        """Seconds to provision the comm substrate (Table 6 ``startup``
        column: 0 for always-on S3/DynamoDB and NICs, ~2 min for an
        ElastiCache cluster, the VM boot for the hybrid PS).  Platforms
        fold this into their fleet startup via ``max``."""
        return self.transport.spec.startup

    def service_cost(self, seconds: float) -> float:
        c = float(self.transport.service_cost(seconds))
        if self._store is not self.transport:
            c += float(self._store.service_cost(seconds))
        return c


# -------------------------------------------------------- legacy adapters ---

class ChannelComm(CommStack):
    """Pure-FaaS: a store-based collective's files on a storage channel
    (seed-era constructor preserved; now a :class:`CommStack`)."""

    def __init__(self, chan, pattern, codec="fp32"):
        super().__init__(chan, pattern, codec)
        self.chan = chan
        self.pattern = pattern if isinstance(pattern, str) else pattern.name


class PSComm(CommStack):
    """Hybrid (Cirrus): VM-hosted parameter server; S3 keeps checkpoints and
    the ASP/SSP global model (Table 2 costs bound the PS itself)."""

    def __init__(self, ps: VMParameterServer, chan: StorageChannel,
                 codec="fp32"):
        super().__init__(ps, "pushpull", codec, store=chan)
        self.ps = ps
        self.chan = chan


class MPIComm(CommStack):
    """IaaS/pod: ring AllReduce over NICs/DCN; worker 0 doubles as the
    in-memory key-value host for ASP/SSP (reached through the same metered
    network)."""

    def __init__(self, net: VMNetwork, codec="fp32"):
        super().__init__(net, "ring", codec)
        self.net = net


# ---------------------------------------------------------------- factory ---

def build_comm_stack(transport: str, collective: str, codec: str = "fp32", *,
                     nic: VMNetwork | None = None,
                     dcn: VMNetwork | None = None) -> CommStack:
    """Turn a resolved ``(transport, collective, codec)`` name triple into
    a backend.  Platforms pass their calibrated ``nic``/``dcn`` networks
    (per-fleet NIC speeds, per-pod DCN constants); everything else is
    instantiated from the registry.  The legacy adapter classes are used so
    ``isinstance``-based platform hooks (startup, checkpoint store) keep
    working unchanged."""
    if transport == "vmps":
        return PSComm(VMParameterServer(), StorageChannel("s3"), codec=codec)
    if transport in NETWORK_TRANSPORTS:
        if collective != "ring":
            net = (nic if transport == "nic" else dcn)
            net = net if net is not None else make_transport(transport)
            return ChannelComm(net, collective, codec=codec)
        if transport == "nic":
            return MPIComm(nic if nic is not None else make_transport("nic"),
                           codec=codec)
        return MPIComm(dcn if dcn is not None
                       else VMNetwork(DCN_BANDWIDTH, DCN_LATENCY, "dcn"),
                       codec=codec)
    return ChannelComm(StorageChannel(transport), collective, codec=codec)
