"""The paper's study models in JAX: LR, SVM, k-means, and MLP stand-ins sized
to MobileNet (12 MB) / ResNet50 (89 MB) parameter footprints.

All are expressed against a common functional interface used by both the
FaaS and IaaS runtimes (paper principle: *same algorithm both sides*):

    init(key, ds)                  -> params (pytree)
    grad(params, batch)            -> (loss, grads)         # SGD family
    local_stats(params, batch)     -> stats                 # EM (k-means)
    apply_stats(params, stats)     -> params
    eval_loss(params, ds)          -> float
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import Dataset

L2 = 1e-4


def _dot(params_w, batch):
    """Dense or sparse x.w"""
    if "idx" in batch:
        return jnp.sum(batch["x"] * params_w[batch["idx"]], axis=1)
    return batch["x"] @ params_w


def _batch_of(ds: Dataset, lo: int, hi: int) -> dict:
    b = {"x": jnp.asarray(ds.x[lo:hi]), "y": jnp.asarray(ds.y[lo:hi])}
    if ds.sparse:
        b["idx"] = jnp.asarray(ds.idx[lo:hi])
    return b


@dataclass(frozen=True)
class StudyModel:
    name: str
    init: Callable
    grad: Optional[Callable] = None
    eval_loss: Optional[Callable] = None
    local_stats: Optional[Callable] = None
    apply_stats: Optional[Callable] = None
    convex: bool = True
    flops_per_row: float = 0.0  # analytic compute model (per data row)


# ------------------------------------------------------------------ LR -------

def make_lr(ds: Dataset) -> StudyModel:
    d = ds.d

    def init(key):
        return jnp.zeros((d,), jnp.float32)

    @jax.jit
    def loss_fn(w, batch):
        z = _dot(w, batch) * batch["y"]
        # paper reports plain logistic loss; L2 only regularizes the grad path
        return jnp.mean(jnp.logaddexp(0.0, -z)) + 0.5 * L2 * jnp.sum(w * w)

    grad = jax.jit(jax.value_and_grad(loss_fn))

    def eval_loss(w, dset: Dataset, max_rows: int = 50_000):
        b = _batch_of(dset, 0, min(dset.n, max_rows))
        z = _dot(w, b) * b["y"]
        return float(jnp.mean(jnp.logaddexp(0.0, -z)))

    nnz = ds.x.shape[1] if ds.sparse else d
    return StudyModel("lr", init, grad, eval_loss, convex=True,
                      flops_per_row=4.0 * nnz)


# ------------------------------------------------------------------ SVM ------

def make_svm(ds: Dataset) -> StudyModel:
    d = ds.d

    def init(key):
        return jnp.zeros((d,), jnp.float32)

    @jax.jit
    def loss_fn(w, batch):
        z = _dot(w, batch) * batch["y"]
        return jnp.mean(jnp.maximum(0.0, 1.0 - z)) + 0.5 * L2 * jnp.sum(w * w)

    grad = jax.jit(jax.value_and_grad(loss_fn))

    def eval_loss(w, dset: Dataset, max_rows: int = 50_000):
        b = _batch_of(dset, 0, min(dset.n, max_rows))
        z = _dot(w, b) * b["y"]
        return float(jnp.mean(jnp.maximum(0.0, 1.0 - z)))

    nnz = ds.x.shape[1] if ds.sparse else d
    return StudyModel("svm", init, grad, eval_loss, convex=True,
                      flops_per_row=4.0 * nnz)


# --------------------------------------------------------------- k-means -----

def make_kmeans(ds: Dataset, k: int = 10) -> StudyModel:
    d = ds.d
    if ds.sparse:
        raise ValueError("kmeans study model requires dense features")

    def init(key):
        i = jax.random.choice(key, ds.n, (k,), replace=False)
        return jnp.asarray(ds.x[np.asarray(i)])

    @jax.jit
    def local_stats(centers, batch):
        x = batch["x"]
        d2 = (jnp.sum(x * x, 1)[:, None] - 2 * x @ centers.T
              + jnp.sum(centers * centers, 1)[None, :])
        a = jnp.argmin(d2, axis=1)
        one = jax.nn.one_hot(a, k, dtype=jnp.float32)
        return {"sums": one.T @ x, "counts": one.sum(0),
                "sse": jnp.sum(jnp.min(d2, axis=1))}

    @jax.jit
    def apply_stats(centers, stats):
        c = stats["counts"][:, None]
        return jnp.where(c > 0, stats["sums"] / jnp.maximum(c, 1.0), centers)

    def eval_loss(centers, dset: Dataset, max_rows: int = 50_000):
        b = _batch_of(dset, 0, min(dset.n, max_rows))
        s = local_stats(centers, b)
        return float(s["sse"] / b["x"].shape[0])

    return StudyModel("kmeans", init, local_stats=local_stats,
                      apply_stats=apply_stats, eval_loss=eval_loss,
                      convex=False, flops_per_row=3.0 * d * k)


# ------------------------------------------------ NN stand-ins (MN / RN) -----

def _mlp_sizes(d_in: int, n_out: int, target_mb: float):
    """Pick one hidden width so total fp32 params ~= target_mb."""
    target = target_mb * 1e6 / 4.0
    # params ~ d_in*h + h*h + h*n_out
    a, b, c = 1.0, d_in + n_out, -target
    h = int((-b + (b * b - 4 * a * c) ** 0.5) / 2)
    return (d_in, h, h, n_out)


def make_mlp(ds: Dataset, target_mb: float, name: str) -> StudyModel:
    """MobileNet-12MB / ResNet50-89MB stand-ins (see DESIGN.md §3: the
    paper's CNNs are stand-ins sized by parameter bytes, which is what
    drives the communication study)."""
    sizes = _mlp_sizes(ds.d, ds.n_classes, target_mb)

    def init(key):
        ks = jax.random.split(key, len(sizes) - 1)
        return [(jax.random.normal(k, (a, b)) * (2.0 / a) ** 0.5,
                 jnp.zeros((b,))) for k, (a, b) in
                zip(ks, zip(sizes[:-1], sizes[1:]))]

    def apply(params, x):
        for i, (w, b) in enumerate(params):
            x = x @ w + b
            if i < len(params) - 1:
                x = jax.nn.relu(x)
        return x

    @jax.jit
    def loss_fn(params, batch):
        logits = apply(params, batch["x"])
        y = batch["y"].astype(jnp.int32)
        if ds.n_classes == 2:
            y = ((y + 1) // 2).astype(jnp.int32)  # {-1,1} -> {0,1}
            logits2 = jnp.stack([jnp.zeros_like(logits[:, 0]), logits[:, 0]], 1) \
                if logits.shape[-1] == 1 else logits
            return -jnp.mean(jax.nn.log_softmax(logits2)[jnp.arange(y.shape[0]), y])
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y])

    grad = jax.jit(jax.value_and_grad(loss_fn))

    def eval_loss(params, dset: Dataset, max_rows: int = 20_000):
        return float(loss_fn(params, _batch_of(dset, 0, min(dset.n, max_rows))))

    flops = 6.0 * sum(a * b for a, b in zip(sizes[:-1], sizes[1:]))
    return StudyModel(name, init, grad, eval_loss, convex=False,
                      flops_per_row=flops)


def model_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))


#: the paper's study stand-ins (the "model" axis values this module serves;
#: real architectures are served by repro.core.workloads)
STUDY_MODELS = ("lr", "svm", "kmeans", "mobilenet", "resnet50")


def make_study_model(name: str, ds: Dataset, **kw) -> StudyModel:
    if name == "lr":
        return make_lr(ds)
    if name == "svm":
        return make_svm(ds)
    if name == "kmeans":
        return make_kmeans(ds, **kw)
    if name == "mobilenet":
        return make_mlp(ds, 12.0, "mobilenet")
    if name == "resnet50":
        return make_mlp(ds, 89.0, "resnet50")
    raise KeyError(name)
