"""Structured trace subsystem: spans, Figure-10 breakdowns, exporters and
conservation gates (DESIGN.md §18)."""
from .breakdown import PHASES, derive_breakdown, render_breakdown
from .export import EXPORTERS, export_chrome, list_exporters, make_exporter
from .invariants import (assert_invariants, check_clock_tiling,
                         check_invariants, render_invariants)
from .record import Span, TraceRecorder

__all__ = [
    "Span", "TraceRecorder",
    "PHASES", "derive_breakdown", "render_breakdown",
    "EXPORTERS", "export_chrome", "make_exporter", "list_exporters",
    "check_clock_tiling", "check_invariants", "assert_invariants",
    "render_invariants",
]


def comm_seconds(ctx) -> float:
    """One source of truth for elastic telemetry: the recorder's meter
    mirror when tracing (bitwise-equal to the engine meter by
    construction), the engine meter otherwise."""
    if ctx.rec is not None:
        return ctx.rec.meters.get("comm", 0.0)
    return ctx.res.breakdown.get("comm", 0.0)
