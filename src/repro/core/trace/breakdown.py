"""Figure-10 phase breakdown derived from spans alone (DESIGN.md §18).

The paper's Figure 10 decomposes end-to-end training into startup, data
loading, computation, and communication.  The recorder's span taxonomy
extends that with the phases the simulator actually exhibits: ``stall``
(stragglers, SSP waits, preemption rework/lost work), ``ckpt`` (save /
restore shards), and ``idle`` (barrier waits).  Everything here is
*derived* -- no meter is consulted, so the aggregation doubles as an
independent check on ``RunResult.breakdown``.
"""
from __future__ import annotations

from .record import TraceRecorder

__all__ = ["PHASES", "derive_breakdown", "render_breakdown"]

# Figure-10 bucket order (presentation + aggregation key order).
PHASES = ("startup", "data", "compute", "comm", "stall", "ckpt", "idle")


def derive_breakdown(rec: TraceRecorder) -> dict:
    """Aggregate spans into the Figure-10 breakdown, per worker and per $.

    Returns::

        {"phases":     {phase: total seconds across workers},
         "per_worker": {wid: {phase: seconds}},
         "wall":       {wid: final clock - birth clock},
         "usd":        {label: attributed dollars, summed per label},
         "bytes":      {"comm": traced comm bytes, "ckpt": traced ckpt bytes}}
    """
    per_worker: dict[int, dict[str, float]] = {w: {} for w in rec.born}
    for s in rec.spans:
        d = per_worker.setdefault(s.worker, {})
        d[s.phase] = d.get(s.phase, 0.0) + (s.t1 - s.t0)
    phases = {p: 0.0 for p in PHASES}
    for d in per_worker.values():
        for p, v in d.items():
            phases[p] = phases.get(p, 0.0) + v
    wall = {w: rec.final.get(w, rec.born[w]) - rec.born[w] for w in rec.born}
    usd: dict[str, float] = {}
    for label, v in rec.cost_ledger():
        usd[label] = usd.get(label, 0.0) + v
    return {
        "phases": phases,
        "per_worker": {w: per_worker[w] for w in sorted(per_worker)},
        "wall": wall,
        "usd": usd,
        "bytes": {"comm": rec.bytes_total("comm"),
                  "ckpt": rec.bytes_total("ckpt")},
    }


def render_breakdown(rec: TraceRecorder, title: str = "") -> str:
    """Text rendering of the Figure-10 table for ``repro trace``."""
    bd = derive_breakdown(rec)
    total_wall = sum(bd["wall"].values())
    lines = []
    if title:
        lines.append(title)
    lines.append(f"{'phase':<10s} {'seconds':>12s} {'share':>8s}")
    for p in PHASES:
        v = bd["phases"].get(p, 0.0)
        share = v / total_wall if total_wall > 0 else 0.0
        lines.append(f"{p:<10s} {v:12.3f} {share:7.1%}")
    other = sum(v for p, v in bd["phases"].items() if p not in PHASES)
    if other:
        lines.append(f"{'other':<10s} {other:12.3f}"
                     f" {other / max(total_wall, 1e-300):7.1%}")
    lines.append(f"{'wall':<10s} {total_wall:12.3f}"
                 f"  ({len(bd['wall'])} workers)")
    if bd["usd"]:
        lines.append("")
        lines.append(f"{'$ term':<16s} {'usd':>14s}")
        for label, v in bd["usd"].items():
            lines.append(f"{label:<16s} {v:14.6f}")
        lines.append(f"{'total':<16s} {rec.cost_total():14.6f}")
    lines.append("")
    lines.append(f"bytes: comm={bd['bytes']['comm']:.0f}"
                 f" ckpt={bd['bytes']['ckpt']:.0f}"
                 f"  events: {len(rec.spans)} spans + {len(rec.marks)} marks")
    return "\n".join(lines)
