"""Trace exporters on the registry convention (DESIGN.md §18).

``EXPORTERS`` maps an exporter name to a function ``recorder -> dict``
whose output serializes straight to JSON.  Both built-ins emit the Chrome
trace-event format (the JSON schema Perfetto's legacy importer and
``chrome://tracing`` both load): spans become complete (``"ph": "X"``)
events with microsecond timestamps, instantaneous marks become ``"i"``
events, and worker timelines map to ``tid`` rows under one ``pid``.

Registered under two names -- ``chrome`` and ``perfetto`` -- so either
spelling works in ``repro trace --export``; ``repro list`` prints both.
"""
from __future__ import annotations

from .record import TraceRecorder

__all__ = ["EXPORTERS", "make_exporter", "list_exporters", "export_chrome"]


def export_chrome(rec: TraceRecorder) -> dict:
    """Chrome trace-event JSON object format.

    ``ts``/``dur`` are microseconds of *simulated* time; ``tid`` is the
    stable worker id (request/replica id for serving traces)."""
    events: list[dict] = []
    for wid in rec.workers():
        events.append({"name": "thread_name", "ph": "M", "pid": 0,
                       "tid": wid, "args": {"name": f"worker {wid}"}})
    for s in rec.spans:
        ev = {"name": s.kind, "cat": s.phase, "ph": "X",
              "ts": s.t0 * 1e6, "dur": (s.t1 - s.t0) * 1e6,
              "pid": 0, "tid": s.worker, "args": {}}
        if s.nbytes:
            ev["args"]["nbytes"] = s.nbytes
        if s.usd:
            ev["args"]["usd"] = s.usd
        if s.meta:
            ev["args"].update(s.meta)
        events.append(ev)
    for m in rec.marks:
        args = {k: v for k, v in m.items()
                if k not in ("kind", "t", "worker")}
        events.append({"name": m["kind"], "cat": "mark", "ph": "i",
                       "ts": m["t"] * 1e6, "pid": 0, "tid": m["worker"],
                       "s": "t", "args": args})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"recorder": rec.kind,
                          "workers": len(rec.born),
                          "spans": len(rec.spans),
                          "marks": len(rec.marks)}}


# name -> exporter(recorder) -> JSON-serializable dict.  "perfetto" is the
# same trace-event emitter: Perfetto ingests Chrome JSON natively.
EXPORTERS = {
    "chrome": export_chrome,
    "perfetto": export_chrome,
}


def make_exporter(name: str):
    """Resolve an exporter by registry name (raises on unknown names with
    the list of valid ones, like every other registry factory)."""
    try:
        return EXPORTERS[name]
    except KeyError:
        raise ValueError(f"unknown exporter {name!r}; "
                         f"choose from {sorted(EXPORTERS)}") from None


def list_exporters() -> list[str]:
    return sorted(EXPORTERS)
