"""Conservation invariants: the tracer as a cross-check on every meter
(DESIGN.md §18).

Three gates, all EXACT (``==`` on floats, no tolerance):

1. **Clock tiling** -- per worker, spans are contiguous (each span starts
   bitwise where the previous ended) from the birth clock to the final /
   retirement clock.  Checked on endpoints, never by re-summing durations.
2. **Cost attribution** -- the ordered $ ledger written by the last
   ``finalize_cost`` call sums (left-associatively) to ``RunResult.cost``.
3. **Byte conservation** -- the comm/ckpt byte ledgers sum to
   ``RunResult.comm_bytes`` / ``RunResult.ckpt_bytes``.

Exactness is by construction, not luck: span endpoints are read back from
the mutated clock array, and the ledgers mirror the engine's accumulation
values *and order* (see ``record.py``).
"""
from __future__ import annotations

from .record import TraceRecorder

__all__ = ["check_clock_tiling", "check_invariants", "assert_invariants",
           "render_invariants"]


def check_clock_tiling(rec: TraceRecorder) -> dict:
    """Invariant 1: spans tile each worker's timeline birth -> final."""
    by_worker: dict[int, list] = {w: [] for w in rec.born}
    for s in rec.spans:
        by_worker.setdefault(s.worker, []).append(s)
    errors: list[str] = []
    for wid in sorted(by_worker):
        spans = sorted(by_worker[wid], key=lambda s: (s.t0, s.t1))
        if wid not in rec.born:
            errors.append(f"worker {wid}: spans but no recorded birth")
            continue
        t = rec.born[wid]
        for s in spans:
            if s.t0 != t:
                errors.append(f"worker {wid}: gap/overlap at {s.kind}: "
                              f"span starts {s.t0!r}, timeline at {t!r}")
            t = s.t1
        end = rec.final.get(wid)
        if end is None:
            errors.append(f"worker {wid}: no final clock recorded")
        elif t != end:
            errors.append(f"worker {wid}: timeline ends at {t!r}, "
                          f"final clock {end!r}")
    return {"ok": not errors, "workers": len(by_worker),
            "spans": len(rec.spans), "errors": errors[:8]}


def check_invariants(res) -> dict:
    """All three gates against a traced ``RunResult``.

    ``res`` must expose ``trace`` (the recorder), ``cost``, ``comm_bytes``
    and ``ckpt_bytes``.
    """
    rec = res.trace
    if rec is None:
        raise ValueError("run was not traced (trace=False)")
    clock = check_clock_tiling(rec)
    traced_usd = rec.cost_total()
    cost = {"ok": traced_usd == res.cost,
            "traced_usd": traced_usd, "metered_usd": res.cost}
    t_comm = rec.bytes_total("comm")
    t_ckpt = rec.bytes_total("ckpt")
    m_ckpt = getattr(res, "ckpt_bytes", 0)
    nbytes = {"ok": t_comm == res.comm_bytes and t_ckpt == m_ckpt,
              "traced_comm": t_comm, "metered_comm": res.comm_bytes,
              "traced_ckpt": t_ckpt, "metered_ckpt": m_ckpt}
    return {"ok": clock["ok"] and cost["ok"] and nbytes["ok"],
            "clock": clock, "cost": cost, "bytes": nbytes}


def assert_invariants(res) -> dict:
    """Raise ``AssertionError`` (with the offending numbers) unless every
    gate passes; return the check results otherwise."""
    inv = check_invariants(res)
    if not inv["clock"]["ok"]:
        raise AssertionError("clock tiling violated: "
                             + "; ".join(inv["clock"]["errors"]))
    if not inv["cost"]["ok"]:
        raise AssertionError(
            f"cost attribution violated: traced "
            f"{inv['cost']['traced_usd']!r} != metered "
            f"{inv['cost']['metered_usd']!r}")
    if not inv["bytes"]["ok"]:
        b = inv["bytes"]
        raise AssertionError(
            f"byte conservation violated: comm {b['traced_comm']!r} vs "
            f"{b['metered_comm']!r}, ckpt {b['traced_ckpt']!r} vs "
            f"{b['metered_ckpt']!r}")
    return inv


def render_invariants(inv: dict) -> str:
    """Three OK/FAIL lines for ``repro trace``."""
    c, u, b = inv["clock"], inv["cost"], inv["bytes"]
    mark = lambda ok: "OK  " if ok else "FAIL"  # noqa: E731
    lines = [
        f"[{mark(c['ok'])}] clock tiling      "
        f"{c['spans']} spans tile {c['workers']} worker timelines",
        f"[{mark(u['ok'])}] cost attribution  "
        f"traced ${u['traced_usd']:.6f} == metered ${u['metered_usd']:.6f}",
        f"[{mark(b['ok'])}] byte conservation "
        f"comm {b['traced_comm']:.0f}B == {b['metered_comm']:.0f}B, "
        f"ckpt {b['traced_ckpt']:.0f}B == {b['metered_ckpt']:.0f}B",
    ]
    for err in c.get("errors", []):
        lines.append(f"       {err}")
    return "\n".join(lines)
