"""The :class:`TraceRecorder`: per-event spans on worker timelines
(DESIGN.md §18).

One recorder observes one simulated run.  Every clock mutation in the
engine/sync/comm/ckpt layers emits a typed :class:`Span` on the mutated
worker's timeline; every metered dollar and wire byte lands in an ordered
ledger.  Three design rules make the recorder a *conservation cross-check*
on the meters rather than a second bookkeeping path:

- **Tiling, not re-summation.**  A span's endpoints are the clock values
  around the mutation (``t0`` captured before, ``t1`` read back from the
  mutated array), so per-worker spans tile the timeline contiguously from
  birth to the final clock and the invariant check compares *endpoints
  bitwise* -- no float re-summation that could drift by ULPs.
- **Mirrored accumulation order.**  The meter mirror (:meth:`meter`), the
  cost ledger (:meth:`cost`) and the byte ledgers (:meth:`bytes_event`)
  append the exact values the engine accumulates, in the exact order, so
  sequential sums are bit-identical to ``RunResult.breakdown`` /
  ``finalize_cost`` / ``comm_bytes`` / ``ckpt_bytes``.
- **Nothing when disabled.**  Every instrumentation site is guarded by
  ``if ctx.rec is not None``; with tracing off no copy, no float op and no
  allocation happens, so ``trace=False`` runs are byte-identical to the
  untraced engine (pinned in ``tests/test_trace.py``).
"""
from __future__ import annotations

__all__ = ["Span", "TraceRecorder"]


class Span:
    """One typed interval on a worker timeline.

    ``worker`` is the STABLE worker id (elastic joiners mint fresh ids;
    serving uses request/replica ids), ``kind`` the event type
    (``"compute"``, ``"comm.reduce"``, ``"ckpt.save"``, ...), ``phase``
    the Figure-10 bucket it aggregates into (``startup``/``data``/
    ``compute``/``comm``/``stall``/``ckpt``/``idle``)."""

    __slots__ = ("worker", "kind", "phase", "t0", "t1", "nbytes", "usd",
                 "meta")

    def __init__(self, worker: int, kind: str, phase: str, t0: float,
                 t1: float, nbytes: float = 0.0, usd: float = 0.0,
                 meta: dict | None = None):
        self.worker = worker
        self.kind = kind
        self.phase = phase
        self.t0 = t0
        self.t1 = t1
        self.nbytes = nbytes
        self.usd = usd
        self.meta = meta

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        d = {"worker": self.worker, "kind": self.kind, "phase": self.phase,
             "t0": self.t0, "t1": self.t1}
        if self.nbytes:
            d["nbytes"] = self.nbytes
        if self.usd:
            d["usd"] = self.usd
        if self.meta:
            d["meta"] = self.meta
        return d

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.worker}, {self.kind!r}, {self.phase!r}, "
                f"[{self.t0:.6g}, {self.t1:.6g}])")


class TraceRecorder:
    """Ordered event record of one simulated run (training or serving).

    Attached to :class:`~repro.core.engine.SimContext` as ``ctx.rec`` (and
    to ``serve()``'s loop state) when ``trace=True``; ``None`` otherwise.
    """

    def __init__(self, kind: str = "train"):
        self.kind = kind                  # "train" | "serve"
        self.spans: list[Span] = []
        self.marks: list[dict] = []       # instant events (codec, shard ops,
                                          # kills, resize decisions, windows)
        self.born: dict[int, float] = {}      # stable id -> birth clock
        self.retired: dict[int, float] = {}   # stable id -> retirement clock
        self.final: dict[int, float] = {}     # stable id -> final clock
        self.meters: dict[str, float] = {}    # breakdown mirror (bitwise)
        self._cost: list[tuple[str, float]] = []    # ordered $ ledger
        self._bytes: dict[str, list[tuple[float, dict | None]]] = {
            "comm": [], "ckpt": []}

    # ---- spans --------------------------------------------------------------
    def span(self, worker: int, kind: str, phase: str, t0: float, t1: float,
             nbytes: float = 0.0, usd: float = 0.0,
             meta: dict | None = None) -> None:
        """Append one span; zero-length spans are dropped (a no-op mutation
        leaves no gap for the tiling check to explain)."""
        if t1 != t0:
            self.spans.append(Span(int(worker), kind, phase, float(t0),
                                   float(t1), nbytes, usd, meta))

    def tile(self, worker_ids, before, after, kind: str, phase: str,
             meta: dict | None = None) -> None:
        """Spans for one vectorized clock mutation: position ``i`` moved
        from ``before[i]`` to ``after[i]``."""
        for i in range(len(worker_ids)):
            self.span(int(worker_ids[i]), kind, phase, float(before[i]),
                      float(after[i]), meta=meta)

    # ---- worker lifecycle ---------------------------------------------------
    def birth(self, worker: int, t: float) -> None:
        self.born[int(worker)] = float(t)

    def retire_worker(self, worker: int, t: float) -> None:
        self.retired[int(worker)] = float(t)
        self.final[int(worker)] = float(t)

    def finalize_clock(self, worker_ids, clock) -> None:
        """Record the end-of-run clock of every LIVE worker (retired ones
        already pinned theirs at retirement)."""
        for i in range(len(worker_ids)):
            self.final[int(worker_ids[i])] = float(clock[i])

    # ---- meter mirror -------------------------------------------------------
    def meter(self, key: str, dt: float) -> None:
        """Mirror of ``SimContext.meter_add`` -- same values, same order,
        so ``rec.meters`` is bitwise-equal to ``RunResult.breakdown``."""
        self.meters[key] = self.meters.get(key, 0.0) + dt

    # ---- $ ledger -----------------------------------------------------------
    def cost_reset(self) -> None:
        """Start a fresh attribution ledger.  ``finalize_cost`` is also
        called mid-run (elastic telemetry snapshots); only the LAST call's
        ledger describes ``RunResult.cost``, so every call resets first."""
        self._cost = []

    def cost(self, label: str, usd: float) -> None:
        self._cost.append((label, float(usd)))

    def cost_total(self) -> float:
        """Left-associative sum in ledger order -- bitwise equal to the
        ``finalize_cost`` return by construction (IEEE ``a - b`` is
        ``a + (-b)``, so rebates enter as negative entries)."""
        total = 0.0
        for _, usd in self._cost:
            total = total + usd
        return total

    def cost_ledger(self) -> list[tuple[str, float]]:
        return list(self._cost)

    # ---- byte ledgers -------------------------------------------------------
    def bytes_event(self, stream: str, nbytes: float,
                    meta: dict | None = None) -> None:
        """One metered byte movement on ``stream`` (``"comm"`` |
        ``"ckpt"``), appended exactly where the engine meter accumulates
        the same value."""
        self._bytes[stream].append((nbytes, meta))

    def bytes_total(self, stream: str) -> float:
        total = 0.0
        for n, _ in self._bytes[stream]:
            total = total + n
        return total

    def bytes_ledger(self, stream: str) -> list:
        return list(self._bytes[stream])

    # ---- instant events -----------------------------------------------------
    def mark(self, kind: str, t: float, worker: int = -1, **meta) -> None:
        self.marks.append({"kind": kind, "t": float(t),
                           "worker": int(worker), **meta})

    # ---- summary ------------------------------------------------------------
    @property
    def n_events(self) -> int:
        return len(self.spans) + len(self.marks)

    def workers(self) -> list[int]:
        """Every stable worker id that was ever born."""
        return sorted(self.born)
