"""Synchronization protocols (paper §3.2.4; DESIGN.md §6).

Each protocol is a strategy object driving the discrete-event engine's
:class:`~repro.core.engine.SimContext`; the same three protocols run on every
infrastructure (FaaS, IaaS, hybrid, spot, heterogeneous fleets):

- :class:`BSP` -- bulk-synchronous rounds; the merge itself is delegated to
  the platform's :class:`~repro.core.engine.CommBackend` (two-phase
  merge/update file pattern on FaaS, ring AllReduce on IaaS, push/pull on the
  hybrid VM-PS), barrier = the max over per-worker completion times.
- :class:`ASP` -- SIREN-style fully-asynchronous global-model overwrite:
  workers run free against a metered key-value store; stale reads emerge
  naturally from the event order.  ASP is SSP with an unbounded staleness.
- :class:`SSP` -- stale-synchronous parallel with staleness bound ``s``
  (paper §3.2.1 design axis): a worker that is more than ``s`` rounds ahead
  of the slowest active worker blocks until the laggard catches up.  ``s=0``
  degenerates to an event-driven barrier; ``s=inf`` is ASP.

Select a protocol with ``FaaSRuntime(sync="bsp"|"asp"|"ssp")`` (or
``"ssp:<s>"`` for an explicit bound, or pass a protocol instance).
"""
from __future__ import annotations

import heapq
import math

import numpy as np

from repro.core.engine import SimContext
from repro.core.patterns import PATTERNS, allreduce, scatter_reduce  # noqa: F401

BSP_NAME = "bsp"
ASP_NAME = "asp"
SSP_NAME = "ssp"


class SyncProtocol:
    """Base class: a protocol runs the whole training loop over a context."""
    name = "base"

    def run(self, ctx: SimContext) -> None:
        raise NotImplementedError


class BSP(SyncProtocol):
    """Bulk-synchronous rounds with per-round lifetime/failure handling."""
    name = BSP_NAME

    def run(self, ctx: SimContext) -> None:
        algo, states, model = ctx.algo, ctx.states, ctx.model
        total_rounds = ctx.max_epochs * algo.rounds_per_epoch(ctx.parts[0])
        est = float(np.max(ctx.c_round * ctx.speeds)) + 5.0
        for rnd in range(total_rounds):
            for i in range(ctx.w):
                ctx.ensure_alive(i, est)
            updates = [algo.local_update(model, st, rnd) for st in states]
            ctx.tick_compute()
            merged = ctx.comm.bsp_reduce(ctx, updates, f"r{rnd}")
            for st in states:
                algo.apply_merged(model, st, merged, ctx.w)
            ctx.res.rounds += 1
            if ctx.record_eval(rnd, total_rounds, algo.eval_params(states[0])):
                break


class SSP(SyncProtocol):
    """Stale-synchronous event loop over a metered global-model store.

    Every worker repeatedly: reads the global model (possibly ``<= s`` rounds
    stale), computes one local update, and writes ``global -= lr * update``
    with a 1/sqrt(T) learning-rate decay (paper §4.5).  The engine pops
    workers in virtual-time order; a worker whose completed-round count leads
    the slowest *active* worker by more than ``s`` parks in a wait set and is
    released (wait time metered under ``"wait"``) when the laggard's next
    update lands.
    """
    name = SSP_NAME

    def __init__(self, staleness: float = 3):
        self.staleness = staleness

    def _bound(self) -> float:
        return self.staleness if self.staleness is not None else math.inf

    def run(self, ctx: SimContext) -> None:
        from jax.flatten_util import ravel_pytree

        algo, states, model = ctx.algo, ctx.states, ctx.model
        w = ctx.w
        store = ctx.comm.kvstore()
        flat0, unravel = ravel_pytree(states[0].params)
        store.put("global", np.asarray(flat0, np.float32))
        rpe = algo.rounds_per_epoch(ctx.parts[0])
        per_worker = ctx.max_epochs * rpe
        total = per_worker * w
        eval_stride = w * max(rpe // 4, 1)
        bound = self._bound()

        rounds = np.zeros(w, dtype=int)
        heap = [(float(ctx.clock[i]), i) for i in range(w)]
        heapq.heapify(heap)
        waiting: dict[int, float] = {}     # worker -> time it parked
        done = 0
        t = float(np.max(ctx.clock))

        def active_min() -> int:
            live = rounds[rounds < per_worker]
            return int(live.min()) if live.size else int(rounds.min())

        while heap and done < total:
            t, i = heapq.heappop(heap)
            lag = rounds[i] - active_min()
            if lag > bound:
                waiting[i] = t
                continue
            ctx.res.max_staleness = max(ctx.res.max_staleness, int(lag))
            ctx.clock[i] = t
            est = float(ctx.c_round[i] * ctx.speeds[i]) + 5.0
            ctx.ensure_alive(i, est)
            t = float(ctx.clock[i])

            g_flat, dt1 = store.get("global")
            states[i].params = unravel(g_flat)
            upd = algo.local_update(model, states[i], done)
            T = max(done // (rpe * w), 1)
            lr = algo.lr / np.sqrt(T)      # 1/sqrt(T) decay (paper §4.5)
            dt2 = store.put("global", (g_flat - lr * upd).astype(np.float32))
            c = ctx.step_compute(i)
            t += dt1 + c + dt2
            ctx.clock[i] = t
            ctx.meter_add("comm", dt1 + dt2)
            rounds[i] += 1
            done += 1
            ctx.res.rounds = done
            if rounds[i] < per_worker:
                heapq.heappush(heap, (t, i))

            # this update may have released parked workers
            if waiting:
                amin = active_min()
                for j in [j for j, _ in waiting.items()
                          if rounds[j] - amin <= bound]:
                    t_park = waiting.pop(j)
                    ctx.meter_add("wait", max(0.0, t - t_park))
                    ctx.clock[j] = max(t, t_park)
                    heapq.heappush(heap, (float(ctx.clock[j]), j))

            if done % eval_stride == 0 or done == total:
                cur, _ = store.get("global")
                if ctx.record_eval_at(t, unravel(cur)):
                    break


class ASP(SSP):
    """Fully-asynchronous (SIREN-style): SSP with no staleness bound."""
    name = ASP_NAME

    def __init__(self):
        super().__init__(staleness=math.inf)


def sync_name(spec) -> str:
    """Canonical string form of a sync spec (``"bsp"``, ``"asp"``,
    ``"ssp:<s>"``) -- the serialization used by
    :class:`repro.experiments.ExperimentSpec`.  Inverse of
    :func:`make_sync` up to protocol identity."""
    proto = make_sync(spec)
    if isinstance(proto, ASP):
        return ASP_NAME
    if isinstance(proto, SSP):
        s = proto.staleness
        return SSP_NAME if s is None else f"{SSP_NAME}:{s:g}"
    return proto.name


def make_sync(spec) -> SyncProtocol:
    """``"bsp"`` | ``"asp"`` | ``"ssp"`` | ``"ssp:<s>"`` | protocol class or
    instance (``sync=SSP(5)`` and ``sync=BSP`` both work)."""
    if isinstance(spec, SyncProtocol):
        return spec
    if isinstance(spec, type) and issubclass(spec, SyncProtocol):
        return spec()
    name, _, arg = str(spec).partition(":")
    if name == BSP_NAME:
        return BSP()
    if name == ASP_NAME:
        return ASP()
    if name == SSP_NAME:
        s = float(arg) if arg else 3.0
        return SSP(int(s) if s.is_integer() else s)   # "ssp:inf" works too
    raise KeyError(f"unknown sync protocol {spec!r}")
