"""Synchronization protocols (paper §3.2.4) -- named entry point.

- BSP: the two-phase merge/update protocol is implemented by the pattern
  functions (:mod:`repro.core.patterns`) -- named files + polling semantics,
  barrier = the max over per-worker completion times.
- ASP: SIREN-style global-model overwrite is the event-driven loop in
  :meth:`repro.core.runtimes.FaaSRuntime._train_asp` (select with
  ``FaaSRuntime(sync="asp")``).
"""
from repro.core.patterns import PATTERNS, allreduce, scatter_reduce  # noqa: F401
from repro.core.runtimes import FaaSRuntime  # noqa: F401

BSP = "bsp"
ASP = "asp"
