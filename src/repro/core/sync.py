"""Synchronization protocols (paper §3.2.4; DESIGN.md §6).

Each protocol is a strategy object driving the discrete-event engine's
:class:`~repro.core.engine.SimContext`; the same three protocols run on every
infrastructure (FaaS, IaaS, hybrid, spot, heterogeneous fleets):

- :class:`BSP` -- bulk-synchronous rounds; the merge itself is delegated to
  the platform's :class:`~repro.core.engine.CommBackend` (two-phase
  merge/update file pattern on FaaS, ring AllReduce on IaaS, push/pull on the
  hybrid VM-PS), barrier = the max over per-worker completion times.
- :class:`ASP` -- SIREN-style fully-asynchronous global-model overwrite:
  workers run free against a metered key-value store; stale reads emerge
  naturally from the event order.  ASP is SSP with an unbounded staleness.
- :class:`SSP` -- stale-synchronous parallel with staleness bound ``s``
  (paper §3.2.1 design axis): a worker that is more than ``s`` rounds ahead
  of the slowest active worker blocks until the laggard catches up.  ``s=0``
  degenerates to an event-driven barrier; ``s=inf`` is ASP.
- :class:`LocalSGD` -- reduced communication (paper §4.2's MA-SGD insight,
  DESIGN.md §11): workers apply their own updates locally for ``H`` rounds,
  then merge the *accumulated* update once -- cross-fleet bytes per round
  drop by exactly ``H``.  The outer merge is plain averaging (``outer="ma"``,
  mathematically MA-SGD) or a DiLoCo Nesterov outer step
  (``outer="diloco"``), optionally with int8 + error-feedback delta
  compression (``compress=True``, wire bytes /4 on top of the ``H`` x).
  ``LocalSGD(h=1)`` IS BSP (bit-identical histories, asserted in tests).

The DiLoCo outer-step math (:class:`DiLoCoOuter`) lives here; the int8
error-feedback quantizer is the shared :mod:`repro.core.comm.codecs`
implementation, which since DESIGN.md §16 executes the fused
:mod:`repro.kernels.quant8` Pallas kernel (one source of truth for this
module, the :class:`~repro.core.comm.Int8EFCodec` wire codec, and the real
multi-pod training stack :mod:`repro.distributed.local_sgd`, which applies
the same ref formula per parameter leaf inside ``shard_map``; the seed-era
``repro.core.sync.quantize_int8_ef`` import path remains as an alias).

Select a protocol with ``FaaSRuntime(sync="bsp"|"asp"|"ssp")`` (or
``"ssp:<s>"``, ``"local:<H>"``, ``"diloco:<H>"``, with an optional
``":c8"`` compression suffix -- or pass a protocol instance).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass

import numpy as np

from repro.core.comm.codecs import (  # noqa: F401  (seed-era aliases: the
    dequantize_int8, int8_encode_decode, int8_wire_floats,  # one shared codec
    quantize_int8_ef,                                       # implementation)
)
from repro.core.engine import SimContext
from repro.core.patterns import PATTERNS, allreduce, scatter_reduce  # noqa: F401

BSP_NAME = "bsp"
ASP_NAME = "asp"
SSP_NAME = "ssp"
LOCAL_NAME = "local"
DILOCO_NAME = "diloco"
COMPRESS_SUFFIX = "c8"

#: the sync-protocol string grammar, one entry per selectable protocol --
#: same registry convention as TRANSPORTS/CODECS/POLICIES/ARRIVALS so
#: ``repro list`` and the lint registry checker can enumerate it.  Keep in
#: step with :func:`make_sync` / :func:`sync_name`.
SYNC_GRAMMARS = (
    f"{BSP_NAME}",
    f"{ASP_NAME}",
    f"{SSP_NAME}[:<staleness>]",
    f"{LOCAL_NAME}[:<H>][:{COMPRESS_SUFFIX}]",
    f"{DILOCO_NAME}[:<H>][:{COMPRESS_SUFFIX}]",
)


def list_syncs() -> list:
    """The selectable sync grammars (``repro list`` prints these)."""
    return list(SYNC_GRAMMARS)


# ------------------------------------------------ shared local-SGD math -----
# One implementation for both halves of the codebase: the discrete-event
# LocalSGD protocol below operates on flat numpy vectors; the real pod
# training stack (distributed/local_sgd.py) applies the same functions per
# parameter leaf inside shard_map.  jnp ops accept numpy inputs, so the
# helpers are array-library agnostic at the call site.

@dataclass(frozen=True)
class DiLoCoOuter:
    """DiLoCo's outer optimizer: Nesterov momentum on the average inner
    delta (delta = outer_params - inner_params, so the step SUBTRACTS)."""
    lr: float = 0.7
    momentum: float = 0.9

    def step(self, outer, mom, mean_delta):
        """-> (new_outer_params, new_momentum); works on any array type."""
        new_mom = self.momentum * mom + mean_delta
        new_outer = outer - self.lr * (self.momentum * new_mom + mean_delta)
        return new_outer, new_mom


class SyncProtocol:
    """Base class: a protocol runs the whole training loop over a context."""
    name = "base"
    #: protocols that call ``ctx.maybe_resize`` at their sync boundaries
    #: declare True; elastic scaling policies (DESIGN.md §13) refuse to
    #: pair with protocols that do not
    supports_resize = False

    def run(self, ctx: SimContext) -> None:
        raise NotImplementedError


class BSP(SyncProtocol):
    """Bulk-synchronous rounds with per-round lifetime/failure handling.
    Elastic fleets resize at any round boundary (every round IS a sync
    point); the remaining round budget is rescaled to keep the epoch count,
    since a resize re-partitions the data and changes rounds-per-epoch."""
    name = BSP_NAME
    supports_resize = True

    def run(self, ctx: SimContext) -> None:
        algo, model = ctx.algo, ctx.model
        rpe = algo.rounds_per_epoch(ctx.parts[0])
        total_rounds = ctx.max_epochs * rpe
        est = float(np.max(ctx.c_round * ctx.speeds)) + 5.0
        rnd = 0
        while rnd < total_rounds:
            states = ctx.states
            for i in range(ctx.w):
                ctx.ensure_alive(i, est)
            updates = [algo.local_update(model, st, rnd) for st in states]
            ctx.tick_compute()
            merged = ctx.comm.bsp_reduce(ctx, updates, f"r{rnd}")
            for st in states:
                algo.apply_merged(model, st, merged, ctx.w)
            ctx.res.rounds += 1
            if ctx.record_eval(rnd, total_rounds, algo.eval_params(states[0])):
                break
            rnd += 1
            ctx.ckpt_boundary(rnd)      # cadence save (DESIGN.md §17)
            stop, total_rounds, rpe, resized = ctx.elastic_boundary(
                rnd, total_rounds, rpe)
            if stop:
                break
            if resized:
                est = float(np.max(ctx.c_round * ctx.speeds)) + 5.0


class SSP(SyncProtocol):
    """Stale-synchronous event loop over a metered global-model store.

    Every worker repeatedly: reads the global model (possibly ``<= s`` rounds
    stale), computes one local update, and writes ``global -= lr * update``
    with a 1/sqrt(T) learning-rate decay (paper §4.5).  The engine pops
    workers in virtual-time order; a worker whose completed-round count leads
    the slowest *active* worker by more than ``s`` parks in a wait set and is
    released (wait time metered under ``"wait"``) when the laggard's next
    update lands.

    Elastic fleets (DESIGN.md §13) resize at eval boundaries, where the
    global model was just read: the membership change reconciles the
    staleness clocks -- parked workers are released (wait metered to the
    boundary), every survivor's completed-round count restarts at 0 so the
    staleness bound is measured within the new membership, the remaining
    per-worker round quota is rescaled from the epochs already done, and
    the event heap is rebuilt over the new fleet.
    """
    name = SSP_NAME
    supports_resize = True

    def __init__(self, staleness: float = 3):
        self.staleness = staleness

    def _bound(self) -> float:
        return self.staleness if self.staleness is not None else math.inf

    def run(self, ctx: SimContext) -> None:
        from jax.flatten_util import ravel_pytree

        algo, states, model = ctx.algo, ctx.states, ctx.model
        w = ctx.w
        store = ctx.comm.kvstore()
        flat0, unravel = ravel_pytree(states[0].params)
        store.put("global", np.asarray(flat0, np.float32))
        rpe = algo.rounds_per_epoch(ctx.parts[0])
        per_worker = ctx.max_epochs * rpe
        total = per_worker * w
        eval_stride = w * max(rpe // 4, 1)
        bound = self._bound()

        rounds = np.zeros(w, dtype=int)
        heap = [(float(ctx.clock[i]), i) for i in range(w)]
        heapq.heapify(heap)
        waiting: dict[int, float] = {}     # worker -> time it parked
        done = 0
        done_mark = 0          # `done` at the last eval boundary
        fleet_round = 0.0      # monotone fleet rounds across resize eras
        epoch_acc = 0.0        # epochs completed across resize eras
        t = float(np.max(ctx.clock))

        def active_min() -> int:
            live = rounds[rounds < per_worker]
            return int(live.min()) if live.size else int(rounds.min())

        while heap and done < total:
            t, i = heapq.heappop(heap)
            lag = rounds[i] - active_min()
            if lag > bound:
                waiting[i] = t
                continue
            ctx.res.max_staleness = max(ctx.res.max_staleness, int(lag))
            ctx.clock[i] = t
            est = float(ctx.c_round[i] * ctx.speeds[i]) + 5.0
            ctx.ensure_alive(i, est)
            t = float(ctx.clock[i])

            g_flat, dt1 = store.get("global")
            states[i].params = unravel(g_flat)
            upd = algo.local_update(model, states[i], done)
            T = max(done // (rpe * w), 1)
            lr = algo.lr / np.sqrt(T)      # 1/sqrt(T) decay (paper §4.5)
            dt2 = store.put("global", (g_flat - lr * upd).astype(np.float32))
            c = ctx.step_compute(i)
            if ctx.rec is not None:
                t_round0 = t
            t += dt1 + c + dt2
            ctx.clock[i] = t
            if ctx.rec is not None:
                # interior split points are approximate partials; the round
                # endpoint is the stored clock, so tiling stays exact
                wid = int(ctx.worker_ids[i])
                s1 = t_round0 + dt1
                s2 = s1 + c
                ctx.rec.span(wid, "comm.get", "comm", t_round0, s1)
                if ctx.speeds[i] > 1.0:
                    mid = s1 + float(ctx.c_round[i])
                    ctx.rec.span(wid, "compute", "compute", s1, mid)
                    ctx.rec.span(wid, "straggler", "stall", mid, s2)
                else:
                    ctx.rec.span(wid, "compute", "compute", s1, s2)
                ctx.rec.span(wid, "comm.put", "comm", s2, t)
            ctx.meter_add("comm", dt1 + dt2)
            # same accounting convention as the BSP backends: one update
            # vector per per-worker round (BSP meters nbytes once per fleet
            # round of w worker-rounds), so protocol comparisons see the
            # protocol, not the bookkeeping
            ctx.meter_bytes(float(g_flat.nbytes) / ctx.w)
            rounds[i] += 1
            done += 1
            ctx.res.rounds = done
            if rounds[i] < per_worker:
                heapq.heappush(heap, (t, i))

            # this update may have released parked workers
            if waiting:
                amin = active_min()
                for j in [j for j, _ in waiting.items()
                          if rounds[j] - amin <= bound]:
                    t_park = waiting.pop(j)
                    ctx.meter_add("wait", max(0.0, t - t_park))
                    if ctx.rec is None:
                        ctx.clock[j] = max(t, t_park)
                    else:
                        wait0 = float(ctx.clock[j])
                        ctx.clock[j] = max(t, t_park)
                        ctx.rec.span(int(ctx.worker_ids[j]), "ssp.wait",
                                     "stall", wait0, float(ctx.clock[j]))
                    heapq.heappush(heap, (float(ctx.clock[j]), j))

            if done % eval_stride == 0 or done == total:
                # era-wise progress counters: `done` mixes worker-rounds
                # from eras with different fleet widths, so policies get a
                # MONOTONE fleet-round count (a naive done // w regresses
                # after a scale-up and would make a schedule oscillate,
                # re-billing joiner startup every swing) and the epoch
                # estimate accumulates per era
                span = done - done_mark
                fleet_round += span / max(w, 1)
                epoch_acc += span / max(rpe * w, 1)
                done_mark = done
                cur, _ = store.get("global")
                if ctx.record_eval_at(t, unravel(cur)):
                    break
                # cadence save at the eval boundary (the global model was
                # just read); the fleet-wide stall shifts every pending
                # event and park time uniformly, preserving the heap order
                dt_ck = ctx.ckpt_boundary(int(fleet_round))
                if dt_ck > 0.0:
                    t += dt_ck
                    heap = [(tj + dt_ck, j) for tj, j in heap]
                    waiting = {j: tp + dt_ck for j, tp in waiting.items()}
                if ctx.elastic is not None and done < total:
                    w_before = w
                    # resize rebuilds worker state from states[0]: hand it
                    # the freshly-read global model first
                    states[0].params = unravel(cur)
                    if ctx.maybe_resize(int(fleet_round)):
                        break
                    if ctx.w != w_before:
                        # ---- membership change: clock reconciliation ----
                        for j, t_park in waiting.items():
                            ctx.meter_add("wait", max(0.0, t - t_park))
                            if j < ctx.w:
                                if ctx.rec is None:
                                    ctx.clock[j] = max(float(ctx.clock[j]), t)
                                else:
                                    wait0 = float(ctx.clock[j])
                                    ctx.clock[j] = max(wait0, t)
                                    ctx.rec.span(int(ctx.worker_ids[j]),
                                                 "ssp.wait", "stall", wait0,
                                                 float(ctx.clock[j]))
                        waiting.clear()
                        epochs_done = epoch_acc
                        rpe = algo.rounds_per_epoch(ctx.parts[0])
                        per_worker = int(np.ceil(
                            max(ctx.max_epochs - epochs_done, 0.0) * rpe))
                        w = ctx.w
                        states = ctx.states
                        rounds = np.zeros(w, dtype=int)
                        total = done + per_worker * w
                        eval_stride = w * max(rpe // 4, 1)
                        # the comm stack was re-composed: seed the (carried
                        # over or fresh) kvstore with the global model
                        store = ctx.comm.kvstore()
                        ctx.meter_add("resize", store.put(
                            "global", np.asarray(cur, np.float32)))
                        heap = [(float(ctx.clock[i]), i) for i in range(w)]
                        heapq.heapify(heap)


class ASP(SSP):
    """Fully-asynchronous (SIREN-style): SSP with no staleness bound."""
    name = ASP_NAME

    def __init__(self):
        super().__init__(staleness=math.inf)


class LocalSGD(SyncProtocol):
    """Local SGD / DiLoCo: sync the fleet every ``h`` rounds, not every
    round (the paper's MA-SGD-beats-GA-SGD regime, §4.2, generalized).

    Between sync rounds every worker applies its OWN update locally
    (``algo.apply_merged(st, own_update, 1)``) while the raw updates
    accumulate; at a sync boundary the workers merge the accumulated
    update vectors through the platform's comm backend and apply the mean
    to the block's base parameters.  Applying the mean accumulated update
    at the base is mathematically identical to averaging the workers'
    parameters (MA-SGD) -- and for ``h=1`` the code path degenerates to
    exactly one ``bsp_reduce`` + ``apply_merged`` per round, making the
    loss history BIT-IDENTICAL to :class:`BSP` on every platform (asserted
    in ``tests/test_localsgd.py``).

    ``outer="diloco"`` instead treats the per-worker parameter displacement
    as a pseudo-gradient and applies :class:`DiLoCoOuter` Nesterov momentum
    to it.  ``compress=True`` ships blockwise int8 + error-feedback
    quantized vectors (:func:`int8_encode_decode`, the fused quant8 Pallas
    kernel): metered wire bytes drop ~4x on top of the ``h`` x; the
    quantization error is carried per worker into the next sync round.

    Requires an algorithm with additive updates (``ga_sgd``): MA/ADMM/EM
    updates are not gradients and already amortize communication their own
    way.

    Elastic fleets (DESIGN.md §13) resize at the averaging boundaries
    only -- between boundaries workers hold un-merged local state that a
    membership change would discard -- and the per-worker accumulators
    (and compression residuals) restart at zero for the new fleet.
    """
    name = LOCAL_NAME
    supports_resize = True

    def __init__(self, h: int = 8, outer: str = "ma", compress: bool = False,
                 outer_lr: float = 0.7, outer_momentum: float = 0.9):
        if outer not in ("ma", "diloco"):
            raise ValueError(f"outer must be 'ma' or 'diloco', got {outer!r}")
        if int(h) < 1:
            raise ValueError(f"sync period H must be >= 1, got {h}")
        self.h = int(h)
        self.outer = outer
        self.compress = bool(compress)
        self.outer_opt = DiLoCoOuter(outer_lr, outer_momentum)

    def _merge(self, ctx: SimContext, vecs: list, residual, tag: str):
        """Merge per-worker fp32 vectors through the metered backend;
        with compression the wire payload is the packed int8 form (codes
        + scale stand-in of identical byte count) and the mean is computed
        from the dequantized vectors (error feedback updates ``residual``
        in place)."""
        if not self.compress:
            return np.asarray(ctx.comm.bsp_reduce(ctx, vecs, tag),
                              np.float32)
        deq = []
        for i, v in enumerate(vecs):
            d, err = int8_encode_decode(v, residual[i])
            residual[i] = err
            deq.append(d)
        wire = [np.zeros(int8_wire_floats(v.size), np.float32) for v in vecs]
        if ctx.rec is not None:
            ctx.rec.mark("codec", float(np.max(ctx.clock)),
                         codec="int8-ef", raw_bytes=int(vecs[0].nbytes),
                         wire_bytes=int(wire[0].nbytes))
        ctx.comm.bsp_reduce(ctx, wire, tag + ".q8")   # meters time+bytes only
        return np.mean(np.stack(deq), axis=0)

    def run(self, ctx: SimContext) -> None:
        from jax.flatten_util import ravel_pytree

        algo, model = ctx.algo, ctx.model
        if not getattr(algo, "additive_update", False):
            raise ValueError(
                f"LocalSGD needs an additive-update algorithm (ga_sgd); "
                f"{algo.name!r} ships non-additive updates -- use bsp/asp/ssp")
        rpe = algo.rounds_per_epoch(ctx.parts[0])
        total_rounds = ctx.max_epochs * rpe
        est = float(np.max(ctx.c_round * ctx.speeds)) + 5.0
        diloco = self.outer == "diloco"

        states = ctx.states
        flat0, unravel = ravel_pytree(states[0].params)
        base = np.asarray(flat0, np.float32)      # params at last sync
        momentum = np.zeros_like(base) if diloco else None
        residual = ([np.zeros_like(base) for _ in range(ctx.w)]
                    if self.compress else None)
        accs = [np.zeros_like(base) for _ in range(ctx.w)]

        rnd = 0
        while rnd < total_rounds:
            states = ctx.states
            for i in range(ctx.w):
                ctx.ensure_alive(i, est)
            updates = [algo.local_update(model, st, rnd) for st in states]
            ctx.tick_compute()
            for i, u in enumerate(updates):
                accs[i] += u
            ctx.res.rounds += 1
            if not ((rnd + 1) % self.h == 0 or rnd == total_rounds - 1):
                for st, u in zip(states, updates):
                    algo.apply_merged(model, st, u, 1)   # local-only round
                rnd += 1
                continue

            # ---- sync boundary: one metered merge for the whole block ----
            if not diloco:
                merged = self._merge(ctx, accs, residual, f"l{rnd}")
                for st in states:
                    st.params = unravel(base)
                    algo.apply_merged(model, st, merged, ctx.w)
            else:
                deltas = []
                for st, acc in zip(states, accs):
                    st.params = unravel(base)
                    algo.apply_merged(model, st, acc, 1)
                    inner = np.asarray(ravel_pytree(st.params)[0], np.float32)
                    deltas.append(base - inner)   # DiLoCo pseudo-gradient
                mean_delta = self._merge(ctx, deltas, residual, f"l{rnd}")
                base, momentum = self.outer_opt.step(base, momentum,
                                                     mean_delta)
                base = np.asarray(base, np.float32)
                for st in states:
                    st.params = unravel(base)
            if not diloco:
                base = np.asarray(ravel_pytree(states[0].params)[0],
                                  np.float32)
            for acc in accs:
                acc[:] = 0.0
            # h == 1 keeps BSP's exact eval cadence (eval_every respected --
            # part of the bit-parity contract); h > 1 evaluates at EVERY
            # averaging boundary (already 1/h of the rounds), so eval_every
            # phase mismatches can never silently disable the target_loss
            # convergence check
            params = algo.eval_params(states[0])
            done = (ctx.record_eval(rnd, total_rounds, params) if self.h == 1
                    else ctx.record_eval_at(float(np.max(ctx.clock)), params))
            if done:
                break
            rnd += 1
            # cadence saves ride the averaging boundaries too: between them
            # workers hold un-merged local state no checkpoint could restore
            ctx.ckpt_boundary(rnd)
            # averaging boundary = the only safe membership change: every
            # worker just resynced to the merged model
            stop, total_rounds, rpe, resized = ctx.elastic_boundary(
                rnd, total_rounds, rpe)
            if stop:
                break
            if resized:
                est = float(np.max(ctx.c_round * ctx.speeds)) + 5.0
                accs = [np.zeros_like(base) for _ in range(ctx.w)]
                if self.compress:
                    residual = [np.zeros_like(base) for _ in range(ctx.w)]


def sync_name(spec) -> str:
    """Canonical string form of a sync spec (``"bsp"``, ``"asp"``,
    ``"ssp:<s>"``, ``"local:<H>"``, ``"diloco:<H>[:c8]"``) -- the
    serialization used by :class:`repro.experiments.ExperimentSpec`.
    Inverse of :func:`make_sync` up to protocol identity."""
    proto = make_sync(spec)
    if isinstance(proto, ASP):
        return ASP_NAME
    if isinstance(proto, SSP):
        s = proto.staleness
        return SSP_NAME if s is None else f"{SSP_NAME}:{s:g}"
    if isinstance(proto, LocalSGD):
        if proto.outer == "diloco" and proto.outer_opt != DiLoCoOuter():
            raise ValueError(
                "custom DiLoCo outer_lr/outer_momentum are not expressible "
                "as a sync string (specs serialize the defaults only); pass "
                "the LocalSGD instance directly to the platform instead")
        head = DILOCO_NAME if proto.outer == "diloco" else LOCAL_NAME
        return (f"{head}:{proto.h}"
                + (f":{COMPRESS_SUFFIX}" if proto.compress else ""))
    return proto.name


def make_sync(spec) -> SyncProtocol:
    """``"bsp"`` | ``"asp"`` | ``"ssp[:<s>]"`` | ``"local[:<H>][:c8]"`` |
    ``"diloco[:<H>][:c8]"`` | protocol class or instance (``sync=SSP(5)``
    and ``sync=BSP`` both work)."""
    if isinstance(spec, SyncProtocol):
        return spec
    if isinstance(spec, type) and issubclass(spec, SyncProtocol):
        return spec()
    name, _, arg = str(spec).partition(":")
    if name == BSP_NAME:
        return BSP()
    if name == ASP_NAME:
        return ASP()
    if name == SSP_NAME:
        s = float(arg) if arg else 3.0
        return SSP(int(s) if s.is_integer() else s)   # "ssp:inf" works too
    if name in (LOCAL_NAME, DILOCO_NAME):
        h_part, _, c_part = arg.partition(":")
        if h_part == COMPRESS_SUFFIX and not c_part:    # "local:c8"
            h_part, c_part = "", COMPRESS_SUFFIX
        if c_part not in ("", COMPRESS_SUFFIX):
            raise KeyError(f"unknown sync protocol suffix {c_part!r} in "
                           f"{spec!r} (only {COMPRESS_SUFFIX!r})")
        return LocalSGD(h=int(h_part) if h_part else 8,
                        outer="diloco" if name == DILOCO_NAME else "ma",
                        compress=c_part == COMPRESS_SUFFIX)
    raise KeyError(f"unknown sync protocol {spec!r}")
