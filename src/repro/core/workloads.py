"""The ``Workload`` layer: one model interface for the whole design space
(DESIGN.md §11).

The engine (:mod:`repro.core.engine`) and the algorithms
(:mod:`repro.core.algorithms`) program against a small duck-typed model
surface -- ``init``/``grad``/``eval_loss`` plus the ``convex`` and
``flops_per_row`` metadata.  Historically only the paper's study stand-ins
(:class:`repro.core.mlmodels.StudyModel`: LR/SVM/k-means/MLP) satisfied it;
this module formalizes that surface as the runtime-checkable
:class:`Workload` protocol and adds a second family of implementations:

- :func:`make_workload` with a study-model name (``"lr"``, ``"svm"``,
  ``"kmeans"``, ``"mobilenet"``, ``"resnet50"``) returns the exact
  ``StudyModel`` the legacy path built -- byte-identical numerics
  (``tests/test_experiments.py`` parity tests still hold);
- with a ``repro.configs`` architecture name (``"smollm_360m"``,
  ``"mamba2_370m"``, ... -- any of the ten assigned archs, underscores for
  dashes/dots) it returns an :class:`ArchWorkload`: the REAL transformer/SSM
  model from :mod:`repro.models`, a REAL jitted fwd/bwd train step, and a
  deterministic token corpus (:class:`repro.data.tokens.TokenStream`).  The
  same GA-SGD/LocalSGD algorithms then run genuine JAX numerics through the
  discrete-event engine on any platform (FaaS, IaaS, pod).

A ``Workload`` also exposes the two analytic quantities the §5.3 cost model
needs -- ``flops_per_row`` (compute per data row) and the update-vector size
(:func:`update_vector_bytes`) -- making this module the single source of
truth that :mod:`repro.core.analytical` derives its ``(s, m, R, C)``
constants from.
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

from repro.core.mlmodels import STUDY_MODELS, make_study_model
from repro.data.synthetic import Dataset, make_dataset, train_val_split

#: architecture workloads train on the synthetic LM corpus, not on the
#: paper's feature datasets
TOKEN_DATASET = "tokens"

#: default sequence length for arch workloads -- one data "row" is one
#: training sequence of this many tokens
DEFAULT_SEQ_LEN = 64


@runtime_checkable
class Workload(Protocol):
    """The engine-facing model surface (what ``simulate`` consumes).

    Implementations: :class:`repro.core.mlmodels.StudyModel` (the paper's
    stand-ins) and :class:`ArchWorkload` (real ``repro.configs``
    architectures).  ``grad`` returns ``(loss, grads_pytree)``; k-means-style
    workloads may expose ``local_stats``/``apply_stats`` instead of ``grad``.
    """

    name: str
    convex: bool
    flops_per_row: float

    def init(self, key) -> Any: ...

    def eval_loss(self, params, ds) -> float: ...


def update_vector_bytes(workload: Workload, params=None) -> int:
    """Bytes of the flat fp32 parameter-shaped update vector one worker
    ships per round -- the ``m`` of the analytical model.  The algorithms
    serialize updates as float32 regardless of the model dtype (see
    ``core/algorithms.py``), so this is 4 bytes per parameter.  Matches
    the engine's per-round ``comm_bytes`` for the SGD-family algorithms
    (gradients / parameters / deltas); EM k-means ships sums+counts, ``k``
    floats more than the centroid parameters."""
    import jax
    from jax.flatten_util import ravel_pytree

    if params is None:
        params = workload.init(jax.random.key(0))
    return int(ravel_pytree(params)[0].size) * 4


#: static (feature_dim, n_classes) per study dataset -- the spec-time size
#: estimator's view of repro.data.synthetic.make_dataset (dims are fixed by
#: the paper; only row counts scale)
_DATASET_SHAPES = {"higgs": (28, 2), "rcv1": (47_236, 2),
                   "cifar10": (3072, 10), "yfcc100m": (4096, 2),
                   "criteo": (1_000_000, 2)}

_ARCH_BYTES_CACHE: dict[tuple, int] = {}


def estimate_update_bytes(model: str, dataset: str = "higgs",
                          model_args: dict | None = None) -> int | None:
    """fp32 update-vector bytes one worker ships per metered reduce,
    WITHOUT materializing data or parameters -- what spec-time comm
    validation (:meth:`repro.core.platform.CommSpec.validate`) checks
    against transport per-item limits (the DynamoDB 400 KB rule of Table
    1).  Returns ``None`` when the size is not statically known (unknown
    dataset); sizes come from the same dimension tables / configs the real
    constructors use, so the estimate matches the simulated payloads."""
    model_args = dict(model_args or {})
    if is_arch_workload(model):
        from repro.configs import get_arch, get_reduced
        from repro.models import build_model
        reduced = bool(model_args.get("reduced", True))
        key = (model, reduced)
        if key not in _ARCH_BYTES_CACHE:
            arch_id = _arch_key(model)
            arch = get_reduced(arch_id) if reduced else get_arch(arch_id)
            _ARCH_BYTES_CACHE[key] = build_model(arch).param_count() * 4
        return _ARCH_BYTES_CACHE[key]
    if dataset not in _DATASET_SHAPES:
        return None
    d, n_classes = _DATASET_SHAPES[dataset]
    if model in ("lr", "svm"):
        return d * 4
    if model == "kmeans":
        k = int(model_args.get("k", 10))
        # EM ships sums (k*d) + counts (k) + sse (1), see update_vector_bytes
        return (k * d + k + 1) * 4
    if model in ("mobilenet", "resnet50"):
        from repro.core.mlmodels import _mlp_sizes
        target_mb = 12.0 if model == "mobilenet" else 89.0
        sizes = _mlp_sizes(d, n_classes, target_mb)
        return sum(a * b + b for a, b in zip(sizes[:-1], sizes[1:])) * 4
    return None


# ---------------------------------------------------------- arch workloads --

def _arch_key(name: str) -> str | None:
    """Map a spec-friendly name (``smollm_360m``) to an arch id
    (``smollm-360m``); None if it is not an architecture name."""
    from repro.configs import ARCH_IDS
    norm = {a.replace("-", "_").replace(".", "_"): a for a in ARCH_IDS}
    return norm.get(name)


def is_arch_workload(name: str) -> bool:
    return _arch_key(name) is not None


class ArchWorkload:
    """A real ``repro.configs`` architecture as an engine workload.

    Wraps :func:`repro.models.build_model` (transformer / SSM / MoE /
    hybrid) behind the :class:`Workload` protocol: ``grad`` is a single
    jitted ``value_and_grad`` of the model's next-token loss, so every
    simulated round runs genuine fwd/bwd numerics.  Batches arrive in the
    engine's ``{"x", "y"}`` convention (int32 token / label matrices, one
    row = one sequence) and are translated to the model's
    ``{"tokens", "labels"}``.

    ``reduced=True`` (default) uses the arch's CPU-sized ``reduced()``
    variant so the whole design space sweeps on a laptop; ``reduced=False``
    builds the full published config (same code path -- only the shapes
    change).  ``flops_per_row`` is the standard ``6 * n_params * seq_len``
    training-FLOPs estimate for whichever config was built, which is what
    the platforms' FLOP/s hooks divide (Lambda vCPUs vs a TPU pod differ by
    ~5 orders of magnitude, exactly the regime the paper's §6 conclusions
    are about).
    """

    convex = False

    def __init__(self, name: str, *, reduced: bool = True,
                 seq_len: int = DEFAULT_SEQ_LEN):
        import jax
        from repro.configs import get_arch, get_reduced
        from repro.models import build_model

        arch_id = _arch_key(name)
        if arch_id is None:
            raise KeyError(f"unknown architecture workload {name!r}")
        self.name = name
        self.seq_len = int(seq_len)
        self.arch = get_reduced(arch_id) if reduced else get_arch(arch_id)
        if self.arch.model.is_encoder or self.arch.model.family == "vlm":
            raise ValueError(
                f"arch workload {name!r}: encoder/VLM batches need "
                "frames/images; only LM-style archs run through the engine")
        self._model = build_model(self.arch)
        self.n_params = self._model.param_count()
        self.flops_per_row = 6.0 * self.n_params * self.seq_len
        scan = self.arch.train.scan_layers

        def loss_fn(params, batch):
            total, _metrics = self._model.loss(
                params, {"tokens": batch["x"], "labels": batch["y"]},
                remat="none", scan_layers=scan)
            return total

        self.grad = jax.jit(jax.value_and_grad(loss_fn))
        self._loss = jax.jit(loss_fn)

    def init(self, key):
        return self._model.init(key)

    def eval_loss(self, params, ds: Dataset, max_rows: int = 512) -> float:
        import jax.numpy as jnp

        n = min(ds.n, max_rows)
        b = {"x": jnp.asarray(ds.x[:n]), "y": jnp.asarray(ds.y[:n])}
        return float(self._loss(params, b))

    def make_data(self, rows: int, seed: int = 0) -> Dataset:
        """Deterministic LM corpus: ``rows`` sequences of ``seq_len`` tokens
        (x) with next-token labels (y), from the Zipf+bigram TokenStream."""
        from repro.data.tokens import TokenStream

        b = TokenStream(self.arch.model.vocab_size, seed).batch(
            rows, self.seq_len)
        return Dataset(TOKEN_DATASET, b["tokens"], b["labels"],
                       n_classes=self.arch.model.vocab_size)


# ---------------------------------------------------------------- factory ---

def make_workload(name: str, *, dataset: str = "higgs", rows: int = 30_000,
                  data_seed: int = 0, val_frac: float = 0.1,
                  **model_args) -> tuple[Workload, Dataset, Dataset]:
    """Build ``(workload, ds_train, ds_val)`` for any point of the model
    axis -- study stand-in or real architecture.

    Study names reproduce the legacy construction order exactly
    (dataset -> split -> model-on-train), so existing specs keep their
    byte-identical histories and cache hashes' results.  Architecture names
    require ``dataset="tokens"`` (their corpus is generated from the arch's
    own vocab/sequence shape) and accept ``reduced``/``seq_len`` in
    ``model_args``.
    """
    if is_arch_workload(name):
        if dataset != TOKEN_DATASET:
            raise ValueError(
                f"architecture workload {name!r} trains on the synthetic "
                f"LM corpus; set dataset={TOKEN_DATASET!r} "
                f"(got {dataset!r})")
        wl = ArchWorkload(name, **model_args)
        ds = wl.make_data(rows, seed=data_seed)
        tr, va = train_val_split(ds, val_frac=val_frac)
        return wl, tr, va
    ds = make_dataset(dataset, rows=rows, seed=data_seed)
    tr, va = train_val_split(ds, val_frac=val_frac)
    return make_study_model(name, tr, **model_args), tr, va


def list_workloads() -> list[str]:
    """Every valid ``ExperimentSpec.model`` value (study stand-ins + the
    LM-style architectures; encoder/VLM archs need non-token inputs and are
    excluded)."""
    from repro.configs import ARCH_IDS, get_arch

    archs = sorted(a.replace("-", "_").replace(".", "_") for a in ARCH_IDS
                   if get_arch(a).model.family not in ("encoder", "vlm")
                   and not get_arch(a).model.is_encoder)
    return list(STUDY_MODELS) + archs
