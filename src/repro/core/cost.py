"""Cloud pricing + hardware constants for the FaaS/IaaS emulation.

Prices are the paper-era (2020/21) us-east-1 list prices the paper used.
Compute throughput constants are calibrated so C^F ~= C^I per core, matching
the paper's observation that Lambda and EC2 data loading + computation take
similar time per row (Fig 10).
"""
from __future__ import annotations

# ---- $ pricing ---------------------------------------------------------------
LAMBDA_GB_S = 1.66667e-5          # $ per GB-second
LAMBDA_REQUEST = 2e-7             # $ per invocation
EC2_HOURLY = {
    "t2.medium": 0.0464,
    "t2.2xlarge": 0.3712,
    "c5.large": 0.085,
    "c5.xlarge": 0.17,
    "c5.4xlarge": 0.68,
    "g3s.xlarge": 0.75,           # NVIDIA M60
    "g4dn.xlarge": 0.526,         # NVIDIA T4
    "m5a.12xlarge": 2.064,
}
ELASTICACHE_HOURLY = {
    "cache.t3.small": 0.034,
    "cache.t3.medium": 0.068,
    "cache.m5.large": 0.156,
}
DYNAMODB_PER_MREQ = 1.25          # $ per million write request units (on-demand)
SPOT_DISCOUNT = 0.3               # spot price as a fraction of on-demand
                                  # (paper-era us-east-1 averages ~65-75% off)
S3_PUT = 5e-6                     # $ per PUT
S3_GET = 4e-7                     # $ per GET

# ---- compute-throughput model -------------------------------------------------
# effective f32 GFLOP/s per worker for the study models (dense matvec-bound)
LAMBDA_3GB_FLOPS = 5e9            # 1.8 vCPU
LAMBDA_1GB_FLOPS = 1.7e9          # 0.6 vCPU
VM_CPU_FLOPS = 5.5e9              # t2.medium (2 vCPU, one training proc)
VM_GPU_FLOPS = {"g3s.xlarge": 150e9, "g4dn.xlarge": 300e9}  # NN models only
VM_GPU_FLOPS_DEFAULT = VM_GPU_FLOPS["g3s.xlarge"]  # unknown-GPU fallback

# ---- serving memory model (DESIGN.md §14) ------------------------------------
# Replica RAM bounds model weights + KV cache; memory bandwidth sets the
# weight-streaming floor of a decode step (the roofline's second leg).
LAMBDA_MEM_BW = 10e9              # bytes/s, Lambda sandbox DDR share
VM_MEM_BW = 12e9                  # bytes/s, t2/c5-class DDR4
VM_GPU_MEM_BW = {"g3s.xlarge": 160e9, "g4dn.xlarge": 320e9}   # HBM/GDDR
VM_GPU_MEM_BW_DEFAULT = VM_GPU_MEM_BW["g4dn.xlarge"]  # unknown-GPU fallback
EC2_RAM_GB = {
    "t2.medium": 4.0, "t2.2xlarge": 32.0,
    "c5.large": 4.0, "c5.xlarge": 8.0, "c5.4xlarge": 32.0,
    "g3s.xlarge": 30.5, "g4dn.xlarge": 16.0, "m5a.12xlarge": 192.0,
}
GPU_HBM_GB = {"g3s.xlarge": 8.0, "g4dn.xlarge": 16.0}

# ---- accelerator pods (the third infrastructure, DESIGN.md §11) --------------
TPU_CHIP_HOURLY = 1.2             # $ per v5e chip-hour, on-demand list price
POD_HBM_GB = 16.0                 # HBM per v5e chip


def lambda_cost(gb: float, seconds: float, invocations: int = 1) -> float:
    return gb * seconds * LAMBDA_GB_S + invocations * LAMBDA_REQUEST


def ec2_cost(instance: str, seconds: float, count: int = 1) -> float:
    return EC2_HOURLY[instance] / 3600.0 * seconds * count
