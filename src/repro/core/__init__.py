"""LambdaML core: the paper's design space as composable pieces.

- algorithms: GA-SGD / MA-SGD / ADMM / EM-kmeans (shared FaaS+IaaS impls)
- comm:       the communication design space as Transport x Collective x
              Codec (storage channels, NIC/DCN rings, hybrid VM-PS;
              allreduce / scatter-reduce / hierarchical / ring / push-pull;
              fp32 / int8+EF / top-k), composed by CommStack and selected
              with the "transport/collective/codec" string grammar
              (channels.py / patterns.py remain as compat shims)
- engine:     the discrete-event simulation core (clocks, failures, metering)
- sync:       BSP / ASP / SSP protocol objects over the engine
- platform:   the Platform protocol + composable FleetSpec / FailureSpec /
              CommSpec (the typed engine-hook interface)
- runtimes:   FaaSRuntime (LambdaML) and IaaSRuntime (distributed-PyTorch)
              thin builders over the specs, incl. spot and hetero fleets
- analytical: the §5.3 cost/performance model + what-if studies

The declarative experiment layer (ExperimentSpec / run_experiment / sweep /
presets / the ``python -m repro`` CLI) lives in :mod:`repro.experiments`.
"""
from repro.core.algorithms import (  # noqa: F401
    ADMM, Algorithm, EMKMeans, GASGD, MASGD, make_algorithm,
)
from repro.core.channels import (  # noqa: F401
    CHANNEL_SPECS, ChannelItemTooLarge, StorageChannel, VMNetwork,
    VMParameterServer,
)
from repro.core.comm import (  # noqa: F401
    Codec, Collective, CommStack, Transport, build_comm_stack, list_codecs,
    list_collectives, list_transports, make_codec, make_collective,
    make_transport,
)
from repro.core.engine import (  # noqa: F401
    FailureProcess, InjectedPreemptions, PoissonPreemptions, RunResult,
    SimContext, StragglerProcess, simulate,
)
from repro.core.mlmodels import StudyModel, make_study_model, model_bytes  # noqa: F401
from repro.core.patterns import allreduce, scatter_reduce  # noqa: F401
from repro.core.platform import (  # noqa: F401
    BasePlatform, CommSpec, FailureSpec, FleetSpec, Platform,
)
from repro.core.runtimes import FaaSRuntime, IaaSRuntime  # noqa: F401
from repro.core.sync import (  # noqa: F401
    ASP, BSP, SSP, SyncProtocol, make_sync, sync_name,
)
