"""LambdaML core: the paper's design space as composable pieces.

- algorithms: GA-SGD / MA-SGD / ADMM / EM-kmeans (shared FaaS+IaaS impls)
- channels:   S3 / Memcached / Redis / DynamoDB / hybrid VM-PS / VM NICs
- patterns:   AllReduce / ScatterReduce over a storage channel
- engine:     the discrete-event simulation core (clocks, failures, metering)
- sync:       BSP / ASP / SSP protocol objects over the engine
- runtimes:   FaaSRuntime (LambdaML) and IaaSRuntime (distributed-PyTorch)
              platform adapters, incl. spot and heterogeneous fleets
- analytical: the §5.3 cost/performance model + what-if studies
"""
from repro.core.algorithms import (  # noqa: F401
    ADMM, Algorithm, EMKMeans, GASGD, MASGD, make_algorithm,
)
from repro.core.channels import (  # noqa: F401
    CHANNEL_SPECS, ChannelItemTooLarge, StorageChannel, VMNetwork,
    VMParameterServer,
)
from repro.core.engine import (  # noqa: F401
    FailureProcess, InjectedPreemptions, PoissonPreemptions, RunResult,
    SimContext, StragglerProcess, simulate,
)
from repro.core.mlmodels import StudyModel, make_study_model, model_bytes  # noqa: F401
from repro.core.patterns import allreduce, scatter_reduce  # noqa: F401
from repro.core.runtimes import FaaSRuntime, IaaSRuntime  # noqa: F401
from repro.core.sync import ASP, BSP, SSP, SyncProtocol, make_sync  # noqa: F401
