"""Communication patterns over a storage channel -- COMPAT SHIM.

The implementations moved to :mod:`repro.core.comm.collectives` when the
communication subsystem became the composable Transport x Collective x
Codec API (DESIGN.md §12): the seed-era free functions are unchanged
(`allreduce`/`scatter_reduce` drive the byte-identical legacy paths), and
the new hierarchical two-level reduce lives alongside them.  New code
should import from :mod:`repro.core.comm`.
"""
from repro.core.comm.collectives import (  # noqa: F401
    PATTERNS, POLL, allreduce, scatter_reduce, two_level_reduce,
)

__all__ = ["PATTERNS", "POLL", "allreduce", "scatter_reduce",
           "two_level_reduce"]
