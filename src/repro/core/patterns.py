"""Communication patterns over a storage channel (paper §3.2.3, Fig 4) with
the two-phase synchronous protocol of §3.2.4 (merge phase + update phase,
file-name polling).

Both patterns take the workers' flat update vectors, move them through the
channel (real payloads), and return (merged_vector, per_worker_times) where
times include the BSP waits -- so AllReduce's leader bottleneck and
ScatterReduce's balanced reduce show up exactly as in Table 3.

Any store implementing the engine's metering interface (DESIGN.md §4.3:
``put``/``get`` returning simulated seconds, a ``spec.latency``) works; the
discrete-event engine plugs these into its BSP rounds via
:class:`repro.core.engine.ChannelComm`.
"""
from __future__ import annotations

import numpy as np

from repro.core.channels import StorageChannel

POLL = 0.01  # s between list() polls (merge-phase waiting)


def _poll_until(t_now: float, t_ready: float, latency: float) -> float:
    """Poll (list) until t_ready; each poll costs one latency."""
    if t_now >= t_ready:
        return t_now + latency
    n_polls = int((t_ready - t_now) / max(POLL, latency)) + 1
    return t_ready + latency  # arrives at ready + one confirming list


def allreduce(channel: StorageChannel, updates: list[np.ndarray], tag: str):
    """Fig 4 left: all write; leader (worker 0) merges; all read merged."""
    w = len(updates)
    lat = channel.spec.latency
    t_put = np.zeros(w)
    for i, u in enumerate(updates):
        t_put[i] = channel.put(f"{tag}/part{i}", u)
    # merge phase: leader polls until all parts visible
    t_all_put = float(np.max(t_put))
    t_leader = _poll_until(t_put[0], t_all_put, lat)
    merged = np.zeros_like(updates[0])
    for i in range(w):
        p, dt = channel.get(f"{tag}/part{i}")
        merged += p
        t_leader += dt
    merged /= w
    t_leader += channel.put(f"{tag}/merged", merged)
    # update phase: everyone else polls for the merged file, then reads it
    times = np.zeros(w)
    for i in range(w):
        if i == 0:
            times[i] = t_leader
        else:
            t = _poll_until(t_put[i], t_leader, lat)
            _, dt = channel.get(f"{tag}/merged")
            times[i] = t + dt
    return merged, times


def scatter_reduce(channel: StorageChannel, updates: list[np.ndarray], tag: str):
    """Fig 4 right: every worker reduces one partition of the update."""
    w = len(updates)
    lat = channel.spec.latency
    n = updates[0].size
    bounds = np.linspace(0, n, w + 1, dtype=int)
    # phase 1: each worker writes w partitions
    t_put = np.zeros(w)
    for i, u in enumerate(updates):
        t = 0.0
        for j in range(w):
            t += channel.put(f"{tag}/p{i}_{j}", u[bounds[j]: bounds[j + 1]])
        t_put[i] = t
    t_all_put = float(np.max(t_put))
    # phase 2: worker j reduces partition j
    merged = np.zeros_like(updates[0])
    t_reduced = np.zeros(w)
    for j in range(w):
        t = _poll_until(t_put[j], t_all_put, lat)
        acc = np.zeros(bounds[j + 1] - bounds[j], updates[0].dtype)
        for i in range(w):
            p, dt = channel.get(f"{tag}/p{i}_{j}")
            acc += p
            t += dt
        acc /= w
        merged[bounds[j]: bounds[j + 1]] = acc
        t += channel.put(f"{tag}/r{j}", acc)
        t_reduced[j] = t
    t_all_reduced = float(np.max(t_reduced))
    # phase 3: everyone reads the other w-1 reduced partitions
    times = np.zeros(w)
    for i in range(w):
        t = _poll_until(t_reduced[i], t_all_reduced, lat)
        for j in range(w):
            if j != i:
                _, dt = channel.get(f"{tag}/r{j}")
                t += dt
        times[i] = t
    return merged, times


PATTERNS = {"allreduce": allreduce, "scatter_reduce": scatter_reduce}
