"""Discrete-event training simulation engine (DESIGN.md §4).

One per-worker virtual-clock event loop drives every (infrastructure x sync
protocol) combination in the study.  The engine owns everything that used to
be duplicated between the FaaS and IaaS training loops:

- per-worker clocks, the startup/load prologue, and the time/cost meters,
- the checkpoint/restart machinery (Lambda 15-minute lifetime rotation and
  spot-instance preemption share one code path, DESIGN.md §7.1),
- pluggable straggler and failure processes,
- the ``CommBackend`` seam: one metering interface implemented by the
  composable :class:`repro.core.comm.CommStack` (Transport x Collective x
  Codec, DESIGN.md §12) -- storage channels, the hybrid VM parameter
  server, VM NICs and the cross-pod DCN all plug in through it.

Sync protocols (:mod:`repro.core.sync`) are strategy objects over a
:class:`SimContext`; infrastructures (:mod:`repro.core.runtimes`) are
platform adapters queried through the explicit
:class:`~repro.core.platform.Platform` protocol (the engine itself stays
import-free of concrete platforms, so new protocols and new platforms
compose for free).

All payloads are REAL numpy arrays (numerics are exact; only time and money
are simulated) -- the paper's statistical/system efficiency split.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any

import numpy as np

if TYPE_CHECKING:                        # platform.py imports engine at runtime
    from repro.core.platform import Platform

from repro.core.ckpt import Checkpointer, CheckpointSpec
from repro.core.comm import (  # noqa: F401  (adapters re-exported)
    ChannelComm, ChannelItemTooLarge, CommStack, MPIComm, PSComm,
    StorageChannel, VMNetwork,
)
from repro.core.mlmodels import model_bytes
from repro.core.trace import TraceRecorder
from repro.data.synthetic import partition


@dataclass
class RunResult:
    """Outcome of one simulated training run (shared FaaS/IaaS schema)."""
    system: str
    algorithm: str
    workers: int
    history: list = field(default_factory=list)   # [(sim_time_s, loss)]
    rounds: int = 0
    sim_time: float = 0.0
    cost: float = 0.0
    breakdown: dict = field(default_factory=dict)
    converged: bool = False
    error: str = ""
    preemptions: int = 0          # involuntary restarts (spot / crash)
    max_staleness: int = 0        # max observed round lag at a model read
    comm_bytes: float = 0.0       # per-worker update bytes moved on the
                                  # metered (slow) substrate, whole run
                                  # (WIRE bytes: codecs shrink this exactly)
    comm_cost: float = 0.0        # $ billed by the comm substrate itself
    ckpt_bytes: float = 0.0       # checkpoint bytes moved through the
                                  # metered checkpoint transport (save puts
                                  # + restore gets, repro.core.ckpt)
    ckpt_time: float = 0.0        # simulated checkpoint transfer seconds
                                  # (excludes the cold-start part of a
                                  # restart -- that stays in breakdown)
    ckpt_cost: float = 0.0        # $ of checkpoint put/get requests
    scaling_timeline: list = field(default_factory=list)
                                  # elastic fleets (DESIGN.md §13): one
                                  # (round, w, resize_cost_s, resize_cost_usd)
                                  # per membership change, so benchmarks can
                                  # plot w(t); [] for fixed fleets; a final
                                  # w=0 entry means the policy stopped the run
    trace: Any = field(default=None, repr=False)
                                  # TraceRecorder when run with trace=True
                                  # (DESIGN.md §18); None otherwise

    @property
    def final_loss(self) -> float:
        return self.history[-1][1] if self.history else float("nan")

    @property
    def comm_time(self) -> float:
        """Simulated seconds spent in metered communication (the
        ``breakdown["comm"]`` meter every backend feeds uniformly)."""
        return self.breakdown.get("comm", 0.0)

    def to_dict(self):
        """Full-precision record payload.  Rounding is presentation-only
        (see :meth:`summary`): the record keeps every metered float exact
        so span-derived breakdown fractions reconcile bitwise with
        ``sim_time`` and ``cost``."""
        d = {"system": self.system, "algorithm": self.algorithm,
             "workers": self.workers, "rounds": self.rounds,
             "sim_time_s": self.sim_time,
             "cost_usd": self.cost,
             "final_loss": self.final_loss,
             "converged": self.converged,
             "preemptions": self.preemptions,
             "max_staleness": self.max_staleness,
             "comm_bytes": self.comm_bytes,
             "comm_time_s": self.comm_time,
             "comm_cost_usd": self.comm_cost,
             "ckpt_bytes": self.ckpt_bytes,
             "ckpt_time_s": self.ckpt_time,
             "ckpt_cost_usd": self.ckpt_cost,
             "scaling_timeline": [[int(r), int(w), float(s), float(c)]
                                  for r, w, s, c in self.scaling_timeline],
             "breakdown": dict(self.breakdown),
             "error": self.error}
        if self.trace is not None and not self.error:
            from repro.core.trace import check_invariants, derive_breakdown
            inv = check_invariants(self)
            bd = derive_breakdown(self.trace)
            d["trace"] = {
                "spans": len(self.trace.spans),
                "marks": len(self.trace.marks),
                "breakdown": bd["phases"],
                "usd": bd["usd"],
                "invariants": {"clock": inv["clock"]["ok"],
                               "cost": inv["cost"]["ok"],
                               "bytes": inv["bytes"]["ok"]},
            }
        return d

    def summary(self):
        """Presentation view of :meth:`to_dict` -- the legacy 2-decimal
        rounding, applied at the edge instead of inside the record."""
        d = self.to_dict()
        d.update(
            sim_time_s=round(self.sim_time, 2),
            cost_usd=round(self.cost, 4),
            comm_time_s=round(self.comm_time, 2),
            comm_cost_usd=round(self.comm_cost, 6),
            ckpt_time_s=round(self.ckpt_time, 2),
            ckpt_cost_usd=round(self.ckpt_cost, 6),
            scaling_timeline=[[int(r), int(w), round(s, 3), round(c, 6)]
                              for r, w, s, c in self.scaling_timeline],
            breakdown={k: round(v, 2) for k, v in self.breakdown.items()})
        return d


# ------------------------------------------------------------ processes -----

@dataclass
class StragglerProcess:
    """Per-worker relative compute slowdown (1.0 = nominal).

    Log-normal jitter plus one deterministic straggler when ``factor > 1``;
    ``cap`` models backup invocations racing the straggler (effective speed =
    min(own, median), DESIGN.md §7.3).
    """
    factor: float = 1.0
    jitter: float = 0.05
    cap_at_median: bool = False

    def speeds(self, w: int, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        s = np.exp(rng.normal(0.0, self.jitter, w))
        if self.factor > 1.0:
            s[rng.integers(0, w)] *= self.factor
        if self.cap_at_median:
            s = np.minimum(s, np.median(s))
        return s


class FailureProcess:
    """Base failure process: no preemptions ever."""

    def next_preemption(self, worker: int, after_t: float,
                        before_t: float) -> float | None:
        """Pop the next preemption for ``worker`` due before ``before_t``
        (or None).  ``after_t`` is the start of the queried healthy-runtime
        window; stochastic processes count exposure from it, deterministic
        ones may ignore it and let past events fire clamped to the present.
        Per-worker calls must be time-monotone; a returned event is
        consumed."""
        return None


class PoissonPreemptions(FailureProcess):
    """Memoryless spot-market preemptions at ``rate`` per worker-hour.

    Exposure is counted in *healthy instance runtime*: a replacement
    instance brought up after a preemption starts a fresh memoryless lease,
    so restart/checkpoint time itself is never preempted (and a high rate
    degrades throughput instead of deadlocking the simulation).
    """

    def __init__(self, rate_per_hour: float, workers: int, seed: int = 0):
        self.scale = 3600.0 / max(rate_per_hour, 1e-12)
        self._rng = np.random.default_rng(seed ^ 0x5107)
        # keyed by STABLE worker id (elastic fleets retire ids for good and
        # mint fresh ones for joiners, DESIGN.md §13); the initial fleet is
        # drawn eagerly in id order so fixed fleets stay byte-identical to
        # the seed-era list-based draws
        self._togo = {i: float(self._rng.exponential(self.scale))
                      for i in range(workers)}   # healthy s until next kill

    def next_preemption(self, worker, after_t, before_t):
        if worker not in self._togo:             # elastic joiner: fresh lease
            self._togo[worker] = float(self._rng.exponential(self.scale))
        window = max(before_t - after_t, 0.0)
        if self._togo[worker] >= window:
            self._togo[worker] -= window
            return None
        t = after_t + self._togo[worker]
        self._togo[worker] = float(self._rng.exponential(self.scale))
        return t


class InjectedPreemptions(FailureProcess):
    """Deterministic preemptions at explicit ``(worker, sim_time)`` points --
    the reproducible way to script a spot scenario in tests/benchmarks.

    Unlike :class:`PoissonPreemptions`, ``after_t`` is ignored: a scripted
    kill never silently vanishes.  An injected time that is already in the
    worker's past (e.g. before startup finished) fires at the next query and
    is executed clamped to the worker's current clock."""

    def __init__(self, at: tuple[tuple[int, float], ...]):
        self.at = tuple((int(wk), float(t)) for wk, t in at)
        self._pending: dict[int, list[float]] = {}
        for wk, t in self.at:
            self._pending.setdefault(wk, []).append(t)
        for ts in self._pending.values():
            ts.sort(reverse=True)  # pop() from the end = earliest first

    def next_preemption(self, worker, after_t, before_t):
        ts = self._pending.get(worker)
        if ts and ts[-1] < before_t:
            return ts.pop()
        return None


# --------------------------------------------------------- comm backends ----

class CommBackend:
    """How a fleet moves update vectors.  All backends expose:

    - ``bsp_reduce(ctx, updates, tag)``: merge one BSP round, advancing
      ``ctx.clock`` and the comm meter; returns the merged vector.
    - ``kvstore()``: a metered key-value store (``put``/``get`` returning
      simulated seconds) holding the global model for ASP/SSP.
    - ``service_cost(seconds)``: $ for the communication substrate itself.

    The one real implementation is the composable
    :class:`repro.core.comm.CommStack` (Transport x Collective x Codec,
    DESIGN.md §12); ``ChannelComm``/``PSComm``/``MPIComm`` are its thin
    legacy adapters, re-exported here for the seed-era import paths.
    """

    def bsp_reduce(self, ctx: "SimContext", updates: list, tag: str):
        raise NotImplementedError

    def kvstore(self):
        raise NotImplementedError

    def startup(self) -> float:
        """Seconds to provision the substrate (0 = always-on)."""
        return 0.0

    def service_cost(self, seconds: float) -> float:
        return 0.0

    def rebuilt(self) -> "CommBackend":
        """A stack re-composed for a resized fleet (DESIGN.md §13): fresh
        per-worker collective/codec state, same metered transports (their
        accumulated op counters and $ carry over).  The base backend is
        width-agnostic and returns itself."""
        return self


# -------------------------------------------------------------- context -----

@dataclass
class SimContext:
    """Mutable state of one simulated run, shared by engine + protocol."""
    platform: Any
    model: Any
    algo: Any
    states: list
    parts: list
    ds_val: Any
    res: RunResult
    comm: CommBackend
    ckpt_store: Any
    failure: FailureProcess
    clock: np.ndarray          # per-worker virtual time (s)
    invoked_at: np.ndarray     # per-worker start of current lease
    speeds: np.ndarray         # straggler multipliers
    c_round: np.ndarray        # per-worker nominal seconds per round
    mbytes: int
    lifetime: float            # s before planned rotation; inf = never
    lifetime_margin: float
    target_loss: float | None
    max_epochs: int
    eval_every: int
    invocations: int = 0
    ckpt: Any = None           # Checkpointer routing save/restore bytes
                               # through the metered transport (§17)
    # ---- elastic-fleet state (DESIGN.md §13; inert for fixed fleets) ----
    ds_train: Any = None          # kept so resizes can re-partition
    elastic: Any = None           # ElasticController, or None = fixed fleet
    worker_ids: np.ndarray = None   # stable identity per position: retired
                                    # ids are never reused, so scripted kills
                                    # for a removed worker can never fire on
                                    # a later joiner
    joined_at: np.ndarray = None    # sim s each CURRENT worker started
                                    # billing (0.0 for the initial fleet)
    retired_cost: float = 0.0       # $ already billed by retired workers
    next_worker_id: int = 0
    last_update_nbytes: int = 0     # raw bytes of the latest reduced update
                                    # vector (EM ships sums+counts, more
                                    # than the params) -- what resize
                                    # feasibility checks item limits with
    rec: Any = None                 # TraceRecorder (DESIGN.md §18), or None;
                                    # every emission site is guarded so the
                                    # disabled path is byte-identical

    @property
    def w(self) -> int:
        return len(self.clock)

    def meter_add(self, key: str, dt: float):
        self.res.breakdown[key] = self.res.breakdown.get(key, 0.0) + dt
        if self.rec is not None:
            # mirrored accumulation: same value, same order, so
            # rec.meters stays bitwise-equal to res.breakdown
            self.rec.meter(key, dt)

    def meter_bytes(self, n: float):
        """Count per-worker update bytes crossing the metered substrate
        (the storage channel, the PS link, VM NICs, or the cross-pod DCN
        -- never the free intra-pod ICI)."""
        self.res.comm_bytes += n
        if self.rec is not None:
            self.rec.bytes_event("comm", n)

    # ---- compute ----
    def tick_compute(self):
        """Advance every worker by one local round of compute."""
        c = self.c_round * self.speeds
        if self.rec is None:
            self.clock += c
        else:
            before = self.clock.copy()
            self.clock += c
            for i in range(self.w):
                wid = int(self.worker_ids[i])
                t0, t1 = float(before[i]), float(self.clock[i])
                if self.speeds[i] > 1.0:
                    # a straggler's extra seconds beyond the nominal round
                    # are a stall, not useful compute (paper §V straggler
                    # mitigation); the split point is interior, so tiling
                    # stays endpoint-exact
                    mid = t0 + float(self.c_round[i])
                    self.rec.span(wid, "compute", "compute", t0, mid)
                    self.rec.span(wid, "straggler", "stall", mid, t1)
                else:
                    self.rec.span(wid, "compute", "compute", t0, t1)
        self.meter_add("compute", float(np.mean(c)))

    def step_compute(self, i: int) -> float:
        """One worker's seconds for one local round (event-driven loops)."""
        c = float(self.c_round[i] * self.speeds[i])
        self.meter_add("compute", c / self.w)
        return c

    # ---- checkpoint / restart machinery (shared lifetime + spot path) ----
    def _rotate(self, i: int, at_time: float, meter_key: str):
        """Bring a fresh replacement for worker ``i`` up at ``at_time``,
        routing checkpoint bytes through the metered transport
        (repro.core.ckpt).

        Save-at-kill mode (``CheckpointSpec.every == 0``, the seed
        semantics): ckpt save + cold start + ckpt restore, byte-identical
        to the inline seed path for the default spec.  Under a periodic
        cadence an INVOLUNTARY kill instead restores the last fleet
        checkpoint and re-does the work since it (nothing can save at the
        moment of a preemption); planned lifetime rotations still save
        on their way out in both modes."""
        ck = self.ckpt
        rec = self.rec
        if rec is not None:
            wid = int(self.worker_ids[i])
            # work since the last sync point dies with the instance: the
            # interval from the worker's clock to the (possibly later) kill
            # time is lost progress, traced as a stall
            rec.span(wid, "preempt.lost", "stall", float(self.clock[i]),
                     at_time, meta={"cause": meter_key})
        if ck is not None and ck.every > 0 and meter_key == "restart":
            restart = self.platform.restart_time()
            dt_get = ck.restore("ckpt/fleet")
            rework = max(at_time - ck.last_ckpt_t, 0.0)
            self.clock[i] = at_time + restart + dt_get + rework
            self.meter_add(meter_key, restart + dt_get + rework)
            if rec is not None:
                # split points are the engine's own left-associative
                # partial sums, so the sub-spans tile bitwise
                s1 = at_time + restart
                s2 = s1 + dt_get
                rec.span(wid, "coldstart", "startup", at_time, s1)
                rec.span(wid, "ckpt.restore", "ckpt", s1, s2)
                rec.span(wid, "rework", "stall", s2, float(self.clock[i]))
        else:
            dt_put = ck.save(f"ckpt/{i}")
            restart = self.platform.restart_time()
            dt_get = ck.restore(f"ckpt/{i}")
            self.clock[i] = at_time + dt_put + restart + dt_get
            self.meter_add(meter_key, dt_put + restart + dt_get)
            if rec is not None:
                s1 = at_time + dt_put
                s2 = s1 + restart
                rec.span(wid, "ckpt.save", "ckpt", at_time, s1)
                rec.span(wid, "coldstart", "startup", s1, s2)
                rec.span(wid, "ckpt.restore", "ckpt", s2,
                         float(self.clock[i]))
        self.invoked_at[i] = self.clock[i]
        self.invocations += 1

    def ckpt_boundary(self, rnd: int) -> float:
        """Periodic fleet checkpoint at a sync boundary
        (``CheckpointSpec.every = N``): every worker stalls for one metered
        fleet save.  Returns the stall seconds (0.0 when the cadence is off
        or not yet due) so event-driven protocols can shift their queues."""
        ck = self.ckpt
        if ck is None or not ck.due(rnd):
            return 0.0
        dt = ck.save("ckpt/fleet")
        if self.rec is None:
            self.clock += dt
        else:
            before = self.clock.copy()
            self.clock += dt
            self.rec.tile(self.worker_ids, before, self.clock,
                          "ckpt.save", "ckpt")
        self.meter_add("checkpoint", dt)
        ck.mark(rnd, float(np.max(self.clock)))
        return dt

    def ensure_alive(self, i: int, est: float):
        """Guarantee worker ``i`` survives its next ``est`` seconds of work:
        consume any spot/crash preemption in the window, then rotate ahead of
        a planned lifetime expiry (the Lambda 15-minute contract).  The
        failure process is queried by STABLE worker id, not position, so a
        worker retired by an elastic scale-down takes its pending failures
        with it."""
        wid = int(self.worker_ids[i])
        t_pre = self.failure.next_preemption(wid, float(self.clock[i]),
                                             float(self.clock[i]) + est)
        while t_pre is not None:
            if self.rec is not None:
                self.rec.mark("preempt", t_pre, wid)
            self._rotate(i, max(t_pre, float(self.clock[i])), "restart")
            self.res.preemptions += 1
            t_pre = self.failure.next_preemption(wid, float(self.clock[i]),
                                                 float(self.clock[i]) + est)
        if (math.isfinite(self.lifetime)
                and self.clock[i] - self.invoked_at[i] + est
                > self.lifetime - self.lifetime_margin):
            self._rotate(i, float(self.clock[i]), "checkpoint")

    # ---- elastic resizing (DESIGN.md §13) ----
    def maybe_resize(self, rnd: int) -> bool:
        """Round-boundary scaling-policy check; no-op for fixed fleets.
        Returns True when the policy says stop (e.g. a cost cap is hit)."""
        if self.elastic is None:
            return False
        return self.elastic.step(self, rnd)

    def elastic_boundary(self, rnd: int, total_rounds: int,
                         rpe: int) -> tuple:
        """The shared round-boundary step for round-loop protocols (BSP,
        LocalSGD): consult the policy and, after a resize, rescale the
        remaining round budget so the EPOCH count is preserved (a resize
        re-partitions the data, changing rounds-per-epoch).

        Returns ``(stop, total_rounds, rpe, resized)``; ``resized`` tells
        the protocol to refresh its own width-dependent locals."""
        if self.elastic is None or rnd >= total_rounds:
            return False, total_rounds, rpe, False
        w0 = self.w
        if self.maybe_resize(rnd):
            return True, total_rounds, rpe, False
        if self.w == w0:
            return False, total_rounds, rpe, False
        new_rpe = self.algo.rounds_per_epoch(self.parts[0])
        total_rounds = rnd + math.ceil((total_rounds - rnd) * new_rpe / rpe)
        return False, total_rounds, new_rpe, True

    def resize(self, new_w: int, rnd: int) -> None:
        """Change the fleet to ``new_w`` workers at a sync boundary.

        Scale-down retires the highest positions (their usage so far is
        billed into ``retired_cost`` and their stable ids are never
        reused); scale-up mints fresh ids and invokes/provisions joiners at
        the platform's measured startup constants (clock stall metered
        under ``breakdown["resize"]``).  Either way the training data is
        re-partitioned over the new fleet, per-worker state is rebuilt from
        the current merged parameters (callers resize only at points where
        ``states[0]`` holds them), and the comm stack is re-composed for
        the new width (error-feedback codec state resets; metered transport
        counters carry over).  The change lands in
        ``RunResult.scaling_timeline``.
        """
        old_w = self.w
        if new_w == old_w:
            return
        t_now = float(np.max(self.clock))
        dt = usd = 0.0
        if new_w < old_w:
            gone = np.arange(new_w, old_w)
            self.retired_cost += float(self.platform.retire_cost(self, gone))
            if self.rec is not None:
                for k in gone:
                    self.rec.retire_worker(int(self.worker_ids[k]),
                                           float(self.clock[k]))
            for name in ("clock", "invoked_at", "joined_at", "speeds",
                         "worker_ids"):
                setattr(self, name, getattr(self, name)[:new_w])
        else:
            added = new_w - old_w
            dt, usd = self.platform.resize_cost(added)
            ids = np.arange(self.next_worker_id, self.next_worker_id + added)
            self.next_worker_id += added
            self.worker_ids = np.concatenate([self.worker_ids, ids])
            self.clock = np.concatenate(
                [self.clock, np.full(added, t_now + dt)])
            self.invoked_at = np.concatenate(
                [self.invoked_at, np.full(added, t_now + dt)])
            self.joined_at = np.concatenate(
                [self.joined_at, np.full(added, t_now)])
            self.speeds = np.concatenate(
                [self.speeds, self.platform.joiner_speeds(ids)])
            self.invocations += added
            self.meter_add("resize", dt)
            if self.rec is not None:
                for k in range(old_w, new_w):
                    wid = int(self.worker_ids[k])
                    self.rec.birth(wid, t_now)
                    self.rec.span(wid, "provision", "startup", t_now,
                                  float(self.clock[k]))
            if self.ckpt is not None:
                # joiners are not born with the model: the merged params are
                # published once through the checkpoint transport and every
                # joiner pulls its copy (metered -- no free weight copy;
                # pulls run in parallel, so the stall is one restore)
                dt_save = self.ckpt.save("ckpt/fleet")
                dt_pull = 0.0
                for _ in range(added):
                    dt_pull = self.ckpt.restore("ckpt/fleet")
                if self.rec is None:
                    self.clock[old_w:] += dt_save + dt_pull
                else:
                    # the engine adds the SCALAR SUM dt_save + dt_pull, so
                    # decomposed save/pull sub-spans would not tile bitwise:
                    # trace one combined span with the split in its meta
                    before = self.clock[old_w:].copy()
                    self.clock[old_w:] += dt_save + dt_pull
                    self.rec.tile(self.worker_ids[old_w:], before,
                                  self.clock[old_w:], "ckpt.join", "ckpt",
                                  meta={"save_s": dt_save,
                                        "pull_s": dt_pull})
                self.invoked_at[old_w:] += dt_save + dt_pull
                self.meter_add("resize", dt_save + dt_pull)
                self.ckpt.mark(rnd, float(self.clock[old_w]))
        self.platform.resize_fleet(new_w)
        params = self.states[0].params          # merged model at the boundary
        self.parts = partition(self.ds_train, new_w)
        self.states = [self.algo.init_worker(self.model, params, p)
                       for p in self.parts]
        flops = self.platform.worker_flops_array(self.model)
        rows = self.algo.rows_per_round(self.parts[0])
        self.c_round = np.asarray(rows * self.model.flops_per_row / flops,
                                  float)
        self.comm = self.comm.rebuilt()
        self.res.workers = new_w
        self.res.scaling_timeline.append(
            (int(rnd), int(new_w), float(dt), float(usd)))
        if self.rec is not None:
            self.rec.mark("resize", t_now, old_w=old_w, new_w=new_w,
                          stall_s=dt, usd=usd)

    # ---- evaluation ----
    def record_eval(self, rnd: int, total_rounds: int, params) -> bool:
        """Round-boundary eval (BSP); returns True when converged."""
        if rnd % self.eval_every == 0 or rnd == total_rounds - 1:
            loss = self.model.eval_loss(params, self.ds_val)
            self.res.history.append((float(np.max(self.clock)), loss))
            if self.target_loss is not None and loss <= self.target_loss:
                self.res.converged = True
                return True
        return False

    def record_eval_at(self, t: float, params) -> bool:
        """Event-time eval (ASP/SSP); returns True when converged."""
        loss = self.model.eval_loss(params, self.ds_val)
        self.res.history.append((t, loss))
        if self.target_loss is not None and loss <= self.target_loss:
            self.res.converged = True
            return True
        return False


# -------------------------------------------------------------- simulate ----

def simulate(platform: "Platform", sync, model, algo, ds_train, ds_val, *,
             target_loss: float | None = None, max_epochs: int = 10,
             eval_every: int = 1, data_local: bool = False,
             elastic=None, trace: bool = False) -> RunResult:
    """Run one training scenario: ``platform`` (any
    :class:`~repro.core.platform.Platform` implementation) x ``sync``
    (protocol object) x ``algo`` on real data/numerics.  ``elastic`` is an
    optional :class:`repro.core.elastic.ElasticController` consulted at
    round boundaries (DESIGN.md §13); ``None`` keeps the fixed-fleet path
    byte-identical to the pre-elastic engine.  ``trace=True`` attaches a
    :class:`~repro.core.trace.TraceRecorder` (DESIGN.md §18) recording
    every event as a span, without perturbing any metered value."""
    import jax

    if elastic is not None:
        # some policies (schedule:<w@0,...>, plan) pin the INITIAL fleet:
        # apply it before anything is invoked or billed
        w0 = elastic.initial_workers(platform.workers)
        if w0 != platform.workers:
            platform.resize_fleet(w0)
    w = platform.workers
    res = RunResult(platform.system_name(), algo.name, w)
    rec = TraceRecorder("train") if trace else None
    res.trace = rec
    if elastic is not None:
        res.scaling_timeline.append((0, w, 0.0, 0.0))
    parts = partition(ds_train, w)
    params0 = model.init(jax.random.key(platform.seed))
    mbytes = model_bytes(params0)
    err = platform.validate(mbytes)
    if err:
        res.error = err
        return res
    states = [algo.init_worker(model, params0, p) for p in parts]

    comm = platform.make_comm()
    ckpt_store = platform.make_ckpt_store(comm)
    ckpt_spec = getattr(platform, "ckpt", None) or CheckpointSpec()
    ckpt = Checkpointer(spec=ckpt_spec, store=ckpt_store, mbytes=int(mbytes),
                        shards=ckpt_spec.shards(w), rec=rec)
    speeds = platform.worker_speeds()
    t_start = platform.startup_time(comm)
    part_bytes = max(p.nbytes for p in parts)
    t_load = platform.load_time(part_bytes, data_local)
    res.breakdown = dict(platform.init_breakdown())
    res.breakdown.update(startup=t_start, load=t_load)
    if rec is not None:
        # seed the meter mirror with the prologue values so the two dicts
        # stay bitwise-equal under the same subsequent accumulations
        rec.meters.update(res.breakdown)

    flops = platform.worker_flops_array(model)
    rows = algo.rows_per_round(parts[0])
    c_round = rows * model.flops_per_row / flops

    ctx = SimContext(
        platform=platform, model=model, algo=algo, states=states, parts=parts,
        ds_val=ds_val, res=res, comm=comm,
        ckpt_store=ckpt_store, ckpt=ckpt,
        failure=platform.failure_process(),
        clock=np.full(w, t_start + t_load),
        invoked_at=np.full(w, t_start + t_load),
        speeds=speeds, c_round=np.asarray(c_round, float), mbytes=mbytes,
        lifetime=platform.lifetime_s(),
        lifetime_margin=platform.lifetime_margin_s(),
        target_loss=target_loss, max_epochs=max_epochs, eval_every=eval_every,
        invocations=w,
        ds_train=ds_train, elastic=elastic,
        worker_ids=np.arange(w), joined_at=np.zeros(w), next_worker_id=w,
        rec=rec)
    if rec is not None:
        # every initial worker is born at t=0 and spends the prologue in
        # startup then data loading (clock starts at t_start + t_load)
        for i in range(w):
            rec.birth(i, 0.0)
            rec.span(i, "startup", "startup", 0.0, t_start)
            rec.span(i, "load", "data", t_start, float(ctx.clock[i]))

    try:
        if ckpt.every > 0:
            # periodic-cadence mode: checkpoint the freshly-initialized
            # fleet first, so the earliest involuntary kill always has a
            # checkpoint to restore (rework is bounded by the cadence)
            dt0 = ctx.ckpt.save("ckpt/fleet")
            if rec is None:
                ctx.clock += dt0
            else:
                before = ctx.clock.copy()
                ctx.clock += dt0
                rec.tile(ctx.worker_ids, before, ctx.clock,
                         "ckpt.save", "ckpt")
            ctx.meter_add("checkpoint", dt0)
            ctx.ckpt.mark(0, float(np.max(ctx.clock)))
        sync.run(ctx)
    except ChannelItemTooLarge as e:
        res.error = str(e)
        return res
    finally:
        res.ckpt_bytes = ctx.ckpt.wire_bytes
        res.ckpt_time = ctx.ckpt.time_s
        res.ckpt_cost = ctx.ckpt.op_usd

    res.sim_time = float(np.max(ctx.clock))
    res.comm_cost = ctx.comm.service_cost(res.sim_time)
    res.cost = platform.finalize_cost(ctx)
    if rec is not None:
        rec.finalize_clock(ctx.worker_ids, ctx.clock)
    return res
