"""FaaS runtime (LambdaML) -- named entry point per DESIGN.md §5.

The platform adapter lives in :mod:`repro.core.runtimes` (FaaS and IaaS
share the algorithm/partition/metering machinery; keeping them in one module
keeps the "same algorithm both sides" guarantee structural), and the shared
training loops live in the discrete-event engine (:mod:`repro.core.engine`,
DESIGN.md §4) driven by the sync protocols of :mod:`repro.core.sync`.
This module is the documented import surface:

    from repro.core.faas import FaaSRuntime, LIFETIME

Serving reuses the same measured constants: ``KEEP_WARM_S`` (sandbox
warm-pool retention) and ``ServingHooks`` (the per-platform serving
contract, DESIGN.md §14) are re-exported here because the serving simulator
documents its FaaS cold starts as "drawn from core/faas.py".
"""
from repro.core.runtimes import (  # noqa: F401
    FaaSRuntime, KEEP_WARM_S, LIFETIME, LIFETIME_MARGIN, RunResult,
    ServingHooks, interp_startup,
)
