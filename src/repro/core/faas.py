"""FaaS runtime (LambdaML) -- named entry point per DESIGN.md §5.

The platform adapter lives in :mod:`repro.core.runtimes` (FaaS and IaaS
share the algorithm/partition/metering machinery; keeping them in one module
keeps the "same algorithm both sides" guarantee structural), and the shared
training loops live in the discrete-event engine (:mod:`repro.core.engine`,
DESIGN.md §4) driven by the sync protocols of :mod:`repro.core.sync`.
This module is the documented import surface:

    from repro.core.faas import FaaSRuntime, LIFETIME
"""
from repro.core.runtimes import (  # noqa: F401
    FaaSRuntime, LIFETIME, LIFETIME_MARGIN, RunResult, interp_startup,
)
