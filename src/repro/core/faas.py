"""FaaS runtime (LambdaML) -- named entry point per DESIGN.md §5.

The implementation lives in :mod:`repro.core.runtimes` (FaaS and IaaS share
the algorithm/partition/metering machinery; keeping them in one module keeps
the "same algorithm both sides" guarantee structural).  This module is the
documented import surface:

    from repro.core.faas import FaaSRuntime, LIFETIME
"""
from repro.core.runtimes import (  # noqa: F401
    FaaSRuntime, LIFETIME, LIFETIME_MARGIN, RunResult, interp_startup,
)
