"""FaaS and IaaS training runtimes (paper §3.3, §5).

Both runtimes execute the REAL optimization math in JAX (identical numerics,
so FaaS and IaaS converge identically for the same algorithm -- the paper's
statistical/system efficiency split) while metering simulated wall-clock and
dollars from the measured constants of Tables 2/6 and the pricing model.

FaaS specifics implemented here:
- starter->worker hierarchical invocation (startup t^F(w)),
- 15-minute worker lifetime: checkpoint to the channel + re-invocation,
- BSP via the two-phase merge/update pattern, ASP via SIREN-style global
  model overwrite (event-driven, stale reads emerge naturally),
- straggler injection + optional backup-invocation mitigation,
- pure-FaaS channels (S3/Memcached/Redis/DynamoDB) or hybrid VM-PS.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

from repro.core import cost as pricing
from repro.core.algorithms import Algorithm
from repro.core.channels import (
    ChannelItemTooLarge, StorageChannel, VMParameterServer, nbytes,
)
from repro.core.mlmodels import StudyModel, model_bytes
from repro.core.patterns import PATTERNS
from repro.data.synthetic import Dataset, partition

# Table 6 startup constants (seconds) -- linear interpolation between points
_T_FAAS = {1: 1.2, 10: 1.2, 50: 11.0, 100: 18.0, 200: 35.0, 300: 50.0}
_T_IAAS = {1: 100.0, 10: 132.0, 50: 160.0, 100: 292.0, 200: 606.0}
B_S3 = 65e6
L_S3 = 8e-2
B_NET = {"t2.medium": 120e6, "c5.large": 225e6, "c5.xlarge": 600e6,
         "t2.2xlarge": 120e6, "c5.4xlarge": 1250e6, "m5a.12xlarge": 1250e6,
         "g3s.xlarge": 1250e6, "g4dn.xlarge": 1250e6}
L_NET = {"t2.medium": 5e-4, "c5.large": 1.5e-4}

LIFETIME = 900.0          # Lambda max duration (s)
LIFETIME_MARGIN = 20.0


def interp_startup(table: dict, w: int) -> float:
    ks = sorted(table)
    if w <= ks[0]:
        return table[ks[0]]
    for a, b in zip(ks, ks[1:]):
        if w <= b:
            f = (w - a) / (b - a)
            return table[a] + f * (table[b] - table[a])
    return table[ks[-1]] * w / ks[-1]


@dataclass
class RunResult:
    system: str
    algorithm: str
    workers: int
    history: list = field(default_factory=list)   # [(sim_time_s, loss)]
    rounds: int = 0
    sim_time: float = 0.0
    cost: float = 0.0
    breakdown: dict = field(default_factory=dict)
    converged: bool = False
    error: str = ""

    @property
    def final_loss(self) -> float:
        return self.history[-1][1] if self.history else float("nan")

    def to_dict(self):
        return {"system": self.system, "algorithm": self.algorithm,
                "workers": self.workers, "rounds": self.rounds,
                "sim_time_s": round(self.sim_time, 2),
                "cost_usd": round(self.cost, 4),
                "final_loss": self.final_loss,
                "converged": self.converged,
                "breakdown": {k: round(v, 2) for k, v in self.breakdown.items()},
                "error": self.error}


def _speeds(w: int, straggler: float, seed: int = 0) -> np.ndarray:
    """Per-worker relative compute slowdown (1.0 = nominal)."""
    rng = np.random.default_rng(seed)
    s = np.exp(rng.normal(0.0, 0.05, w))
    if straggler > 1.0:
        s[rng.integers(0, w)] *= straggler
    return s


@dataclass
class FaaSRuntime:
    """LambdaML."""
    workers: int = 10
    channel: str = "s3"                  # s3|memcached|redis|dynamodb|vmps
    pattern: str = "allreduce"           # allreduce|scatter_reduce
    sync: str = "bsp"                    # bsp|asp
    lambda_gb: float = 3.0
    straggler: float = 1.0
    backup_invocations: bool = False     # straggler mitigation (beyond paper)
    lifetime: float = LIFETIME
    seed: int = 0

    def worker_flops(self) -> float:
        return (pricing.LAMBDA_3GB_FLOPS if self.lambda_gb >= 3.0
                else pricing.LAMBDA_1GB_FLOPS)

    def train(self, model: StudyModel, algo: Algorithm, ds_train: Dataset,
              ds_val: Dataset, *, target_loss: float | None = None,
              max_epochs: int = 10, eval_every: int = 1) -> RunResult:
        import jax

        w = self.workers
        res = RunResult("faas", algo.name, w)
        parts = partition(ds_train, w)
        params0 = model.init(jax.random.key(self.seed))
        states = [algo.init_worker(model, params0, p) for p in parts]
        part_bytes = max(p.nbytes for p in parts)
        mbytes = model_bytes(params0)
        if 4 * mbytes * self.lambda_gb == 0 or mbytes > self.lambda_gb * 1e9 / 3:
            res.error = "model exceeds Lambda memory"
            return res
        speeds = _speeds(w, self.straggler, self.seed)
        if self.backup_invocations:
            # backup lambda races the straggler; effective speed = min(x, p50)
            speeds = np.minimum(speeds, np.median(speeds))

        hybrid = self.channel == "vmps"
        chan = StorageChannel("s3" if hybrid else self.channel)
        ps = VMParameterServer() if hybrid else None

        t_start = interp_startup(_T_FAAS, w)
        if hybrid:
            t_start = max(t_start, ps.startup)
        t_start = max(t_start, chan.spec.startup)
        t_load = L_S3 + part_bytes / B_S3
        clock = np.full(w, t_start + t_load)
        res.breakdown = {"startup": t_start, "load": t_load,
                         "compute": 0.0, "comm": 0.0, "checkpoint": 0.0}
        invoked_at = clock.copy()
        invocations = w
        flops = self.worker_flops()
        rows = algo.rows_per_round(parts[0])
        c_round = rows * model.flops_per_row / flops

        if self.sync == "asp":
            return self._train_asp(model, algo, states, parts, ds_val, chan,
                                   res, clock, c_round, speeds, target_loss,
                                   max_epochs, invocations)

        rpe = algo.rounds_per_epoch(parts[0])
        epoch_rows = parts[0].n
        total_rounds = max_epochs * rpe * max(1, algo.rows_per_round(parts[0])
                                              // max(epoch_rows, 1)) \
            if algo.name == "ga_sgd" else max_epochs
        if algo.name == "ga_sgd":
            total_rounds = max_epochs * rpe

        try:
            for rnd in range(total_rounds):
                # lifetime management: checkpoint + re-invoke if needed
                est = c_round * float(np.max(speeds)) + 5.0
                for i in range(w):
                    if clock[i] - invoked_at[i] + est > self.lifetime - LIFETIME_MARGIN:
                        dt = chan.put(f"ckpt/{i}", np.zeros(mbytes // 4,
                                                            np.float32))
                        restart = interp_startup(_T_FAAS, 1)
                        _, dtg = chan.get(f"ckpt/{i}")
                        clock[i] += dt + restart + dtg
                        res.breakdown["checkpoint"] += dt + restart + dtg
                        invoked_at[i] = clock[i]
                        invocations += 1

                updates = [algo.local_update(model, st, rnd) for st in states]
                c = c_round * speeds
                clock += c
                res.breakdown["compute"] += float(np.mean(c))
                if hybrid:
                    size = updates[0].nbytes
                    dt = ps.push_pull_round(size, w)
                    merged = np.mean(updates, axis=0)
                    clock += dt
                    res.breakdown["comm"] += dt
                else:
                    merged, times = PATTERNS[self.pattern](
                        chan, updates, f"r{rnd}")
                    base = float(np.max(clock))  # BSP barrier
                    res.breakdown["comm"] += float(np.mean(times))
                    clock = base + times
                for st in states:
                    algo.apply_merged(model, st, merged, w)
                res.rounds += 1
                if rnd % eval_every == 0 or rnd == total_rounds - 1:
                    loss = model.eval_loss(algo.eval_params(states[0]), ds_val)
                    res.history.append((float(np.max(clock)), loss))
                    if target_loss is not None and loss <= target_loss:
                        res.converged = True
                        break
        except ChannelItemTooLarge as e:
            res.error = str(e)
            return res

        res.sim_time = float(np.max(clock))
        res.cost = (pricing.lambda_cost(self.lambda_gb,
                                        float(np.sum(clock)), invocations)
                    + chan.service_cost(res.sim_time)
                    + (pricing.ec2_cost(ps.instance, res.sim_time)
                       if hybrid else 0.0))
        return res

    # ---------------------------------------------------------------- ASP ----
    def _train_asp(self, model, algo, states, parts, ds_val, chan, res,
                   clock, c_round, speeds, target_loss, max_epochs,
                   invocations):
        """SIREN-style: one global model on storage, workers run free."""
        import jax
        from jax.flatten_util import ravel_pytree

        w = self.workers
        flat0, unravel = ravel_pytree(states[0].params)
        chan.put("global", np.asarray(flat0, np.float32))
        rpe = algo.rounds_per_epoch(parts[0])
        total = max_epochs * rpe * w
        heap = [(clock[i], i) for i in range(w)]
        heapq.heapify(heap)
        done = 0
        while done < total:
            t, i = heapq.heappop(heap)
            g_flat, dt1 = chan.get("global")
            states[i].params = unravel(g_flat)
            upd = algo.local_update(model, states[i], done)
            # SGD step on the (possibly stale) global model
            T = max(done // (rpe * w), 1)
            lr = algo.lr / np.sqrt(T)  # 1/sqrt(T) decay (paper §4.5)
            new = g_flat - lr * upd
            dt2 = chan.put("global", new.astype(np.float32))
            c = c_round * speeds[i]
            t += dt1 + c + dt2
            res.breakdown["comm"] += dt1 + dt2
            res.breakdown["compute"] += c / w
            heapq.heappush(heap, (t, i))
            done += 1
            res.rounds = done
            if done % (w * max(rpe // 4, 1)) == 0 or done == total:
                cur, _ = chan.get("global")
                loss = model.eval_loss(unravel(cur), ds_val)
                res.history.append((t, loss))
                if target_loss is not None and loss <= target_loss:
                    res.converged = True
                    break
        res.sim_time = max(t for t, _ in heap) if heap else 0.0
        res.cost = (pricing.lambda_cost(self.lambda_gb, res.sim_time * w,
                                        invocations)
                    + chan.service_cost(res.sim_time))
        return res


@dataclass
class IaaSRuntime:
    """Distributed-PyTorch-style VM cluster (strong IaaS baseline)."""
    workers: int = 10
    instance: str = "t2.medium"
    gpu: bool = False
    straggler: float = 1.0
    seed: int = 0

    def worker_flops(self, model: StudyModel) -> float:
        if self.gpu and not model.convex:
            return pricing.VM_GPU_FLOPS.get(self.instance, 150e9)
        return pricing.VM_CPU_FLOPS

    def train(self, model: StudyModel, algo: Algorithm, ds_train: Dataset,
              ds_val: Dataset, *, target_loss: float | None = None,
              max_epochs: int = 10, eval_every: int = 1,
              data_local: bool = False) -> RunResult:
        import jax

        w = self.workers
        res = RunResult("iaas" + ("-gpu" if self.gpu else ""), algo.name, w)
        parts = partition(ds_train, w)
        params0 = model.init(jax.random.key(self.seed))
        states = [algo.init_worker(model, params0, p) for p in parts]
        mbytes = model_bytes(params0)
        speeds = _speeds(w, self.straggler, self.seed)
        bn = B_NET.get(self.instance, 120e6)
        ln = L_NET.get(self.instance, 5e-4)

        t_start = interp_startup(_T_IAAS, w)
        part_bytes = max(p.nbytes for p in parts)
        t_load = part_bytes / (B_NET[self.instance] if data_local else B_S3)
        clock = np.full(w, t_start + t_load)
        res.breakdown = {"startup": t_start, "load": t_load,
                         "compute": 0.0, "comm": 0.0}
        flops = self.worker_flops(model)
        rows = algo.rows_per_round(parts[0])
        c_round = rows * model.flops_per_row / flops
        rpe = algo.rounds_per_epoch(parts[0])
        total_rounds = max_epochs * rpe

        for rnd in range(total_rounds):
            updates = [algo.local_update(model, st, rnd) for st in states]
            merged = np.mean(updates, axis=0)
            c = c_round * speeds
            # MPI AllReduce (paper model): (2w-2) * (m/w/Bn + Ln)
            t_comm = (2 * w - 2) * (updates[0].nbytes / w / bn + ln) if w > 1 else 0.0
            clock = float(np.max(clock + c)) + t_comm
            clock = np.full(w, clock)
            res.breakdown["compute"] += float(np.mean(c))
            res.breakdown["comm"] += t_comm
            for st in states:
                algo.apply_merged(model, st, merged, w)
            res.rounds += 1
            if rnd % eval_every == 0 or rnd == total_rounds - 1:
                loss = model.eval_loss(algo.eval_params(states[0]), ds_val)
                res.history.append((float(np.max(clock)), loss))
                if target_loss is not None and loss <= target_loss:
                    res.converged = True
                    break

        res.sim_time = float(np.max(clock))
        res.cost = pricing.ec2_cost(self.instance, res.sim_time, w)
        return res
