"""FaaS and IaaS training runtimes (paper §3.3, §5; DESIGN.md §5).

Both runtimes execute the REAL optimization math in JAX (identical numerics,
so FaaS and IaaS converge identically for the same algorithm -- the paper's
statistical/system efficiency split) while metering simulated wall-clock and
dollars from the measured constants of Tables 2/6 and the pricing model.

Since the engine refactor (DESIGN.md §4) the classes here are *platform
adapters*: dataclass configs that hand the discrete-event engine
(:mod:`repro.core.engine`) their startup/load/restart timings, worker fleet
shape, communication backend, failure process, and cost model.  The training
loops themselves -- one BSP round loop and one ASP/SSP event loop -- live in
:mod:`repro.core.sync` and are shared by every platform.

FaaS specifics (LambdaML):
- starter->worker hierarchical invocation (startup t^F(w)),
- 15-minute worker lifetime: checkpoint to the channel + re-invocation,
- BSP via the two-phase merge/update pattern, ASP/SSP via SIREN-style global
  model on the channel (event-driven, stale reads emerge naturally),
- straggler injection + optional backup-invocation mitigation,
- pure-FaaS channels (S3/Memcached/Redis/DynamoDB) or hybrid VM-PS,
- heterogeneous fleets: per-worker Lambda memory sizes (``lambda_gb`` tuple).

IaaS specifics (distributed-PyTorch-style VM cluster):
- ring AllReduce over VM NICs; worker 0 hosts the ASP/SSP model store,
- spot fleets (``spot=True``): preemption events (Poisson or injected) +
  restart-from-checkpoint via S3, discounted hourly pricing,
- heterogeneous fleets: per-worker instance types (``instance`` tuple);
  the collective runs at the slowest NIC.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core import cost as pricing
from repro.core.channels import StorageChannel, VMNetwork, VMParameterServer
from repro.core.engine import (  # noqa: F401  (RunResult re-exported)
    ChannelComm, FailureProcess, InjectedPreemptions, MPIComm, PoissonPreemptions,
    PSComm, RunResult, StragglerProcess, simulate,
)

# Table 6 startup constants (seconds) -- linear interpolation between points
_T_FAAS = {1: 1.2, 10: 1.2, 50: 11.0, 100: 18.0, 200: 35.0, 300: 50.0}
_T_IAAS = {1: 100.0, 10: 132.0, 50: 160.0, 100: 292.0, 200: 606.0}
B_S3 = 65e6
L_S3 = 8e-2
B_NET = {"t2.medium": 120e6, "c5.large": 225e6, "c5.xlarge": 600e6,
         "t2.2xlarge": 120e6, "c5.4xlarge": 1250e6, "m5a.12xlarge": 1250e6,
         "g3s.xlarge": 1250e6, "g4dn.xlarge": 1250e6}
L_NET = {"t2.medium": 5e-4, "c5.large": 1.5e-4}

LIFETIME = 900.0          # Lambda max duration (s)
LIFETIME_MARGIN = 20.0


def interp_startup(table: dict, w: int) -> float:
    ks = sorted(table)
    if w <= ks[0]:
        return table[ks[0]]
    for a, b in zip(ks, ks[1:]):
        if w <= b:
            f = (w - a) / (b - a)
            return table[a] + f * (table[b] - table[a])
    return table[ks[-1]] * w / ks[-1]


def _per_worker(value, w: int) -> np.ndarray:
    """Broadcast a scalar or validate a per-worker sequence of length w."""
    if np.isscalar(value) or isinstance(value, str):
        return np.asarray([value] * w)
    arr = np.asarray(value)
    if len(arr) != w:
        raise ValueError(f"per-worker config has {len(arr)} entries, "
                         f"expected {w}")
    return arr


def _make_failure(rate: float, at: tuple, workers: int,
                  seed: int) -> FailureProcess:
    if at:
        return InjectedPreemptions(tuple(at))
    if rate > 0.0:
        return PoissonPreemptions(rate, workers, seed)
    return FailureProcess()


@dataclass
class FaaSRuntime:
    """LambdaML (platform adapter for the discrete-event engine)."""
    workers: int = 10
    channel: str = "s3"                  # s3|memcached|redis|dynamodb|vmps
    pattern: str = "allreduce"           # allreduce|scatter_reduce
    sync: object = "bsp"                 # bsp|asp|ssp|ssp:<s>|SyncProtocol
    lambda_gb: object = 3.0              # scalar or per-worker sizes (hetero)
    straggler: float = 1.0
    backup_invocations: bool = False     # straggler mitigation (beyond paper)
    lifetime: float = LIFETIME
    seed: int = 0
    preempt_rate: float = 0.0            # worker crashes per worker-hour
    preempt_at: tuple = ()               # injected (worker, sim_time) kills

    # ---- user entry point ---------------------------------------------------
    def train(self, model, algo, ds_train, ds_val, *,
              target_loss: float | None = None, max_epochs: int = 10,
              eval_every: int = 1) -> RunResult:
        from repro.core.sync import make_sync
        return simulate(self, make_sync(self.sync), model, algo,
                        ds_train, ds_val, target_loss=target_loss,
                        max_epochs=max_epochs, eval_every=eval_every)

    # ---- fleet shape --------------------------------------------------------
    def _gb_array(self) -> np.ndarray:
        return _per_worker(self.lambda_gb, self.workers).astype(float)

    def worker_flops(self) -> float:
        """Slowest worker's FLOP/s (scalar convenience over the array)."""
        return float(np.min(self.worker_flops_array(None)))

    def worker_flops_array(self, model) -> np.ndarray:
        gb = self._gb_array()
        return np.where(gb >= 3.0, pricing.LAMBDA_3GB_FLOPS,
                        pricing.LAMBDA_1GB_FLOPS)

    def worker_speeds(self) -> np.ndarray:
        return StragglerProcess(
            factor=self.straggler,
            cap_at_median=self.backup_invocations).speeds(self.workers,
                                                          self.seed)

    # ---- engine hooks -------------------------------------------------------
    def system_name(self) -> str:
        return "faas"

    def validate(self, mbytes: int) -> str:
        gb_min = float(np.min(self._gb_array()))
        if 4 * mbytes * gb_min == 0 or mbytes > gb_min * 1e9 / 3:
            return "model exceeds Lambda memory"
        return ""

    def make_comm(self):
        if self.channel == "vmps":
            return PSComm(VMParameterServer(), StorageChannel("s3"))
        return ChannelComm(StorageChannel(self.channel), self.pattern)

    def make_ckpt_store(self, comm):
        return comm.chan          # FaaS comm is always ChannelComm or PSComm

    def startup_time(self, comm) -> float:
        t = interp_startup(_T_FAAS, self.workers)
        if isinstance(comm, PSComm):
            t = max(t, comm.ps.startup)
        if isinstance(comm, ChannelComm):
            t = max(t, comm.chan.spec.startup)
        return t

    def load_time(self, part_bytes: int, data_local: bool = False) -> float:
        return L_S3 + part_bytes / B_S3

    def restart_time(self) -> float:
        return interp_startup(_T_FAAS, 1)

    def lifetime_s(self) -> float:
        return self.lifetime

    def lifetime_margin_s(self) -> float:
        return LIFETIME_MARGIN

    def failure_process(self) -> FailureProcess:
        return _make_failure(self.preempt_rate, self.preempt_at,
                             self.workers, self.seed)

    def init_breakdown(self) -> dict:
        return {"startup": 0.0, "load": 0.0, "compute": 0.0, "comm": 0.0,
                "checkpoint": 0.0}

    def finalize_cost(self, ctx) -> float:
        gb_seconds = float(np.dot(self._gb_array(), ctx.clock))
        sim_time = float(np.max(ctx.clock))
        return (gb_seconds * pricing.LAMBDA_GB_S
                + ctx.invocations * pricing.LAMBDA_REQUEST
                + ctx.comm.service_cost(sim_time))


@dataclass
class IaaSRuntime:
    """Distributed-PyTorch-style VM cluster (strong IaaS baseline)."""
    workers: int = 10
    instance: object = "t2.medium"       # scalar or per-worker types (hetero)
    gpu: bool = False
    straggler: float = 1.0
    seed: int = 0
    sync: object = "bsp"                 # bsp|asp|ssp|ssp:<s>|SyncProtocol
    spot: bool = False                   # preemptible fleet + discounted $
    preempt_rate: float = 2.0            # preemptions per worker-hour (spot)
    preempt_at: tuple = ()               # injected (worker, sim_time) kills
    ckpt_channel: str = "s3"             # where spot checkpoints live

    # ---- user entry point ---------------------------------------------------
    def train(self, model, algo, ds_train, ds_val, *,
              target_loss: float | None = None, max_epochs: int = 10,
              eval_every: int = 1, data_local: bool = False) -> RunResult:
        from repro.core.sync import make_sync
        return simulate(self, make_sync(self.sync), model, algo,
                        ds_train, ds_val, target_loss=target_loss,
                        max_epochs=max_epochs, eval_every=eval_every,
                        data_local=data_local)

    # ---- fleet shape --------------------------------------------------------
    def _instances(self) -> list[str]:
        return list(_per_worker(self.instance, self.workers))

    def worker_flops(self, model) -> float:
        """Slowest worker's FLOP/s (scalar convenience over the array)."""
        return float(np.min(self.worker_flops_array(model)))

    def worker_flops_array(self, model) -> np.ndarray:
        if self.gpu and not model.convex:
            return np.asarray([pricing.VM_GPU_FLOPS.get(i, 150e9)
                               for i in self._instances()])
        return np.full(self.workers, pricing.VM_CPU_FLOPS)

    def worker_speeds(self) -> np.ndarray:
        return StragglerProcess(factor=self.straggler).speeds(self.workers,
                                                              self.seed)

    # ---- engine hooks -------------------------------------------------------
    def system_name(self) -> str:
        return ("iaas" + ("-gpu" if self.gpu else "")
                + ("-spot" if self.spot else ""))

    def validate(self, mbytes: int) -> str:
        return ""

    def _net(self) -> VMNetwork:
        insts = self._instances()
        bn = min(B_NET.get(i, 120e6) for i in insts)       # slowest NIC
        ln = max(L_NET.get(i, 5e-4) for i in insts)
        return VMNetwork(bn, ln)

    def make_comm(self):
        return MPIComm(self._net())

    def make_ckpt_store(self, comm):
        return StorageChannel(self.ckpt_channel)

    def startup_time(self, comm) -> float:
        return interp_startup(_T_IAAS, self.workers)

    def load_time(self, part_bytes: int, data_local: bool = False) -> float:
        if data_local:
            return part_bytes / min(B_NET.get(i, 120e6)
                                    for i in self._instances())
        return part_bytes / B_S3

    def restart_time(self) -> float:
        return interp_startup(_T_IAAS, 1)

    def lifetime_s(self) -> float:
        return math.inf                  # VMs run until the job ends

    def lifetime_margin_s(self) -> float:
        return 0.0

    def failure_process(self) -> FailureProcess:
        # explicit injections always apply; the Poisson rate (which has a
        # nonzero default) only kicks in for spot fleets
        if self.preempt_at:
            return InjectedPreemptions(tuple(self.preempt_at))
        if self.spot and self.preempt_rate > 0.0:
            return PoissonPreemptions(self.preempt_rate, self.workers,
                                      self.seed)
        return FailureProcess()

    def init_breakdown(self) -> dict:
        return {"startup": 0.0, "load": 0.0, "compute": 0.0, "comm": 0.0}

    def finalize_cost(self, ctx) -> float:
        sim_time = float(np.max(ctx.clock))
        hourly = sum(pricing.EC2_HOURLY[i] for i in self._instances())
        if self.spot:
            hourly *= pricing.SPOT_DISCOUNT
        return (hourly / 3600.0 * sim_time
                + ctx.ckpt_store.service_cost(sim_time))
