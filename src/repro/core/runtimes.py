"""FaaS and IaaS training runtimes (paper §3.3, §5; DESIGN.md §5).

Both runtimes execute the REAL optimization math in JAX (identical numerics,
so FaaS and IaaS converge identically for the same algorithm -- the paper's
statistical/system efficiency split) while metering simulated wall-clock and
dollars from the measured constants of Tables 2/6 and the pricing model.

Since the Platform redesign (DESIGN.md §9) the classes here are *thin
builders* over the composable specs of :mod:`repro.core.platform`:
:class:`~repro.core.platform.FleetSpec` (workers, per-worker Lambda memory
or instance types, stragglers), :class:`~repro.core.platform.FailureSpec`
(Poisson rate / injected kills / spot pricing) and
:class:`~repro.core.platform.CommSpec` (channel, reduce pattern).  The
legacy flat keyword constructors (``FaaSRuntime(workers=10, channel="s3")``)
keep working and simply populate the specs; spec objects can also be passed
directly (``FaaSRuntime(fleet=FleetSpec(...), failure=FailureSpec(...))``)
so a hetero/spot/straggler scenario composes with either platform.

Each class implements the platform-specific half of the
:class:`~repro.core.platform.Platform` protocol; the spec-derivable half
(training entry point, fleet speeds, failure processes) lives once in
:class:`~repro.core.platform.BasePlatform`, and the training loops
themselves -- one BSP round loop and one ASP/SSP event loop -- live in
:mod:`repro.core.sync`, shared by every platform.

FaaS specifics (LambdaML):
- starter->worker hierarchical invocation (startup t^F(w)),
- 15-minute worker lifetime: checkpoint to the channel + re-invocation,
- BSP via the two-phase merge/update pattern, ASP/SSP via SIREN-style global
  model on the channel (event-driven, stale reads emerge naturally),
- straggler injection + optional backup-invocation mitigation,
- pure-FaaS channels (S3/Memcached/Redis/DynamoDB) or hybrid VM-PS,
- heterogeneous fleets: per-worker Lambda memory sizes (``lambda_gb`` tuple).

IaaS specifics (distributed-PyTorch-style VM cluster):
- ring AllReduce over VM NICs; worker 0 hosts the ASP/SSP model store,
- spot fleets (``spot=True``): preemption events (Poisson or injected) +
  restart-from-checkpoint via S3, discounted hourly pricing,
- heterogeneous fleets: per-worker instance types (``instance`` tuple);
  the collective runs at the slowest NIC.

Pod specifics (accelerator pods, DESIGN.md §11): one engine worker = one
pod slice; compute from the roofline model applied to the actual workload
config; intra-pod collectives free (inside the MFU), cross-pod DCN as the
metered comm substrate -- see :class:`PodPlatform`.
"""
from __future__ import annotations

import numpy as np

from repro.core import cost as pricing
from repro.core.channels import StorageChannel, VMNetwork, VMParameterServer
from repro.core.ckpt import ckpt_transport_constants, make_ckpt_transport
from repro.core.comm.transports import (
    CHANNEL_SPECS, DCN_BANDWIDTH, DCN_LATENCY, NIC_BANDWIDTH, NIC_LATENCY,
)
from repro.core.engine import (  # noqa: F401  (RunResult re-exported)
    ChannelComm, FailureProcess, InjectedPreemptions, MPIComm, PoissonPreemptions,
    PSComm, RunResult, StragglerProcess, simulate,
)
from repro.core.platform import (  # noqa: F401  (specs re-exported)
    BasePlatform, CommSpec, FailureSpec, FleetSpec, Platform, ServingHooks,
    per_worker,
)

# Table 6 startup constants (seconds) -- see interp_startup for how worker
# counts between and beyond the measured points are handled
_T_FAAS = {1: 1.2, 10: 1.2, 50: 11.0, 100: 18.0, 200: 35.0, 300: 50.0}
_T_IAAS = {1: 100.0, 10: 132.0, 50: 160.0, 100: 292.0, 200: 606.0}
# data-plane S3 constants: the same Table 6 row the "s3" comm transport is
# built from (one source of truth in repro.core.comm.transports)
B_S3 = CHANNEL_SPECS["s3"].bandwidth
L_S3 = CHANNEL_SPECS["s3"].latency
# the t2.medium row doubles as the comm package's "nic" transport default
B_NET = {"t2.medium": NIC_BANDWIDTH, "c5.large": 225e6, "c5.xlarge": 600e6,
         # t2.2xlarge's NIC coincides with the t2.medium row's value but is
         # its own Table 6 measurement, not a copy of NIC_BANDWIDTH:
         "t2.2xlarge": 120e6,  # lint: ignore[C001]
         "c5.4xlarge": 1250e6, "m5a.12xlarge": 1250e6,
         "g3s.xlarge": 1250e6, "g4dn.xlarge": 1250e6}
L_NET = {"t2.medium": NIC_LATENCY, "c5.large": 1.5e-4}

LIFETIME = 900.0          # Lambda max duration (s)
LIFETIME_MARGIN = 20.0
KEEP_WARM_S = 600.0       # Lambda sandbox warm-pool retention (serving)

_per_worker = per_worker  # back-compat alias (pre-Platform name)


def interp_startup(table: dict, w: int) -> float:
    """Startup seconds for a ``w``-worker fleet from a Table 6 column.

    Piecewise-linear interpolation between measured worker counts; below
    the smallest measured count the smallest entry is returned unchanged.
    ABOVE the largest measured count the curve is extrapolated *linearly
    through the origin* from the last point (``t = table[k_max] * w /
    k_max``), i.e. startup is assumed to keep scaling proportionally with
    fleet size at the last measured per-worker rate -- a deliberately
    pessimistic tail for what-if studies beyond the paper's 200-300 worker
    measurements.
    """
    ks = sorted(table)
    if w <= ks[0]:
        return table[ks[0]]
    for a, b in zip(ks, ks[1:]):
        if w <= b:
            f = (w - a) / (b - a)
            return table[a] + f * (table[b] - table[a])
    return table[ks[-1]] * w / ks[-1]


class FaaSRuntime(BasePlatform):
    """LambdaML platform: thin builder over Fleet/Failure/Comm specs.

    Accepts either the legacy flat keywords (``workers=``, ``channel=``,
    ``lambda_gb=``, ``preempt_rate=``, ...) or explicit spec objects
    (``fleet=``, ``failure=``, ``comm=``); a spec object wins over the flat
    keywords it covers.
    """

    def __init__(self, workers: int = 10, channel: str = "s3",
                 pattern: str = "allreduce", sync: object = "bsp",
                 lambda_gb: object = 3.0, straggler: float = 1.0,
                 backup_invocations: bool = False, lifetime: float = LIFETIME,
                 seed: int = 0, preempt_rate: float = 0.0,
                 preempt_at: tuple = (), scaling: object = "static", *,
                 fleet: FleetSpec | None = None,
                 failure: FailureSpec | None = None,
                 comm: CommSpec | None = None,
                 ckpt: object = None):
        super().__init__(
            fleet=fleet if fleet is not None else FleetSpec(
                workers=workers, lambda_gb=lambda_gb, straggler=straggler,
                backup_invocations=backup_invocations),
            failure=failure if failure is not None else FailureSpec(
                rate=preempt_rate, inject=tuple(preempt_at)),
            comm=comm if comm is not None else CommSpec(
                channel=channel, pattern=pattern),
            sync=sync, seed=seed, scaling=scaling, ckpt=ckpt)
        self.lifetime = lifetime

    # ---- legacy flat attributes (read-only views over the specs) ------------
    @property
    def channel(self) -> str:
        return self.comm.channel

    @property
    def pattern(self) -> str:
        return self.comm.pattern

    @property
    def lambda_gb(self):
        return self.fleet.lambda_gb

    @property
    def straggler(self) -> float:
        return self.fleet.straggler

    @property
    def backup_invocations(self) -> bool:
        return self.fleet.backup_invocations

    @property
    def preempt_rate(self) -> float:
        return self.failure.resolved_rate()

    @property
    def preempt_at(self) -> tuple:
        return self.failure.inject

    # ---- fleet shape --------------------------------------------------------
    def worker_flops_array(self, model) -> np.ndarray:
        gb = self.fleet.gb_array()
        return np.where(gb >= 3.0, pricing.LAMBDA_3GB_FLOPS,
                        pricing.LAMBDA_1GB_FLOPS)

    # ---- engine hooks -------------------------------------------------------
    def system_name(self) -> str:
        return "faas"

    def validate(self, mbytes: int) -> str:
        """Memory-headroom check: the model (plus the runtime's working
        copies -- gradients, the merge buffer, serialization) must fit in
        one third of the *smallest* Lambda in the fleet.  GPU fleets are
        rejected outright: AWS Lambda has no GPU offering, so ``gpu=True``
        can only mean a FleetSpec written for IaaS was reused unchanged."""
        if self.fleet.gpu:
            return ("FleetSpec.gpu=True is meaningless on FaaS: AWS Lambda "
                    "has no GPUs (the paper's GPU-FaaS what-if lives in the "
                    "analytical model, core/analytical.py Q2).  Drop gpu "
                    "from the fleet or use platform='iaas'/'pod'")
        gb_min = float(np.min(self.fleet.gb_array()))
        headroom_bytes = gb_min * 1e9 / 3.0
        if mbytes > headroom_bytes:
            return (f"model ({mbytes / 1e6:.1f} MB) exceeds 1/3 of the "
                    f"smallest Lambda's memory ({gb_min:.1f} GB)")
        try:
            # the comm stack's pairing + per-item rules (DynamoDB 400 KB ->
            # Table 1 "N/A") fail here, before any simulated second elapses
            self.comm.validate(platform="faas", model_bytes=mbytes,
                               workers=self.workers)
        except ValueError as e:
            return str(e)
        return ""

    def make_comm(self):
        from repro.core.comm import build_comm_stack
        return build_comm_stack(*self.comm.resolved("faas"))

    def make_ckpt_store(self, comm):
        if self.ckpt.transport is not None:   # dedicated checkpoint channel
            return make_ckpt_transport(self.ckpt.transport)
        return comm.kvstore()     # the storage channel (PSComm: its S3 side)

    def ckpt_channel_spec(self):
        # the default FaaS checkpoint home IS the comm kvstore, so the
        # derived restart reads the resolved comm transport's constants
        if self.ckpt.transport is not None:
            return ckpt_transport_constants(self.ckpt.transport)
        return ckpt_transport_constants(self.comm.resolved("faas")[0])

    def startup_time(self, comm) -> float:
        return max(interp_startup(_T_FAAS, self.workers), comm.startup())

    def load_time(self, part_bytes: int, data_local: bool = False) -> float:
        return L_S3 + part_bytes / B_S3

    def restart_time(self, model_bytes: int = 0) -> float:
        dt = interp_startup(_T_FAAS, 1)
        if model_bytes > 0:       # derived: startup + metered restore
            dt += self.ckpt.restore_seconds(
                model_bytes, self.ckpt_channel_spec(), self.workers)
        return dt

    def lifetime_s(self) -> float:
        return self.lifetime

    def lifetime_margin_s(self) -> float:
        return LIFETIME_MARGIN

    def init_breakdown(self) -> dict:
        return {"startup": 0.0, "load": 0.0, "compute": 0.0, "comm": 0.0,
                "checkpoint": 0.0}

    def finalize_cost(self, ctx) -> float:
        # Lambda bills execution time only: each live worker's clock minus
        # when it was (re-)invoked into the fleet (joined_at == 0 for the
        # whole initial fleet, so fixed fleets bill exactly as before);
        # retired workers' usage was folded into retired_cost on exit
        gb_s = float(np.dot(self.fleet.gb_array(),
                            ctx.clock - ctx.joined_at))
        sim_time = float(np.max(ctx.clock))
        # a DEDICATED checkpoint channel bills its service/op prices on
        # top; the default store is the comm kvstore, already billed above
        ckpt_usd = (ctx.ckpt_store.service_cost(sim_time)
                    if self.ckpt.transport is not None else 0.0)
        usd_gb_s = gb_s * pricing.LAMBDA_GB_S
        usd_req = ctx.invocations * pricing.LAMBDA_REQUEST
        usd_comm = ctx.comm.service_cost(sim_time)
        if ctx.rec is not None:
            # invariant 2 ledger (DESIGN.md §18): each additive term, in
            # the summation order, so the sequential ledger sum is bitwise
            # the return value; reset because mid-run telemetry snapshots
            # call finalize_cost too and only the last call's ledger counts
            ctx.rec.cost_reset()
            ctx.rec.cost("lambda_gb_s", usd_gb_s)
            ctx.rec.cost("requests", usd_req)
            ctx.rec.cost("comm_service", usd_comm)
            ctx.rec.cost("retired", ctx.retired_cost)
            ctx.rec.cost("ckpt_service", ckpt_usd)
        return usd_gb_s + usd_req + usd_comm + ctx.retired_cost + ckpt_usd

    # ---- elastic-fleet hooks (DESIGN.md §13) --------------------------------
    def resize_cost(self, added: int) -> tuple:
        """Joiners are re-invoked like any fleet of ``added`` Lambdas:
        hierarchical-invocation startup seconds (Table 6) plus the request
        fees and the GB-seconds burned while starting (reported for the
        timeline; the $ themselves flow through invocations/clock)."""
        dt = interp_startup(_T_FAAS, added)
        gb = float(self.fleet.gb_array()[0])
        usd = added * (pricing.LAMBDA_REQUEST + gb * dt * pricing.LAMBDA_GB_S)
        return dt, usd

    def retire_cost(self, ctx, idx) -> float:
        gb = self.fleet.gb_array()[idx]
        return (float(np.dot(gb, ctx.clock[idx] - ctx.joined_at[idx]))
                * pricing.LAMBDA_GB_S)

    # ---- serving hooks (DESIGN.md §14) --------------------------------------
    def serving_hooks(self) -> ServingHooks:
        """Request-billed serving: one Lambda per in-flight request, the
        sandbox invoke curve as the cold start, S3 as the weight store."""
        if isinstance(self.fleet.lambda_gb, tuple):
            raise ValueError("serving needs a homogeneous fleet: per-worker "
                             "lambda_gb tuples cannot autoscale")
        gb = float(self.fleet.gb_array()[0])
        if self.ckpt.transport is not None:   # weights live where ckpts do
            ch = ckpt_transport_constants(self.ckpt.transport)
            load_bw, load_lat = ch.bandwidth, ch.latency
        else:
            load_bw, load_lat = B_S3, L_S3
        return ServingHooks(
            system="faas", billing="request",
            flops=float(self.worker_flops_array(None)[0]),
            memory_bytes=gb * 1e9,
            mem_bandwidth=pricing.LAMBDA_MEM_BW,
            gb=gb, gb_s_usd=pricing.LAMBDA_GB_S,
            request_fee_usd=pricing.LAMBDA_REQUEST,
            keep_warm_s=KEEP_WARM_S,
            cold_start_s=self.restart_time(),
            load_bandwidth=load_bw, load_latency=load_lat,
            load_shards=self.ckpt.shards(self.workers))


class IaaSRuntime(BasePlatform):
    """Distributed-PyTorch-style VM cluster: thin builder over the specs.

    Accepts the legacy flat keywords (``workers=``, ``instance=``,
    ``spot=``, ``preempt_rate=``, ...) or explicit spec objects; a spec
    object wins over the flat keywords it covers.  The Poisson preemption
    rate (default 2/worker-hour) only arms on spot fleets; injected kills
    always apply.
    """

    def __init__(self, workers: int = 10, instance: object = "t2.medium",
                 gpu: bool = False, straggler: float = 1.0, seed: int = 0,
                 sync: object = "bsp", spot: bool = False,
                 preempt_rate: float = 2.0, preempt_at: tuple = (),
                 ckpt_channel: str = "s3", scaling: object = "static", *,
                 fleet: FleetSpec | None = None,
                 failure: FailureSpec | None = None,
                 comm: CommSpec | None = None,
                 ckpt: object = None):
        super().__init__(
            fleet=fleet if fleet is not None else FleetSpec(
                workers=workers, instance=instance, gpu=gpu,
                straggler=straggler),
            failure=failure if failure is not None else FailureSpec(
                rate=preempt_rate, inject=tuple(preempt_at), spot=spot),
            comm=comm if comm is not None else CommSpec(
                ckpt_channel=ckpt_channel),
            sync=sync, seed=seed, scaling=scaling, ckpt=ckpt)

    # ---- legacy flat attributes (read-only views over the specs) ------------
    @property
    def instance(self):
        return self.fleet.instance

    @property
    def gpu(self) -> bool:
        return self.fleet.gpu

    @property
    def straggler(self) -> float:
        return self.fleet.straggler

    @property
    def spot(self) -> bool:
        return self.failure.spot

    @property
    def preempt_rate(self) -> float:
        return self.failure.resolved_rate(self.SPOT_DEFAULT_RATE)

    @property
    def preempt_at(self) -> tuple:
        return self.failure.inject

    @property
    def ckpt_channel(self) -> str:
        return self.comm.ckpt_channel

    # ---- fleet shape --------------------------------------------------------
    def worker_flops_array(self, model) -> np.ndarray:
        # With no model to inspect, a GPU fleet reports GPU FLOP/s (the
        # capability estimate); with a model, convex workloads fall back to
        # CPU speed -- the paper's NN-only GPU rule.
        if self.fleet.gpu and (model is None or not model.convex):
            return np.asarray([pricing.VM_GPU_FLOPS.get(
                                   i, pricing.VM_GPU_FLOPS_DEFAULT)
                               for i in self.fleet.instances()])
        return np.full(self.workers, pricing.VM_CPU_FLOPS)

    # ---- engine hooks -------------------------------------------------------
    def system_name(self) -> str:
        return ("iaas" + ("-gpu" if self.fleet.gpu else "")
                + ("-spot" if self.failure.spot else ""))

    def _net(self) -> VMNetwork:
        insts = self.fleet.instances()
        bn = min(B_NET.get(i, NIC_BANDWIDTH) for i in insts)  # slowest NIC
        ln = max(L_NET.get(i, 5e-4) for i in insts)
        return VMNetwork(bn, ln)

    def make_comm(self):
        from repro.core.comm import build_comm_stack
        return build_comm_stack(*self.comm.resolved("iaas"), nic=self._net())

    def make_ckpt_store(self, comm):
        if self.ckpt.transport is not None:   # dedicated checkpoint channel
            return make_ckpt_transport(self.ckpt.transport)
        return StorageChannel(self.comm.ckpt_channel)

    def startup_time(self, comm) -> float:
        # NICs add nothing; a pinned storage/PS stack waits for its service
        # to provision, exactly as on FaaS
        return max(interp_startup(_T_IAAS, self.workers), comm.startup())

    def load_time(self, part_bytes: int, data_local: bool = False) -> float:
        if data_local:
            return part_bytes / min(B_NET.get(i, NIC_BANDWIDTH)
                                    for i in self.fleet.instances())
        return part_bytes / B_S3

    def restart_time(self, model_bytes: int = 0) -> float:
        dt = interp_startup(_T_IAAS, 1)
        if model_bytes > 0:       # derived: startup + metered restore
            dt += self.ckpt.restore_seconds(
                model_bytes, self.ckpt_channel_spec(), self.workers)
        return dt

    #: default spot-market preemption rate (per worker-hour) when the
    #: FailureSpec leaves ``rate=None``
    SPOT_DEFAULT_RATE = 2.0

    def failure_process(self) -> FailureProcess:
        # injected kills always apply; the Poisson rate (spot-market
        # default when unset) only arms on spot fleets
        return self.failure.process(self.workers, self.seed,
                                    armed=self.failure.spot,
                                    default_rate=self.SPOT_DEFAULT_RATE)

    def _hourly_total(self) -> float:
        """The fleet's (spot-discounted) $/hour -- the ONE derivation the
        bill uses; kept as sum-then-discount so fixed-fleet costs stay
        byte-identical to the pre-elastic expression."""
        hourly = sum(pricing.EC2_HOURLY[i] for i in self.fleet.instances())
        if self.failure.spot:
            hourly *= self.failure.spot_discount
        return hourly

    def _hourly_array(self) -> np.ndarray:
        """Per-worker split of :meth:`_hourly_total` (elastic rebates and
        retirements only -- both are no-ops on fixed fleets)."""
        rates = np.asarray([pricing.EC2_HOURLY[i]
                            for i in self.fleet.instances()])
        if self.failure.spot:
            rates = rates * self.failure.spot_discount
        return rates

    def finalize_cost(self, ctx) -> float:
        sim_time = float(np.max(ctx.clock))
        hourly = self._hourly_total()
        # elastic joiners are only billed from when they were provisioned:
        # subtract the pre-join span (0.0 for fixed fleets, keeping the
        # seed-era expression byte-identical); retired VMs were billed into
        # retired_cost when they left the fleet
        joined_rebate = float(np.dot(self._hourly_array(),
                                     ctx.joined_at)) / 3600.0
        # comm substrate dollars: $0 for the default NIC ring, but a pinned
        # storage/PS stack bills its hourly + per-op prices like on FaaS
        usd_vm = hourly / 3600.0 * sim_time
        usd_ckpt = ctx.ckpt_store.service_cost(sim_time)
        usd_comm = ctx.comm.service_cost(sim_time)
        if ctx.rec is not None:
            # invariant 2 ledger (DESIGN.md §18): the rebate enters as a
            # negative entry -- IEEE a - b == a + (-b), so the sequential
            # ledger sum is bitwise the return value
            ctx.rec.cost_reset()
            ctx.rec.cost("vm_hours", usd_vm)
            ctx.rec.cost("joined_rebate", -joined_rebate)
            ctx.rec.cost("retired", ctx.retired_cost)
            ctx.rec.cost("ckpt_service", usd_ckpt)
            ctx.rec.cost("comm_service", usd_comm)
        return (usd_vm - joined_rebate
                + ctx.retired_cost + usd_ckpt + usd_comm)

    # ---- elastic-fleet hooks (DESIGN.md §13) --------------------------------
    def resize_cost(self, added: int) -> tuple:
        """Provisioning an ``added``-VM extension follows the same Table 6
        cluster-startup curve as the initial fleet; the reported $ is the
        provisioning time billed at the (spot-discounted) hourly rate."""
        dt = interp_startup(_T_IAAS, added)
        usd = added * float(self._hourly_array()[0]) * dt / 3600.0
        return dt, usd

    def retire_cost(self, ctx, idx) -> float:
        span = ctx.clock[idx] - ctx.joined_at[idx]
        return float(np.dot(self._hourly_array()[idx], span)) / 3600.0

    # ---- serving hooks (DESIGN.md §14) --------------------------------------
    def serving_hooks(self) -> ServingHooks:
        """Provisioned serving: hourly-billed VM replicas, Table 6 cluster
        bring-up as the provisioning curve, S3 as the weight store.  GPU
        fleets serve from device memory at device bandwidth."""
        if isinstance(self.fleet.instance, tuple):
            raise ValueError("serving needs a homogeneous fleet: per-worker "
                             "instance tuples cannot autoscale")
        inst = str(self.fleet.instances()[0])
        if self.fleet.gpu:
            mem_gb = pricing.GPU_HBM_GB.get(inst, 16.0)
            mem_bw = pricing.VM_GPU_MEM_BW.get(
                inst, pricing.VM_GPU_MEM_BW_DEFAULT)
        else:
            mem_gb = pricing.EC2_RAM_GB.get(inst, 4.0)
            mem_bw = pricing.VM_MEM_BW
        if self.ckpt.transport is not None:   # weights live where ckpts do
            ch = ckpt_transport_constants(self.ckpt.transport)
            load_bw, load_lat = ch.bandwidth, ch.latency
        else:
            load_bw, load_lat = B_S3, 0.0
        return ServingHooks(
            system=self.system_name(), billing="provisioned",
            flops=float(self.worker_flops_array(None)[0]),
            memory_bytes=mem_gb * 1e9, mem_bandwidth=mem_bw,
            hourly_usd=float(self._hourly_array()[0]),
            cold_start_s=self.restart_time(),
            load_bandwidth=load_bw, load_latency=load_lat,
            load_shards=self.ckpt.shards(self.workers),
            provision_table=tuple(sorted(_T_IAAS.items())))


# --------------------------------------------------------------- pods -------

#: pod-slice provisioning seconds by slice count (queue + topology bring-up;
#: same interp_startup convention as the Table 6 columns)
_T_POD = {1: 45.0, 4: 75.0, 16: 120.0, 64: 240.0}

#: cross-pod data-center network: per-pod egress bandwidth and latency
#: (the shared repro.core.comm "dcn" transport constants).  Intra-pod ICI
#: is NOT metered here -- collectives inside a pod ride the compute term
#: (they are part of the MFU discount), which is exactly the
#: slow-channel/fast-compute split the paper studies on FaaS.
POD_DCN_BANDWIDTH = DCN_BANDWIDTH  # bytes/s per pod
POD_DCN_LATENCY = DCN_LATENCY      # s per collective phase


class PodPlatform(BasePlatform):
    """Accelerator pods: the third infrastructure (DESIGN.md §11).

    Each engine "worker" is one pod slice of ``chips_per_pod`` chips.  The
    per-round compute time comes from the roofline model of
    :mod:`repro.distributed.roofline` applied to the actual workload config:
    the engine divides ``rows x workload.flops_per_row`` (``6 N D`` for a
    real :class:`~repro.core.workloads.ArchWorkload`) by this platform's
    FLOP/s hook, ``chips_per_pod * PEAK_FLOPS * mfu`` -- i.e. useful model
    FLOPs over roofline-discounted hardware peak.  ``mfu`` defaults to 0.4
    (the asserted ballpark); pass ``mfu="measured"`` to read the
    benchmarked compute-bound roofline fraction from the committed
    ``BENCH_kernels.json`` (:mod:`repro.core.calibration`), or pass the
    fraction of a :class:`~repro.distributed.roofline.RooflineReport`
    directly.

    Intra-pod collectives are free (folded into ``mfu``); CROSS-pod traffic
    is the metered substrate: a ring all-reduce over the DCN, reusing the
    IaaS :class:`~repro.core.engine.MPIComm`/``VMNetwork`` machinery with
    DCN constants.  This is the regime where ``sync="local:<H>"`` /
    ``"diloco:<H>"`` pays off -- the pod-mesh mirror of the paper's MA-SGD
    result, implemented for real meshes in
    :mod:`repro.distributed.local_sgd`.

    The composable specs are reused unchanged: ``FleetSpec.workers`` is the
    pod count (stragglers model slow hosts/interference), ``FailureSpec``
    with ``spot=True`` models preemptible capacity at the spot discount,
    ``CommSpec.ckpt_channel`` is where checkpoints live.
    """

    #: constructor knobs an ExperimentSpec may pass via ``platform_args``
    #: (everything else is spec-derived and would collide or be ignored)
    SPEC_TUNABLES = frozenset({"chips_per_pod", "mfu", "dcn_bandwidth",
                               "dcn_latency", "chip_hourly"})

    def __init__(self, pods: int = 4, chips_per_pod: int = 4,
                 mfu: float | str = 0.4, sync: object = "bsp", seed: int = 0,
                 dcn_bandwidth: float = POD_DCN_BANDWIDTH,
                 dcn_latency: float = POD_DCN_LATENCY,
                 chip_hourly: float = pricing.TPU_CHIP_HOURLY,
                 straggler: float = 1.0, preempt_at: tuple = (),
                 scaling: object = "static", *,
                 fleet: FleetSpec | None = None,
                 failure: FailureSpec | None = None,
                 comm: CommSpec | None = None,
                 ckpt: object = None):
        super().__init__(
            fleet=fleet if fleet is not None else FleetSpec(
                workers=pods, straggler=straggler),
            failure=failure if failure is not None else FailureSpec(
                inject=tuple(preempt_at)),
            comm=comm if comm is not None else CommSpec(),
            sync=sync, seed=seed, scaling=scaling, ckpt=ckpt)
        if chips_per_pod < 1:
            raise ValueError(f"chips_per_pod must be >= 1, got {chips_per_pod}")
        from repro.core.calibration import resolve_mfu
        mfu = resolve_mfu(mfu)     # "measured" -> benchmarked fraction
        if not 0.0 < mfu <= 1.0:
            raise ValueError(f"mfu must be in (0, 1], got {mfu}")
        self.chips_per_pod = int(chips_per_pod)
        self.mfu = float(mfu)
        self.dcn_bandwidth = float(dcn_bandwidth)
        self.dcn_latency = float(dcn_latency)
        self.chip_hourly = float(chip_hourly)

    @property
    def pods(self) -> int:
        return self.workers

    # ---- fleet shape --------------------------------------------------------
    def worker_flops_array(self, model) -> np.ndarray:
        from repro.distributed.roofline import PEAK_FLOPS
        return np.full(self.workers,
                       self.chips_per_pod * PEAK_FLOPS * self.mfu)

    # ---- engine hooks -------------------------------------------------------
    def system_name(self) -> str:
        return "pod" + ("-spot" if self.failure.spot else "")

    def validate(self, mbytes: int) -> str:
        """Pods are accelerator slices already: a ``gpu=True`` fleet can
        only mean an IaaS FleetSpec was reused unchanged, so reject it
        (same policy as FaaS) rather than silently billing TPU hours for a
        requested GPU.  (``instance``/``lambda_gb`` carry non-None defaults
        and cannot be distinguished from intent; they are documented as
        not consulted here.)"""
        if self.fleet.gpu:
            return ("FleetSpec.gpu=True is meaningless on the pod platform "
                    "(a pod IS the accelerator -- size it with "
                    "chips_per_pod/mfu).  GPU fleets are "
                    "platform='iaas' with gpu instance types")
        return ""

    def make_comm(self):
        from repro.core.comm import build_comm_stack
        return build_comm_stack(
            *self.comm.resolved("pod"),
            dcn=VMNetwork(self.dcn_bandwidth, self.dcn_latency, "dcn"))

    def make_ckpt_store(self, comm):
        if self.ckpt.transport is not None:   # dedicated checkpoint channel
            return make_ckpt_transport(self.ckpt.transport)
        return StorageChannel(self.comm.ckpt_channel)

    def startup_time(self, comm) -> float:
        return max(interp_startup(_T_POD, self.workers), comm.startup())

    def load_time(self, part_bytes: int, data_local: bool = False) -> float:
        if data_local:
            return self.dcn_latency + part_bytes / self.dcn_bandwidth
        return L_S3 + part_bytes / B_S3

    def restart_time(self, model_bytes: int = 0) -> float:
        dt = interp_startup(_T_POD, 1)
        if model_bytes > 0:       # derived: startup + metered restore
            dt += self.ckpt.restore_seconds(
                model_bytes, self.ckpt_channel_spec(), self.workers)
        return dt

    SPOT_DEFAULT_RATE = IaaSRuntime.SPOT_DEFAULT_RATE

    def failure_process(self) -> FailureProcess:
        # preemptible (spot) pod capacity behaves like spot VMs: the rate
        # only arms on spot fleets, scripted kills always fire
        return self.failure.process(self.workers, self.seed,
                                    armed=self.failure.spot,
                                    default_rate=self.SPOT_DEFAULT_RATE)

    def _fleet_hourly(self) -> float:
        """The whole mesh's (spot-discounted) $/hour -- the ONE derivation
        the bill uses; kept multiply-then-discount so fixed-fleet costs
        stay byte-identical to the pre-elastic expression."""
        hourly = self.workers * self.chips_per_pod * self.chip_hourly
        if self.failure.spot:
            hourly *= self.failure.spot_discount
        return hourly

    def _pod_hourly(self) -> float:
        """Per-pod share of :meth:`_fleet_hourly` (elastic rebates,
        retirements and joiner provisioning only)."""
        hourly = self.chips_per_pod * self.chip_hourly
        if self.failure.spot:
            hourly *= self.failure.spot_discount
        return hourly

    def finalize_cost(self, ctx) -> float:
        sim_time = float(np.max(ctx.clock))
        hourly = self._fleet_hourly()
        # elastic pod slices bill from when the reshape granted them
        # (joined_at == 0 for fixed fleets -- expression unchanged);
        # released slices were billed into retired_cost at the reshape
        joined_rebate = self._pod_hourly() * float(np.sum(ctx.joined_at)) \
            / 3600.0
        # DCN rings bill $0; pinned storage/PS stacks bill their service
        usd_pod = hourly / 3600.0 * sim_time
        usd_ckpt = ctx.ckpt_store.service_cost(sim_time)
        usd_comm = ctx.comm.service_cost(sim_time)
        if ctx.rec is not None:
            # invariant 2 ledger (DESIGN.md §18), rebate as a negative entry
            ctx.rec.cost_reset()
            ctx.rec.cost("pod_hours", usd_pod)
            ctx.rec.cost("joined_rebate", -joined_rebate)
            ctx.rec.cost("retired", ctx.retired_cost)
            ctx.rec.cost("ckpt_service", usd_ckpt)
            ctx.rec.cost("comm_service", usd_comm)
        return (usd_pod - joined_rebate
                + ctx.retired_cost + usd_ckpt + usd_comm)

    # ---- elastic-fleet hooks (DESIGN.md §13) --------------------------------
    def resize_cost(self, added: int) -> tuple:
        """Growing the mesh by ``added`` slices pays the pod-provisioning
        queue/topology bring-up curve for the new slices."""
        dt = interp_startup(_T_POD, added)
        return dt, added * self._pod_hourly() * dt / 3600.0

    def retire_cost(self, ctx, idx) -> float:
        span = ctx.clock[idx] - ctx.joined_at[idx]
        return self._pod_hourly() * float(np.sum(span)) / 3600.0

    # ---- serving hooks (DESIGN.md §14) --------------------------------------
    def serving_hooks(self) -> ServingHooks:
        """Provisioned serving on pod slices: weights shard across the
        slice, so the streaming floor rides the aggregate HBM bandwidth --
        which is exactly why continuous batching pays on this platform."""
        from repro.distributed.roofline import HBM_BW, PEAK_FLOPS
        if self.ckpt.transport is not None:   # weights live where ckpts do
            ch = ckpt_transport_constants(self.ckpt.transport)
            load_bw, load_lat = ch.bandwidth, ch.latency
        else:
            load_bw, load_lat = B_S3, L_S3
        return ServingHooks(
            system=self.system_name(), billing="provisioned",
            flops=self.chips_per_pod * PEAK_FLOPS * self.mfu,
            memory_bytes=self.chips_per_pod * pricing.POD_HBM_GB * 1e9,
            mem_bandwidth=self.chips_per_pod * HBM_BW,
            hourly_usd=self._pod_hourly(),
            cold_start_s=self.restart_time(),
            load_bandwidth=load_bw, load_latency=load_lat,
            load_shards=self.ckpt.shards(self.workers),
            provision_table=tuple(sorted(_T_POD.items())))
