"""The ``Platform`` protocol and its composable configuration specs.

This module formalizes the engine-hook interface that
:mod:`repro.core.runtimes` used to implement purely by convention, and
splits the monolithic runtime dataclasses into three orthogonal, reusable
pieces (DESIGN.md §9):

- :class:`FleetSpec`   -- how many workers and what each one is (per-worker
  Lambda memory OR per-worker instance type, straggler factor, backup
  invocations).  The SAME FleetSpec composes with any platform: only the
  fields the platform understands are consulted.
- :class:`FailureSpec` -- the failure scenario (Poisson preemption rate,
  deterministically injected kills, spot pricing + discount).
- :class:`CommSpec`    -- how updates move (storage channel, reduce pattern,
  checkpoint channel).

:class:`BasePlatform` implements every spec-derivable engine hook once;
concrete platforms (``FaaSRuntime``, ``IaaSRuntime``) add only the genuinely
platform-specific ones (startup/load timings, comm backend construction,
pricing).  :class:`Platform` is the runtime-checkable protocol the engine
programs against -- any object satisfying it simulates through
:func:`repro.core.engine.simulate`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Protocol, runtime_checkable

import numpy as np

from repro.core import cost as pricing
from repro.core.ckpt import CheckpointSpec, ckpt_transport_constants
from repro.core.engine import (
    CommBackend, FailureProcess, InjectedPreemptions, PoissonPreemptions,
    RunResult, StragglerProcess, simulate,
)


def per_worker(value, w: int) -> np.ndarray:
    """Broadcast a scalar or validate a per-worker sequence of length w."""
    if np.isscalar(value) or isinstance(value, str):
        return np.asarray([value] * w)
    arr = np.asarray(value)
    if len(arr) != w:
        raise ValueError(f"per-worker config has {len(arr)} entries, "
                         f"expected {w}")
    return arr


def _freeze(obj, name: str, value):
    object.__setattr__(obj, name, value)


# ------------------------------------------------------------------ specs ----

@dataclass(frozen=True)
class FleetSpec:
    """Worker fleet shape, independent of the platform that runs it.

    ``lambda_gb`` is consulted by FaaS platforms (scalar or per-worker GB,
    paper §5 heterogeneity), ``instance``/``gpu`` by IaaS platforms; the
    straggler knobs apply everywhere.  Per-worker sequences must have
    exactly ``workers`` entries (validated lazily, when the fleet is used).

    ``min_workers``/``max_workers`` bound what an elastic scaling policy
    (DESIGN.md §13) may resize the fleet to; ``None`` means 1 / the
    engine's :data:`repro.core.elastic.MAX_FLEET`.  They are inert under
    the default ``scaling="static"``.
    """
    workers: int = 10
    lambda_gb: Any = 3.0                 # FaaS: scalar GB or per-worker tuple
    instance: Any = "t2.medium"          # IaaS: scalar type or per-worker tuple
    gpu: bool = False                    # IaaS: GPU instances (NN models only)
    straggler: float = 1.0               # slowdown of one injected straggler
    backup_invocations: bool = False     # straggler mitigation (FaaS)
    min_workers: int | None = None       # elastic floor (None = 1)
    max_workers: int | None = None       # elastic ceiling (None = MAX_FLEET)

    def __post_init__(self):
        if isinstance(self.lambda_gb, list):
            _freeze(self, "lambda_gb", tuple(self.lambda_gb))
        if isinstance(self.instance, list):
            _freeze(self, "instance", tuple(self.instance))
        lo = 1 if self.min_workers is None else int(self.min_workers)
        hi = self.max_workers
        if lo < 1:
            raise ValueError(f"min_workers must be >= 1, got {lo}")
        if hi is not None and int(hi) < lo:
            raise ValueError(f"max_workers ({hi}) < min_workers ({lo})")
        if not (lo <= self.workers <= (int(hi) if hi is not None
                                       else self.workers)):
            raise ValueError(
                f"workers={self.workers} outside the elastic bounds "
                f"[{lo}, {hi}]")

    def gb_array(self) -> np.ndarray:
        return per_worker(self.lambda_gb, self.workers).astype(float)

    def instances(self) -> list[str]:
        return [str(i) for i in per_worker(self.instance, self.workers)]

    def speeds(self, seed: int) -> np.ndarray:
        return StragglerProcess(
            factor=self.straggler,
            cap_at_median=self.backup_invocations).speeds(self.workers, seed)

    def joiner_speeds(self, ids, seed: int) -> np.ndarray:
        """Speed multipliers for elastic joiners, drawn per STABLE worker
        id (so a given joiner's speed never depends on when it joins).
        Joiners get the fleet's log-normal jitter but no fresh injected
        straggler -- the deterministic straggler of ``speeds`` belongs to
        the initial draw."""
        return np.asarray([
            float(np.exp(np.random.default_rng((seed, int(i)))
                         .normal(0.0, 0.05)))
            for i in ids])


@dataclass(frozen=True)
class FailureSpec:
    """Failure scenario: stochastic rate, scripted kills, spot pricing.

    ``process()`` builds the engine's :class:`FailureProcess`: injected
    kills always win (they are the reproducible way to script a scenario);
    the Poisson rate applies only when ``armed`` (FaaS arms it whenever
    the rate is positive; IaaS arms it only for spot fleets, matching the
    legacy ``preempt_rate``-only-if-``spot`` semantics).

    ``rate=None`` means "the platform's default": 0 for on-demand/FaaS
    fleets, 2 preemptions per worker-hour for spot IaaS fleets -- so a
    bare ``FailureSpec(spot=True)`` buys the discount WITH the
    preemption risk, exactly like the legacy ``IaaSRuntime(spot=True)``.

    ``trace`` replays a RECORDED preemption trace instead (a bundled
    fixture name or a file path, :mod:`repro.core.failures`) -- failure
    timing from data, not Poisson only.  Precedence: ``inject`` (an
    explicit script always wins) > ``trace`` > Poisson rate.
    """
    rate: float | None = None            # preemptions per worker-hour
    inject: tuple = ()                   # ((worker, sim_time), ...) kills
    spot: bool = False                   # preemptible fleet, discounted $
    spot_discount: float = pricing.SPOT_DISCOUNT   # spot $ / on-demand $
    trace: str = ""                      # recorded trace: fixture name|path

    def __post_init__(self):
        _freeze(self, "inject",
                tuple((int(w), float(t)) for w, t in self.inject))

    def resolved_rate(self, default: float = 0.0) -> float:
        return default if self.rate is None else self.rate

    def process(self, workers: int, seed: int, armed: bool = True,
                default_rate: float = 0.0) -> FailureProcess:
        if self.inject:
            return InjectedPreemptions(self.inject)
        if self.trace:
            from repro.core.failures import TracePreemptions
            return TracePreemptions.from_spec(self.trace, workers)
        rate = self.resolved_rate(default_rate)
        if armed and rate > 0.0:
            return PoissonPreemptions(rate, workers, seed)
        return FailureProcess()


@dataclass(frozen=True)
class CommSpec:
    """How the fleet communicates: one point of the Transport x Collective
    x Codec space (:mod:`repro.core.comm`, DESIGN.md §12).

    The seed-era fields keep their platform-interpreted meaning --
    ``channel``/``pattern`` are what FaaS runs (Tables 1-3), IaaS/pod
    fleets default to ring over their NIC/DCN, ``ckpt_channel`` is where
    spot/lifetime checkpoints live.  The explicit ``transport`` /
    ``collective`` overrides (``None`` = platform default) and the
    ``codec`` pin the full stack on ANY platform; the
    ``"transport/collective/codec"`` string grammar
    (:meth:`CommSpec.parse`, accepted anywhere a CommSpec is --
    ``ExperimentSpec(comm="s3/scatter_reduce/int8")``) fills them in one
    shot.
    """
    channel: str = "s3"                  # s3|memcached[_large]|redis|
                                         #   dynamodb|vmps (FaaS transport)
    pattern: str = "allreduce"           # allreduce|scatter_reduce|
                                         #   hierarchical[:<g>] (store reduce)
    ckpt_channel: str = "s3"
    codec: str = "fp32"                  # fp32|int8|topk[:<fraction>]
    transport: str | None = None         # explicit transport (wins over
                                         #   channel; nic/dcn allowed)
    collective: str | None = None        # explicit collective (wins over
                                         #   pattern; ring/pushpull allowed)

    def __post_init__(self):
        from repro.core import comm as C
        # structural name validation, eagerly (a sweep should reject at
        # expansion, not crash mid-batch inside make_comm)
        for name in (self.channel, self.ckpt_channel):
            C.transport_constants(name)          # raises on unknown
        C.make_collective(self.pattern)
        C.make_codec(self.codec)
        if self.transport is not None:
            C.transport_constants(self.transport)
        if self.collective is not None:
            C.make_collective(self.collective)

    # ---- the string grammar -------------------------------------------------
    @classmethod
    def parse(cls, text: str, *, ckpt_channel: str = "s3") -> "CommSpec":
        """``"<transport>[/<collective>[/<codec>]]"`` -> CommSpec (see
        :mod:`repro.core.comm.grammar` for defaults and examples).  The
        legacy ``channel``/``pattern`` views mirror the parsed parts where
        they are expressible."""
        from repro.core import comm as C
        transport, collective, codec = C.parse_stack(text)
        kw: dict = dict(transport=transport, collective=collective,
                        codec=codec, ckpt_channel=ckpt_channel)
        if transport not in C.NETWORK_TRANSPORTS:
            kw["channel"] = transport
        if collective is not None and (
                collective.partition(":")[0] in C.STORE_COLLECTIVES):
            kw["pattern"] = collective
        return cls(**kw)

    def resolved(self, platform: str = "faas") -> tuple[str, str, str]:
        """The concrete ``(transport, collective, codec)`` this spec means
        on ``platform`` -- explicit overrides win; otherwise FaaS reduces
        ``pattern`` over ``channel``, IaaS rings over NICs, pods over the
        DCN, and the VM-PS transport implies push/pull."""
        from repro.core import comm as C
        t = self.transport
        if t is None:
            t = {"iaas": "nic", "pod": "dcn"}.get(platform, self.channel)
        c = self.collective
        if c is None:
            c = (self.pattern if t not in ("vmps", "nic", "dcn")
                 else C.default_collective(t))
        return t, c, self.codec

    def stack_name(self, platform: str = "faas") -> str:
        """Canonical ``transport/collective/codec`` string on ``platform``."""
        from repro.core.comm import stack_name
        return stack_name(*self.resolved(platform))

    def validate(self, platform: str | None = None, model_bytes=None,
                 workers: int | None = None) -> None:
        """Raise on stacks that cannot run (pairing/platform rules) or
        cannot fit (transport per-item limits vs the codec'd update size:
        DynamoDB's 400 KB limit becomes an eager
        :class:`~repro.core.comm.ChannelItemTooLarge`, reproducing Table
        1's "N/A" cells at spec time).  ``model_bytes`` is the fp32
        update-vector size; pass a callable for lazy estimation."""
        from repro.core.comm import validate_stack
        validate_stack(*self.resolved(platform or "faas"),
                       platform=platform, model_bytes=model_bytes,
                       workers=workers)


def check_sync_codec(proto, codec: str) -> None:
    """Codecs encode the *update vectors of collective reduces* (BSP and
    the LocalSGD/DiLoCo sync boundaries); the ASP/SSP event loop exchanges
    the raw fp32 global model through the kvstore instead, so a lossy
    codec there would be a silent no-op -- reject it rather than return
    fp32 results labeled int8/topk."""
    from repro.core.comm import make_codec
    from repro.core.sync import SSP
    if isinstance(proto, SSP) and not make_codec(codec).is_identity:
        raise ValueError(
            f"comm codec {codec!r} has no effect under sync="
            f"{proto.name!r}: codecs apply to collective reduces "
            f"(bsp / local:<H> / diloco:<H>); the ASP/SSP global-model "
            f"store moves raw fp32 -- drop the codec or switch sync")


# ------------------------------------------------------- serving hooks ------

@dataclass(frozen=True)
class ServingHooks:
    """What the request-driven serving simulator needs from a platform
    (DESIGN.md §14) -- the serving-side mirror of the engine hooks.

    ``billing`` selects the simulator's money model: ``"request"`` platforms
    (FaaS) pay per-request GB-seconds + an invocation fee and scale to zero;
    ``"provisioned"`` platforms (IaaS, pods) pay hourly per replica from the
    moment a replica is requested until it is retired.  All constants come
    from the same :mod:`repro.core.cost` tables the training engine bills
    against, so a serving dollar is traceable to the same sources as a
    training dollar.
    """

    system: str                    # platform tag for results ("faas"/...)
    billing: str                   # "request" | "provisioned"
    flops: float                   # per-replica FLOP/s (homogeneous fleet)
    memory_bytes: float            # per-replica RAM/HBM: weights + KV budget
    mem_bandwidth: float           # bytes/s weight-streaming floor
    hourly_usd: float = 0.0        # per replica (provisioned billing)
    gb: float = 0.0                # FaaS memory size (request billing)
    gb_s_usd: float = 0.0          # FaaS $ per GB-second
    request_fee_usd: float = 0.0   # FaaS $ per invocation
    keep_warm_s: float = 0.0       # FaaS sandbox warm-pool retention
    cold_start_s: float = 0.0      # sandbox/VM bring-up, EXCLUDING model load
    load_bandwidth: float = 1.0    # bytes/s for pulling weights on cold start
    load_latency: float = 0.0      # per-pull latency (S3 round trip)
    load_shards: int = 1           # weight objects pulled (sharded ckpt)
    provision_table: tuple = ()    # ((w, s), ...) fleet-extension curve

    def model_load_s(self, model_bytes: float) -> float:
        """Seconds to pull the weights into a fresh replica (one latency
        per checkpoint shard, bandwidth over the full byte size)."""
        return self.load_shards * self.load_latency \
            + model_bytes / self.load_bandwidth

    def cold_start_total_s(self, model_bytes: float) -> float:
        """Full cold start: sandbox/VM bring-up + weight pull."""
        return self.cold_start_s + self.model_load_s(model_bytes)

    def provision_s(self, added: int) -> float:
        """Seconds to extend a provisioned fleet by ``added`` replicas
        (same Table 6 interpolation as the elastic training hooks)."""
        if not self.provision_table:
            return 0.0
        from repro.core.runtimes import interp_startup
        return interp_startup(dict(self.provision_table), added)


# --------------------------------------------------------------- protocol ----

@runtime_checkable
class Platform(Protocol):
    """The engine-hook interface (DESIGN.md §5).  Anything implementing it
    can be simulated: the engine never imports a concrete platform.

    Implementations must also expose ``workers: int`` and ``seed: int``.
    """

    def system_name(self) -> str: ...

    def validate(self, mbytes: int) -> str:
        """Empty string if a model of ``mbytes`` fits; else the error."""
        ...

    def make_comm(self) -> CommBackend: ...

    def make_ckpt_store(self, comm: CommBackend) -> Any:
        """Metered store holding lifetime/preemption checkpoints."""
        ...

    def startup_time(self, comm: CommBackend) -> float: ...

    def load_time(self, part_bytes: int, data_local: bool = False) -> float: ...

    def restart_time(self, model_bytes: int = 0) -> float:
        """Cold-start seconds for one replacement worker.  With
        ``model_bytes > 0`` the platform DERIVES the full restart:
        startup plus the metered restore of the model's actual byte
        size through the checkpoint transport (DESIGN.md §17) -- no
        platform asserts a checkpoint-free restart."""
        ...

    def lifetime_s(self) -> float:
        """Planned worker lease (900 s on Lambda, inf on VMs)."""
        ...

    def lifetime_margin_s(self) -> float: ...

    def failure_process(self) -> FailureProcess: ...

    def worker_flops(self, model=None) -> float:
        """Slowest worker's FLOP/s; ``model`` optional (used by GPU fleets
        to decide whether the model can use the accelerator)."""
        ...

    def worker_flops_array(self, model) -> np.ndarray: ...

    def worker_speeds(self) -> np.ndarray: ...

    def init_breakdown(self) -> dict: ...

    def finalize_cost(self, ctx) -> float: ...

    # ---- elastic-fleet hooks (DESIGN.md §13) --------------------------------
    def resize_fleet(self, new_w: int) -> None:
        """Reshape the platform's own fleet view to ``new_w`` workers."""
        ...

    def resize_cost(self, added: int) -> tuple:
        """``(seconds, dollars)`` to bring ``added`` joiners up: the clock
        stall the fleet sees, and the directly-attributable $ reported in
        the scaling timeline (billing itself flows through the meters)."""
        ...

    def retire_cost(self, ctx, idx) -> float:
        """$ the workers at positions ``idx`` have accrued when they are
        retired at a scale-down (their usage leaves the live arrays)."""
        ...

    def joiner_speeds(self, ids) -> np.ndarray:
        """Straggler-jitter multipliers for joiners with stable ids."""
        ...


# ------------------------------------------------------------ base class ----

@dataclass
class BasePlatform:
    """Shared, spec-driven half of a :class:`Platform` implementation.

    Concrete platforms are thin: they add startup/load timing tables, the
    comm-backend factory, and pricing.  Everything derivable from the specs
    (fleet speeds, failure processes, the training entry point) lives here
    exactly once.
    """
    fleet: FleetSpec = field(default_factory=FleetSpec)
    failure: FailureSpec = field(default_factory=FailureSpec)
    comm: CommSpec = field(default_factory=CommSpec)
    sync: object = "bsp"                 # bsp|asp|ssp|ssp:<s>|SyncProtocol
    seed: int = 0
    scaling: object = "static"           # static|schedule:<w@r,..>|smlt|
                                         #   cost_cap:<$>|ScalingPolicy inst.
    ckpt: object = field(default_factory=CheckpointSpec)
                                         # CheckpointSpec | "s3:every=5:sharded"

    def __post_init__(self):
        if isinstance(self.comm, str):   # "s3/scatter_reduce/int8" grammar
            self.comm = CommSpec.parse(self.comm)
        if self.ckpt is None:
            self.ckpt = CheckpointSpec()
        elif isinstance(self.ckpt, str):  # "s3:every=5:sharded" grammar
            self.ckpt = CheckpointSpec.parse(self.ckpt)

    # ---- user entry point ---------------------------------------------------
    def train(self, model, algo, ds_train, ds_val, *,
              target_loss: float | None = None, max_epochs: int = 10,
              eval_every: int = 1, data_local: bool = False,
              trace: bool = False) -> RunResult:
        from repro.core.elastic import build_controller
        from repro.core.sync import make_sync
        proto = make_sync(self.sync)
        check_sync_codec(proto, self.comm.codec)
        elastic = build_controller(self.scaling, self.fleet)
        if elastic is not None and not getattr(proto, "supports_resize",
                                               False):
            raise ValueError(
                f"scaling policy {elastic.policy.name!r} needs a sync "
                f"protocol that supports mid-run resizing; {proto.name!r} "
                f"does not declare supports_resize")
        # elastic runs mutate self.fleet through resize_fleet; restore it
        # so train() stays repeatable (a second call starts from the
        # configured width, not wherever the last run ended).  Note that a
        # policy INSTANCE passed as scaling= keeps its observation state
        # across calls by design (reading it back is the point -- e.g.
        # CostCapPolicy.max_round_spend); string specs build fresh.
        fleet0 = self.fleet
        try:
            return simulate(self, proto, model, algo,
                            ds_train, ds_val, target_loss=target_loss,
                            max_epochs=max_epochs, eval_every=eval_every,
                            data_local=data_local, elastic=elastic,
                            trace=trace)
        finally:
            self.fleet = fleet0

    # ---- spec-derived hooks -------------------------------------------------
    @property
    def workers(self) -> int:
        return self.fleet.workers

    def worker_speeds(self) -> np.ndarray:
        return self.fleet.speeds(self.seed)

    def worker_flops(self, model=None) -> float:
        """Slowest worker's FLOP/s (scalar convenience over the array)."""
        return float(np.min(self.worker_flops_array(model)))

    def failure_process(self) -> FailureProcess:
        return self.failure.process(self.workers, self.seed)

    def ckpt_channel_spec(self):
        """The :class:`~repro.core.comm.ChannelSpec` checkpoint bytes move
        over: an explicit ``CheckpointSpec.transport`` wins; otherwise the
        platform's default checkpoint channel (``comm.ckpt_channel`` here;
        FaaS overrides to its resolved comm transport, whose kvstore holds
        the checkpoints by default)."""
        if self.ckpt.transport is not None:
            return ckpt_transport_constants(self.ckpt.transport)
        return ckpt_transport_constants(self.comm.ckpt_channel)

    def validate(self, mbytes: int) -> str:
        return ""

    def lifetime_s(self) -> float:
        return math.inf

    def lifetime_margin_s(self) -> float:
        return 0.0

    def init_breakdown(self) -> dict:
        return {"startup": 0.0, "load": 0.0, "compute": 0.0, "comm": 0.0}

    # ---- elastic-fleet hooks (DESIGN.md §13) --------------------------------
    def resize_fleet(self, new_w: int) -> None:
        """Reshape the fleet spec to ``new_w`` workers.  Only homogeneous
        fleets can resize (per-worker ``lambda_gb``/``instance`` tuples
        have no meaning for joiners) -- the controller builder rejects
        heterogeneous fleets before a run starts; this re-checks as a
        backstop."""
        import dataclasses
        for name in ("lambda_gb", "instance"):
            if isinstance(getattr(self.fleet, name), tuple):
                raise ValueError(
                    f"cannot resize a fleet with per-worker {name}: elastic "
                    f"scaling needs a homogeneous fleet")
        self.fleet = dataclasses.replace(self.fleet, workers=int(new_w))

    def resize_cost(self, added: int) -> tuple:
        return 0.0, 0.0

    def retire_cost(self, ctx, idx) -> float:
        return 0.0

    def joiner_speeds(self, ids) -> np.ndarray:
        return self.fleet.joiner_speeds(ids, self.seed)
