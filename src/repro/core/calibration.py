"""Measured-MFU calibration: the pod platform's FLOP/s discount, closed-loop.

:class:`repro.core.runtimes.PodPlatform` discounts hardware peak by an MFU
factor (``worker_flops = chips_per_pod * PEAK_FLOPS * mfu``).  Historically
that was an *asserted* ``0.4``; this module makes ``mfu="measured"`` read
the benchmarked value instead, so ``python -m repro plan`` pod rows derive
from measurements (DESIGN.md §16).

The measurement: ``benchmarks/bench_kernels.py`` compiles the full
smollm-360m train_4k step on a 2x4 host mesh (``repro.launch.dryrun`` in a
subprocess -- jax pins the device count at first init) and records the
**compute-bound roofline fraction** ``model_flops / (chips * PEAK_FLOPS *
t_compute)`` == useful-FLOPs share of executed HLO FLOPs
(:func:`compute_measured_mfu`), emitted as ``roofline_fraction`` in the
committed ``BENCH_kernels.json``.  Train shapes are compute-bound on TPU
(arithmetic intensity far above the ridge; the host-compiled *byte* counts
are a CPU-backend artifact -- see ``roofline.analyze``), so the
compute-bound fraction IS the roofline MFU estimate for this workload.

:func:`measured_mfu` reads the committed snapshot at the repo root; the
:data:`MEASURED_MFU` constant is the same number baked in as the fallback
for installs without the file.  This module is a C001 lint home: the
measured value may not be re-hardcoded elsewhere.
"""
from __future__ import annotations

import json
from pathlib import Path

#: fallback snapshot of BENCH_kernels.json's ``roofline_fraction`` --
#: regenerate with ``python -m benchmarks.bench_kernels`` after kernel or
#: model changes and keep this in step (asserted in tests)
MEASURED_MFU = 0.520

_BENCH_KERNELS = Path(__file__).resolve().parents[3] / "BENCH_kernels.json"


def compute_measured_mfu(artifact: dict) -> float:
    """Compute-bound roofline fraction of one dry-run artifact:
    ``model_flops_global / (chips * PEAK_FLOPS * t_compute_s)``."""
    from repro.distributed.roofline import PEAK_FLOPS

    denom = artifact["chips"] * PEAK_FLOPS * artifact["t_compute_s"]
    return float(artifact["model_flops_global"] / denom)


def measured_mfu(path: Path | None = None) -> float:
    """The benchmarked MFU: ``roofline_fraction`` from the committed
    ``BENCH_kernels.json`` (:data:`MEASURED_MFU` when the file is absent
    or predates the measurement)."""
    p = _BENCH_KERNELS if path is None else Path(path)
    try:
        payload = json.loads(p.read_text())
    except (OSError, ValueError):
        return MEASURED_MFU
    frac = payload.get("roofline_fraction")
    if not isinstance(frac, (int, float)) or not 0.0 < frac <= 1.0:
        return MEASURED_MFU
    return float(frac)


def resolve_mfu(mfu) -> float:
    """``"measured"`` -> :func:`measured_mfu`; numbers pass through.
    The one resolution point shared by :class:`PodPlatform` and the
    analytic planner's pod rows."""
    if isinstance(mfu, str):
        if mfu != "measured":
            raise ValueError(
                f"mfu must be a number in (0, 1] or 'measured', got {mfu!r}")
        return measured_mfu()
    return float(mfu)
