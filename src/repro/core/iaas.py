"""IaaS runtime (distributed-PyTorch-style VM cluster) -- named entry point
per DESIGN.md §5; implementation in :mod:`repro.core.runtimes`."""
from repro.core.runtimes import IaaSRuntime, RunResult  # noqa: F401
