"""IaaS runtime (distributed-PyTorch-style VM cluster) -- named entry point
per DESIGN.md §5; platform adapter in :mod:`repro.core.runtimes`, shared
training loops in the discrete-event engine (DESIGN.md §4).

Spot fleets (``IaaSRuntime(spot=True, ...)``) and heterogeneous fleets
(``instance=("c5.large", "t2.medium", ...)``) are configured here too --
see DESIGN.md §7.
"""
from repro.core.runtimes import IaaSRuntime, RunResult  # noqa: F401
