"""Optimizers: SGD / AdamW / AdamW with 8-bit block-quantized moments.

Interface (functional):
    opt = make_optimizer(train_cfg)
    state = opt.init(params)
    new_params, new_state, stats = opt.update(grads, state, params)

8-bit states (``adamw8bit``) store m and v as int8 codes with fp32 scales per
256-block *along the last dim* -- the codes keep the exact shape (and thus
the exact sharding) of the parameter, so FSDP sharding carries over and no
resharding happens inside the update.  10 bytes/param (bf16 p + int8 m,v +
scales) instead of 18 is what lets llama3-405b fit a 256-chip pod.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

BLOCK = 256


def _block_of(last: int) -> int:
    return BLOCK if last % BLOCK == 0 else last


def quantize_blockwise(x: jax.Array):
    """fp32 tensor -> (int8 codes, same shape; fp32 scales (..., last/block))."""
    shape = x.shape
    last = shape[-1] if shape else 1
    b = _block_of(last)
    xb = x.astype(jnp.float32).reshape(shape[:-1] + (max(last // b, 1), b))
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(shape), scale


def dequantize_blockwise(q: jax.Array, scale: jax.Array) -> jax.Array:
    shape = q.shape
    last = shape[-1] if shape else 1
    b = _block_of(last)
    xb = q.astype(jnp.float32).reshape(shape[:-1] + (max(last // b, 1), b))
    return (xb * scale[..., None]).reshape(shape)


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]
    state_axes: Callable[[Any], Any]  # param axes pytree -> state axes pytree


def clip_by_global_norm(grads, max_norm: float):
    """Norm accumulated in fp32; clipped grads KEEP their input dtype.

    (§Perf iteration D7: casting to fp32 before clipping placed the gradient
    all-reduce on fp32 tensors -- 2x the wire bytes.  bf16 gradient sync with
    fp32 norm/optimizer math is the standard recipe.)
    """
    gsq = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def _zip_update(params, grads, *states, fn):
    """Apply fn(p, g, *state_leaves) leaf-wise, returning tuple-of-trees."""
    leaves_p, tdef = jax.tree.flatten(params)
    per_leaf = [tdef.flatten_up_to(t) for t in (grads, *states)]
    outs = [fn(p, *rest) for p, *rest in zip(leaves_p, *per_leaf)]
    n = len(outs[0])
    return tuple(jax.tree.unflatten(tdef, [o[i] for o in outs]) for i in range(n))


def _adamw_math(g, m, v, p, cfg: TrainConfig, t):
    b1, b2 = cfg.beta1, cfg.beta2
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * jnp.square(g)
    mh = m / (1 - b1 ** t)
    vh = v / (1 - b2 ** t)
    upd = mh / (jnp.sqrt(vh) + 1e-8) + cfg.weight_decay * p.astype(jnp.float32)
    return m, v, upd


def make_optimizer(cfg: TrainConfig) -> Optimizer:
    lr = cfg.learning_rate

    if cfg.optimizer == "sgd":
        def init(params):
            return {"step": jnp.zeros((), jnp.int32)}

        def update(grads, state, params):
            grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
            new = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32) - lr * g).astype(p.dtype),
                params, grads)
            return new, {"step": state["step"] + 1}, {"grad_norm": gnorm}

        return Optimizer(init, update, lambda paxes: {"step": ()})

    if cfg.optimizer == "adamw":
        def init(params):
            z = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
            return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
                    "step": jnp.zeros((), jnp.int32)}

        def update(grads, state, params):
            grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
            t = (state["step"] + 1).astype(jnp.float32)

            def f(p, g, m, v):
                m2, v2, u = _adamw_math(g, m, v, p, cfg, t)
                return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m2, v2

            new_p, new_m, new_v = _zip_update(params, grads, state["m"],
                                              state["v"], fn=f)
            return new_p, {"m": new_m, "v": new_v, "step": state["step"] + 1}, \
                {"grad_norm": gnorm}

        def state_axes(paxes):
            return {"m": paxes, "v": paxes, "step": ()}

        return Optimizer(init, update, state_axes)

    if cfg.optimizer == "adamw8bit":
        def init(params):
            def qz(p):
                q, s = quantize_blockwise(jnp.zeros(p.shape, jnp.float32))
                return {"q": q, "s": s}
            return {"m": jax.tree.map(qz, params),
                    "v": jax.tree.map(qz, params),
                    "step": jnp.zeros((), jnp.int32)}

        def update(grads, state, params):
            grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
            t = (state["step"] + 1).astype(jnp.float32)

            def f(p, g, mq, vq):
                m = dequantize_blockwise(mq["q"], mq["s"])
                v = jnp.square(dequantize_blockwise(vq["q"], vq["s"]))  # v >= 0
                m2, v2, u = _adamw_math(g, m, v, p, cfg, t)
                nmq, nms = quantize_blockwise(m2)
                nvq, nvs = quantize_blockwise(jnp.sqrt(v2))  # store sqrt(v): better dyn range
                newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
                return newp, {"q": nmq, "s": nms}, {"q": nvq, "s": nvs}

            new_p, new_m, new_v = _zip_update(params, grads, state["m"],
                                              state["v"], fn=f)
            return new_p, {"m": new_m, "v": new_v, "step": state["step"] + 1}, \
                {"grad_norm": gnorm}

        def state_axes(paxes):
            def qax(ax):
                # codes share the param's axes; per-block scales share them too
                # (last dim shrinks by the block factor; divisibility enforced
                # at pspec-resolution time)
                return {"q": ax, "s": ax}
            is_ax = lambda x: isinstance(x, tuple)  # noqa: E731
            return {"m": jax.tree.map(qax, paxes, is_leaf=is_ax),
                    "v": jax.tree.map(qax, paxes, is_leaf=is_ax),
                    "step": ()}

        return Optimizer(init, update, state_axes)

    raise ValueError(cfg.optimizer)
