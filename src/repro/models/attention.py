"""Attention blocks: GQA (causal / bidirectional / cross), MLA, decode paths.

Long sequences use a chunked, online-softmax ("flash-style") pure-jnp path so
the s x s score matrix is never materialized; the Pallas TPU kernel in
``repro.kernels.flash_attention`` implements the same contract and is swapped
in by the step builder when ``use_pallas=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import hint
from repro.models.common import rope, spec, softmax_fp32

import os

# seqs longer than this use the chunked (flash-style) path; below it the
# plain einsum path avoids lax.map slicing a sharded seq dim (which forces
# GSPMD into "involuntary full rematerialization" replication -- see
# EXPERIMENTS.md §Perf iteration L1)
CHUNK_THRESHOLD = int(os.environ.get("REPRO_ATTN_CHUNK_THRESHOLD", 8192))
Q_CHUNK = int(os.environ.get("REPRO_ATTN_Q_CHUNK", 1024))


# ------------------------------------------------------------------ specs ----

def gqa_spec(cfg: ModelConfig, d_model: int | None = None):
    d = d_model or cfg.d_model
    h, m, k = cfg.num_heads, cfg.kv_heads, cfg.hdim
    return {
        "wq": spec((d, h, k), ("embed", "heads", "head_dim"), d ** -0.5),
        "wk": spec((d, m, k), ("embed", "kv_heads", "head_dim"), d ** -0.5),
        "wv": spec((d, m, k), ("embed", "kv_heads", "head_dim"), d ** -0.5),
        "wo": spec((h, k, d), ("heads", "head_dim", "embed"),
                   (h * k) ** -0.5 / (2 * cfg.num_layers) ** 0.5),
    }


def mla_spec(cfg: ModelConfig):
    d, h = cfg.d_model, cfg.num_heads
    r, dr, dn, dv = cfg.kv_lora_rank, cfg.qk_rope_head_dim, cfg.qk_nope_head_dim, cfg.v_head_dim
    return {
        "wq": spec((d, h, dn + dr), ("embed", "heads", "head_dim"), d ** -0.5),
        "w_kv_down": spec((d, r + dr), ("embed", "lora"), d ** -0.5),
        "w_k_up": spec((r, h, dn), ("lora", "heads", "head_dim"), r ** -0.5),
        "w_v_up": spec((r, h, dv), ("lora", "heads", "head_dim"), r ** -0.5),
        "wo": spec((h, dv, d), ("heads", "head_dim", "embed"),
                   (h * dv) ** -0.5 / (2 * cfg.num_layers) ** 0.5),
    }


# ----------------------------------------------------------------- core ------

def _sdpa(q, k, v, *, causal: bool, q_pos0: int = 0):
    """q (b,s,h,dk), k/v (b,t,m,dk|dv) -> (b,s,h,dv); GQA by head grouping.

    Wrapped in named_scope("flashrgn"): on TPU this whole region runs as the
    Pallas flash kernel (kernels/flash_attention, validated vs this exact
    math); the dry-run analyzer uses the scope marker to substitute the
    kernel's true HBM I/O for the jnp lowering's score materialization.
    """
    with jax.named_scope("flashrgn"):
        b, s, h, dk = q.shape
        t, m = k.shape[1], k.shape[2]
        g = h // m
        qg = q.reshape(b, s, m, g, dk)
        scores = jnp.einsum("bsmgk,btmk->bmgst", qg, k) / (dk ** 0.5)
        if causal:
            qp = jnp.arange(s) + q_pos0
            kp = jnp.arange(t)
            mask = qp[:, None] >= kp[None, :]
            probs = softmax_fp32(scores, where=mask[None, None, None])
        else:
            probs = softmax_fp32(scores)
        out = jnp.einsum("bmgst,btmv->bsmgv", probs.astype(v.dtype), v)
        return out.reshape(b, s, h, v.shape[-1])


def _sdpa_chunked(q, k, v, *, causal: bool, q_chunk: int = Q_CHUNK):
    """Flash-style: lax.map over query chunks; scores never exceed (b,m,g,qc,t)."""
    b, s, h, dk = q.shape
    if s % q_chunk != 0 or s <= q_chunk:
        return _sdpa(q, k, v, causal=causal)
    n = s // q_chunk
    qc = q.reshape(b, n, q_chunk, h, dk).transpose(1, 0, 2, 3, 4)  # (n,b,qc,h,dk)

    def one(args):
        i, qi = args
        return _sdpa(qi, k, v, causal=causal, q_pos0=i * q_chunk)

    outs = jax.lax.map(one, (jnp.arange(n), qc))                   # (n,b,qc,h,dv)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, v.shape[-1])


def sdpa(q, k, v, *, causal: bool):
    if q.shape[1] > CHUNK_THRESHOLD:
        return _sdpa_chunked(q, k, v, causal=causal)
    return _sdpa(q, k, v, causal=causal)


# ------------------------------------------------------------- GQA block -----

def gqa_attention(x, p, cfg: ModelConfig, *, causal: bool, positions,
                  kv_src=None, use_rope: bool = True):
    """Self- or cross-attention. kv_src: source sequence for cross-attn."""
    src = x if kv_src is None else kv_src
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dmk->btmk", src, p["wk"])
    v = jnp.einsum("btd,dmk->btmk", src, p["wv"])
    q = hint(q, "batch", None, "heads", "head_dim")
    k = hint(k, "batch", None, "kv_heads", "head_dim")
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    out = sdpa(q, k, v, causal=causal)
    out = hint(out, "batch", None, "heads", "head_dim")
    # seq-sharded output -> reduce-scatter for the TP partial sum (§Perf L3)
    return hint(jnp.einsum("bshk,hkd->bsd", out, p["wo"]),
                "batch", "seq", "embed")


def gqa_prefill_kv(x, p, cfg: ModelConfig, *, positions, use_rope: bool = True):
    """K/V as stored in the decode cache."""
    k = jnp.einsum("btd,dmk->btmk", x, p["wk"])
    v = jnp.einsum("btd,dmk->btmk", x, p["wv"])
    if use_rope:
        k = rope(k, positions, cfg.rope_theta)
    return k, v


def gqa_decode(x1, p, cfg: ModelConfig, cache_k, cache_v, pos, *,
               update_cache: bool = True, use_rope: bool = True):
    """One-token decode. x1 (b,1,d); cache_k/v (b,S,m,dk). pos: scalar int."""
    b, _, d = x1.shape
    S, m = cache_k.shape[1], cache_k.shape[2]
    q = jnp.einsum("bsd,dhk->bshk", x1, p["wq"])
    if use_rope:
        q = rope(q, jnp.full((1,), pos), cfg.rope_theta)
    if update_cache:
        k1 = jnp.einsum("bsd,dmk->bsmk", x1, p["wk"])
        v1 = jnp.einsum("bsd,dmk->bsmk", x1, p["wv"])
        if use_rope:
            k1 = rope(k1, jnp.full((1,), pos), cfg.rope_theta)
        cache_k = jax.lax.dynamic_update_slice(cache_k, k1.astype(cache_k.dtype),
                                               (0, pos, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v1.astype(cache_v.dtype),
                                               (0, pos, 0, 0))
    h, dk = q.shape[2], q.shape[3]
    g = h // m
    qg = q.reshape(b, m, g, dk)
    cache_k = hint(cache_k, "batch", "kv_seq", "kv_heads", "head_dim")
    cache_v = hint(cache_v, "batch", "kv_seq", "kv_heads", "head_dim")
    scores = jnp.einsum("bmgk,btmk->bmgt", qg, cache_k) / (dk ** 0.5)
    valid = jnp.arange(S)[None, None, None, :] <= pos
    probs = softmax_fp32(scores, where=valid)
    out = jnp.einsum("bmgt,btmv->bmgv", probs.astype(cache_v.dtype), cache_v)
    out = out.reshape(b, 1, h, cache_v.shape[-1])
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache_k, cache_v


# ------------------------------------------------------------- MLA block -----

def _mla_qkv(x, p, cfg: ModelConfig, positions):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    r = cfg.kv_lora_rank
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    down = jnp.einsum("bsd,dr->bsr", x, p["w_kv_down"])
    c_kv, k_rope = down[..., :r], down[..., r:]
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(x, p, cfg: ModelConfig, *, causal: bool, positions):
    """Training/prefill MLA: materialize per-head K/V from the latent."""
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(x, p, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_k_up"])
    v = jnp.einsum("bsr,rhv->bshv", c_kv, p["w_v_up"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    b, s = x.shape[0], x.shape[1]
    h = cfg.num_heads
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, cfg.qk_rope_head_dim))
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    out = sdpa(q, k, v, causal=causal)
    return hint(jnp.einsum("bshv,hvd->bsd", out, p["wo"]),
                "batch", "seq", "embed")


def mla_decode(x1, p, cfg: ModelConfig, cache_ckv, cache_krope, pos):
    """Absorbed-projection MLA decode: attend in the latent space.

    cache_ckv (b,S,r); cache_krope (b,S,dr).  W_uk is absorbed into the query
    (q_lat = q_nope @ W_uk) so scores are computed directly against the cached
    latent -- the deployment trick from the DeepSeek-V2 paper.
    """
    q_nope, q_rope, c_kv1, k_rope1 = _mla_qkv(
        x1, p, cfg, jnp.full((1,), pos))
    cache_ckv = jax.lax.dynamic_update_slice(
        cache_ckv, c_kv1.astype(cache_ckv.dtype), (0, pos, 0))
    cache_krope = jax.lax.dynamic_update_slice(
        cache_krope, k_rope1.astype(cache_krope.dtype), (0, pos, 0))
    b = x1.shape[0]
    S = cache_ckv.shape[1]
    dn, dr, r = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.kv_lora_rank
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_k_up"])      # absorb W_uk
    scores = (jnp.einsum("bshr,btr->bhst", q_lat, cache_ckv)
              + jnp.einsum("bshk,btk->bhst", q_rope, cache_krope)) / ((dn + dr) ** 0.5)
    valid = (jnp.arange(S)[None, None, None, :] <= pos)
    probs = softmax_fp32(scores, where=valid)
    out_lat = jnp.einsum("bhst,btr->bshr", probs.astype(cache_ckv.dtype), cache_ckv)
    out = jnp.einsum("bshr,rhv->bshv", out_lat, p["w_v_up"])       # absorb W_uv
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"]), cache_ckv, cache_krope
