"""Shared utilities for the model zoo: param specs, init, dtype policy.

Parameters are plain nested dicts of jnp arrays.  Every leaf is described by a
``ParamSpec = (shape, logical_axes, init_scale)``; the same spec pytree drives
both initialization and sharding resolution (logical axis -> mesh axis), so
init and distribution can never drift apart.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

ParamSpec = tuple  # (shape: tuple[int,...], axes: tuple[str|None,...], scale: float)
SpecTree = Any     # nested dict of ParamSpec
Params = Any       # nested dict of jnp.ndarray


def spec(shape, axes, scale=0.02) -> ParamSpec:
    assert len(shape) == len(axes), (shape, axes)
    return (tuple(shape), tuple(axes), float(scale))


def stack_spec(tree: SpecTree, n: int, axis_name: str = "layers") -> SpecTree:
    """Add a leading stacking dim of size n to every leaf (for scan-over-layers)."""
    def f(s: ParamSpec) -> ParamSpec:
        shape, axes, scale = s
        return ((n,) + shape, (axis_name,) + axes, scale)
    return jax.tree.map(f, tree, is_leaf=_is_spec)


def _is_spec(x) -> bool:
    return isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple)


def init_params(key: jax.Array, tree: SpecTree, dtype: str) -> Params:
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, (shape, _axes, scale) in zip(keys, leaves):
        if scale == 0.0:
            out.append(jnp.zeros(shape, dtype=dtype))
        elif scale == 1.0 and len(shape) == 1:  # norm scales
            out.append(jnp.ones(shape, dtype=dtype))
        else:
            out.append((jax.random.truncated_normal(k, -2, 2, shape, jnp.float32)
                        * scale).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_params(tree: SpecTree, dtype: str) -> Params:
    """ShapeDtypeStruct pytree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s[0], jnp.dtype(dtype)), tree, is_leaf=_is_spec)


def param_axes(tree: SpecTree) -> Any:
    return jax.tree.map(lambda s: s[1], tree, is_leaf=_is_spec)


def param_count(tree: SpecTree) -> int:
    return sum(int(np.prod(s[0])) for s in jax.tree.leaves(tree, is_leaf=_is_spec))


# ---------------------------------------------------------------- numerics ----

def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """Variance reduction in fp32; elementwise stays in x.dtype.

    (§Perf iteration M3: the earlier fp32-throughout version materialized two
    full fp32 copies of the residual per norm -- ~20% of total HBM traffic on
    the SSM archs.  The fp32 reduction keeps the accuracy-critical part; the
    bf16 multiply is standard practice.)
    """
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., s, heads, d); positions: (s,) or broadcastable."""
    d = x.shape[-1]
    assert d % 2 == 0, d
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    angles = positions.astype(jnp.float32)[..., None] * freqs        # (..., s, d/2)
    cos = jnp.cos(angles)[..., None, :]                              # (..., s, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softmax_fp32(x: jax.Array, axis: int = -1, where=None) -> jax.Array:
    xf = x.astype(jnp.float32)
    if where is not None:
        xf = jnp.where(where, xf, -1e30)
    return jax.nn.softmax(xf, axis=axis)


def cross_entropy(logits: jax.Array, labels: jax.Array, mask=None):
    """Mean token CE. logits (..., V) fp32; labels int; mask optional bool."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
