"""Mamba2 SSD (state-space duality) block: chunked scan + one-step decode.

Follows the minimal SSD formulation of Dao & Gu (arXiv:2405.21060): within a
chunk the output is a masked "attention" (C B^T ∘ L) X; across chunks a small
recurrence carries the (heads, head_dim, state) SSM state.  The Pallas TPU
kernel in ``repro.kernels.ssd_scan`` implements the chunk kernel; this module
is the pure-jnp reference used on CPU and as the kernel oracle.

Sharding note: the fused in_proj of the reference CUDA implementation is
split into per-component projections (z/x/B/C/dt) so the big d_inner pieces
can be TP-sharded over "model" without slicing a sharded dimension at
non-aligned offsets; the depthwise conv is likewise split (a depthwise conv
over a concatenation == separate depthwise convs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import hint
from repro.models.common import rms_norm, spec


def ssm_spec(cfg: ModelConfig):
    d, di, n, hh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.conv_width
    sc = d ** -0.5
    return {
        "in_z": spec((d, di), ("embed", "ff"), sc),
        "in_x": spec((d, di), ("embed", "ff"), sc),
        "in_B": spec((d, n), ("embed", "state"), sc),
        "in_C": spec((d, n), ("embed", "state"), sc),
        "in_dt": spec((d, hh), ("embed", "heads"), sc),
        "conv_x": spec((w, di), ("conv", "ff"), 0.2),
        "conv_x_b": spec((di,), ("ff",), 0.0),
        "conv_B": spec((w, n), ("conv", "state"), 0.2),
        "conv_B_b": spec((n,), ("state",), 0.0),
        "conv_C": spec((w, n), ("conv", "state"), 0.2),
        "conv_C_b": spec((n,), ("state",), 0.0),
        "a_log": spec((hh,), ("heads",), 1.0),   # A = -exp(a_log) ~ -e
        "d_skip": spec((hh,), ("heads",), 1.0),
        "dt_bias": spec((hh,), ("heads",), 0.0),
        "norm": spec((di,), ("ff",), 1.0),
        "out_proj": spec((di, d), ("ff", "embed"),
                         di ** -0.5 / (2 * max(cfg.num_layers, 1)) ** 0.5),
    }


def _segsum(a):
    """(..., l) -> (..., l, l) lower-triangular segment sums (excl. diag of a_j)."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, ss, -jnp.inf)


def ssd_scan(x, dt, a_log, B, C, chunk: int, init_state=None):
    """Chunked SSD.

    x (b,s,h,p); dt (b,s,h) >=0 (post-softplus); a_log (h,), A = -exp(a_log);
    B,C (b,s,n).  Returns y (b,s,h,p) fp32 and final state (b,h,p,n) fp32.

    Precision policy (§Perf iteration M2): decay math (cumsum/exp/segsum) and
    state accumulation stay fp32; the big (b,s,...) tensors carried between
    einsums keep the INPUT dtype (bf16 in training), with fp32 matmul
    accumulation via preferred_element_type.  Halves the HBM traffic of the
    jnp path; fp32 inputs (tests/oracles) are bit-identical to before.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    cdt = x.dtype                                           # compute dtype
    A = -jnp.exp(a_log.astype(jnp.float32))                 # (h,)
    da = dt.astype(jnp.float32) * A                         # (b,s,h) log-decays
    xb = (x.astype(jnp.float32)
          * dt.astype(jnp.float32)[..., None]).astype(cdt)

    def r(t, trailing):
        return t.reshape((b, nc, chunk) + trailing)

    xc, dac = r(xb, (h, p)), r(da, (h,))
    Bc, Cc = r(B.astype(cdt), (n,)), r(C.astype(cdt), (n,))
    cum = jnp.cumsum(dac, axis=2)                           # (b,nc,l,h) inclusive

    # 1) intra-chunk: y_diag[l] = sum_{m<=l} (C_l.B_m) L[l,m] x_m
    L = jnp.exp(_segsum(dac.transpose(0, 1, 3, 2)))         # (b,nc,h,l,m) fp32
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc,
                        preferred_element_type=jnp.float32)
    y_diag = jnp.einsum("bchlm,bcmhp->bclhp",
                        (scores[:, :, None] * L).astype(cdt), xc,
                        preferred_element_type=jnp.float32)

    # 2) chunk-final states: S_c = sum_m exp(sum_{j>m} da_j) B_m x_m^T
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum).astype(cdt)  # (b,nc,l,h)
    states = jnp.einsum("bclh,bcln,bclhp->bchpn", dec_end, Bc, xc,
                        preferred_element_type=jnp.float32)

    # 3) inter-chunk recurrence (fp32: small (b,h,p,n) state)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (b,nc,h)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp
        return carry * dec[:, :, None, None] + st, carry    # emit entering state

    final, prev = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev = prev.transpose(1, 0, 2, 3, 4)                    # (b,nc,h,p,n)

    # 4) carry-in contribution: y_off[l] = C_l . (exp(cum[l]) S_prev)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc.astype(jnp.float32),
                       prev, jnp.exp(cum))

    y = y_diag + y_off
    return y.reshape(b, s, h, p), final


def _conv1d_causal(x, w, b, cache=None):
    """Depthwise causal conv. x (b,s,c); w (wd,c); cache (b,wd-1,c) or None."""
    wd = w.shape[0]
    pad = (jnp.zeros((x.shape[0], wd - 1, x.shape[2]), x.dtype)
           if cache is None else cache.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    new_cache = xp[:, x.shape[1]:, :]  # last wd-1 inputs
    out = sum(xp[:, i: i + x.shape[1], :] * w[i][None, None, :] for i in range(wd))
    return out + b[None, None, :], new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype):
    """Per-layer decode cache leaves (stacked by the model over layers)."""
    w = cfg.conv_width
    return {
        "conv_x": jnp.zeros((batch, w - 1, cfg.d_inner), dtype),
        "conv_B": jnp.zeros((batch, w - 1, cfg.ssm_state), dtype),
        "conv_C": jnp.zeros((batch, w - 1, cfg.ssm_state), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                           jnp.float32),
    }


def ssm_cache_axes():
    return {
        "conv_x": ("batch", "conv", "ff"),
        "conv_B": ("batch", "conv", "state"),
        "conv_C": ("batch", "conv", "state"),
        "state": ("batch", "heads", None, "state"),
    }


def mamba2_block(xin, p, cfg: ModelConfig, cache=None, single_step: bool = False):
    """Mamba2 mixer. xin (b,s,d) -> out (b,s,d) [, new_cache if cache given]."""
    b, s, d = xin.shape
    di, n, hh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z = xin @ p["in_z"]
    xs = xin @ p["in_x"]
    Braw = xin @ p["in_B"]
    Craw = xin @ p["in_C"]
    dt_raw = xin @ p["in_dt"]
    cc = cache or {}
    xs, ncx = _conv1d_causal(xs, p["conv_x"], p["conv_x_b"], cc.get("conv_x"))
    B, ncB = _conv1d_causal(Braw, p["conv_B"], p["conv_B_b"], cc.get("conv_B"))
    C, ncC = _conv1d_causal(Craw, p["conv_C"], p["conv_C_b"], cc.get("conv_C"))
    xs, B, C = jax.nn.silu(xs), jax.nn.silu(B), jax.nn.silu(C)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    xh = xs.reshape(b, s, hh, hp)
    xh = hint(xh, "batch", None, "heads", None)

    if single_step:
        A = -jnp.exp(p["a_log"].astype(jnp.float32))
        dec = jnp.exp(dt[:, 0, :] * A)                      # (b,h)
        st = (cache["state"].astype(jnp.float32) * dec[:, :, None, None]
              + jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0],
                           B[:, 0].astype(jnp.float32),
                           xh[:, 0].astype(jnp.float32)))
        y = jnp.einsum("bn,bhpn->bhp", C[:, 0].astype(jnp.float32), st)[:, None]
        new_state = st
    else:
        y, new_state = ssd_scan(xh, dt, p["a_log"], B, C, cfg.ssm_chunk,
                                init_state=cc.get("state"))
    y = y + (xh.astype(jnp.float32)
             * p["d_skip"].astype(jnp.float32)[None, None, :, None])
    y = y.reshape(b, s, di).astype(xin.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if cache:
        return out, {"conv_x": ncx, "conv_B": ncB, "conv_C": ncC,
                     "state": new_state}
    return out
