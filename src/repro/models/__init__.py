"""Model zoo public API."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax

from repro.configs.base import ArchConfig, ModelConfig
from repro.models import transformer as tfm
from repro.models.common import (
    abstract_params, init_params, param_axes, param_count,
)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def spec(self):
        return tfm.model_spec(self.cfg)

    def init(self, key: jax.Array):
        return init_params(key, self.spec, self.cfg.dtype)

    def abstract(self):
        return abstract_params(self.spec, self.cfg.dtype)

    def axes(self):
        return param_axes(self.spec)

    def param_count(self) -> int:
        return param_count(self.spec)

    def forward(self, params, batch, *, remat="none", scan_layers=True,
                last_only=False):
        return tfm.forward(params, batch, self.cfg, remat=remat,
                           scan_layers=scan_layers, last_only=last_only)

    def loss(self, params, batch, *, remat="none", scan_layers=True):
        return tfm.loss_fn(params, batch, self.cfg, remat=remat,
                           scan_layers=scan_layers)

    def init_cache(self, batch: int, max_seq: int, *, abstract=False):
        return tfm.init_cache(self.cfg, batch, max_seq, abstract=abstract)

    def cache_axes(self):
        return tfm.cache_axes(self.cfg)

    def decode_step(self, params, cache, token, pos):
        return tfm.decode_step(params, cache, token, pos, self.cfg)

    def prefill(self, params, batch, max_seq=None):
        return tfm.prefill(params, batch, self.cfg, max_seq=max_seq)

    def prime_cross_cache(self, params, cache, image_embeds):
        return tfm.prime_cross_cache(params, cache, image_embeds, self.cfg)


def build_model(arch: ArchConfig | ModelConfig) -> Model:
    cfg = arch.model if isinstance(arch, ArchConfig) else arch
    return Model(cfg)
