"""Capacity-based top-k Mixture-of-Experts with scatter dispatch.

Design notes (roofline-driven):
- The classic one-hot dispatch einsum costs O(T*E*C*D) FLOPs -- for grok-1 at
  train_4k that is ~13x the useful expert FLOPs, wrecking the
  MODEL_FLOPS/HLO_FLOPS ratio.  We instead dispatch with scatter-add/gather
  (no matmul FLOPs), GShard-style *grouped* so each data shard's tokens stay
  local: buffers are (G, E, C, D) with G == number of data shards, so the
  scatter/gather are batched ops with the G dim sharded over ("pod","data")
  and never cross the data axis.
- Expert weights: expert axis sharded over "model" when divisible (EP,
  deepseek 64e), else each expert's d_ff is TP-sharded (grok 8e).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import hint
from repro.models.common import spec


def moe_spec(cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff or cfg.d_ff
    s = {
        "router": spec((d, e), ("embed", "experts"), d ** -0.5),
        "w_gate": spec((e, d, f), ("experts", "embed", "ff"), d ** -0.5),
        "w_up": spec((e, d, f), ("experts", "embed", "ff"), d ** -0.5),
        "w_down": spec((e, f, d), ("experts", "ff", "embed"),
                       f ** -0.5 / (2 * cfg.num_layers) ** 0.5),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        s["shared"] = {
            "w_gate": spec((d, fs), ("embed", "ff"), d ** -0.5),
            "w_up": spec((d, fs), ("embed", "ff"), d ** -0.5),
            "w_down": spec((fs, d), ("ff", "embed"),
                           fs ** -0.5 / (2 * cfg.num_layers) ** 0.5),
        }
    return s


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = int(tokens_per_group * cfg.experts_per_token * cfg.capacity_factor
            / cfg.num_experts)
    return max(c, cfg.experts_per_token)


def moe_block(x: jax.Array, p, cfg: ModelConfig, groups: int = 1):
    """x (b,s,d) -> (y (b,s,d), aux_loss scalar)."""
    b, s, d = x.shape
    T = b * s
    G = groups if T % groups == 0 else 1
    Tg = T // G
    E, K = cfg.num_experts, cfg.experts_per_token
    C = _capacity(Tg, cfg)

    xt = x.reshape(G, Tg, d)
    xt = hint(xt, "group", None, "embed")
    logits = jnp.einsum("gtd,de->gte", xt, p["router"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                       # (G,Tg,E)
    gate_k, idx_k = jax.lax.top_k(gates, K)                       # (G,Tg,K)
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e frac_tokens_e * mean_gate_e
    me = jnp.mean(gates, axis=(0, 1))
    ce = jnp.mean((jax.nn.one_hot(idx_k, E).sum(2)), axis=(0, 1)) / K
    aux = E * jnp.sum(me * ce)

    # queue position of each assignment within its expert (token-major order)
    idx_flat = idx_k.reshape(G, Tg * K)                           # (G, A)
    onehot = jax.nn.one_hot(idx_flat, E, dtype=jnp.int32)         # (G, A, E)
    pos = jnp.cumsum(onehot, axis=1) - onehot                     # exclusive
    pos = jnp.take_along_axis(pos, idx_flat[..., None], axis=-1)[..., 0]
    valid = pos < C
    slot = jnp.where(valid, idx_flat * C + pos, E * C)            # drop -> overflow

    # dispatch: batched scatter into (G, E*C+1, d)
    upd = jnp.repeat(xt, K, axis=1).reshape(G, Tg * K, d)

    def scatter_g(sl, up):
        return jnp.zeros((E * C + 1, d), up.dtype).at[sl].add(up)

    buf = jax.vmap(scatter_g)(slot, upd)[:, : E * C].reshape(G, E, C, d)
    buf = hint(buf, "group", "experts", None, "embed")

    # expert compute (SwiGLU)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    h = hint(h, "group", "experts", None, "ff")
    out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    out = hint(out, "group", "experts", None, "embed")

    # combine: gather back to tokens, weight by (renormalized) gates
    out_flat = out.reshape(G, E * C, d)
    out_flat = jnp.concatenate(
        [out_flat, jnp.zeros((G, 1, d), out_flat.dtype)], axis=1)
    y = jax.vmap(lambda o, sl: o[sl])(out_flat, slot)             # (G, A, d)
    w = (gate_k.reshape(G, Tg * K) * valid).astype(y.dtype)
    y = (y * w[..., None]).reshape(G, Tg, K, d).sum(axis=2)

    if cfg.num_shared_experts:
        sh = p["shared"]
        hs = jax.nn.silu(xt @ sh["w_gate"]) * (xt @ sh["w_up"])
        y = y + hs @ sh["w_down"]

    return y.reshape(b, s, d), aux
