"""Unified model builder for all 10 assigned architectures.

Families: dense | moe (grok / deepseek-MLA) | encoder (hubert) | vlm
(llama-3.2-vision) | ssm (mamba2) | hybrid (zamba2).

All families share: scan-over-layers with stacked params (small HLO, fast
compile for the 512-device dry-run), RMSNorm, RoPE, fp32 logits, and a
decode path against an explicit cache pytree.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import current as sharding_ctx, hint
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (
    abstract_params, cross_entropy, init_params, param_axes, rms_norm, spec,
    stack_spec,
)

AUX_COEF = 0.01  # load-balance loss weight


# ================================================================ specs ======

def mlp_spec(cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    out_scale = f ** -0.5 / (2 * cfg.num_layers) ** 0.5
    s = {"w_up": spec((d, f), ("embed", "ff"), d ** -0.5),
         "w_down": spec((f, d), ("ff", "embed"), out_scale)}
    if cfg.act == "swiglu":
        s["w_gate"] = spec((d, f), ("embed", "ff"), d ** -0.5)
    return s


def _attn_spec(cfg: ModelConfig):
    return attn.mla_spec(cfg) if cfg.use_mla else attn.gqa_spec(cfg)


def _block_spec(cfg: ModelConfig, kind: str):
    ln = lambda: spec((cfg.d_model,), ("embed",), 1.0)  # noqa: E731
    if kind == "attn_mlp":
        return {"ln1": ln(), "attn": _attn_spec(cfg), "ln2": ln(),
                "mlp": mlp_spec(cfg)}
    if kind == "attn_moe":
        return {"ln1": ln(), "attn": _attn_spec(cfg), "ln2": ln(),
                "moe": moe_mod.moe_spec(cfg)}
    if kind == "attn_dense_first":  # deepseek layer 0
        return {"ln1": ln(), "attn": _attn_spec(cfg), "ln2": ln(),
                "mlp": mlp_spec(cfg, cfg.dense_d_ff)}
    if kind == "cross":
        return {"ln1": ln(), "attn": attn.gqa_spec(cfg), "ln2": ln(),
                "mlp": mlp_spec(cfg)}
    if kind == "ssm":
        return {"ln": ln(), "mixer": ssm_mod.ssm_spec(cfg)}
    raise ValueError(kind)


def model_spec(cfg: ModelConfig):
    d, v = cfg.d_model, cfg.vocab_size
    s: dict[str, Any] = {}
    if not cfg.is_encoder:
        s["embed"] = spec((v, d), ("vocab", "embed"), 1.0 / (d ** 0.5))
    s["final_norm"] = spec((d,), ("embed",), 1.0)
    s["unembed"] = spec((d, v), ("embed", "vocab"), d ** -0.5)

    fam = cfg.family
    if fam in ("dense", "encoder"):
        s["blocks"] = stack_spec(_block_spec(cfg, "attn_mlp"), cfg.num_layers)
    elif fam == "moe":
        n_moe = cfg.num_layers - cfg.first_k_dense
        if cfg.first_k_dense:
            s["first"] = stack_spec(_block_spec(cfg, "attn_dense_first"),
                                    cfg.first_k_dense)
        s["blocks"] = stack_spec(_block_spec(cfg, "attn_moe"), n_moe)
    elif fam == "vlm":
        k = cfg.cross_attn_every
        assert cfg.num_layers % k == 0
        g = cfg.num_layers // k
        s["blocks"] = stack_spec({
            "self": stack_spec(_block_spec(cfg, "attn_mlp"), k - 1, "inner"),
            "cross": _block_spec(cfg, "cross"),
        }, g)
    elif fam == "ssm":
        s["blocks"] = stack_spec(_block_spec(cfg, "ssm"), cfg.num_layers)
    elif fam == "hybrid":
        k = cfg.attn_every
        assert cfg.num_layers % k == 0
        g = cfg.num_layers // k
        s["blocks"] = stack_spec(
            {"ssm": stack_spec(_block_spec(cfg, "ssm"), k, "inner")}, g)
        s["shared_attn"] = _block_spec(cfg, "attn_mlp")  # ONE copy, reused
    else:
        raise ValueError(fam)
    return s


# ============================================================ forward ========

def mlp_apply(x, p, cfg: ModelConfig):
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    h = hint(h, "batch", None, "ff")
    # output hinted seq-sharded so the TP partial-sum lowers to
    # reduce-scatter (Megatron-SP) instead of all-reduce + slice (§Perf L3)
    return hint(h @ p["w_down"], "batch", "seq", "embed")


def _self_attn(x, p, cfg, *, causal, positions):
    if cfg.use_mla:
        return attn.mla_attention(x, p, cfg, causal=causal, positions=positions)
    return attn.gqa_attention(x, p, cfg, causal=causal, positions=positions)


def _attn_block(x, p, cfg, *, causal, positions, ff_fn):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + _self_attn(h, p["attn"], cfg, causal=causal, positions=positions)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + ff_fn(h)
    return hint(x, "batch", "seq", "embed")


def _cross_block(x, p, cfg, *, img):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    x = x + attn.gqa_attention(h, p["attn"], cfg, causal=False, positions=None,
                               kv_src=img, use_rope=False)
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + mlp_apply(h, p["mlp"], cfg)
    return x


def _ssm_block(x, p, cfg):
    return x + ssm_mod.mamba2_block(rms_norm(x, p["ln"], cfg.norm_eps),
                                    p["mixer"], cfg)


def _wrap_remat(fn, remat: str):
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if remat == "full":
        return jax.checkpoint(fn)
    return fn


def scan_blocks(body, carry, xs, scan: bool = True):
    """lax.scan or an unrolled Python loop (same contract).

    Unrolling lets XLA overlap per-layer collectives across layers (a §Perf
    lever) at the cost of compile time; scan keeps the 512-device dry-run
    HLO small.
    """
    if scan:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _moe_groups() -> int:
    ctx = sharding_ctx()
    if ctx is None:
        return 1
    axes = ctx.map.get("batch") or ()
    g = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        g *= ctx.mesh.shape[a]
    return max(g, 1)


def forward(params, batch, cfg: ModelConfig, *, remat: str = "none",
            last_only: bool = False, scan_layers: bool = True):
    """-> (logits (b,s,v) fp32, aux scalar). last_only: unembed final position
    only (prefill lowering: avoids a (b,s,vocab) logits buffer)."""
    fam = cfg.family
    causal = not cfg.is_encoder
    if cfg.is_encoder:
        x = batch["frames"].astype(jnp.dtype(cfg.dtype))
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    x = hint(x, "batch", "seq", "embed")
    s = x.shape[1]
    positions = jnp.arange(s)
    aux0 = jnp.zeros((), jnp.float32)

    if fam in ("dense", "encoder"):
        def body(carry, bp):
            return _attn_block(carry, bp, cfg, causal=causal, positions=positions,
                               ff_fn=lambda h: mlp_apply(h, bp["mlp"], cfg)), None
        x, _ = scan_blocks(_wrap_remat(body, remat), x, params["blocks"], scan_layers)
        aux = aux0

    elif fam == "moe":
        groups = _moe_groups()
        if cfg.first_k_dense:
            def fbody(carry, bp):
                return _attn_block(carry, bp, cfg, causal=True,
                                   positions=positions,
                                   ff_fn=lambda h: mlp_apply(h, bp["mlp"], cfg)), None
            x, _ = scan_blocks(_wrap_remat(fbody, remat), x, params["first"], scan_layers)

        def body(carry, bp):
            x, aux = carry
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            x = x + _self_attn(h, bp["attn"], cfg, causal=True, positions=positions)
            h = rms_norm(x, bp["ln2"], cfg.norm_eps)
            y, a = moe_mod.moe_block(h, bp["moe"], cfg, groups)
            x = hint(x + y, "batch", "seq", "embed")
            return (x, aux + a), None
        (x, aux), _ = scan_blocks(_wrap_remat(body, remat), (x, aux0),
                                  params["blocks"], scan_layers)

    elif fam == "vlm":
        img = batch["image_embeds"].astype(x.dtype)

        def body(carry, bp):
            def inner(c, ip):
                return _attn_block(c, ip, cfg, causal=True, positions=positions,
                                   ff_fn=lambda h: mlp_apply(h, ip["mlp"], cfg)), None
            c, _ = scan_blocks(inner, carry, bp["self"], scan_layers)
            return _cross_block(c, bp["cross"], cfg, img=img), None
        x, _ = scan_blocks(_wrap_remat(body, remat), x, params["blocks"], scan_layers)
        aux = aux0

    elif fam == "ssm":
        def body(carry, bp):
            return _ssm_block(carry, bp, cfg), None
        x, _ = scan_blocks(_wrap_remat(body, remat), x, params["blocks"], scan_layers)
        aux = aux0

    elif fam == "hybrid":
        shared = params["shared_attn"]

        def body(carry, bp):
            def inner(c, ip):
                return _ssm_block(c, ip, cfg), None
            c, _ = scan_blocks(inner, carry, bp["ssm"], scan_layers)
            c = _attn_block(c, shared, cfg, causal=True, positions=positions,
                            ff_fn=lambda h: mlp_apply(h, shared["mlp"], cfg))
            return c, None
        x, _ = scan_blocks(_wrap_remat(body, remat), x, params["blocks"], scan_layers)
        aux = aux0
    else:
        raise ValueError(fam)

    if last_only:
        x = x[:, -1:, :]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    # logits stay in the model dtype; cross_entropy does fp32 logsumexp
    # internally.  (§Perf iteration D8: a preferred_element_type=f32 here
    # made every backward cotangent fp32, doubling gradient all-reduce and
    # activation-gradient traffic model-wide.)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    logits = hint(logits, "batch", "seq", "vocab")
    return logits.astype(jnp.float32) if last_only else logits, aux


def loss_fn(params, batch, cfg: ModelConfig, *, remat: str = "none",
            scan_layers: bool = True):
    logits, aux = forward(params, batch, cfg, remat=remat, scan_layers=scan_layers)
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    total = loss + AUX_COEF * aux
    return total, {"loss": loss, "aux": aux}


# ============================================================= cache =========

def _kv_cache_leaf(cfg, n, b, s, dtype, stack=()):
    m, k = cfg.kv_heads, cfg.hdim
    shape = tuple(stack) + (b, s, m, k)
    axes = tuple("layers" for _ in stack) + ("batch", "kv_seq", "kv_heads", "head_dim")
    return shape, axes, dtype


def cache_struct(cfg: ModelConfig, batch: int, max_seq: int):
    """-> pytree of (shape, logical_axes, dtype) describing the decode cache."""
    dt = jnp.dtype(cfg.dtype)
    fam = cfg.family
    if fam == "dense":
        kv = _kv_cache_leaf(cfg, cfg.num_layers, batch, max_seq, dt,
                            (cfg.num_layers,))
        return {"k": kv, "v": kv}
    if fam == "moe":
        nl = cfg.num_layers
        if cfg.use_mla:
            r, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
            return {
                "ckv": ((nl, batch, max_seq, r),
                        ("layers", "batch", "kv_seq", "lora"), dt),
                "krope": ((nl, batch, max_seq, dr),
                          ("layers", "batch", "kv_seq", "head_dim"), dt),
            }
        kv = _kv_cache_leaf(cfg, nl, batch, max_seq, dt, (nl,))
        return {"k": kv, "v": kv}
    if fam == "vlm":
        g = cfg.num_layers // cfg.cross_attn_every
        inner = cfg.cross_attn_every - 1
        m, k = cfg.kv_heads, cfg.hdim
        kv = ((g, inner, batch, max_seq, m, k),
              ("layers", "layers", "batch", "kv_seq", "kv_heads", "head_dim"), dt)
        xkv = ((g, batch, cfg.num_image_tokens, m, k),
               ("layers", "batch", "img_seq", "kv_heads", "head_dim"), dt)
        return {"k": kv, "v": kv, "xk": xkv, "xv": xkv}
    if fam == "ssm":
        nl, w = cfg.num_layers, cfg.conv_width
        return {
            "conv_x": ((nl, batch, w - 1, cfg.d_inner),
                       ("layers", "batch", "conv", "ff"), dt),
            "conv_B": ((nl, batch, w - 1, cfg.ssm_state),
                       ("layers", "batch", "conv", "state"), dt),
            "conv_C": ((nl, batch, w - 1, cfg.ssm_state),
                       ("layers", "batch", "conv", "state"), dt),
            "state": ((nl, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                      ("layers", "batch", "heads", None, "state"), jnp.float32),
        }
    if fam == "hybrid":
        g = cfg.num_layers // cfg.attn_every
        k = cfg.attn_every
        w = cfg.conv_width
        m, hd = cfg.kv_heads, cfg.hdim
        return {
            "conv_x": ((g, k, batch, w - 1, cfg.d_inner),
                       ("layers", "layers", "batch", "conv", "ff"), dt),
            "conv_B": ((g, k, batch, w - 1, cfg.ssm_state),
                       ("layers", "layers", "batch", "conv", "state"), dt),
            "conv_C": ((g, k, batch, w - 1, cfg.ssm_state),
                       ("layers", "layers", "batch", "conv", "state"), dt),
            "state": ((g, k, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                      ("layers", "layers", "batch", "heads", None, "state"),
                      jnp.float32),
            "attn_k": ((g, batch, max_seq, m, hd),
                       ("layers", "batch", "kv_seq", "kv_heads", "head_dim"), dt),
            "attn_v": ((g, batch, max_seq, m, hd),
                       ("layers", "batch", "kv_seq", "kv_heads", "head_dim"), dt),
        }
    raise ValueError(f"{fam} has no decode cache")


def _is_leaf(x):
    return isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, *, abstract=False):
    st = cache_struct(cfg, batch, max_seq)
    if abstract:
        return jax.tree.map(lambda t: jax.ShapeDtypeStruct(t[0], t[2]), st,
                            is_leaf=_is_leaf)
    return jax.tree.map(lambda t: jnp.zeros(t[0], t[2]), st, is_leaf=_is_leaf)


def cache_axes(cfg: ModelConfig, batch: int = 1, max_seq: int = 8):
    return jax.tree.map(lambda t: t[1], cache_struct(cfg, batch, max_seq),
                        is_leaf=_is_leaf)


# ============================================================ decode =========

def prime_cross_cache(params, cache, image_embeds, cfg: ModelConfig):
    """VLM: fill the per-group cross-attention K/V from the image embeddings.

    Must be called once before decode (the cross K/V are position-independent,
    so they are computed exactly once, not per decode step).
    """
    assert cfg.family == "vlm"
    img = image_embeds.astype(jnp.dtype(cfg.dtype))

    def one(bp):
        cp = bp["cross"]
        k = jnp.einsum("btd,dmk->btmk", img, cp["attn"]["wk"])
        v = jnp.einsum("btd,dmk->btmk", img, cp["attn"]["wv"])
        return k, v

    ks, vs = jax.vmap(one)(params["blocks"])
    cache = dict(cache)
    cache["xk"] = ks.astype(cache["xk"].dtype)
    cache["xv"] = vs.astype(cache["xv"].dtype)
    return cache


def scan_decode(body, x0, xs, cache):
    """scan over layers with the cache as an IN-PLACE carry.

    ``body(x, xs_i, cache_slice) -> (x, new_cache_slice)``; cache leaves are
    stacked (L, ...).  Carrying the full cache and dynamic-update-slicing at
    the layer index keeps XLA's while-carry aliasing in place -- the
    xs->ys formulation double-buffered the whole multi-GB cache every layer
    (42 % of decode HBM traffic for llama3-405b; §Perf decode diagnosis).
    Read-only per-layer tensors belong in ``xs`` instead.
    """
    leaves, tdef = jax.tree.flatten(cache)

    def f(carry, xs_i):
        x, cl, i = carry
        sl = [jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False)
              for a in cl]
        x, new_slice = body(x, xs_i, jax.tree.unflatten(tdef, sl))
        new_leaves = tdef.flatten_up_to(new_slice)
        cl = [jax.lax.dynamic_update_index_in_dim(a, ns.astype(a.dtype), i, 0)
              for a, ns in zip(cl, new_leaves)]
        return (x, cl, i + 1), None

    (x, leaves, _), _ = jax.lax.scan(f, (x0, leaves, jnp.int32(0)), xs)
    return x, jax.tree.unflatten(tdef, leaves)


def _mlp_ff(p, cfg):
    return lambda h: mlp_apply(h, p, cfg)


def _attn_block_decode(x1, p, cfg, ck, cv, pos):
    h = rms_norm(x1, p["ln1"], cfg.norm_eps)
    a, ck, cv = attn.gqa_decode(h, p["attn"], cfg, ck, cv, pos)
    x1 = x1 + a
    h = rms_norm(x1, p["ln2"], cfg.norm_eps)
    return x1 + mlp_apply(h, p["mlp"], cfg), ck, cv


def _ssm_block_decode(x1, p, cfg, cache):
    h = rms_norm(x1, p["ln"], cfg.norm_eps)
    y, new_cache = ssm_mod.mamba2_block(h, p["mixer"], cfg, cache=cache,
                                        single_step=True)
    return x1 + y, new_cache


def decode_step(params, cache, token, pos, cfg: ModelConfig):
    """token (b,) int32; pos scalar int32 -> (logits (b,v) fp32, new cache)."""
    fam = cfg.family
    x = jnp.take(params["embed"], token[:, None], axis=0)  # (b,1,d)

    if fam == "dense":
        def body(carry, bp, sl):
            y, ck, cv = _attn_block_decode(carry, bp, cfg, sl["k"], sl["v"],
                                           pos)
            return y, {"k": ck, "v": cv}
        x, cache = scan_decode(body, x, params["blocks"],
                               {"k": cache["k"], "v": cache["v"]})

    elif fam == "moe":
        groups = 1
        if cfg.first_k_dense:
            def fbody(carry, bp, sl):
                h = rms_norm(carry, bp["ln1"], cfg.norm_eps)
                a, ckv, kr = attn.mla_decode(h, bp["attn"], cfg, sl["ckv"],
                                             sl["krope"], pos)
                carry = carry + a
                h = rms_norm(carry, bp["ln2"], cfg.norm_eps)
                return (carry + mlp_apply(h, bp["mlp"], cfg),
                        {"ckv": ckv, "krope": kr})
            nf = cfg.first_k_dense
            x, first_c = scan_decode(fbody, x, params["first"],
                                     {"ckv": cache["ckv"][:nf],
                                      "krope": cache["krope"][:nf]})

        def body(carry, bp, sl):
            h = rms_norm(carry, bp["ln1"], cfg.norm_eps)
            if cfg.use_mla:
                a, c1, c2 = attn.mla_decode(h, bp["attn"], cfg, sl["a"],
                                            sl["b"], pos)
            else:
                a, c1, c2 = attn.gqa_decode(h, bp["attn"], cfg, sl["a"],
                                            sl["b"], pos)
            carry = carry + a
            h = rms_norm(carry, bp["ln2"], cfg.norm_eps)
            y, _ = moe_mod.moe_block(h, bp["moe"], cfg, groups)
            return carry + y, {"a": c1, "b": c2}

        if cfg.use_mla:
            nf = cfg.first_k_dense
            x, main_c = scan_decode(body, x, params["blocks"],
                                    {"a": cache["ckv"][nf:],
                                     "b": cache["krope"][nf:]})
            if cfg.first_k_dense:
                cache = {"ckv": jnp.concatenate([first_c["ckv"], main_c["a"]]),
                         "krope": jnp.concatenate([first_c["krope"],
                                                   main_c["b"]])}
            else:
                cache = {"ckv": main_c["a"], "krope": main_c["b"]}
        else:
            x, main_c = scan_decode(body, x, params["blocks"],
                                    {"a": cache["k"], "b": cache["v"]})
            cache = {"k": main_c["a"], "v": main_c["b"]}

    elif fam == "vlm":
        def body(carry, xs, sl):
            bp, xk, xv = xs

            def inner(c, ip, isl):
                y, ick, icv = _attn_block_decode(c, ip, cfg, isl["k"],
                                                 isl["v"], pos)
                return y, {"k": ick, "v": icv}
            c, new_inner = scan_decode(inner, carry, bp["self"],
                                       {"k": sl["k"], "v": sl["v"]})
            # cross-attn against cached image K/V
            cp = bp["cross"]
            h = rms_norm(c, cp["ln1"], cfg.norm_eps)
            b = h.shape[0]
            q = jnp.einsum("bsd,dhk->bshk", h, cp["attn"]["wq"])
            m = cfg.kv_heads
            g = cfg.num_heads // m
            qg = q.reshape(b, m, g, cfg.hdim)
            sc = jnp.einsum("bmgk,btmk->bmgt", qg, xk) / (cfg.hdim ** 0.5)
            pr = jax.nn.softmax(sc.astype(jnp.float32), axis=-1).astype(xv.dtype)
            o = jnp.einsum("bmgt,btmv->bmgv", pr, xv)
            o = o.reshape(b, 1, cfg.num_heads, cfg.hdim)
            c = c + jnp.einsum("bshk,hkd->bsd", o, cp["attn"]["wo"])
            h = rms_norm(c, cp["ln2"], cfg.norm_eps)
            c = c + mlp_apply(h, cp["mlp"], cfg)
            return c, new_inner
        x, new_kv = scan_decode(
            body, x, (params["blocks"], cache["xk"], cache["xv"]),
            {"k": cache["k"], "v": cache["v"]})
        cache = {"k": new_kv["k"], "v": new_kv["v"],
                 "xk": cache["xk"], "xv": cache["xv"]}

    elif fam == "ssm":
        def body(carry, bp, sl):
            return _ssm_block_decode(carry, bp, cfg, sl)
        x, cache = scan_decode(
            body, x, params["blocks"],
            {k: cache[k] for k in ("conv_x", "conv_B", "conv_C", "state")})

    elif fam == "hybrid":
        shared = params["shared_attn"]

        def body(carry, bp, sl):
            def inner(c, ip, isl):
                return _ssm_block_decode(c, ip, cfg, isl)
            ssm_sl = {k: sl[k] for k in ("conv_x", "conv_B", "conv_C",
                                         "state")}
            c, n_ssm = scan_decode(inner, carry, bp["ssm"], ssm_sl)
            y, ck, cv = _attn_block_decode(c, shared, cfg, sl["attn_k"],
                                           sl["attn_v"], pos)
            n_ssm.update({"attn_k": ck, "attn_v": cv})
            return y, n_ssm
        x, cache = scan_decode(body, x, params["blocks"],
                               {k: cache[k] for k in cache})
    else:
        raise ValueError(f"{fam} does not support decode")

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"],
                        preferred_element_type=jnp.float32)
    return logits[:, 0, :], cache


# ============================================================ prefill ========

def prefill(params, batch, cfg: ModelConfig, max_seq: int | None = None):
    """Run the prompt, return (logits_last (b,v), filled cache).

    For simplicity the cache is sized to the prompt length (or max_seq) and
    K/V are recomputed via the standard forward plus per-layer K/V capture.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    S = max_seq or s
    logits, _ = forward(params, batch, cfg)
    cache = init_cache(cfg, b, S)
    positions = jnp.arange(s)
    x = jnp.take(params["embed"], tokens, axis=0)
    fam = cfg.family

    if fam in ("dense", "moe") and not cfg.use_mla:
        def body(carry, bp):
            h = rms_norm(carry, bp["ln1"], cfg.norm_eps)
            k, v = attn.gqa_prefill_kv(h, bp["attn"], cfg, positions=positions)
            if fam == "dense":
                ff = _mlp_ff(bp["mlp"], cfg)
                carry = _attn_block(carry, bp, cfg, causal=True,
                                    positions=positions, ff_fn=ff)
            else:
                h2 = rms_norm(carry, bp["ln1"], cfg.norm_eps)
                carry = carry + _self_attn(h2, bp["attn"], cfg, causal=True,
                                           positions=positions)
                hh = rms_norm(carry, bp["ln2"], cfg.norm_eps)
                y, _a = moe_mod.moe_block(hh, bp["moe"], cfg, _moe_groups())
                carry = carry + y
            return carry, (k, v)
        _, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
        return logits[:, -1, :], cache

    raise NotImplementedError(
        f"prefill cache capture for family {fam!r}: use decode-from-scratch or "
        "the serving layer")
