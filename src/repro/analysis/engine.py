"""The lint engine: parsed-module cache, findings, suppressions, runner.

``repro lint`` (DESIGN.md §15) statically enforces the contracts every PR
in this repo leans on -- metered cost/clock discipline, seeded determinism,
the string-grammar registries, and the spec-hash schema-evolution rules.
Checkers (:mod:`repro.analysis.checkers`) are registered on the same
string-grammar convention as the sync/comm/scaling/arrivals registries and
all operate over one shared :class:`ModuleCache`, so the tree is read and
parsed exactly once per run no matter how many checkers are selected.

A finding renders as ``file:line CODE message`` (or structured JSON with
``--format json``).  Any finding can be silenced on its line with a
suppression comment naming the code::

    t0 = time.time()   # lint: ignore[D001] -- wall-clock benchmark harness
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional

__all__ = ["Finding", "ParsedModule", "ModuleCache", "LintEngine",
           "REPO_ROOT", "render_text", "render_json"]

#: repo root (``src/repro/analysis/engine.py`` -> three parents up from src)
REPO_ROOT = Path(__file__).resolve().parents[3]

#: directories the default lint run covers, relative to the repo root
DEFAULT_TREES = ("src/repro", "benchmarks")

_SUPPRESS_RE = re.compile(r"#\s*lint:\s*ignore\[([A-Za-z0-9_*,\s]+)\]")


@dataclass(frozen=True)
class Finding:
    """One structured lint finding -- ``file:line CODE message``."""

    file: str          # repo-relative posix path
    line: int
    code: str          # e.g. "D001"
    message: str
    checker: str = ""  # registry name of the checker that produced it

    def render(self) -> str:
        return f"{self.file}:{self.line} {self.code} {self.message}"

    def to_dict(self) -> dict:
        return asdict(self)


class ParsedModule:
    """One source file parsed once: AST + raw lines + suppression map."""

    def __init__(self, path: Path, rel: str):
        self.path = path
        self.rel = rel
        self.source = path.read_text()
        self.lines = self.source.splitlines()
        self.tree = ast.parse(self.source, filename=rel)
        # line -> set of suppressed codes ("*" = all)
        self.suppressed: Dict[int, set] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if m:
                codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
                self.suppressed[i] = codes

    def is_suppressed(self, line: int, code: str) -> bool:
        codes = self.suppressed.get(line)
        return bool(codes) and (code in codes or "*" in codes)


class ModuleCache:
    """The shared parse layer every checker reads from.

    Modules are parsed lazily and exactly once; checkers iterate
    :meth:`modules` (optionally filtered by repo-relative path prefixes) and
    never call ``ast.parse`` themselves.  ``force_all=True`` (explicit CLI
    paths / fixture tests) makes every file visible to every checker
    regardless of the checker's default scope.
    """

    def __init__(self, root: Path = REPO_ROOT,
                 files: Optional[Iterable[Path]] = None,
                 force_all: bool = False):
        self.root = Path(root)
        self.force_all = force_all
        if files is None:
            found: List[Path] = []
            for tree in DEFAULT_TREES:
                base = self.root / tree
                if base.is_dir():
                    found.extend(p for p in sorted(base.rglob("*.py"))
                                 if "__pycache__" not in p.parts)
            self.files = found
        else:
            self.files = [Path(f) for f in files]
        self._parsed: Dict[str, ParsedModule] = {}
        self._errors: List[Finding] = []

    def relpath(self, path: Path) -> str:
        try:
            return path.resolve().relative_to(self.root).as_posix()
        except ValueError:
            return path.as_posix()

    def get(self, path: Path) -> Optional[ParsedModule]:
        rel = self.relpath(path)
        if rel not in self._parsed:
            try:
                self._parsed[rel] = ParsedModule(path, rel)
            except SyntaxError as e:
                self._errors.append(Finding(
                    file=rel, line=e.lineno or 1, code="E999",
                    message=f"syntax error: {e.msg}", checker="engine"))
                self._parsed[rel] = None  # type: ignore[assignment]
        return self._parsed[rel]

    def load(self, relative: str) -> Optional[ParsedModule]:
        """Fetch one module by repo-relative path, whether or not it is in
        the scanned file set (the spec-hash checker reads its spec sources
        this way)."""
        return self.get(self.root / relative)

    def modules(self, prefixes: Iterable[str] = ()) -> Iterable[ParsedModule]:
        """Parsed modules whose repo-relative path starts with any prefix
        (all files when no prefix is given or the cache is forced)."""
        prefixes = tuple(prefixes)
        for path in self.files:
            rel = self.relpath(path)
            if (not prefixes or self.force_all
                    or any(rel.startswith(p) for p in prefixes)):
                mod = self.get(path)
                if mod is not None:
                    yield mod

    @property
    def parse_errors(self) -> List[Finding]:
        return list(self._errors)


class LintEngine:
    """Run a selection of checkers over one shared cache."""

    def __init__(self, checkers: Iterable, cache: ModuleCache):
        self.checkers = list(checkers)
        self.cache = cache

    def run(self) -> List[Finding]:
        findings: List[Finding] = []
        for checker in self.checkers:
            for f in checker.run(self.cache):
                mod = self.cache._parsed.get(f.file)
                if mod is not None and mod.is_suppressed(f.line, f.code):
                    continue
                findings.append(f)
        findings.extend(self.cache.parse_errors)
        findings.sort(key=lambda f: (f.file, f.line, f.code))
        return findings


# ------------------------------------------------------------- rendering ----

def render_text(findings: List[Finding], n_files: int) -> str:
    lines = [f.render() for f in findings]
    noun = "finding" if len(findings) == 1 else "findings"
    lines.append(f"# {len(findings)} {noun} in {n_files} file(s)")
    return "\n".join(lines)


def render_json(findings: List[Finding], n_files: int) -> str:
    by_code: Dict[str, int] = {}
    for f in findings:
        by_code[f.code] = by_code.get(f.code, 0) + 1
    return json.dumps({
        "schema": "repro.lint/v1",
        "files": n_files,
        "findings": [f.to_dict() for f in findings],
        "summary": {"total": len(findings),
                    "by_code": dict(sorted(by_code.items()))},
    }, indent=1)
