"""The checker registry: six project-invariant lints (DESIGN.md §15).

Each checker guards a contract a previous PR established dynamically and
nothing enforced statically:

==============  =====  ======================================================
checker         codes  invariant (establishing PR)
==============  =====  ======================================================
determinism     D001   no wall clocks in simulator code -- every second is
                       simulated (PR 1's metering discipline)
                D002   no unseeded/global RNG -- runs replay byte-identically
                       from ``seed`` (PR 1/2 parity pins)
spec_hash       H001-3 frozen spec field sets may only change together with
                       their HASH_SCHEMA salt + committed manifest (PR 3's
                       cache-evolution contract, re-keyed in PRs 5/6)
registry        R001   every registered grammar name surfaces in
                       ``repro list`` (PR 2's discoverability rule)
                R002   every registry keeps a parse round-trip test
                       (PR 4/5/6 convention)
units           U001   metering names use the canonical ``_s``/``_usd``/
                       ``_bytes``/``_gb`` suffixes, not ad-hoc aliases
                U002   no +/- arithmetic across different unit suffixes
metering        M001   metered cost/clock attributes mutate only inside the
                       engine/platform/comm home modules (PR 1/5/6)
                M002   the billing hooks (``finalize_cost``/``resize_cost``/
                       ``retire_cost``) are called only by the engine and
                       the elastic telemetry path (PR 5)
constants       C001   measured Table-6/pricing/roofline constants live in
                       exactly one module each -- no re-hardcoded copies
                       (the "two implementations of one cost" rule PRs 3-5
                       repeatedly paid down)
==============  =====  ======================================================

Checkers are selected by name on the same string-grammar convention as the
sync/comm/scaling/arrivals registries: ``repro lint --select units,metering``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, Iterator, List, Optional, Set, Tuple

from repro.analysis.engine import Finding, ModuleCache, ParsedModule

__all__ = ["CHECKERS", "Checker", "make_checker", "list_checkers",
           "select_checkers"]


class Checker:
    """Protocol-by-convention: a named pass over the shared module cache."""

    name: str = "?"
    description: str = ""
    codes: Dict[str, str] = {}
    #: repo-relative path prefixes the checker scans by default
    scope: Tuple[str, ...] = ()
    #: tree-level checkers reason about the whole repo (registries, the
    #: spec-hash manifest) and are skipped when explicit paths are linted,
    #: unless selected by name
    tree_level: bool = False

    def run(self, cache: ModuleCache) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, mod_or_rel, line: int, code: str,
                message: str) -> Finding:
        rel = (mod_or_rel.rel if isinstance(mod_or_rel, ParsedModule)
               else mod_or_rel)
        return Finding(file=rel, line=line, code=code, message=message,
                       checker=self.name)


# ------------------------------------------------------------ determinism ---

#: wall-clock callables on the stdlib time module
_WALL_TIME_FUNCS = {"time", "time_ns", "perf_counter", "perf_counter_ns",
                    "monotonic", "monotonic_ns", "process_time",
                    "process_time_ns"}
_WALL_DT_FUNCS = {"now", "utcnow", "today"}
#: the seeded numpy constructors that ARE allowed
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "BitGenerator",
                 "PCG64", "PCG64DXSM", "Philox", "SFC64", "MT19937"}


class DeterminismChecker(Checker):
    """Simulator code may not read wall clocks or unseeded RNG state.

    Every second and every random draw in ``core/``, ``serving/`` and
    ``experiments/`` must come from the simulated clock and an explicit
    ``np.random.default_rng(seed)`` / ``jax.random.key(seed)`` -- that is
    what makes every record in ``experiments/runs/`` replayable.  The
    ``launch/`` entry points and ``benchmarks/`` time real hardware and are
    deliberately out of scope.
    """

    name = "determinism"
    description = ("no wall clocks / unseeded RNG in simulator code "
                   "(core, serving, experiments)")
    codes = {"D001": "wall-clock read in simulated code",
             "D002": "unseeded or global RNG"}
    scope = ("src/repro/core/", "src/repro/serving/",
             "src/repro/experiments/")

    def run(self, cache: ModuleCache) -> Iterator[Finding]:
        for mod in cache.modules(self.scope):
            yield from self._check_module(mod)

    def _check_module(self, mod: ParsedModule) -> Iterator[Finding]:
        time_mods: Set[str] = set()      # names bound to the time module
        time_funcs: Set[str] = set()     # from time import time, ...
        dt_mods: Set[str] = set()        # import datetime [as d]
        dt_classes: Set[str] = set()     # from datetime import datetime/date
        rng_mods: Set[str] = set()       # import random [as r]
        rng_funcs: Set[str] = set()      # from random import random, ...
        np_mods: Set[str] = set()        # import numpy as np

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    bound = a.asname or a.name.split(".")[0]
                    if a.name == "time":
                        time_mods.add(bound)
                    elif a.name == "datetime":
                        dt_mods.add(bound)
                    elif a.name == "random":
                        rng_mods.add(bound)
                    elif a.name in ("numpy", "numpy.random"):
                        np_mods.add(bound)
            elif isinstance(node, ast.ImportFrom):
                if node.module == "time":
                    time_funcs.update(a.asname or a.name for a in node.names
                                      if a.name in _WALL_TIME_FUNCS)
                elif node.module == "datetime":
                    dt_classes.update(a.asname or a.name for a in node.names
                                      if a.name in ("datetime", "date"))
                elif node.module == "random":
                    rng_funcs.update(a.asname or a.name for a in node.names)

        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if isinstance(fn, ast.Name):
                if fn.id in time_funcs:
                    yield self.finding(
                        mod, node.lineno, "D001",
                        f"wall-clock call {fn.id}() in simulated code; "
                        f"derive time from the simulated clock")
                elif fn.id in rng_funcs:
                    yield self.finding(
                        mod, node.lineno, "D002",
                        f"stdlib random.{fn.id}() is not seed-replayable; "
                        f"use np.random.default_rng(seed)")
                continue
            if not isinstance(fn, ast.Attribute):
                continue
            base = fn.value
            # time.time(), time.perf_counter(), ...
            if (isinstance(base, ast.Name) and base.id in time_mods
                    and fn.attr in _WALL_TIME_FUNCS):
                yield self.finding(
                    mod, node.lineno, "D001",
                    f"wall-clock call {base.id}.{fn.attr}() in simulated "
                    f"code; every second must come from the simulated clock")
            # datetime.now() / date.today() (class imported directly)
            elif (isinstance(base, ast.Name) and base.id in dt_classes
                    and fn.attr in _WALL_DT_FUNCS):
                yield self.finding(
                    mod, node.lineno, "D001",
                    f"wall-clock call {base.id}.{fn.attr}() in simulated "
                    f"code; pass timestamps in explicitly")
            # datetime.datetime.now() (module imported)
            elif (isinstance(base, ast.Attribute)
                    and base.attr in ("datetime", "date")
                    and isinstance(base.value, ast.Name)
                    and base.value.id in dt_mods
                    and fn.attr in _WALL_DT_FUNCS):
                yield self.finding(
                    mod, node.lineno, "D001",
                    f"wall-clock call via the datetime module in simulated "
                    f"code ({base.attr}.{fn.attr}())")
            # random.random(), random.randint(), random.seed(), ...
            elif isinstance(base, ast.Name) and base.id in rng_mods:
                yield self.finding(
                    mod, node.lineno, "D002",
                    f"stdlib {base.id}.{fn.attr}() is global-state RNG; "
                    f"use np.random.default_rng(seed)")
            # np.random.<legacy>() -- the seeded constructors are fine
            elif (isinstance(base, ast.Attribute) and base.attr == "random"
                    and isinstance(base.value, ast.Name)
                    and base.value.id in np_mods
                    and fn.attr not in _NP_RANDOM_OK):
                yield self.finding(
                    mod, node.lineno, "D002",
                    f"np.random.{fn.attr}() uses numpy's global RNG state; "
                    f"use np.random.default_rng(seed)")


# -------------------------------------------------------------- spec_hash ---

class SpecHashChecker(Checker):
    """Frozen spec schemas may only drift together with their salt.

    Static mirror of the ``spec_hash`` docstring contract: the dataclass
    field set (names + default source text) of every hashed spec is
    fingerprinted off the AST and compared to the committed
    ``spec_manifest.json`` (see :mod:`repro.analysis.manifest`).
    """

    name = "spec_hash"
    description = ("ExperimentSpec/ServingSpec field sets vs HASH_SCHEMA "
                   "salts vs the committed manifest")
    codes = {"H001": "spec fields changed without a salt bump",
             "H002": "salt bumped but manifest stale",
             "H003": "manifest missing or incomplete"}
    tree_level = True

    def __init__(self, manifest_path=None, specs=None):
        from repro.analysis.manifest import MANIFEST_PATH
        self.manifest_path = manifest_path or MANIFEST_PATH
        self.specs = specs

    def run(self, cache: ModuleCache) -> Iterator[Finding]:
        from repro.analysis.manifest import check_manifest
        yield from check_manifest(cache, self.manifest_path, self.specs)


# --------------------------------------------------------------- registry ---

class RegistryChecker(Checker):
    """Every string-grammar registry stays discoverable and round-trippable.

    R001: each registered name must surface in ``python -m repro list``
    (the discoverability rule: a grammar nobody can list is a grammar
    nobody sweeps).  R002: each registry must be exercised by at least one
    parse/round-trip test under ``tests/`` (the convention every registry
    PR followed).  This checker imports the live registries -- the one
    place the lint engine goes beyond the AST, because the registries are
    themselves built dynamically (dict comprehensions over CHANNEL_SPECS
    etc.) and a stale parallel list here would be exactly the drift this
    tool exists to kill.
    """

    name = "registry"
    description = ("registered grammar names appear in `repro list` and "
                   "have parse round-trip tests")
    codes = {"R001": "registry name missing from `repro list`",
             "R002": "registry has no parse round-trip test"}
    tree_level = True

    #: registry -> (defining module, registry symbol, required-any test ids)
    TABLE = {
        "sync": ("src/repro/core/sync.py", "SYNC_GRAMMARS",
                 {"make_sync", "sync_name"}),
        "transport": ("src/repro/core/comm/transports.py", "TRANSPORTS",
                      {"make_transport", "parse_stack",
                       "transport_constants"}),
        "collective": ("src/repro/core/comm/collectives.py", "COLLECTIVES",
                       {"make_collective"}),
        "codec": ("src/repro/core/comm/codecs.py", "CODECS",
                  {"make_codec"}),
        "scaling": ("src/repro/core/elastic/policies.py", "POLICIES",
                    {"make_policy", "validate_scaling"}),
        "arrivals": ("src/repro/serving/arrivals.py", "ARRIVALS",
                     {"make_arrivals"}),
        "ckpt": ("src/repro/core/ckpt/spec.py", "CKPT_TRANSPORTS",
                 {"make_ckpt", "make_ckpt_transport"}),
        "failure": ("src/repro/core/failures.py", "FAILURES",
                    {"make_failure"}),
        "checkers": ("src/repro/analysis/checkers.py", "CHECKERS",
                     {"make_checker", "select_checkers"}),
        "exporter": ("src/repro/core/trace/export.py", "EXPORTERS",
                     {"make_exporter", "list_exporters"}),
    }

    @staticmethod
    def _names(registry: str) -> List[str]:
        if registry == "sync":
            from repro.core.sync import list_syncs
            return [g.partition(":")[0].partition("[")[0]
                    for g in list_syncs()]
        if registry == "transport":
            from repro.core.comm import list_transports
            return list_transports()
        if registry == "collective":
            from repro.core.comm import list_collectives
            return list_collectives()
        if registry == "codec":
            from repro.core.comm import list_codecs
            return list_codecs()
        if registry == "scaling":
            from repro.core.elastic.policies import POLICIES
            return sorted(POLICIES) + ["plan"]
        if registry == "arrivals":
            from repro.serving.arrivals import ARRIVALS
            return sorted(ARRIVALS)
        if registry == "ckpt":
            from repro.core.ckpt import list_ckpts
            return sorted(list_ckpts())
        if registry == "failure":
            from repro.core.failures import FAILURES
            return sorted(FAILURES)
        if registry == "checkers":
            return sorted(CHECKERS)
        if registry == "exporter":
            from repro.core.trace import list_exporters
            return list_exporters()
        raise KeyError(registry)

    @staticmethod
    def _cli_list_output() -> str:
        import contextlib
        import io
        from repro.__main__ import cmd_list
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            cmd_list(None)
        return buf.getvalue()

    @staticmethod
    def _symbol_line(cache: ModuleCache, rel: str, symbol: str) -> int:
        mod = cache.load(rel)
        if mod is None:
            return 1
        for node in mod.tree.body:
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target] if isinstance(node, ast.AnnAssign)
                       else [])
            for t in targets:
                if isinstance(t, ast.Name) and t.id == symbol:
                    return node.lineno
        return 1

    def run(self, cache: ModuleCache) -> Iterator[Finding]:
        listing = self._cli_list_output()
        test_ids: Set[str] = set()
        tests_dir = cache.root / "tests"
        if tests_dir.is_dir():
            for path in sorted(tests_dir.glob("test_*.py")):
                mod = cache.get(path)
                if mod is None:
                    continue
                for node in ast.walk(mod.tree):
                    if isinstance(node, ast.Name):
                        test_ids.add(node.id)
                    elif isinstance(node, ast.Attribute):
                        test_ids.add(node.attr)

        for registry, (rel, symbol, required) in self.TABLE.items():
            line = self._symbol_line(cache, rel, symbol)
            base = [n.partition(":")[0] for n in self._names(registry)]
            missing = sorted(n for n in base if n not in listing)
            for name in missing:
                yield self.finding(
                    rel, line, "R001",
                    f"{registry} registry entry {name!r} is not printed by "
                    f"`python -m repro list` -- every selectable grammar "
                    f"name must be discoverable (wire it into cmd_list)")
            if not test_ids & required:
                yield self.finding(
                    rel, line, "R002",
                    f"{registry} registry has no parse round-trip test: "
                    f"nothing under tests/ references any of "
                    f"{sorted(required)}")


# ------------------------------------------------------------------ units ---

#: canonical metering suffixes (checked longest-first so ``_bytes`` wins
#: over ``_s``); each suffix is its own unit -- adding ``_s`` to ``_ms`` is
#: exactly the class of bug the convention exists to prevent
_UNIT_SUFFIXES = ("_bytes", "_flops", "_usd", "_qps", "_gb", "_mb", "_kb",
                  "_ms", "_s")
#: ad-hoc aliases of a canonical suffix -> the canonical form
_UNIT_ALIASES = {
    "_seconds": "_s", "_second": "_s", "_secs": "_s", "_sec": "_s",
    "_msecs": "_ms", "_msec": "_ms", "_millis": "_ms",
    "_dollars": "_usd", "_dollar": "_usd",
    "_byte": "_bytes", "_gigabytes": "_gb", "_megabytes": "_mb",
}


def _unit_of(name: str) -> Optional[str]:
    for alias, canon in _UNIT_ALIASES.items():
        if name.endswith(alias):
            return canon
    for suffix in _UNIT_SUFFIXES:
        if name.endswith(suffix):
            return suffix
    return None


def _node_name(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


class UnitsChecker(Checker):
    """Suffix hygiene in metering code.

    U001: a binding whose name spells a unit must use the canonical suffix
    (``_s``/``_ms``/``_usd``/``_bytes``/``_gb``/...), not an ad-hoc alias
    like ``_seconds`` -- greppability is the point of the convention.
    U002: ``+``/``-`` between two names carrying *different* unit suffixes
    is a unit error by construction (multiplying/dividing across units is
    how conversions are written, adding across them never is).
    """

    name = "units"
    description = ("canonical _s/_usd/_bytes/_gb suffixes in metering "
                   "code; no mixed-unit +/- arithmetic")
    codes = {"U001": "non-canonical unit suffix",
             "U002": "+/- across different unit suffixes"}
    scope = ("src/repro/core/", "src/repro/serving/",
             "src/repro/experiments/")

    def run(self, cache: ModuleCache) -> Iterator[Finding]:
        for mod in cache.modules(self.scope):
            yield from self._check_module(mod)

    def _check_module(self, mod: ParsedModule) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    name = _node_name(t)
                    yield from self._alias(mod, node.lineno, name)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                for a in (args.posonlyargs + args.args + args.kwonlyargs):
                    yield from self._alias(mod, a.lineno, a.arg)
            elif (isinstance(node, ast.BinOp)
                    and isinstance(node.op, (ast.Add, ast.Sub))):
                lu = self._operand_unit(node.left)
                ru = self._operand_unit(node.right)
                if lu and ru and lu != ru:
                    op = "+" if isinstance(node.op, ast.Add) else "-"
                    yield self.finding(
                        mod, node.lineno, "U002",
                        f"'{_node_name(node.left)} {op} "
                        f"{_node_name(node.right)}' adds values of "
                        f"different units ({lu} vs {ru}); convert "
                        f"explicitly before summing")

    def _alias(self, mod: ParsedModule, line: int,
               name: Optional[str]) -> Iterator[Finding]:
        if not name:
            return
        for alias, canon in _UNIT_ALIASES.items():
            if name.endswith(alias):
                yield self.finding(
                    mod, line, "U001",
                    f"{name!r} uses the non-canonical unit suffix "
                    f"'{alias}'; the metering convention is "
                    f"'{name[: -len(alias)]}{canon}'")
                return

    @staticmethod
    def _operand_unit(node: ast.AST) -> Optional[str]:
        name = _node_name(node)
        return _unit_of(name) if name else None


# --------------------------------------------------------------- metering ---

#: the modules that legitimately own metered state mutation
_METERING_HOME = ("src/repro/core/engine.py", "src/repro/core/runtimes.py",
                  "src/repro/core/platform.py", "src/repro/core/channels.py",
                  "src/repro/core/faas.py", "src/repro/core/iaas.py",
                  "src/repro/core/sync.py", "src/repro/core/comm/",
                  "src/repro/core/ckpt/", "src/repro/core/elastic/",
                  "src/repro/serving/sim.py")
_METERED_ATTRS = {"cost", "sim_time", "comm_bytes", "comm_cost", "op_cost",
                  "retired_cost", "clock", "invoked_at",
                  "ckpt_bytes", "ckpt_time", "ckpt_cost"}
_BILLING_HOOKS = {"finalize_cost", "resize_cost", "retire_cost"}


class MeteringChecker(Checker):
    """Money and simulated time mutate only through the metering path.

    Outside the engine/platform/comm/serving-sim home modules, writing a
    metered attribute (``.cost``, ``.sim_time``, ``.comm_bytes``, ...) or
    calling a platform billing hook (``finalize_cost``/``resize_cost``/
    ``retire_cost``) creates a second bookkeeping path -- the drift class
    PRs 3-5 repeatedly removed.  Consumers read results; only the engine
    writes them.
    """

    name = "metering"
    description = ("metered cost/clock attrs and billing hooks only mutate "
                   "inside the engine home modules")
    codes = {"M001": "metered attribute mutated outside the engine",
             "M002": "billing hook called outside the engine"}
    scope = ("src/repro/", "benchmarks/")

    def run(self, cache: ModuleCache) -> Iterator[Finding]:
        for mod in cache.modules(self.scope):
            if any(mod.rel.startswith(h) for h in _METERING_HOME):
                continue
            yield from self._check_module(mod)

    def _check_module(self, mod: ParsedModule) -> Iterator[Finding]:
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    for sub in ast.walk(t):
                        if (isinstance(sub, ast.Attribute)
                                and sub.attr in _METERED_ATTRS):
                            yield self.finding(
                                mod, node.lineno, "M001",
                                f"direct write to metered attribute "
                                f"'.{sub.attr}' outside the engine home "
                                f"modules; route it through the metering "
                                f"helpers (SimContext.meter_add / "
                                f"finalize_cost / resize hooks)")
            elif (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _BILLING_HOOKS):
                yield self.finding(
                    mod, node.lineno, "M002",
                    f"billing hook .{node.func.attr}() called outside the "
                    f"engine/elastic home modules; the engine owns when a "
                    f"run is billed (read RunResult.cost instead)")


# ------------------------------------------------------------------ trace ---

#: the modules the TraceRecorder is wired through (DESIGN.md §18): every
#: metered mutation in these files has a span/mark/byte-event emission site
_TRACE_HOME = ("src/repro/core/engine.py", "src/repro/core/sync.py",
               "src/repro/core/comm/stack.py", "src/repro/core/ckpt/store.py",
               "src/repro/core/runtimes.py", "src/repro/serving/sim.py")
#: attribute writes that move metered state (clocks, meters, money, bytes)
_TRACED_ATTRS = {"clock", "breakdown", "comm_bytes", "ckpt_bytes",
                 "wire_bytes", "op_usd", "time_s", "cost", "retired_cost",
                 "sim_time"}


class TraceChecker(Checker):
    """Metered mutations in the recorder-instrumented modules stay traced.

    The conservation gates (clock tiling, $ attribution, byte accounting --
    :mod:`repro.core.trace.invariants`) only hold if every NEW metered
    mutation emits a matching span/mark/byte event.  This checker makes the
    contract structural: inside the trace home modules, any function that
    writes a metered attribute must also reference the recorder (``rec`` /
    ``ctx.rec`` / ``self.rec``) -- or carry an explicit
    ``# lint: ignore[T001]`` stating why no event is owed (e.g. a numeric
    no-op re-assignment the invariants already cover).
    """

    name = "trace"
    description = ("metered mutations in the trace home modules carry a "
                   "span emission (or an explicit ignore)")
    codes = {"T001": "metered mutation without a recorder emission path"}
    scope = _TRACE_HOME

    @staticmethod
    def _references_rec(fn: ast.AST) -> bool:
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and node.id == "rec":
                return True
            if isinstance(node, ast.Attribute) and node.attr == "rec":
                return True
        return False

    @staticmethod
    def _metered_writes(fn: ast.AST) -> Iterator[Tuple[int, str]]:
        for node in ast.walk(fn):
            if not isinstance(node, (ast.Assign, ast.AugAssign)):
                continue
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                for sub in ast.walk(t):
                    if (isinstance(sub, ast.Attribute)
                            and sub.attr in _TRACED_ATTRS):
                        yield node.lineno, sub.attr

    def run(self, cache: ModuleCache) -> Iterator[Finding]:
        for mod in cache.modules(self.scope):
            for node in ast.walk(mod.tree):
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                # nested defs are walked as part of the enclosing function:
                # a closure that mutates meters may lean on the enclosing
                # scope's recorder reference (the serving loops do)
                writes = list(self._metered_writes(node))
                if writes and not self._references_rec(node):
                    line, attr = writes[0]
                    yield self.finding(
                        mod, line, "T001",
                        f"function {node.name}() writes metered attribute "
                        f"'.{attr}' but never references the trace "
                        f"recorder; emit a span/mark/byte event next to the "
                        f"mutation (DESIGN.md §18) or annotate the line "
                        f"with `# lint: ignore[T001]` explaining why no "
                        f"event is owed")


# -------------------------------------------------------------- constants ---

#: modules that own measured constants: everything numeric defined at
#: module/class level here is "owned" and may not be re-hardcoded elsewhere
_CONSTANT_HOMES = ("src/repro/core/calibration.py",
                   "src/repro/core/comm/transports.py",
                   "src/repro/core/cost.py",
                   "src/repro/distributed/roofline.py")


def _significant_digits(value: float) -> int:
    text = f"{abs(value):.12g}"
    mantissa = text.split("e")[0].replace(".", "").strip("0")
    return len(mantissa)


def _distinctive(value: float) -> bool:
    """Is this constant specific enough that an equal literal elsewhere is
    almost certainly a copy?  >= 3 significant digits (0.0464, 1.66667e-5,
    819e9), or >= 2 at magnitudes >= 1e3 (65e6, 120e6).  Deliberately
    excludes round knobs like 0.3, 10e9 or 1.2 that recur innocently."""
    a = abs(value)
    if a == 0.0:
        return False
    sig = _significant_digits(value)
    return sig >= 3 or (sig >= 2 and a >= 1e3)


class ConstantsChecker(Checker):
    """Measured constants have exactly one home module.

    Collects every distinctive float defined at module/class level in the
    home modules (Table 6 channel constants, AWS pricing, the v5e roofline)
    and flags equal float literals anywhere else in ``src/repro`` +
    ``benchmarks`` -- a re-hardcoded ``65e6`` is a second implementation of
    the S3 bandwidth waiting to drift.
    """

    name = "constants"
    description = ("no re-hardcoded transport/pricing/roofline constants "
                   "outside their home modules")
    codes = {"C001": "owned measured constant re-hardcoded"}
    scope = ("src/repro/", "benchmarks/")

    def _owned(self, cache: ModuleCache) -> Dict[float, str]:
        owned: Dict[float, str] = {}
        for home in _CONSTANT_HOMES:
            mod = cache.load(home)
            if mod is None:
                continue
            stmts: List[ast.stmt] = []
            for node in mod.tree.body:
                if isinstance(node, (ast.Assign, ast.AnnAssign)):
                    stmts.append(node)
                elif isinstance(node, ast.ClassDef):
                    stmts.extend(s for s in node.body
                                 if isinstance(s, (ast.Assign, ast.AnnAssign)))
            for stmt in stmts:
                label = "?"
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                names = [t.id for t in targets if isinstance(t, ast.Name)]
                if names:
                    label = names[0]
                for sub in ast.walk(stmt):
                    if (isinstance(sub, ast.Constant)
                            and type(sub.value) is float
                            and _distinctive(sub.value)):
                        owned.setdefault(
                            float(sub.value),
                            f"{label} ({mod.rel}:{sub.lineno})")
        return owned

    def run(self, cache: ModuleCache) -> Iterator[Finding]:
        owned = self._owned(cache)
        if not owned:
            return
        for mod in cache.modules(self.scope):
            if mod.rel in _CONSTANT_HOMES:
                continue
            for node in ast.walk(mod.tree):
                if (isinstance(node, ast.Constant)
                        and type(node.value) is float
                        and float(node.value) in owned):
                    yield self.finding(
                        mod, node.lineno, "C001",
                        f"literal {node.value!r} re-hardcodes the measured "
                        f"constant {owned[float(node.value)]}; import it "
                        f"from its home module so the value cannot drift")


# ----------------------------------------------------------------- registry --

#: name -> zero-config factory, same convention as TRANSPORTS/CODECS/POLICIES
CHECKERS = {
    "determinism": DeterminismChecker,
    "spec_hash": SpecHashChecker,
    "registry": RegistryChecker,
    "units": UnitsChecker,
    "metering": MeteringChecker,
    "trace": TraceChecker,
    "constants": ConstantsChecker,
}


def make_checker(name: str) -> Checker:
    try:
        cls = CHECKERS[name]
    except KeyError:
        raise KeyError(f"unknown checker {name!r}; available: "
                       f"{', '.join(sorted(CHECKERS))}") from None
    return cls()


def select_checkers(select: Optional[Iterable[str]] = None,
                    paths_given: bool = False) -> List[Checker]:
    """The checkers one lint run executes.  ``select`` narrows by name;
    with explicit paths and no selection, tree-level checkers (registry,
    spec_hash) are skipped -- they reason about the whole repo, not a file
    subset."""
    if select:
        return [make_checker(n) for n in select]
    out = []
    for name in CHECKERS:
        checker = make_checker(name)
        if paths_given and checker.tree_level:
            continue
        out.append(checker)
    return out


def list_checkers() -> List[str]:
    """Human-oriented registry listing for ``repro list``."""
    out = []
    for name, cls in CHECKERS.items():
        codes = "/".join(cls.codes)
        out.append(f"{name:<12s} [{codes}] {cls.description}")
    return out
