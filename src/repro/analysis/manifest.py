"""Spec-hash drift manifest (the ``spec_hash`` checker's committed state).

``ExperimentSpec.spec_hash`` / ``ServingSpec.spec_hash`` elide fields at
their default value under a salt (``HASH_SCHEMA`` / ``SERVE_HASH_SCHEMA``),
so the on-disk record caches survive schema growth -- but ONLY as long as
whoever touches the frozen field set also reasons about the salt (PR 3
established the contract; PRs 5 and 6 each bumped a salt).  Nothing used to
enforce that reasoning.  This module fingerprints the frozen dataclass
field sets **statically** (AST -- names plus default-value source text) and
compares them against the committed ``spec_manifest.json``:

- field set or defaults changed, salt unchanged  -> ``H001``
- salt changed, manifest not regenerated         -> ``H002``
- manifest missing/unreadable                    -> ``H003``

``python -m repro lint --write-manifest`` regenerates the manifest, and
deliberately REFUSES while an H001 is outstanding: the only path to green
is bump the salt, then regenerate -- the lint equivalent of the cache
re-key PRs 5/6 performed by hand.
"""
from __future__ import annotations

import ast
import json
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.engine import Finding, ModuleCache, REPO_ROOT

MANIFEST_SCHEMA = "repro.lint.manifest/v1"
MANIFEST_PATH = Path(__file__).resolve().parent / "spec_manifest.json"

#: the hashed frozen specs this repo maintains: class -> (source file,
#: salt constant name).  Extend this table when a new spec-hash family
#: lands (and run ``--write-manifest``).
HASHED_SPECS = {
    "ExperimentSpec": ("src/repro/experiments/spec.py", "HASH_SCHEMA"),
    "ServingSpec": ("src/repro/experiments/serving.py", "SERVE_HASH_SCHEMA"),
}


def dataclass_fields(tree: ast.Module,
                     classname: str) -> Tuple[int, Dict[str, Optional[str]]]:
    """(class def line, {field name -> default-value source or None}) for
    one dataclass, read straight off the AST."""
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == classname:
            fields: Dict[str, Optional[str]] = {}
            for stmt in node.body:
                if (isinstance(stmt, ast.AnnAssign)
                        and isinstance(stmt.target, ast.Name)):
                    default = (ast.unparse(stmt.value)
                               if stmt.value is not None else None)
                    fields[stmt.target.id] = default
            return node.lineno, fields
    raise LookupError(f"class {classname} not found")


def salt_value(tree: ast.Module, salt_name: str) -> Tuple[int, str]:
    """(line, value) of the module-level ``<salt_name> = "..."`` constant."""
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
        for t in targets:
            if isinstance(t, ast.Name) and t.id == salt_name:
                value = node.value
                if isinstance(value, ast.Constant) and isinstance(
                        value.value, str):
                    return node.lineno, value.value
    raise LookupError(f"salt constant {salt_name} not found")


def current_state(cache: ModuleCache,
                  specs: Dict[str, tuple] = None) -> Dict[str, dict]:
    """The live fingerprint of every hashed spec: salt + field map."""
    out: Dict[str, dict] = {}
    for cls, (source, salt_name) in (specs or HASHED_SPECS).items():
        mod = cache.load(source)
        if mod is None:
            continue
        line, fields = dataclass_fields(mod.tree, cls)
        salt_line, salt = salt_value(mod.tree, salt_name)
        out[cls] = {"source": source, "salt_name": salt_name, "salt": salt,
                    "fields": fields, "_line": line,
                    "_salt_line": salt_line}
    return out


def load_manifest(path: Path = MANIFEST_PATH) -> Optional[dict]:
    try:
        data = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError):
        return None
    if data.get("schema") != MANIFEST_SCHEMA:
        return None
    return data


def _diff(old: Dict[str, Optional[str]],
          new: Dict[str, Optional[str]]) -> str:
    added = sorted(set(new) - set(old))
    removed = sorted(set(old) - set(new))
    changed = sorted(k for k in set(old) & set(new) if old[k] != new[k])
    parts: List[str] = []
    if added:
        parts.append(f"added {added}")
    if removed:
        parts.append(f"removed {removed}")
    if changed:
        parts.append(f"defaults changed {changed}")
    return "; ".join(parts) or "reordered"


def check_manifest(cache: ModuleCache, manifest_path: Path = MANIFEST_PATH,
                   specs: Dict[str, tuple] = None) -> Iterator[Finding]:
    """Yield the H001/H002/H003 findings for the current tree."""
    state = current_state(cache, specs)
    manifest = load_manifest(manifest_path)
    if manifest is None:
        for cls, cur in state.items():
            yield Finding(
                file=cur["source"], line=cur["_line"], code="H003",
                message=(f"{cls}: no committed spec-hash manifest at "
                         f"{manifest_path.name}; run `python -m repro lint "
                         f"--write-manifest`"), checker="spec_hash")
        return
    recorded = manifest.get("specs", {})
    for cls, cur in state.items():
        rec = recorded.get(cls)
        if rec is None:
            yield Finding(
                file=cur["source"], line=cur["_line"], code="H003",
                message=(f"{cls} is hashed but absent from the manifest; "
                         f"run `python -m repro lint --write-manifest`"),
                checker="spec_hash")
            continue
        fields_changed = rec["fields"] != cur["fields"]
        salt_changed = rec["salt"] != cur["salt"]
        if fields_changed and not salt_changed:
            yield Finding(
                file=cur["source"], line=cur["_line"], code="H001",
                message=(f"{cls} frozen field set changed "
                         f"({_diff(rec['fields'], cur['fields'])}) without "
                         f"bumping {cur['salt_name']} "
                         f"(still {cur['salt']!r}): old cached records "
                         f"would alias the new schema -- bump the salt, "
                         f"re-key experiments/runs/ if needed, then run "
                         f"`python -m repro lint --write-manifest`"),
                checker="spec_hash")
        elif salt_changed:
            yield Finding(
                file=cur["source"], line=cur["_salt_line"], code="H002",
                message=(f"{cls}: {cur['salt_name']} bumped "
                         f"{rec['salt']!r} -> {cur['salt']!r} but the "
                         f"manifest still records the old schema; run "
                         f"`python -m repro lint --write-manifest`"),
                checker="spec_hash")


def write_manifest(cache: ModuleCache, manifest_path: Path = MANIFEST_PATH,
                   specs: Dict[str, tuple] = None) -> str:
    """Regenerate the manifest.  Refuses while a field-set change is not
    covered by a salt bump (H001) -- the bump must come first."""
    blockers = [f for f in check_manifest(cache, manifest_path, specs)
                if f.code == "H001"]
    if blockers:
        raise ValueError(
            "refusing to rewrite the spec-hash manifest over an unbumped "
            "schema change:\n" + "\n".join(f.render() for f in blockers))
    state = current_state(cache, specs)
    payload = {
        "schema": MANIFEST_SCHEMA,
        "specs": {cls: {k: v for k, v in cur.items()
                        if not k.startswith("_")}
                  for cls, cur in sorted(state.items())},
    }
    Path(manifest_path).write_text(json.dumps(payload, indent=1,
                                              sort_keys=True) + "\n")
    return str(manifest_path)
