"""repro.analysis -- static enforcement of the simulator's contracts.

``python -m repro lint`` runs the checker registry (determinism, spec-hash
drift, registry consistency, unit hygiene, metering discipline, constant
duplication) over a shared parsed-module cache.  See DESIGN.md §15.
"""
from repro.analysis.engine import (Finding, LintEngine, ModuleCache,
                                   ParsedModule, REPO_ROOT, render_json,
                                   render_text)
from repro.analysis.checkers import (CHECKERS, Checker, list_checkers,
                                     make_checker, select_checkers)
from repro.analysis.manifest import (MANIFEST_PATH, check_manifest,
                                     write_manifest)

__all__ = [
    "Finding", "LintEngine", "ModuleCache", "ParsedModule", "REPO_ROOT",
    "render_json", "render_text",
    "CHECKERS", "Checker", "list_checkers", "make_checker",
    "select_checkers",
    "MANIFEST_PATH", "check_manifest", "write_manifest",
    "run_lint",
]


def run_lint(paths=None, select=None, root=REPO_ROOT):
    """One-call lint: (findings, n_files).  ``paths`` restricts the file
    set (and skips tree-level checkers unless ``select`` names them)."""
    cache = ModuleCache(root=root, files=paths, force_all=paths is not None)
    checkers = select_checkers(select, paths_given=paths is not None)
    return LintEngine(checkers, cache).run(), len(cache.files)
