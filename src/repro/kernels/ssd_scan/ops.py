"""Public wrapper for the SSD chunk kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_kernel


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_fused(x, dt, a_log, B, C, *, chunk: int = 256,
                   interpret: bool | None = None):
    """Model-facing contract (matches repro.models.ssm.ssd_scan):
    x (b, s, h, p); dt (b, s, h) post-softplus; a_log (h,); B/C (b, s, n).
    Returns (y (b, s, h, p) fp32, state (b, h, p, n) fp32).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, h, p = x.shape
    n = B.shape[-1]
    A = -jnp.exp(a_log.astype(jnp.float32))                     # (h,)
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(b * h, s)
    af = jnp.broadcast_to(A[None, :], (b, h)).reshape(b * h)
    Bf = jnp.broadcast_to(B[:, None], (b, h, s, n)).reshape(b * h, s, n)
    Cf = jnp.broadcast_to(C[:, None], (b, h, s, n)).reshape(b * h, s, n)
    y, st = ssd_scan_kernel(xf, dtf, af, Bf, Cf, chunk=chunk,
                            interpret=interpret)
    return (y.reshape(b, h, s, p).transpose(0, 2, 1, 3).astype(jnp.float32),
            st.reshape(b, h, p, n))
