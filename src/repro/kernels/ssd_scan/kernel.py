"""Mamba2 SSD chunk kernel (TPU): intra-chunk dual form + carried state.

Grid = (batch*heads, n_chunks); the chunk dim is sequential so the (p, n)
SSM state lives in VMEM scratch across chunks -- the HBM-resident
inter-chunk state tensors of the jnp reference (materialized (b, nc, h, p,
n)) never exist.  Per chunk the kernel computes the paper's (SSD, Dao & Gu
2024) blocks:

    y_diag = (C B^T ∘ L) (x*dt)          -- MXU matmuls, (l x l) masked
    y_off  = decay_in * (C S_prev^T)     -- carried state contribution
    S_new  = decay_chunk * S_prev + (dec_end * x*dt)^T B

dt / decay handling is fp32 throughout (exp/segsum are precision-critical).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_out_ref, state_ref,
            *, chunk: int):
    ic = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...].astype(jnp.float32)            # (l, p)
    dt = dt_ref[...].astype(jnp.float32)          # (l, 1)
    A = a_ref[0]                                  # scalar (per head)
    B = b_ref[...].astype(jnp.float32)            # (l, n)
    C = c_ref[...].astype(jnp.float32)            # (l, n)

    da = dt[:, 0] * A                             # (l,) log decays
    cum = jnp.cumsum(da)                          # inclusive
    xdt = x * dt                                  # (l, p)

    # L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))   # (l, l)
    y = jax.lax.dot(scores * L, xdt, preferred_element_type=jnp.float32)

    # carried-state contribution
    st = state_ref[...]                           # (p, n)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, st, (((1,), (1,)), ((), ())))          # (l, p)

    # state update
    dec_end = jnp.exp(cum[-1] - cum)              # (l,)
    state_ref[...] = (jnp.exp(cum[-1]) * st
                      + jax.lax.dot_general(xdt * dec_end[:, None], B,
                                            (((0,), (0,)), ((), ()))))
    y_ref[...] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _emit_state():
        st_out_ref[...] = state_ref[...]


def ssd_scan_kernel(x, dt, a, B, C, *, chunk: int, interpret: bool = True):
    """x (bh, s, p); dt (bh, s); a (bh,) = A (negative); B/C (bh, s, n).

    Returns y (bh, s, p) fp32-accurate and final state (bh, p, n) fp32.
    """
    bh, s, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    grid = (bh, s // chunk)
    dt2 = dt[..., None]
    a2 = a.reshape(bh, 1)

    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, 1), lambda b, c: (b, 0)),
            pl.BlockSpec((None, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, n), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, p, n), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt2, a2, B, C)
