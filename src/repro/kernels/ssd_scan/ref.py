"""Oracle for the SSD chunk kernel: exact sequential state recurrence.

    S_t = exp(dt_t * A) * S_{t-1} + dt_t * B_t (x_t)^T
    y_t = C_t . S_t

(The models' chunked jnp ssd_scan is separately tested against this same
recurrence in tests/test_models.py -- kernel, chunked-jnp and recurrence all
agree.)
"""
from __future__ import annotations

import jax.numpy as jnp


def ssd_scan_ref(x, dt, a, B, C):
    """x (bh, s, p); dt (bh, s); a (bh,) negative; B/C (bh, s, n)
    -> (y (bh, s, p), final state (bh, p, n))."""
    bh, s, p = x.shape
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    st = jnp.zeros((bh, p, B.shape[-1]), jnp.float32)
    ys = []
    for t in range(s):
        dec = jnp.exp(dtf[:, t] * a)[:, None, None]
        upd = jnp.einsum("bp,bn->bpn", xf[:, t] * dtf[:, t, None],
                         B[:, t].astype(jnp.float32))
        st = st * dec + upd
        ys.append(jnp.einsum("bpn,bn->bp", st, C[:, t].astype(jnp.float32)))
    return jnp.stack(ys, axis=1).astype(x.dtype), st
