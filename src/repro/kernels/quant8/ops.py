"""Public wrapper: arbitrary-shape tensors <-> padded (rows, 256) tiles."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quant8.kernel import BLOCK, dequantize8_kernel, quantize8_kernel


def _pad_rows(flat):
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(-1, BLOCK), pad


@partial(jax.jit, static_argnames=("interpret",))
def quantize8(x, *, interpret: bool | None = None):
    """Any-shape fp tensor -> (codes int8 (rows, 256), scales (rows, 1))."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    rows, _ = _pad_rows(x.astype(jnp.float32).reshape(-1))
    return quantize8_kernel(rows, interpret=interpret)


def dequantize8(q, s, shape, *, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    x = dequantize8_kernel(q, s, interpret=interpret).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return x[:n].reshape(shape)
