"""Public wrapper: arbitrary-shape tensors <-> padded (rows, 256) tiles.

Padding contract (DESIGN.md §16): the flat tensor is zero-padded up to a
multiple of BLOCK=256 and reshaped to (rows, 256); rows are then zero-padded
to a multiple of the kernel's BM grid step.  Zero padding never changes a
real block's max-abs, so block scales — and therefore codes, dequantized
values and residuals for the real elements — are bit-identical to the
unpadded math.  Padding exists only on-device: returned codes/scales are
sliced to the ``ceil(n/256)`` REAL blocks and wire accounting
(`int8_wire_floats`) never counts it.

Backend selection: ``backend=None`` reads ``REPRO_CODEC_BACKEND``
(``kernel`` default → Pallas, interpret off-TPU / Mosaic on TPU;
``ref``/``numpy`` → the straight-line :mod:`.ref` oracle through the SAME
padding plumbing, so both backends agree bit-for-bit).
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quant8.kernel import (
    BLOCK,
    BM,
    dequantize8_kernel,
    quantize8_ef_kernel,
    quantize8_kernel,
)
from repro.kernels.quant8.ref import dequantize8_ref, quantize8_ef_ref, quantize8_ref


def resolve_backend(backend: str | None = None) -> str:
    """'kernel' | 'ref' (env REPRO_CODEC_BACKEND; 'numpy' aliases 'ref')."""
    if backend is None:
        backend = os.environ.get("REPRO_CODEC_BACKEND", "kernel")
    if backend == "numpy":
        backend = "ref"
    if backend not in ("kernel", "ref"):
        raise ValueError(
            f"unknown codec backend {backend!r} (want kernel|ref|numpy)")
    return backend


def _pad_tiles(flat):
    """flat (n,) -> ((rows', 256) zero-padded tiles, n_real_blocks)."""
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    tiles = flat.reshape(-1, BLOCK)
    blocks = tiles.shape[0]
    rpad = (-blocks) % min(BM, blocks)
    if rpad:
        tiles = jnp.concatenate(
            [tiles, jnp.zeros((rpad, BLOCK), tiles.dtype)])
    return tiles, blocks


@partial(jax.jit, static_argnames=("interpret", "backend"))
def _quantize8(x, *, interpret: bool, backend: str):
    tiles, blocks = _pad_tiles(x.astype(jnp.float32).reshape(-1))
    if backend == "kernel":
        q, s = quantize8_kernel(tiles, interpret=interpret)
    else:
        q, s = quantize8_ref(tiles)
    return q[:blocks], s[:blocks]


def quantize8(x, *, interpret: bool | None = None, backend: str | None = None):
    """Any-shape fp tensor -> (codes int8 (blocks, 256), scales (blocks, 1))."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _quantize8(x, interpret=interpret, backend=resolve_backend(backend))


@partial(jax.jit, static_argnames=("interpret", "backend", "shape"))
def _dequantize8(q, s, *, interpret: bool, backend: str, shape):
    blocks = q.shape[0]
    rpad = (-blocks) % min(BM, blocks)
    if rpad:
        q = jnp.concatenate([q, jnp.zeros((rpad, BLOCK), q.dtype)])
        s = jnp.concatenate([s, jnp.ones((rpad, 1), s.dtype)])
    if backend == "kernel":
        x = dequantize8_kernel(q, s, interpret=interpret)
    else:
        x = dequantize8_ref(q, s)
    n = 1
    for d in shape:
        n *= d
    return x.reshape(-1)[:n].reshape(shape)


def dequantize8(q, s, shape, *, interpret: bool | None = None,
                backend: str | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _dequantize8(q, s, interpret=interpret,
                        backend=resolve_backend(backend), shape=tuple(shape))


@partial(jax.jit, static_argnames=("interpret", "backend"))
def _int8_roundtrip(x, *, interpret: bool, backend: str):
    flat = x.astype(jnp.float32).reshape(-1)
    tiles, blocks = _pad_tiles(flat)
    if backend == "kernel":
        q, s, deq, err = quantize8_ef_kernel(tiles, interpret=interpret)
    else:
        q, s, deq, err = quantize8_ef_ref(tiles)
    n = flat.shape[0]
    deq = deq.reshape(-1)[:n].reshape(x.shape)
    err = err.reshape(-1)[:n].reshape(x.shape)
    return q[:blocks], s[:blocks], deq, err


def int8_roundtrip(x, *, interpret: bool | None = None,
                   backend: str | None = None):
    """Fused EF quantize of any-shape x.

    Returns (codes (blocks, 256) int8, scales (blocks, 1) f32,
    deq shaped-like-x, residual shaped-like-x); residual is x - deq (to
    the last ulp — see ref.quantize8_ef_ref on FMA contraction), and both
    backends return bit-identical results.  One fused pass on the kernel
    backend.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _int8_roundtrip(x, interpret=interpret,
                           backend=resolve_backend(backend))
