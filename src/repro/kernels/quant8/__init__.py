from repro.kernels.quant8.ops import dequantize8, quantize8  # noqa: F401
