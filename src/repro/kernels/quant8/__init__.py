from repro.kernels.quant8.ops import (  # noqa: F401
    dequantize8,
    int8_roundtrip,
    quantize8,
    resolve_backend,
)
