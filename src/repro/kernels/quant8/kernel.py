"""Blockwise int8 quantize / dequantize Pallas kernels.

Communication-reduction hot path (the paper's m/w-per-round term): gradients
/ model deltas are quantized to int8 with one fp32 scale per 256-element
block before crossing the slow (inter-pod / storage) channel.  Pure
VPU-elementwise work tiled (BM, 256): each grid step loads one (BM, 256)
fp32 tile from HBM, writes the int8 codes + (BM, 1) scales -- bandwidth-
optimal, one pass.

This module is the ONE implementation of the codec's quantizer math
(DESIGN.md §16): the :class:`~repro.core.comm.Int8EFCodec` wire codec
executes these kernels (interpret mode off-TPU, real Mosaic lowering on
TPU), validated bit-for-bit against the :mod:`repro.kernels.quant8.ref`
oracle.  :func:`quantize8_ef_kernel` is the error-feedback variant the
codec hot path uses: codes, scales, dequantized values AND the residual in
a single pass over the data (three separate quantize/dequantize/subtract
passes would stream the tensor three times).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256          # quantization block (elements)
BM = 256             # rows per grid step


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                   # (bm, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True),
                        1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _quant_ef_kernel(x_ref, q_ref, s_ref, d_ref, e_ref):
    """Fused error-feedback quantize: one pass emits the wire form (codes +
    per-block scales), the dequantized values the merge consumes, and the
    residual ``x - deq`` carried into the next round."""
    x = x_ref[...].astype(jnp.float32)                   # (bm, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True),
                        1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    deq = q * scale
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale
    d_ref[...] = deq
    e_ref[...] = x - deq


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...])


def _rows_grid(rows: int) -> tuple[int, int]:
    bm = min(BM, rows)
    assert rows % bm == 0, (rows, bm)
    return bm, rows // bm


def quantize8_kernel(x, *, interpret: bool = True):
    """x (rows, BLOCK) fp32 -> (int8 codes (rows, BLOCK), scales (rows, 1))."""
    rows = x.shape[0]
    bm, grid = _rows_grid(rows)
    return pl.pallas_call(
        _quant_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((bm, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, BLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32)],
        interpret=interpret,
    )(x)


def quantize8_ef_kernel(x, *, interpret: bool = True):
    """x (rows, BLOCK) fp32 -> (codes int8, scales (rows, 1), dequantized
    (rows, BLOCK) f32, residual (rows, BLOCK) f32) in ONE pass."""
    rows = x.shape[0]
    bm, grid = _rows_grid(rows)
    row_spec = pl.BlockSpec((bm, BLOCK), lambda i: (i, 0))
    return pl.pallas_call(
        _quant_ef_kernel,
        grid=(grid,),
        in_specs=[row_spec],
        out_specs=[row_spec,
                   pl.BlockSpec((bm, 1), lambda i: (i, 0)),
                   row_spec, row_spec],
        out_shape=[jax.ShapeDtypeStruct((rows, BLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32),
                   jax.ShapeDtypeStruct((rows, BLOCK), jnp.float32),
                   jax.ShapeDtypeStruct((rows, BLOCK), jnp.float32)],
        interpret=interpret,
    )(x)


def dequantize8_kernel(q, s, *, interpret: bool = True):
    rows = q.shape[0]
    bm, grid = _rows_grid(rows)
    return pl.pallas_call(
        _dequant_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((bm, BLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, BLOCK), jnp.float32),
        interpret=interpret,
    )(q, s)
