"""Blockwise int8 quantize / dequantize Pallas kernels.

Communication-reduction hot path (the paper's m/w-per-round term): gradients
/ model deltas are quantized to int8 with one fp32 scale per 256-element
block before crossing the slow (inter-pod / storage) channel.  Pure
VPU-elementwise work tiled (BM, 256): each grid step loads one (BM, 256)
fp32 tile from HBM, writes the int8 codes + (BM, 1) scales -- bandwidth-
optimal, one pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256          # quantization block (elements)
BM = 256             # rows per grid step


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                   # (bm, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1, keepdims=True),
                        1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...])


def quantize8_kernel(x, *, interpret: bool = True):
    """x (rows, BLOCK) fp32 -> (int8 codes (rows, BLOCK), scales (rows, 1))."""
    rows = x.shape[0]
    bm = min(BM, rows)
    assert rows % bm == 0
    return pl.pallas_call(
        _quant_kernel,
        grid=(rows // bm,),
        in_specs=[pl.BlockSpec((bm, BLOCK), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, BLOCK), jnp.int8),
                   jax.ShapeDtypeStruct((rows, 1), jnp.float32)],
        interpret=interpret,
    )(x)


def dequantize8_kernel(q, s, *, interpret: bool = True):
    rows = q.shape[0]
    bm = min(BM, rows)
    assert rows % bm == 0
    return pl.pallas_call(
        _dequant_kernel,
        grid=(rows // bm,),
        in_specs=[pl.BlockSpec((bm, BLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, BLOCK), jnp.float32),
        interpret=interpret,
    )(q, s)
