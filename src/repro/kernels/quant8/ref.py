"""Oracle: the optimizer's numpy-style blockwise quantization."""
from __future__ import annotations

import jax.numpy as jnp


def quantize8_ref(x):
    """x (rows, 256) -> (q int8, scales (rows, 1))."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1,
                                keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize8_ref(q, s):
    return q.astype(jnp.float32) * s
