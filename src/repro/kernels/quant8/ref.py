"""Oracle: straight-line jnp blockwise quantization.

This is the ONE statement of the int8 quantizer math.  The Pallas kernel
(`kernel.py`) must match it bit-for-bit in interpret mode; the codec's
explicit non-kernel fallback (``REPRO_CODEC_BACKEND=ref``) and the
TP-sharded per-channel path in `repro.distributed.local_sgd` both call it
directly with their own axis layout.  ``axis=-1`` generality is what lets
one formula serve the (rows, 256) blockwise wire codec and the per-row
per-channel in-jit path.
"""
from __future__ import annotations

import jax.numpy as jnp


def quantize8_ref(x, axis: int = -1):
    """x (.., n) -> (q int8, scales (.., 1)) with one scale per `axis` slice."""
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis,
                                keepdims=True), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize8_ref(q, s):
    return q.astype(jnp.float32) * s


def quantize8_ef_ref(x, axis: int = -1):
    """Error-feedback variant: (q, scale, deq, residual).

    ``residual = x - deq`` from the *emitted* deq (not re-derived).  Under
    jit, XLA may contract ``q*scale`` and the subtraction into an FMA, so
    recomputing ``x - deq`` outside matches only to the last ulp — but the
    kernel backend produces bit-identical (deq, residual) to this oracle,
    which is the invariant the EF codecs and parity tests rely on.
    """
    q, scale = quantize8_ref(x, axis=axis)
    deq = dequantize8_ref(q, scale)
    return q, scale, deq, x.astype(jnp.float32) - deq
