"""Public wrapper: GQA plumbing + interpret-mode switch."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool | None = None):
    """q (b, sq, h, dk); k/v (b, sk, m, dk) with h % m == 0 (GQA).

    Returns (b, sq, h, dk).  interpret=None -> auto (False on TPU).
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, sq, h, d = q.shape
    sk, m = k.shape[1], k.shape[2]
    g = h // m
    # fold GQA: repeat each kv head g times, flatten (b, heads)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, sk, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, sk, d)
    o = flash_attention_kernel(qf, kf, vf, causal=causal,
                               sm_scale=d ** -0.5, block_q=block_q,
                               block_k=block_k, interpret=interpret)
    return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
