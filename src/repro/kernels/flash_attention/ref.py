"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool, sm_scale: float):
    """q (bh, sq, d); k/v (bh, sk, d) -> (bh, sq, d). fp32 softmax."""
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
