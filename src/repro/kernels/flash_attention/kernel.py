"""Flash attention Pallas kernel (TPU): online-softmax over K/V blocks.

Grid = (batch*heads, n_q_blocks, n_kv_blocks); the last grid dim is
sequential on TPU, so the (block_q, d) accumulator, running max and running
sum live in VMEM scratch across kv iterations.  Block shapes are multiples
of (8, 128) to line up with VREG/MXU tiling; K/V stream HBM->VMEM one block
per step, which is the roofline-optimal pattern when the KV sequence does
not fit VMEM (32k+ contexts).  Causal blocks strictly above the diagonal
are skipped via pl.when (half the work at long context).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            sm_scale: float, causal: bool, block_q: int, block_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    if causal:
        run = (ik * block_k) <= (iq * block_q + block_q - 1)
    else:
        run = ik >= 0  # always true (traced)

    @pl.when(run)
    def _body():
        q = q_ref[...].astype(jnp.float32)                      # (bq, d)
        k = k_ref[...].astype(jnp.float32)                      # (bk, d)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        s = s * sm_scale                                      # (bq, bk)
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]                                   # (bq, 1)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_cur)
        corr = jnp.exp(m_prev - m_cur)                        # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool, sm_scale: float,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = True):
    """q (bh, sq, d); k/v (bh, sk, d) -> o (bh, sq, d)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    grid = (bh, sq // block_q, sk // block_k)

    return pl.pallas_call(
        functools.partial(_kernel, sm_scale=sm_scale, causal=causal,
                          block_q=block_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
