"""Public wrapper: any-shape top-k EF filter over padded (rows, 256) tiles.

Same padding contract as quant8 (DESIGN.md §16): zero-pad the flat tensor
to the tile grid.  Padding zeros can only be "kept" when tau == 0, and a
kept zero is still 0.0, so sliced outputs are identical to unpadded math.
Backend selection shares `repro.kernels.quant8.ops.resolve_backend`
(``REPRO_CODEC_BACKEND``: kernel default, ref/numpy fallback).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.quant8.ops import resolve_backend
from repro.kernels.topk_ef.kernel import BLOCK, BM, topk_ef_kernel
from repro.kernels.topk_ef.ref import topk_ef_ref, topk_tau_ref


@partial(jax.jit, static_argnames=("k", "interpret", "backend"))
def _topk_ef(x, *, k: int, interpret: bool, backend: str):
    flat = x.astype(jnp.float32).reshape(-1)
    tau = topk_tau_ref(flat, k)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    tiles = flat.reshape(-1, BLOCK)
    blocks = tiles.shape[0]
    rpad = (-blocks) % min(BM, blocks)
    if rpad:
        tiles = jnp.concatenate(
            [tiles, jnp.zeros((rpad, BLOCK), tiles.dtype)])
    if backend == "kernel":
        out, res = topk_ef_kernel(tiles, tau, interpret=interpret)
    else:
        out, res = topk_ef_ref(tiles, tau)
    out = out.reshape(-1)[:n].reshape(x.shape)
    res = res.reshape(-1)[:n].reshape(x.shape)
    return out, res


def topk_ef(x, k: int, *, interpret: bool | None = None,
            backend: str | None = None):
    """Keep the >= k largest-|x| elements of any-shape x, zero the rest.

    Returns (kept, residual) both shaped like x with
    ``kept + residual == x`` bitwise.  Ties at the k-th magnitude are all
    kept, so nonzero count can exceed k on tied data.  k is clamped to
    [1, x.size].
    """
    k = max(1, min(int(k), x.size))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _topk_ef(x, k=k, interpret=interpret,
                    backend=resolve_backend(backend))
