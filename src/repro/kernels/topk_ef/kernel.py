"""Fused magnitude-threshold + residual-carry Pallas kernel (top-k EF).

The MLLess-style significance filter: keep elements whose magnitude clears
the k-th-largest-|x| threshold tau, zero the rest — and emit the
complementary residual (the suppressed mass carried into the next round's
error feedback) in the SAME pass.  Pure VPU-elementwise given the scalar
tau, tiled (BM, 256) like quant8; tau rides in SMEM.  A separate
filter-then-subtract would stream the tensor twice for what is one
compare + two selects per element.

tau itself (a global k-selection) is computed by the caller
(`ops.topk_ef` via ``lax.top_k``) — selection is not a tiling-friendly
primitive, the threshold *application* is where the bytes move.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 256          # lane tile width (elements)
BM = 256             # rows per grid step


def _topk_ef_kernel(tau_ref, x_ref, out_ref, res_ref):
    x = x_ref[...].astype(jnp.float32)                   # (bm, BLOCK)
    tau = tau_ref[0]
    keep = jnp.abs(x) >= tau
    out_ref[...] = jnp.where(keep, x, 0.0)
    res_ref[...] = jnp.where(keep, 0.0, x)


def topk_ef_kernel(x, tau, *, interpret: bool = True):
    """x (rows, BLOCK) f32, tau scalar -> (kept (rows, BLOCK), residual).

    ``kept + residual == x`` exactly (each element lands in exactly one
    output, unmodified); ties at tau are all kept.
    """
    rows = x.shape[0]
    bm = min(BM, rows)
    assert rows % bm == 0, (rows, bm)
    tau = jnp.broadcast_to(jnp.asarray(tau, jnp.float32), (1,))
    row_spec = pl.BlockSpec((bm, BLOCK), lambda i: (i, 0))
    return pl.pallas_call(
        _topk_ef_kernel,
        grid=(rows // bm,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM), row_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[jax.ShapeDtypeStruct((rows, BLOCK), jnp.float32),
                   jax.ShapeDtypeStruct((rows, BLOCK), jnp.float32)],
        interpret=interpret,
    )(tau, x)
