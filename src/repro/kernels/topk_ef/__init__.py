from repro.kernels.topk_ef.ops import topk_ef  # noqa: F401
