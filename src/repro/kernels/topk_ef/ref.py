"""Oracle: straight-line jnp top-k threshold filter with residual."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_tau_ref(x, k: int):
    """tau = k-th largest |x| over the flat tensor (k static, 1 <= k <= n)."""
    a = jnp.abs(x.astype(jnp.float32)).reshape(-1)
    return jax.lax.top_k(a, k)[0][-1]


def topk_ef_ref(x, tau):
    """(kept, residual): keep |x| >= tau (ties all kept), rest to residual.

    Each element lands unmodified in exactly one output, so
    ``kept + residual == x`` holds bitwise.
    """
    xf = x.astype(jnp.float32)
    keep = jnp.abs(xf) >= tau
    return jnp.where(keep, xf, 0.0), jnp.where(keep, 0.0, xf)
