"""Pallas TPU kernels for the perf-critical compute hot-spots.

Each kernel subpackage ships three modules:
  kernel.py -- pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    -- jit'd public wrapper (shape plumbing, interpret-mode switch)
  ref.py    -- pure-jnp oracle used by the tests' allclose sweeps

This container is CPU-only: kernels are VALIDATED with interpret=True
(Python-level execution of the kernel body); on TPU the same pallas_call
lowers to Mosaic.  The jnp model paths double as the oracles.

Kernels:
  flash_attention  -- fused causal/bidir attention (training/prefill)
  decode_attention -- flash-decoding over a KV cache (serve_step)
  ssd_scan         -- Mamba2 SSD chunk kernel with carried state
  quant8           -- blockwise int8 quantize/dequant (gradient compression)
"""
