"""Pure-jnp oracle for flash decoding."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, length, *, sm_scale: float):
    """q (bm, g, d); k/v (bm, S, d); positions >= length masked."""
    s = jnp.einsum("bgd,bkd->bgk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    mask = jnp.arange(k.shape[1])[None, None, :] < length
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bgk,bkd->bgd", p, v.astype(jnp.float32)).astype(q.dtype)
