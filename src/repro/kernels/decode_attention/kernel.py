"""Flash-decoding Pallas kernel: one query token vs. a long KV cache.

Decode is memory-bound: the whole job is streaming the (S, d) cache through
VMEM once.  Grid = (batch*kv_heads, n_kv_blocks) with the KV dim sequential;
running (g, d) accumulator + softmax stats live in scratch (g = GQA group =
q heads per kv head, so all group queries amortize one cache read -- the
GQA-aware layout matters: a per-q-head kernel would read the cache g times).
A `length` scalar masks cache positions beyond the current decode position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 1024
NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            sm_scale: float, block_k: int):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0]

    @pl.when(ik * block_k < length)
    def _body():
        q = q_ref[...].astype(jnp.float32)                  # (g, d)
        k = k_ref[...].astype(jnp.float32)                  # (bk, d)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * sm_scale
        kpos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)            # (g, bk)
        m_prev = m_ref[...]                                 # (g, 1)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_cur)
        corr = jnp.exp(m_prev - m_cur)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_kernel(q, k, v, length, *, sm_scale: float,
                            block_k: int = DEFAULT_BLOCK_K,
                            interpret: bool = True):
    """q (bm, g, d); k/v (bm, S, d); length scalar int32 -> o (bm, g, d)."""
    bm, g, d = q.shape
    S = k.shape[1]
    block_k = min(block_k, S)
    assert S % block_k == 0
    grid = (bm, S // block_k)
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (1,))

    return pl.pallas_call(
        functools.partial(_kernel, sm_scale=sm_scale, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((None, g, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, g, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bm, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(length, q, k, v)
