"""Public wrapper: (b, h, d) query + (b, S, m, d) cache -> (b, h, d)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_kernel


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, cache_k, cache_v, length, *, block_k: int = 1024,
                     interpret: bool | None = None):
    """q (b, h, dk); cache_k/v (b, S, m, dk); length = valid prefix length."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, d = q.shape
    S, m = cache_k.shape[1], cache_k.shape[2]
    g = h // m
    qf = q.reshape(b, m, g, d).reshape(b * m, g, d)
    kf = cache_k.transpose(0, 2, 1, 3).reshape(b * m, S, d)
    vf = cache_v.transpose(0, 2, 1, 3).reshape(b * m, S, d)
    o = decode_attention_kernel(qf, kf, vf, length, sm_scale=d ** -0.5,
                                block_k=block_k, interpret=interpret)
    return o.reshape(b, m, g, d).reshape(b, h, d)
