"""Reproduce the paper's end-to-end FaaS-vs-IaaS study (Figs 10-12) and the
analytical-model what-ifs (Figs 13-15) through the declarative experiment
API (DESIGN.md §10) -- every section below is also available directly from
the CLI, e.g.:

    PYTHONPATH=src python -m repro run fig10_breakdown
    PYTHONPATH=src python -m repro sweep fig11_end2end --grid fleet.workers=5,10,25

    PYTHONPATH=src python examples/faas_vs_iaas.py [--workers 10 25 50]
"""
import argparse

from repro.core.analytical import CostInputs, q1_fast_hybrid
from repro.experiments import (
    ExperimentSpec, FleetSpec, get_preset, run_experiment, sweep,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, nargs="+", default=[5, 10, 25])
    ap.add_argument("--rows", type=int, default=50_000)
    args = ap.parse_args()

    print("== runtime/cost vs workers (LR+ADMM, the FaaS-friendly regime) ==")
    base = ExperimentSpec(name="adm", model="lr", dataset="higgs",
                          rows=args.rows, algorithm="admm",
                          algo_args={"lr": 0.1, "local_epochs": 5},
                          max_epochs=3)
    grid = {"fleet.workers": args.workers}
    faas = sweep(base.with_(platform="faas"), grid)
    iaas = sweep(base.with_(platform="iaas"), grid)
    print(f"{'w':>4s} {'faas_t':>9s} {'faas_$':>9s} {'iaas_t':>9s} {'iaas_$':>9s}")
    for f, i in zip(faas, iaas):
        print(f"{f.spec.fleet.workers:4d} "
              f"{f.result['sim_time_s']:8.1f}s ${f.result['cost_usd']:8.4f} "
              f"{i.result['sim_time_s']:8.1f}s ${i.result['cost_usd']:8.4f}")

    print("\n== breakdown (w=10, GA-SGD, 10 epochs) -- paper Fig 10 ==")
    labels = {"fig10_faas_s3": "FaaS/S3", "fig10_faas_memcached": "FaaS/Memc",
              "fig10_hybridps": "Hybrid VM-PS", "fig10_iaas": "IaaS"}
    for spec in get_preset("fig10_breakdown").build(quick=True):
        bd = run_experiment(spec).result["breakdown"]
        print(f"{labels[spec.name]:14s} startup={bd['startup']:7.1f}s "
              f"load={bd['load']:5.2f}s compute={bd['compute']:6.2f}s "
              f"comm={bd['comm']:8.2f}s")

    print("\n== sync protocols through the engine (BSP / ASP / SSP s=2) ==")
    for spec in get_preset("fig8_sync").build(quick=True):
        r = run_experiment(spec).result
        print(f"{spec.sync:7s} rounds={r['rounds']:4d} "
              f"time={r['sim_time_s']:7.1f}s loss={r['final_loss']:.4f} "
              f"max_staleness={r['max_staleness']}")

    print("\n== spot-instance IaaS: preemptions + restart-from-checkpoint ==")
    demand, spot = (run_experiment(s) for s in
                    get_preset("spot_vs_ondemand").build(quick=True))
    d, s = demand.result, spot.result
    same = abs(s["final_loss"] - d["final_loss"]) < 1e-6
    print(f"on-demand {d['sim_time_s']:7.1f}s ${d['cost_usd']:.4f}   "
          f"spot {s['sim_time_s']:7.1f}s ${s['cost_usd']:.4f} "
          f"({s['preemptions']} preemptions, identical numerics: {same})")

    print("\n== heterogeneous fleets compose with either platform ==")
    het = ExperimentSpec(name="hetero4", model="lr", dataset="higgs",
                         rows=args.rows, algorithm="admm",
                         algo_args={"lr": 0.1, "local_epochs": 5},
                         max_epochs=3, platform="iaas",
                         fleet=FleetSpec(workers=4,
                                         instance=("c5.large", "c5.large",
                                                   "t2.medium", "t2.medium"),
                                         lambda_gb=(3.0, 3.0, 1.0, 1.0)))
    for plat in ("iaas", "faas"):        # the SAME FleetSpec, both platforms
        r = run_experiment(het.with_(platform=plat)).result
        print(f"{plat:5s} {r['sim_time_s']:7.1f}s ${r['cost_usd']:.4f} "
              f"loss={r['final_loss']:.4f}")

    print("\n== what-if: 10 GB/s FaaS<->VM link (paper Fig 14) ==")
    wl = CostInputs(s_bytes=220e6, m_bytes=12e6, R=500, C=400.0)
    for k, v in q1_fast_hybrid(wl, 10).items():
        print(f"  {k:16s} {v:9.0f}s")
    print("\nFaaS wins the small-model/fast-convergence regime; the moment "
          "per-round bytes (m) grow, IaaS/GPU wins both time and cost.")


if __name__ == "__main__":
    main()
