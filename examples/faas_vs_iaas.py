"""Reproduce the paper's end-to-end FaaS-vs-IaaS study (Figs 10-12) and the
analytical-model what-ifs (Figs 13-15) in one script.

    PYTHONPATH=src python examples/faas_vs_iaas.py [--workers 10 25 50]
"""
import argparse

from repro.core.algorithms import make_algorithm
from repro.core.analytical import Workload, faas_time, iaas_time, q1_fast_hybrid
from repro.core.mlmodels import make_study_model
from repro.core.runtimes import FaaSRuntime, IaaSRuntime
from repro.data.synthetic import make_dataset, train_val_split


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, nargs="+", default=[5, 10, 25])
    ap.add_argument("--rows", type=int, default=50_000)
    args = ap.parse_args()

    ds = make_dataset("higgs", rows=args.rows)
    tr, va = train_val_split(ds)
    model = make_study_model("lr", tr)

    print("== runtime/cost vs workers (LR+ADMM, the FaaS-friendly regime) ==")
    print(f"{'w':>4s} {'faas_t':>9s} {'faas_$':>9s} {'iaas_t':>9s} {'iaas_$':>9s}")
    for w in args.workers:
        f = FaaSRuntime(workers=w).train(
            model, make_algorithm("admm", lr=0.1, local_epochs=5), tr, va,
            max_epochs=3)
        i = IaaSRuntime(workers=w).train(
            model, make_algorithm("admm", lr=0.1, local_epochs=5), tr, va,
            max_epochs=3)
        print(f"{w:4d} {f.sim_time:8.1f}s ${f.cost:8.4f} "
              f"{i.sim_time:8.1f}s ${i.cost:8.4f}")

    print("\n== breakdown (w=10, GA-SGD, 10 epochs) -- paper Fig 10 ==")
    for name, rt in [("FaaS/S3", FaaSRuntime(workers=10)),
                     ("Hybrid VM-PS", FaaSRuntime(workers=10, channel="vmps")),
                     ("IaaS", IaaSRuntime(workers=10))]:
        r = rt.train(model, make_algorithm("ga_sgd", lr=0.3, batch_size=2048),
                     tr, va, max_epochs=10)
        bd = r.breakdown
        print(f"{name:14s} startup={bd['startup']:7.1f}s load={bd['load']:5.2f}s"
              f" compute={bd['compute']:6.2f}s comm={bd['comm']:8.2f}s")

    print("\n== sync protocols through the engine (BSP / ASP / SSP s=2) ==")
    for sync in ("bsp", "asp", "ssp:2"):
        r = FaaSRuntime(workers=10, sync=sync, straggler=6.0).train(
            model, make_algorithm("ga_sgd", lr=0.3, batch_size=2048), tr, va,
            max_epochs=3)
        print(f"{sync:7s} rounds={r.rounds:4d} time={r.sim_time:7.1f}s "
              f"loss={r.final_loss:.4f} max_staleness={r.max_staleness}")

    print("\n== spot-instance IaaS: preemptions + restart-from-checkpoint ==")
    demand = IaaSRuntime(workers=10).train(
        model, make_algorithm("ga_sgd", lr=0.3, batch_size=2048), tr, va,
        max_epochs=3)
    t0 = demand.breakdown["startup"]
    spot = IaaSRuntime(workers=10, spot=True,
                       preempt_at=((2, t0 + 2.0), (7, t0 + 5.0))).train(
        model, make_algorithm("ga_sgd", lr=0.3, batch_size=2048), tr, va,
        max_epochs=3)
    print(f"on-demand {demand.sim_time:7.1f}s ${demand.cost:.4f}   "
          f"spot {spot.sim_time:7.1f}s ${spot.cost:.4f} "
          f"({spot.preemptions} preemptions, identical numerics: "
          f"{abs(spot.final_loss - demand.final_loss) < 1e-6})")

    print("\n== what-if: 10 GB/s FaaS<->VM link (paper Fig 14) ==")
    wl = Workload(s_bytes=220e6, m_bytes=12e6, R=500, C=400.0)
    for k, v in q1_fast_hybrid(wl, 10).items():
        print(f"  {k:16s} {v:9.0f}s")
    print("\nFaaS wins the small-model/fast-convergence regime; the moment "
          "per-round bytes (m) grow, IaaS/GPU wins both time and cost.")


if __name__ == "__main__":
    main()
