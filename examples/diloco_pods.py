import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""MA-SGD / DiLoCo across pods, end to end on 8 emulated devices.

The paper's technique (sync models every H steps instead of gradients every
step) running as a REAL training loop on a (pod=2, data=2, model=2) mesh:
H inner steps with collectives confined to each pod, then one outer sync
(plain averaging for --algo ma_sgd, Nesterov outer step for --algo diloco,
optionally int8-compressed).  Prints the loss curve and the measured
cross-pod bytes per step vs the GA-SGD baseline.

    PYTHONPATH=src python examples/diloco_pods.py --algo diloco --h 8 --compress
"""
import argparse    # noqa: E402
import dataclasses  # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_reduced                      # noqa: E402
from repro.configs.base import ShapeConfig                 # noqa: E402
from repro.distributed.hlo_analysis import analyze_hlo     # noqa: E402
from repro.distributed.local_sgd import build_local_sgd    # noqa: E402
from repro.distributed.step import build_train_step        # noqa: E402
from repro.launch.mesh import make_mesh                    # noqa: E402
from repro.launch.specs import make_batch                  # noqa: E402
from repro.models import build_model                       # noqa: E402
from repro.optim import make_optimizer                     # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="diloco", choices=["ma_sgd", "diloco"])
    ap.add_argument("--h", type=int, default=8, help="inner steps per sync")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--compress", action="store_true")
    args = ap.parse_args()

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    shape = ShapeConfig("demo", 128, 8, "train")
    arch = get_reduced("smollm-360m")
    arch = arch.replace(train=dataclasses.replace(
        arch.train, algorithm=args.algo, sync_period=args.h,
        compress_cross_pod=args.compress, learning_rate=3e-3))

    ls = build_local_sgd(arch, mesh, shape)
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    P = ls.n_pods
    params_st = jax.tree.map(lambda x: jnp.stack([x] * P), params)
    opt = make_optimizer(arch.train)
    opt_st = jax.tree.map(lambda x: jnp.stack([x] * P), opt.init(params))
    out_state = None  # initialized from params on first sync (see below)

    with mesh:
        # measured cross-pod traffic, this config vs GA baseline
        inner = analyze_hlo(ls.lower_inner().compile().as_text(), pod_size=4)
        outer = analyze_hlo(ls.lower_outer().compile().as_text(), pod_size=4)
        ga = build_train_step(arch, mesh, shape)
        ga_r = analyze_hlo(ga.lower().compile().as_text(), pod_size=4)
        eff = inner["cross_pod_bytes"] + outer["cross_pod_bytes"] / args.h
        print(f"cross-pod bytes/step: GA-SGD {ga_r['cross_pod_bytes'] / 1e6:.2f} MB"
              f" -> {args.algo}(H={args.h}"
              f"{',int8' if args.compress else ''}) {eff / 1e6:.3f} MB "
              f"({ga_r['cross_pod_bytes'] / max(eff, 1e-9):.0f}x less)")
        print(f"inner-step cross-pod bytes: {inner['cross_pod_bytes']:.0f} "
              "(zero by construction)\n")

        out_state = ls.init_outer_fn(params_st)
        step = 0
        for r in range(args.rounds):
            for _ in range(args.h):
                batch = make_batch(arch, 8, 128, seed=step)
                batch = jax.tree.map(jnp.asarray, batch)
                params_st, opt_st, m = ls.inner_fn(params_st, opt_st, batch)
                step += 1
                if step % 4 == 0:
                    print(f"  step {step:3d}  loss {float(m['loss'][0]):.4f}")
            params_st, out_state = ls.outer_fn(params_st, out_state)
            print(f"== outer sync {r + 1} (every H={args.h}) done ==")
        leaf = jax.tree.leaves(params_st)[2]
        print("replicas equal after final sync:",
              bool(jnp.allclose(leaf[0], leaf[1], atol=1e-3)))


if __name__ == "__main__":
    main()
