"""End-to-end LM training driver: data pipeline -> model -> optimizer ->
checkpoint/preemption -> (optionally) elastic resume.

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 300
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 500   # real run
    PYTHONPATH=src python examples/train_lm.py --arch smollm-360m ...      # any zoo arch

The ``tiny`` preset trains a ~2M-param smollm-family model for a few hundred
steps on CPU in a couple of minutes and shows a real falling loss; ``100m``
is the same driver at ~100M params (sized for a real accelerator).  The
driver checkpoints through the PreemptionGuard exactly like a Lambda worker
racing its 15-minute lifetime (paper §3.3.1) -- kill it anytime and rerun
with the same --ckpt-dir to resume, with the same or a different
--num-workers (elastic data resharding).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.configs import get_arch, get_reduced
from repro.configs.base import ModelConfig
from repro.data.tokens import TokenStream
from repro.models import build_model
from repro.optim import make_optimizer

PRESETS = {
    "tiny": ModelConfig(name="tiny", family="dense", num_layers=4,
                        d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
                        d_ff=512, vocab_size=2048, rope_theta=1e4),
    "100m": ModelConfig(name="lm-100m", family="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=4, head_dim=64,
                        d_ff=2048, vocab_size=32768, rope_theta=1e4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--arch", default=None, help="use a zoo arch instead")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--lifetime", type=float, default=900.0,
                    help="simulated worker lifetime (s), à la Lambda")
    ap.add_argument("--num-workers", type=int, default=1)
    ap.add_argument("--worker", type=int, default=0)
    args = ap.parse_args()

    if args.arch:
        arch = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
        cfg = arch.model.replace(dtype="float32")
        tc = arch.train
    else:
        cfg = PRESETS[args.preset].replace(dtype="float32")
        from repro.configs.base import TrainConfig
        tc = TrainConfig(learning_rate=args.lr, weight_decay=0.01)

    import dataclasses
    model = build_model(cfg)
    print(f"model {cfg.name}: {model.param_count():,} params")
    opt = make_optimizer(dataclasses.replace(tc, learning_rate=args.lr))
    stream = TokenStream(cfg.vocab_size, seed=0, worker=args.worker,
                         num_workers=args.num_workers)

    restored, meta = ckpt.load_latest(args.ckpt_dir)
    if restored is not None:
        params = jax.tree.map(jnp.asarray, restored["params"])
        opt_state = jax.tree.map(jnp.asarray, restored["opt"])
        step0 = int(meta["step"])
        stream.restore(meta["stream"], args.worker, args.num_workers)
        print(f"resumed from step {step0} "
              f"(elastic: now {args.num_workers} workers)")
    else:
        params = model.init(jax.random.key(0))
        opt_state = opt.init(params)
        step0 = 0

    @jax.jit
    def train_step(p, s, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda pp: model.loss(pp, batch), has_aux=True)(p)
        new_p, new_s, stats = opt.update(grads, s, p)
        return new_p, new_s, loss, stats["grad_norm"]

    guard = ckpt.PreemptionGuard(lifetime_s=args.lifetime)
    t0 = time.time()
    for step in range(step0, args.steps):
        batch = jax.tree.map(jnp.asarray, stream.batch(args.batch, args.seq))
        ts = time.time()
        params, opt_state, loss, gnorm = train_step(params, opt_state, batch)
        guard.record_step(time.time() - ts)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:5d}  loss {float(loss):.4f}  "
                  f"gnorm {float(gnorm):.3f}  {time.time() - t0:6.1f}s")
        if (step and step % args.ckpt_every == 0) or guard.should_checkpoint():
            ckpt.save(args.ckpt_dir, step + 1,
                      {"params": params, "opt": opt_state},
                      {"stream": stream.state()})
            ckpt.retain(args.ckpt_dir, keep=2)
            if guard.should_checkpoint():
                print(f"step {step}: lifetime nearly exhausted -- checkpoint "
                      "committed; a fresh invocation would resume here")
                guard.renew()
    ckpt.save(args.ckpt_dir, args.steps, {"params": params, "opt": opt_state},
              {"stream": stream.state()})
    print(f"done: final loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
