"""Quickstart: the paper's core result in 60 seconds on a laptop.

Trains logistic regression on (synthetic) Higgs with the three distributed
optimization algorithms under BOTH the FaaS (LambdaML) and IaaS runtimes and
prints the time/cost tradeoff -- the paper's Fig 9/Table-5-style comparison.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.algorithms import make_algorithm
from repro.core.mlmodels import make_study_model
from repro.core.runtimes import FaaSRuntime, IaaSRuntime
from repro.data.synthetic import make_dataset, train_val_split


def main():
    ds = make_dataset("higgs", rows=50_000)
    tr, va = train_val_split(ds)
    model = make_study_model("lr", tr)

    print(f"{'system':22s} {'algo':8s} {'rounds':>6s} {'sim time':>10s} "
          f"{'cost':>9s} {'loss':>8s}")
    for alg, kw in [("ga_sgd", dict(lr=0.3, batch_size=1024)),
                    ("ma_sgd", dict(lr=0.3, batch_size=1024)),
                    ("admm", dict(lr=0.1, local_epochs=10))]:
        for sys_name, rt in [("FaaS (LambdaML/S3)", FaaSRuntime(workers=10)),
                             ("IaaS (PyTorch-like)", IaaSRuntime(workers=10))]:
            r = rt.train(model, make_algorithm(alg, **kw), tr, va,
                         max_epochs=5)
            print(f"{sys_name:22s} {alg:8s} {r.rounds:6d} "
                  f"{r.sim_time:9.1f}s ${r.cost:8.4f} {r.final_loss:8.4f}")

    print("\nPaper's insight #1: ADMM/MA (communication-efficient) make FaaS "
          "competitive;\ninsight #2: even when FaaS is faster it is not much "
          "cheaper (Lambda GB-s pricing).")


if __name__ == "__main__":
    main()
