"""Batched serving example: prefill + decode with the KV-cache API.

    PYTHONPATH=src python examples/serve_lm.py --arch smollm-360m --reduced
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch, get_reduced
from repro.models import build_model
from repro.serving import Generator, perplexity


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    arch = get_reduced(args.arch) if args.reduced else get_arch(args.arch)
    arch = arch.replace(model=arch.model.replace(dtype="float32"))
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    print(f"{arch.name}: {model.param_count():,} params (reduced={args.reduced})")

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, arch.model.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    gen = Generator(arch, params,
                    max_seq=args.prompt_len + args.new_tokens + 1)
    t0 = time.time()
    out = gen.generate(prompts, max_new_tokens=args.new_tokens,
                       temperature=args.temperature)
    dt = time.time() - t0
    n_new = args.batch * args.new_tokens
    print(f"generated {n_new} tokens in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s batched)")
    print("sample row:", out[0].tolist())
    print(f"teacher-forced ppl of generated text: "
          f"{perplexity(model, params, out):.2f}")


if __name__ == "__main__":
    main()
