"""Dev loop: forward+grad+decode every reduced arch on CPU, report failures."""
import sys
import traceback

import jax
import jax.numpy as jnp

from repro.configs import list_archs, get_reduced
from repro.models import build_model
from repro.launch.specs import make_batch

ok = True
for name in list_archs():
    arch = get_reduced(name)
    model = build_model(arch)
    try:
        params = model.init(jax.random.key(0))
        batch = make_batch(arch, batch=2, seq=32)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        assert jnp.isfinite(loss), f"loss not finite: {loss}"
        gnorm = jax.tree.reduce(
            lambda a, b: a + b,
            jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads))
        assert jnp.isfinite(gnorm), "grad not finite"
        msg = f"{name:24s} loss={float(loss):.4f} params={model.param_count():,}"
        if model.cfg.supports_decode:
            cache = model.init_cache(2, 16)
            tok = jnp.array([1, 2], jnp.int32)
            logits, cache = model.decode_step(params, cache, tok, jnp.int32(0))
            assert logits.shape == (2, model.cfg.vocab_size), logits.shape
            assert bool(jnp.all(jnp.isfinite(logits))), "decode logits not finite"
            logits2, cache = model.decode_step(params, cache, tok, jnp.int32(1))
            assert bool(jnp.all(jnp.isfinite(logits2)))
            msg += " decode=ok"
        print(msg)
    except Exception:
        ok = False
        print(f"{name}: FAIL")
        traceback.print_exc()
print("ALL OK" if ok else "FAILURES")
sys.exit(0 if ok else 1)
