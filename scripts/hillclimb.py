import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
"""§Perf hillclimb driver: one (arch x shape x mesh) cell per invocation with
config overrides, printing the three roofline terms + collective/memory
breakdown.  Each hypothesis->change->measure iteration is one command:

  PYTHONPATH=src python scripts/hillclimb.py --arch llama3-405b \
      --shape train_4k --mesh 16x16 \
      --set train.remat=dots --set sharding.seq=None \
      --env REPRO_ATTN_CHUNK_THRESHOLD=8192 --tag L3
"""
import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
from pathlib import Path  # noqa: E402


def parse_value(v: str):
    if v in ("None", "none", "null"):
        return None
    if v in ("True", "true"):
        return True
    if v in ("False", "false"):
        return False
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        pass
    if "," in v:
        return tuple(parse_value(x) for x in v.split(","))
    return v


def apply_overrides(arch, sets):
    for kv in sets:
        key, val = kv.split("=", 1)
        section, field = key.split(".", 1)
        obj = getattr(arch, {"model": "model", "train": "train",
                             "sharding": "sharding"}[section])
        obj = dataclasses.replace(obj, **{field: parse_value(val)})
        arch = dataclasses.replace(arch, **{section: obj})
    return arch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--set", action="append", default=[], dest="sets")
    ap.add_argument("--tag", default="iter")
    ap.add_argument("--local-sgd", action="store_true",
                    help="measure MA-SGD/DiLoCo inner+outer instead of GA")
    ap.add_argument("--save", action="store_true")
    args = ap.parse_args()

    # env overrides must be set before repro imports read them
    import jax  # noqa: F401
    from repro.configs import get_arch
    from repro.distributed import roofline as rl
    from repro.distributed.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_mesh

    dims = tuple(int(x) for x in args.mesh.split("x"))
    names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
    mesh = make_mesh(dims, names)
    arch = apply_overrides(get_arch(args.arch), args.sets)
    chips = mesh.devices.size
    total, active = rl.active_params(arch)
    mflops = rl.model_flops(arch, args.shape, total, active)

    t0 = time.time()
    if args.local_sgd:
        from repro.distributed.local_sgd import build_local_sgd
        ls = build_local_sgd(arch, mesh, args.shape)
        with mesh:
            ci = ls.lower_inner().compile()
            co = ls.lower_outer().compile()
        pod_sz = mesh.devices.size // mesh.shape["pod"]
        ri = analyze_hlo(ci.as_text(), pod_size=pod_sz)
        ro = analyze_hlo(co.as_text(), pod_size=pod_sz)
        H = arch.train.sync_period
        # effective per-step = inner + outer/H
        eff = {k: ri[k] + ro[k] / H for k in ("flops", "bytes", "coll_bytes")}
        rep = rl.RooflineReport(
            arch=args.arch, shape=args.shape, mesh=args.mesh, chips=chips,
            hlo_flops=eff["flops"], hlo_bytes=eff["bytes"],
            collective_bytes=eff["coll_bytes"], model_flops=mflops,
            collectives={"inner": ri["coll"], "outer": ro["coll"]})
        mem = ci.memory_analysis()
        extra = {"inner_coll_bytes": ri["coll_bytes"],
                 "outer_coll_bytes": ro["coll_bytes"], "H": H,
                 "inner_cross_pod_bytes": ri["cross_pod_bytes"],
                 "outer_cross_pod_bytes": ro["cross_pod_bytes"],
                 "cross_pod_bytes_per_step": ri["cross_pod_bytes"]
                 + ro["cross_pod_bytes"] / H}
    else:
        from repro.distributed.step import build_step
        step = build_step(arch, mesh, args.shape)
        with mesh:
            lowered = step.lower()
            compiled = lowered.compile()
        pod_sz = (mesh.devices.size // mesh.shape["pod"]
                  if "pod" in mesh.axis_names else None)
        rep = rl.analyze(compiled, compiled.as_text(), arch_name=args.arch,
                         shape=args.shape, mesh_desc=args.mesh, chips=chips,
                         mflops=mflops, pod_size=pod_sz)
        mem = compiled.memory_analysis()
        extra = {}

    d = rep.to_dict()
    d.update(extra)
    d["tag"] = args.tag
    d["sets"] = args.sets
    d["env"] = {k: v for k, v in os.environ.items()
                if k.startswith("REPRO_ATTN")}
    d["temp_bytes"] = int(getattr(mem, "temp_size_in_bytes", 0))
    d["t_build_s"] = round(time.time() - t0, 1)

    print(f"== {args.arch} x {args.shape} x {args.mesh} [{args.tag}] ==")
    print(f"  sets: {args.sets}  env: {d['env']}")
    print(f"  t_compute    = {rep.t_compute:.3f} s")
    print(f"  t_memory     = {rep.t_memory:.3f} s")
    print(f"  t_collective = {rep.t_collective:.3f} s  (operand-bytes model)")
    print(f"  bottleneck   = {rep.bottleneck}   roofline_frac = "
          f"{rep.roofline_fraction:.4f}   useful/HLO flops = "
          f"{rep.flops_ratio:.3f}")
    adj = rep.extra.get("t_memory_kernel_adj_s")
    if adj is not None and rep.extra.get("scope_bytes", 0) > 0:
        bound_adj = max(rep.t_compute, adj, rep.t_collective)
        print(f"  [flash-kernel adj] t_memory = {adj:.3f} s -> "
              f"bound = {('compute' if bound_adj == rep.t_compute else 'memory' if bound_adj == adj else 'collective')} "
              f"frac = {rep.useful_time / bound_adj:.4f}")
    tadj = rep.extra.get("t_memory_tpu_adj_s")
    if tadj is not None:
        bound_t = max(rep.t_compute, tadj, rep.t_collective)
        print(f"  [+tpu-dtype adj]   t_memory = {tadj:.3f} s -> "
              f"frac = {rep.useful_time / bound_t:.4f}")
    print(f"  temp/device  = {d['temp_bytes'] / 2**30:.2f} GiB   "
          f"build = {d['t_build_s']}s")
    if not args.local_sgd:
        for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute"):
            c = rep.collectives[k]
            if c["count"]:
                print(f"    {k:20s} {c['operand_bytes'] / 1e9:10.1f} GB  "
                      f"n={c['count']}")
    else:
        print(f"  inner coll = {extra['inner_coll_bytes'] / 1e9:.1f} GB  "
              f"outer coll = {extra['outer_coll_bytes'] / 1e9:.1f} GB  "
              f"H = {extra['H']}")
        print(f"  CROSS-POD bytes/step = inner {extra['inner_cross_pod_bytes'] / 1e9:.3f} GB"
              f" + outer/H {extra['outer_cross_pod_bytes'] / 1e9:.3f}/{extra['H']} GB"
              f" = {extra['cross_pod_bytes_per_step'] / 1e9:.3f} GB")
    if not args.local_sgd and rep.extra.get("cross_pod_bytes") is not None:
        print(f"  CROSS-POD bytes/step = "
              f"{rep.extra['cross_pod_bytes'] / 1e9:.3f} GB")
    if args.save:
        out = Path("experiments/perf")
        out.mkdir(parents=True, exist_ok=True)
        p = out / f"{args.arch}__{args.shape}__{args.mesh}__{args.tag}.json"
        p.write_text(json.dumps(d, indent=1, default=str))
        print(f"  saved {p}")


if __name__ == "__main__":
    main()
