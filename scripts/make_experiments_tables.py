"""Render EXPERIMENTS.md tables from experiments/dryrun/*.json."""
import json
import sys
from pathlib import Path

DRY = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def fmt(x, digits=3):
    if x == 0:
        return "0"
    if x < 1e-3 or x >= 1e4:
        return f"{x:.2e}"
    return f"{x:.{digits}f}"


def main():
    recs = []
    for p in sorted(DRY.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("reduced"):
            continue
        recs.append(d)

    arch_order = ["grok-1-314b", "deepseek-v2-lite-16b", "hubert-xlarge",
                  "phi3-medium-14b", "llama3-405b", "stablelm-3b",
                  "smollm-360m", "zamba2-2.7b", "mamba2-370m",
                  "llama-3.2-vision-90b"]
    shape_order = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

    def key(d):
        return (arch_order.index(d["arch"]), shape_order.index(d["shape"]),
                d["mesh"])

    recs.sort(key=key)

    print("| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) | "
          "bound | roofline frac | useful/HLO flops | coll bytes/dev | "
          "temp GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for d in recs:
        if d.get("skipped"):
            if d["mesh"] == "16x16":
                print(f"| {d['arch']} | {d['shape']} | - | - | - | - | "
                      f"SKIP ({d['reason']}) | - | - | - | - |")
            continue
        mem = d.get("memory_analysis", {})
        temp = mem.get("temp_size_in_bytes", 0) / 2**30
        print(f"| {d['arch']} | {d['shape']} | {d['mesh']} "
              f"| {fmt(d['t_compute_s'])} | {fmt(d['t_memory_s'])} "
              f"| {fmt(d['t_collective_s'])} | {d['bottleneck']} "
              f"| {d['roofline_fraction']:.3f} | {d['flops_ratio']:.2f} "
              f"| {fmt(d['collective_bytes_per_device'] / 1e9)} GB "
              f"| {temp:.1f} |")

    print("\n\n### Collective op breakdown (single-pod train_4k)\n")
    print("| arch | all-gather | all-reduce | reduce-scatter | all-to-all | "
          "collective-permute |")
    print("|---|---|---|---|---|---|")
    for d in recs:
        if d.get("skipped") or d["shape"] != "train_4k" or d["mesh"] != "16x16":
            continue
        c = d["collectives"]
        def gb(k):
            return f"{c[k]['operand_bytes'] / 1e9:.1f} GB ({c[k]['count']})"
        print(f"| {d['arch']} | {gb('all-gather')} | {gb('all-reduce')} | "
              f"{gb('reduce-scatter')} | {gb('all-to-all')} | "
              f"{gb('collective-permute')} |")


if __name__ == "__main__":
    main()
