"""REQUIRED smoke tests: every assigned arch, reduced config, one forward +
one train step on CPU; assert output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.launch.specs import make_batch
from repro.models import build_model
from repro.optim import make_optimizer

B, S = 2, 32


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_shapes_no_nan(name):
    arch = get_reduced(name)
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    batch = make_batch(arch, B, S)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (B, S, arch.model.vocab_size)
    # logits keep the model dtype (bf16 in training); the loss does fp32
    # logsumexp internally -- see transformer.forward (§Perf D8)
    assert logits.dtype == jnp.dtype(arch.model.dtype)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCH_IDS)
def test_train_step_no_nan(name):
    arch = get_reduced(name)
    model = build_model(arch)
    opt = make_optimizer(arch.train)
    params = model.init(jax.random.key(0))
    state = opt.init(params)
    batch = make_batch(arch, B, S)

    @jax.jit
    def step(p, s, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda pp: model.loss(pp, b), has_aux=True)(p)
        new_p, new_s, stats = opt.update(grads, s, p)
        return new_p, new_s, loss, stats["grad_norm"]

    p1, s1, loss, gnorm = step(params, state, batch)
    assert bool(jnp.isfinite(loss)) and bool(jnp.isfinite(gnorm))
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), params, p1))
    assert moved > 0
    # a second step keeps everything finite
    p2, s2, loss2, _ = step(p1, s1, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("name", [n for n in ARCH_IDS
                                  if n != "hubert-xlarge"])
def test_decode_step_shapes(name):
    arch = get_reduced(name)
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(B, 16)
    tok = jnp.array([1, 2], jnp.int32)
    logits, cache = model.decode_step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (B, arch.model.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
