"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional test dependency (declared in pyproject.toml
under ``[project.optional-dependencies] test``); the whole module skips
cleanly when it is not installed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dependency; "
                    "pip install hypothesis (or `.[test]`) to run these")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.channels import StorageChannel
from repro.core.patterns import allreduce, scatter_reduce
from repro.data.tokens import TokenStream
from repro.optim import dequantize_blockwise, quantize_blockwise

SETTINGS = dict(max_examples=25, deadline=None)


@given(st.integers(1, 12), st.integers(1, 300), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_patterns_agree_with_mean(w, n, seed):
    """AllReduce and ScatterReduce must both produce the exact mean."""
    rng = np.random.default_rng(seed)
    ups = [rng.standard_normal(n).astype(np.float32) for _ in range(w)]
    want = np.mean(ups, axis=0)
    m1, t1 = allreduce(StorageChannel("s3"), ups, "a")
    m2, t2 = scatter_reduce(StorageChannel("s3"), ups, "b")
    np.testing.assert_allclose(m1, want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(m2, want, rtol=1e-5, atol=1e-6)
    assert np.all(t1 >= 0) and np.all(t2 >= 0) and len(t1) == len(t2) == w


def test_scatter_reduce_beats_allreduce_for_large_models():
    """Paper Table 3: ResNet50-sized updates (89 MB here scaled to 44 MB for
    test RAM), w=10 -> AllReduce's single leader serializes the w gets and
    loses ~2x; for a tiny LR-sized model AllReduce wins (less per-op
    latency)."""
    rng = np.random.default_rng(0)
    w, n = 10, 11_000_000  # 44 MB fp32
    ups = [rng.standard_normal(n).astype(np.float32) for _ in range(w)]
    _, t_ar = allreduce(StorageChannel("s3"), ups, "a")
    _, t_sr = scatter_reduce(StorageChannel("s3"), ups, "b")
    assert float(np.max(t_sr)) < float(np.max(t_ar)) / 1.5
    small = [rng.standard_normal(64).astype(np.float32) for _ in range(w)]
    _, t_ar2 = allreduce(StorageChannel("s3"), small, "c")
    _, t_sr2 = scatter_reduce(StorageChannel("s3"), small, "d")
    assert float(np.max(t_ar2)) < float(np.max(t_sr2))


@given(st.integers(0, 2 ** 20), st.integers(1, 7), st.integers(1, 4),
       st.integers(0, 3))
@settings(**SETTINGS)
def test_token_stream_elastic_coverage(pos, w_old, w_new, batch):
    """Resharding a TokenStream to a different worker count preserves the
    global sample sequence: the union of per-worker global indices equals
    the same contiguous range."""
    def indices(workers, position, bs):
        out = []
        for wk in range(workers):
            ts = TokenStream(128, seed=1, worker=wk, num_workers=workers,
                             position=position)
            out.extend(position + i * workers + wk for i in range(bs))
        return sorted(out)

    bs = batch + 1
    assert indices(w_old, pos, bs) == list(range(pos, pos + bs * w_old))
    assert indices(w_new, pos, bs) == list(range(pos, pos + bs * w_new))


@given(st.integers(1, 4096), st.integers(0, 100), st.floats(0.1, 100.0))
@settings(**SETTINGS)
def test_quantize_roundtrip_bound(n, seed, scale):
    """|dequant(quant(x)) - x| <= blockwise max|x| / 127 / 2 (+eps)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    q, s = quantize_blockwise(x)
    xd = dequantize_blockwise(q, s)
    assert q.dtype == jnp.int8
    bound = float(jnp.max(s)) * 0.5 * 1.02 + 1e-9
    assert float(jnp.max(jnp.abs(xd - x))) <= bound


@given(st.integers(1, 8), st.integers(1, 64))
@settings(**SETTINGS)
def test_channel_time_monotone_in_size(w, kb):
    """Bigger payloads never get cheaper (per channel spec)."""
    ch = StorageChannel("s3")
    small = ch.put("a", np.zeros(kb * 256, np.float32))
    big = ch.put("b", np.zeros(2 * kb * 256, np.float32))
    assert big > small


@given(st.integers(1, 400))
@settings(**SETTINGS)
def test_faas_analytical_dominates_startup_for_small_work(w):
    """t_F(w) << t_I(w) for all worker counts (Table 6)."""
    from repro.core.analytical import TABLE6
    from repro.core.runtimes import interp_startup
    assert interp_startup(TABLE6["t_F"], w) < interp_startup(TABLE6["t_I"], w)


@given(st.integers(1, 7), st.integers(2, 5), st.booleans(), st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_local_sgd_bytes_per_inner_step_shrink_by_h(h, w, compress, epochs):
    """Protocol-parity property (DESIGN.md §11): for ANY (H, fleet size,
    epochs), LocalSGD meters exactly one merge of the update vector per H
    inner rounds (int8 deltas shrink the wire payload to ~1/4 more)."""
    from repro.core.algorithms import make_algorithm
    from repro.core.mlmodels import make_study_model
    from repro.core.runtimes import PodPlatform
    from repro.core.sync import int8_wire_floats
    from repro.data.synthetic import make_dataset, train_val_split

    tr, va = train_val_split(make_dataset("higgs", rows=900))
    model = make_study_model("lr", tr)
    algo = make_algorithm("ga_sgd", lr=0.2, batch_size=256)
    sync = f"local:{h}" + (":c8" if compress else "")
    res = PodPlatform(pods=w, sync=sync).train(model, algo, tr, va,
                                               max_epochs=epochs)
    assert not res.error
    syncs = sum(1 for rnd in range(res.rounds)
                if (rnd + 1) % h == 0 or rnd == res.rounds - 1)
    wire = (int8_wire_floats(tr.d) * 4) if compress else tr.d * 4
    assert res.comm_bytes == syncs * wire


# ------------------------------------------------ trace conservation (§18) --

#: platform x sync x codec x failure corners (the invariants must hold on
#: ANY of them; tests/test_trace.py pins the same grid deterministically)
_TRACE_GRID = [
    {"platform": "faas", "sync": "bsp"},
    {"platform": "faas", "sync": "asp"},
    {"platform": "faas", "sync": "ssp:2",
     "fleet": {"workers": 3, "straggler": 3.0}},
    {"platform": "iaas", "sync": "bsp", "comm": {"codec": "int8"}},
    {"platform": "iaas", "sync": "ssp:2",
     "failure": {"inject": [[0, 30.0]], "spot": True}, "ckpt": "s3:every=2"},
    {"platform": "iaas", "sync": "bsp", "scaling": "smlt:2",
     "fleet": {"workers": 4}},
    {"platform": "pod", "sync": "local:2:c8"},
]


@given(st.integers(0, len(_TRACE_GRID) - 1), st.integers(0, 3),
       st.integers(1, 2))
@settings(max_examples=12, deadline=None)
def test_trace_conservation_invariants_property(idx, seed, epochs):
    """For ANY spec corner and seed, tracing changes no metered value and
    the three conservation gates hold EXACTLY: spans tile each worker's
    clock, the $ ledger sums to finalize_cost, traced bytes == the meters
    (DESIGN.md §18)."""
    from repro.core.trace import assert_invariants
    from repro.experiments import ExperimentSpec

    over = {"rows": 2_000, "max_epochs": epochs, "seed": seed,
            "fleet": {"workers": 2},
            "algo_args": {"lr": 0.2, "batch_size": 1024},
            **_TRACE_GRID[idx]}
    spec = ExperimentSpec.from_dict(over)
    model, algo, tr, va = spec.build_workload()
    runtime = spec.build_runtime()
    res = runtime.train(model, algo, tr, va, max_epochs=epochs, trace=True)
    assert not res.error
    inv = assert_invariants(res)
    assert inv["ok"]
    assert res.trace.meters == res.breakdown
    plain = spec.build_runtime().train(model, algo, tr, va,
                                       max_epochs=epochs)
    assert plain.sim_time == res.sim_time
    assert plain.cost == res.cost
    assert plain.breakdown == res.breakdown
