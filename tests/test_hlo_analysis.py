"""HLO cost analyzer validation -- the §Roofline measurement tool.

The central claims (EXPERIMENTS.md §2 note 1):
1. cost_analysis() does NOT scale with scanned layer count; the analyzer does
   (trip-count multiplication).
2. analyzer(scanned) ~= analyzer(unrolled) for the same model.
3. analyzer(unrolled) ~= cost_analysis(unrolled) FLOPs.
Multi-device compiles need a subprocess (device count pins at jax init).
"""
import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
ENV = {**os.environ, "PYTHONPATH": str(ROOT / "src")}

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"]="--xla_force_host_platform_device_count=8"
import dataclasses, json, jax
from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.distributed.step import build_train_step
from repro.distributed.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_mesh
mesh = make_mesh((2,4),("data","model"))
sh = ShapeConfig("t", 512, 16, "train")
out = {}
for L in (2, 8):
    for scan in (True, False):
        arch = get_reduced("smollm-360m")
        arch = arch.replace(model=arch.model.replace(num_layers=L),
                            train=dataclasses.replace(arch.train,
                                                      scan_layers=scan))
        step = build_train_step(arch, mesh, sh)
        with mesh:
            c = step.lower().compile()
        r = analyze_hlo(c.as_text())
        ca = c.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        out[f"L{L}_scan{scan}"] = {"flops": r["flops"], "bytes": r["bytes"],
                                   "coll": r["coll_bytes"],
                                   "ca_flops": float(ca.get("flops", 0))}
print(json.dumps(out))
"""


def test_analyzer_trip_counts_and_agreement():
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=ENV, cwd=ROOT,
                       capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, r.stdout + r.stderr
    out = json.loads(r.stdout.strip().splitlines()[-1])
    s2, s8 = out["L2_scanTrue"], out["L8_scanTrue"]
    u2, u8 = out["L2_scanFalse"], out["L8_scanFalse"]
    # 1. analyzer flops scale with layer count on scanned models...
    assert 2.0 < s8["flops"] / s2["flops"] < 4.5
    # ...while raw cost_analysis barely moves (the bug we work around)
    assert s8["ca_flops"] / s2["ca_flops"] < 1.3
    # 2. scanned ~= unrolled per the analyzer
    assert abs(s8["flops"] - u8["flops"]) / u8["flops"] < 0.10
    assert abs(s8["coll"] - u8["coll"]) / max(u8["coll"], 1) < 0.10
    # 3. analyzer ~= cost_analysis on the unrolled compile
    assert abs(u8["flops"] - u8["ca_flops"]) / u8["ca_flops"] < 0.25


def test_parse_collectives_units():
    from repro.distributed.hlo_analysis import analyze_hlo
    hlo = """
HloModule m

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p), replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %ag = f32[1024]{0} all-gather(%ar), replica_groups=[2,4]<=[8], dimensions={0}
}
"""
    r = analyze_hlo(hlo)
    assert r["coll"]["all-reduce"]["operand_bytes"] == 4096
    assert r["coll"]["all-gather"]["operand_bytes"] == 1024  # result / group


def test_cross_pod_classification():
    from repro.distributed.hlo_analysis import HloCost, Instr
    hc = HloCost("", pod_size=4)
    intra = Instr("x", "f32[8]", "all-reduce",
                  "%p), replica_groups={{0,1,2,3},{4,5,6,7}}")
    cross = Instr("x", "f32[8]", "all-reduce",
                  "%p), replica_groups={{0,4},{1,5},{2,6},{3,7}}")
    assert not hc._spans_pods(intra)
    assert hc._spans_pods(cross)
    permute_intra = Instr("x", "f32[8]", "collective-permute",
                          "%p), source_target_pairs={{0,1},{1,0},{4,5},{5,4}}")
    permute_cross = Instr("x", "f32[8]", "collective-permute",
                          "%p), source_target_pairs={{0,4},{4,0}}")
    assert not hc._spans_pods(permute_intra)
    assert hc._spans_pods(permute_cross)
