"""Serving simulator (DESIGN.md §14): arrival processes, money identities,
KV packing, Generator parity, and autoscaler regressions.

The property suite (hypothesis) checks the invariants the ISSUE pins:
Poisson arrivals hit nominal QPS, p50 <= p99, total $ recomputes exactly
from per-request fees / provisioned spans, KV packing never busts the HBM
budget, and zero traffic costs exactly the idle-fleet floor.  Deterministic
mirrors of each property run even without hypothesis installed.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import cost as pricing
from repro.core.elastic import CostCapPolicy, SMLTPolicy
from repro.core.elastic.telemetry import ServingTelemetry
from repro.core.platform import FleetSpec, ServingHooks
from repro.core.runtimes import (
    _T_IAAS, FaaSRuntime, IaaSRuntime, KEEP_WARM_S, PodPlatform,
    interp_startup,
)
from repro.serving import (
    LatencyModel, ServingSMLT, make_arrivals, make_autoscaler, provision_for,
    serve,
)
from repro.serving.arrivals import (
    DiurnalArrivals, FlashArrivals, PoissonArrivals, TraceArrivals,
    list_arrivals,
)

ROOT = Path(__file__).resolve().parents[1]
ENV = {**os.environ, "PYTHONPATH": str(ROOT / "src")}


@pytest.fixture(scope="module")
def lat_cpu():
    """Full-size smollm on Lambda-class constants (param count is analytic,
    so this never materializes weights)."""
    return LatencyModel.from_arch("smollm_360m", flops=pricing.LAMBDA_3GB_FLOPS,
                                  mem_bandwidth=pricing.LAMBDA_MEM_BW)


@pytest.fixture(scope="module")
def lat_vm():
    return LatencyModel.from_arch("smollm_360m", flops=pricing.VM_CPU_FLOPS,
                                  mem_bandwidth=pricing.VM_MEM_BW)


# ------------------------------------------------------------ arrivals ------

def test_poisson_hits_nominal_qps():
    """Mean arrival count over seeds sits within 10% of qps * duration."""
    qps, dur = 5.0, 200.0
    counts = [len(PoissonArrivals(qps).times(dur, seed=s)) for s in range(6)]
    assert abs(np.mean(counts) - qps * dur) < 0.10 * qps * dur
    for s, c in enumerate(counts):       # each draw within 6 sigma
        assert abs(c - qps * dur) <= 6 * np.sqrt(qps * dur)


def test_poisson_times_sorted_and_clipped():
    t = PoissonArrivals(3.0).times(50.0, seed=1)
    assert np.all(np.diff(t) >= 0) and t[-1] < 50.0
    assert PoissonArrivals(0.0).times(100.0).size == 0


def test_diurnal_rate_interpolates_and_wraps():
    a = make_arrivals("diurnal:1@0,9@12")
    assert a.rate(0.0) == 1.0
    assert a.rate(86400 / 2) == 9.0
    assert a.rate(86400 / 4) == pytest.approx(5.0)   # linear between points
    assert a.rate(86400 * 3 / 4) == pytest.approx(5.0)  # wraps back down
    assert a.peak_qps == 9.0
    b = make_arrivals("diurnal:2@0,8@12,day=300")    # 24 h in 300 s
    assert b.rate(150.0) == 8.0


def test_flash_rate_plateau():
    a = make_arrivals("flash:0.5,10,60,30")
    assert a.rate(59.9) == 0.5 and a.rate(60.0) == 10.0
    assert a.rate(89.9) == 10.0 and a.rate(90.0) == 0.5
    assert a.peak_qps == 10.0
    t = a.times(200.0, seed=0)
    spike = np.sum((t >= 60) & (t < 90))
    assert spike > 0.5 * len(t)          # the spike dominates the run


def test_trace_roundtrip_and_file(tmp_path):
    inline = TraceArrivals.from_times([5.0, 1.0, 3.0])
    np.testing.assert_allclose(inline.times(4.0), [1.0, 3.0])
    f = tmp_path / "trace.txt"
    f.write_text("0.5\n1.5\n2.5\n")
    a = make_arrivals(f"trace:{f}")
    np.testing.assert_allclose(a.times(10.0), [0.5, 1.5, 2.5])


def test_arrivals_registry_errors():
    with pytest.raises(ValueError, match="unknown arrival"):
        make_arrivals("pareto:3")
    with pytest.raises(ValueError, match="needs an argument"):
        make_arrivals("poisson")
    assert set(list_arrivals()) == {"poisson", "diurnal", "flash", "trace"}


# --------------------------------------------------------- latency model ----

def test_kv_bytes_follow_arch_dims(lat_cpu):
    from repro.configs import get_arch
    m = get_arch("smollm-360m").model
    per_token = m.num_layers * 2 * m.kv_heads * m.hdim * 2   # bf16
    assert lat_cpu.kv_bytes_token == per_token
    assert lat_cpu.kv_bytes(64) == 64 * per_token
    assert lat_cpu.model_bytes == lat_cpu.n_params * 2


def test_step_is_roofline_max(lat_cpu):
    compute = 2.0 * lat_cpu.n_params / lat_cpu.flops
    streaming = lat_cpu.model_bytes / lat_cpu.mem_bandwidth
    assert lat_cpu.step_s(1) == max(compute, streaming)
    assert lat_cpu.step_s(4) >= lat_cpu.step_s(1)
    # request mirrors Generator's loop: prompt + new decode_step calls
    assert lat_cpu.request_steps(7, 5) == 12


def test_ssm_arch_has_constant_state():
    lat = LatencyModel.from_arch("mamba2-370m", flops=1e12,
                                 mem_bandwidth=1e11)
    assert lat.kv_bytes_token == 0 and lat.kv_bytes_const > 0
    assert lat.kv_bytes(100) == lat.kv_bytes(1)


def test_encoder_rejected():
    with pytest.raises(ValueError, match="encoder-only"):
        LatencyModel.from_arch("hubert-xlarge", flops=1e12,
                               mem_bandwidth=1e11)


# ------------------------------------------------------- platform hooks -----

def test_serving_hooks_all_platforms():
    f = FaaSRuntime(workers=4).serving_hooks()
    assert f.billing == "request" and f.gb_s_usd == pricing.LAMBDA_GB_S
    assert f.request_fee_usd == pricing.LAMBDA_REQUEST
    assert f.keep_warm_s == KEEP_WARM_S
    i = IaaSRuntime(workers=2).serving_hooks()
    assert i.billing == "provisioned"
    assert i.hourly_usd == pricing.EC2_HOURLY["t2.medium"]
    assert i.provision_s(2) == interp_startup(_T_IAAS, 2)
    p = PodPlatform(pods=1, chips_per_pod=4).serving_hooks()
    assert p.billing == "provisioned"
    assert p.hourly_usd == 4 * pricing.TPU_CHIP_HOURLY
    assert p.memory_bytes == 4 * pricing.POD_HBM_GB * 1e9


def test_heterogeneous_fleet_rejected():
    with pytest.raises(ValueError, match="homogeneous"):
        FaaSRuntime(lambda_gb=(1.0, 3.0), workers=2).serving_hooks()
    with pytest.raises(ValueError, match="homogeneous"):
        IaaSRuntime(fleet=FleetSpec(workers=2,
                                    instance=("t2.medium", "c5.large"))
                    ).serving_hooks()


def test_model_too_big_rejected():
    big = LatencyModel(arch="x", n_params=10**9, flops=5e9,
                      mem_bandwidth=1e10, kv_bytes_token=0)   # 2 GB bf16
    with pytest.raises(ValueError, match="do not fit"):
        serve(FaaSRuntime(lambda_gb=1.0, workers=2), big, "poisson:1",
              duration_s=10)


# ----------------------------------------------------- money identities -----

def test_faas_cost_is_sum_of_per_request_fees(lat_cpu):
    res = serve(FaaSRuntime(workers=16), lat_cpu, "poisson:0.5",
                duration_s=120.0, seed=3)
    assert res.completed > 0
    assert res.cost == sum(res.per_request_usd)          # exact, not approx
    # every fee is one of the two shapes the constants allow (warm/cold)
    service = lat_cpu.service_s(32, 32)
    hooks = FaaSRuntime(workers=16).serving_hooks()
    warm = hooks.gb * service * hooks.gb_s_usd + hooks.request_fee_usd
    cold = (hooks.gb * (service + hooks.cold_start_total_s(lat_cpu.model_bytes))
            * hooks.gb_s_usd + hooks.request_fee_usd)
    for fee in res.per_request_usd:
        assert fee == warm or fee == cold
    assert sum(1 for fee in res.per_request_usd
               if fee == cold) == res.cold_starts


def test_provisioned_cost_is_sum_of_span_hours(lat_vm):
    res = serve(IaaSRuntime(workers=3), lat_vm, "poisson:0.2",
                duration_s=200.0, seed=4)
    assert res.cost == sum((t1 - t0) * hourly / 3600.0
                           for t0, t1, hourly in res.provisioned)
    assert len(res.provisioned) == 3


def test_zero_traffic_costs_idle_floor(lat_cpu, lat_vm):
    faas = serve(FaaSRuntime(workers=8), lat_cpu, "poisson:0",
                 duration_s=300.0)
    assert faas.requests == 0 and faas.cost == 0.0       # scale-to-zero
    iaas = serve(IaaSRuntime(workers=3), lat_vm, "poisson:0",
                 duration_s=300.0)
    floor = 3 * pricing.EC2_HOURLY["t2.medium"] * 300.0 / 3600.0
    assert iaas.cost == pytest.approx(floor, rel=1e-12)
    assert iaas.sim_time == 300.0


def test_p50_le_p99(lat_cpu, lat_vm):
    for res in (serve(FaaSRuntime(workers=8), lat_cpu, "poisson:1",
                      duration_s=60.0, seed=5),
                serve(IaaSRuntime(workers=4), lat_vm, "poisson:1",
                      duration_s=60.0, seed=5)):
        assert res.completed > 0
        assert res.p50_s <= res.p99_s


# ------------------------------------------------- KV packing / batching ----

def test_kv_packing_never_exceeds_budget():
    pod = PodPlatform(pods=1, chips_per_pod=4)
    hooks = pod.serving_hooks()
    lat = LatencyModel.from_arch("smollm_360m", flops=hooks.flops,
                                 mem_bandwidth=hooks.mem_bandwidth)
    res = serve(pod, lat, "poisson:100", duration_s=20.0, window_s=5.0,
                max_batch=64, seed=6)
    assert res.peak_batch > 1                    # batching actually engaged
    assert 0 < res.peak_kv_bytes <= res.kv_budget_bytes
    assert res.peak_kv_bytes <= res.peak_batch * lat.kv_bytes(64)


def test_batch_respects_max_batch_and_kv(lat_vm):
    # kv budget that only fits 2 requests forces batch <= 2 even with room
    hooks = IaaSRuntime(workers=1).serving_hooks()
    kv_req = lat_vm.kv_bytes(64)
    tight = LatencyModel(arch=lat_vm.arch, n_params=int(
        (hooks.memory_bytes - 2.5 * kv_req) / 2), flops=lat_vm.flops,
        mem_bandwidth=lat_vm.mem_bandwidth,
        kv_bytes_token=lat_vm.kv_bytes_token)
    res = serve(IaaSRuntime(workers=1), tight, "poisson:30",
                duration_s=10.0, max_batch=32, seed=7)
    assert res.peak_batch <= 2
    assert res.peak_kv_bytes <= hooks.memory_bytes - tight.model_bytes


# ----------------------------------------------------- hypothesis suite -----

def test_property_suite(lat_cpu, lat_vm):
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=12, deadline=None)
    @given(qps=st.floats(min_value=0.0, max_value=4.0),
           dur=st.floats(min_value=20.0, max_value=120.0),
           workers=st.integers(min_value=1, max_value=8),
           seed=st.integers(min_value=0, max_value=2**16),
           faas=st.booleans())
    def prop(qps, dur, workers, seed, faas):
        if faas:
            platform, lat = FaaSRuntime(workers=workers), lat_cpu
        else:
            platform, lat = IaaSRuntime(workers=workers), lat_vm
        res = serve(platform, lat, f"poisson:{qps}", duration_s=dur,
                    seed=seed)
        if res.latencies:
            assert res.p50_s <= res.p99_s
        if faas:
            assert res.cost == sum(res.per_request_usd)
            if res.requests == 0:
                assert res.cost == 0.0
        else:
            assert res.cost == sum((t1 - t0) * h / 3600.0
                                   for t0, t1, h in res.provisioned)
        assert res.peak_kv_bytes <= res.kv_budget_bytes
        assert res.completed + res.rejected + res.dropped <= res.requests

    prop()

    @settings(max_examples=10, deadline=None)
    @given(qps=st.floats(min_value=0.5, max_value=20.0),
           seed=st.integers(min_value=0, max_value=2**16))
    def arrivals_prop(qps, seed):
        n = len(PoissonArrivals(qps).times(100.0, seed))
        assert abs(n - qps * 100.0) <= 6 * np.sqrt(qps * 100.0) + 1

    arrivals_prop()


# ------------------------------------------------------ Generator parity ----

@pytest.fixture(scope="module")
def reduced_gen():
    jax = pytest.importorskip("jax")
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.serving import Generator
    arch = get_reduced("smollm-360m")
    arch = arch.replace(model=arch.model.replace(dtype="float32"))
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    return arch, Generator(arch, params, max_seq=32)


def test_sim_latency_pins_generator_decode_loop(reduced_gen):
    """The parity satellite: the simulator's warm single-request latency is
    byte-identical to the real Generator's prefill+decode step count under
    the shared LatencyModel -- one cost, one implementation."""
    arch, gen = reduced_gen
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, arch.model.vocab_size, (1, 7)).astype(np.int32)
    gen.decode_steps = 0
    gen.generate(prompts, max_new_tokens=5)
    assert gen.decode_steps == 12                 # 7 prefill + 5 decode

    hooks = IaaSRuntime(workers=1).serving_hooks()
    lat = LatencyModel.from_arch("smollm_360m", flops=hooks.flops,
                                 mem_bandwidth=hooks.mem_bandwidth,
                                 reduced=True)
    want = gen.simulated_latency_s(lat)           # decode_steps * step_s(1)

    trace = TraceArrivals.from_times([0.0])
    warm_vm = serve(IaaSRuntime(workers=1), lat, trace, duration_s=30.0,
                    prompt_len=7, new_tokens=5)
    assert warm_vm.completed == 1
    assert warm_vm.latencies[0] == want           # byte-identical

    faas_hooks = FaaSRuntime(workers=1).serving_hooks()
    lat_f = LatencyModel.from_arch("smollm_360m", flops=faas_hooks.flops,
                                   mem_bandwidth=faas_hooks.mem_bandwidth,
                                   reduced=True)
    warm_faas = serve(FaaSRuntime(workers=1), lat_f, trace, duration_s=30.0,
                      prompt_len=7, new_tokens=5, prewarm=1)
    assert warm_faas.cold_starts == 0
    assert warm_faas.latencies[0] == gen.simulated_latency_s(lat_f)


# --------------------------------------------------- autoscaler suite -------

def _tele(**kw):
    base = dict(round=1, workers=4, qps=1.0, queue_depth=0, p50_ms=10.0,
                p99_ms=20.0, utilization=0.5, cost_so_far=0.0, sim_time=30.0,
                min_workers=1, max_workers=64)
    base.update(kw)
    return ServingTelemetry(**base)


def test_serving_smlt_contract():
    pol = ServingSMLT(factor=2, cooldown_s=100.0)
    assert pol.observe(_tele(queue_depth=5)) == 8        # backlog: widen
    assert pol.observe(_tele(sim_time=60.0, queue_depth=5)) == 4   # cooldown
    assert pol.observe(_tele(sim_time=200.0, utilization=0.9)) == 8
    assert pol.observe(_tele(sim_time=400.0, utilization=0.1)) == 2
    assert pol.observe(_tele(sim_time=500.0, utilization=0.5)) == 4  # hold


def test_make_autoscaler_grammar():
    assert make_autoscaler(None) is None
    assert make_autoscaler("static") is None
    assert isinstance(make_autoscaler("smlt:4"), ServingSMLT)
    assert make_autoscaler("smlt:4").factor == 4
    assert isinstance(make_autoscaler("cost_cap:0.5"), CostCapPolicy)
    assert isinstance(make_autoscaler(SMLTPolicy(factor=2)), ServingSMLT)
    with pytest.raises(ValueError, match="plan"):
        make_autoscaler("plan")


def test_cost_cap_serving_obeys_budget_plus_one_window(lat_cpu):
    """Mirror of the training property: total $ <= budget + one window's
    spend (fees accrue at admission, so every window sees them)."""
    budget = 0.004
    policy = CostCapPolicy(budget)
    res = serve(FaaSRuntime(workers=32), lat_cpu, "poisson:2",
                duration_s=240.0, window_s=10.0, scaling=policy, seed=8)
    assert res.scaling_timeline[-1][1] == 0          # it did stop
    assert res.dropped > 0                           # traffic kept coming
    assert res.cost <= budget + policy.max_round_spend + 1e-12


def test_flash_crowd_schedule_provably_worse_than_smlt(lat_vm):
    """The autoscaler regression the ISSUE pins: on a flash crowd, a width
    pinned by schedule loses on p99 to load-driven smlt -- asserted."""
    fleet = FleetSpec(workers=2, max_workers=32)
    flash = "flash:0.1,2,60,240"
    kw = dict(duration_s=600.0, window_s=15.0, seed=3)
    smlt = serve(IaaSRuntime(fleet=fleet, scaling="smlt"), lat_vm, flash,
                 **kw)
    sched = serve(IaaSRuntime(fleet=fleet, scaling="schedule:2@0"), lat_vm,
                  flash, **kw)
    assert smlt.completed == sched.completed == smlt.requests
    assert max(w for _, w, _ in smlt.scaling_timeline) > 2   # it widened
    assert smlt.p99_s < sched.p99_s                  # provably better
    # the widened capacity is billed: smlt cannot be cheaper than pinned
    assert smlt.cost > sched.cost


def test_provisioned_scale_up_pays_table6_curve(lat_vm):
    """Scale-ups come online after the same interp_startup curve elastic
    training pays (+ the weight pull), visible as cold_starts and as spans
    that start at the decision window."""
    fleet = FleetSpec(workers=1, max_workers=8)
    res = serve(IaaSRuntime(fleet=fleet, scaling="schedule:1@0,4@2"),
                lat_vm, "poisson:0.5", duration_s=240.0, window_s=15.0,
                seed=9)
    assert res.cold_starts == 3                      # 1 -> 4 provisions 3
    assert (2, 4, 45.0) in [(w_idx, w, t) for w_idx, w, t
                            in res.scaling_timeline]
    # the joiners bill from the decision time, not from readiness
    starts = sorted(t0 for t0, _, _ in res.provisioned)
    assert starts.count(45.0) == 3


# ----------------------------------------------------------- spec + CLI -----

def test_serving_spec_roundtrip_and_cache(tmp_path):
    from repro.experiments.serving import ServingSpec, run_serving
    spec = ServingSpec(name="t", arrival="poisson:0.2", duration_s=30.0,
                       fleet=FleetSpec(workers=2))
    assert ServingSpec.from_json(spec.to_json()) == spec
    assert spec.spec_hash() == spec.with_(name="renamed").spec_hash()
    assert spec.spec_hash() != spec.with_(arrival="poisson:0.3").spec_hash()
    first = run_serving(spec, cache_dir=tmp_path)
    again = run_serving(spec, cache_dir=tmp_path)
    assert not first.cached and again.cached
    assert again.result == first.result
    assert (tmp_path / f"serve_{spec.spec_hash()}.json").exists()


def test_serving_spec_rejections():
    from repro.experiments.serving import ServingSpec
    with pytest.raises(ValueError, match="platform"):
        ServingSpec(platform="azure")
    with pytest.raises(ValueError, match="arrival"):
        ServingSpec(arrival="pareto:3")
    with pytest.raises(ValueError, match="zoo arch"):
        ServingSpec(model="lr")


def test_cli_serve_smoke(tmp_path):
    out = tmp_path / "serve.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro", "serve", "--arrival", "poisson:0.5",
         "--duration-s", "60", "--no-cache", "--out", str(out)],
        env=ENV, capture_output=True, text=True, cwd=ROOT)
    assert r.returncode == 0, r.stderr
    recs = json.loads(out.read_text())
    assert recs[0]["schema"] == "repro.serving/v1"
    assert recs[0]["result"]["requests"] >= 0
