"""Serving: greedy generation consistency + perplexity sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.serving import Generator, perplexity


@pytest.fixture(scope="module")
def small():
    arch = get_reduced("smollm-360m")
    arch = arch.replace(model=arch.model.replace(dtype="float32"))
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    return arch, model, params


def test_greedy_generation_matches_forward_argmax(small):
    """The first generated token must equal argmax of the forward logits at
    the last prompt position (teacher forcing <-> decode equivalence)."""
    arch, model, params = small
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, arch.model.vocab_size, (2, 7)).astype(np.int32)
    gen = Generator(arch, params, max_seq=32)
    out = gen.generate(prompts, max_new_tokens=3)
    assert out.shape == (2, 10)
    logits, _ = model.forward(params, {"tokens": jnp.asarray(prompts)})
    want = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
    np.testing.assert_array_equal(out[:, 7], want)


def test_generation_deterministic(small):
    arch, _, params = small
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, arch.model.vocab_size, (1, 5)).astype(np.int32)
    gen = Generator(arch, params, max_seq=16)
    a = gen.generate(prompts, max_new_tokens=4)
    b = gen.generate(prompts, max_new_tokens=4)
    np.testing.assert_array_equal(a, b)


def test_sampling_temperature(small):
    arch, _, params = small
    rng = np.random.default_rng(2)
    prompts = rng.integers(0, arch.model.vocab_size, (1, 4)).astype(np.int32)
    gen = Generator(arch, params, max_seq=16)
    a = gen.generate(prompts, max_new_tokens=6, temperature=2.0, seed=1)
    b = gen.generate(prompts, max_new_tokens=6, temperature=2.0, seed=2)
    assert a.shape == b.shape == (1, 10)
    # different seeds should (overwhelmingly) differ at high temperature
    assert not np.array_equal(a, b)


def test_perplexity_finite(small):
    arch, model, params = small
    rng = np.random.default_rng(3)
    toks = rng.integers(0, arch.model.vocab_size, (2, 16)).astype(np.int32)
    p = perplexity(model, params, toks)
    assert np.isfinite(p) and p > 1.0
