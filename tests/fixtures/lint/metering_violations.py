"""Seeded metering-discipline violations (never imported; AST fixture).

Line numbers are asserted exactly in tests/test_analysis.py.
"""


def steal_the_books(ctx, res) -> None:
    ctx.cost = 0.0                           # M001 (line 8)
    res.sim_time += 1.0                      # M001 (line 9)
    ctx.clock[0] = 5.0                       # M001 (line 10)
    res.comm_bytes, x = 0, 1                 # M001 (line 11), tuple target


def bill_early(platform, ctx) -> float:
    return platform.finalize_cost(ctx)       # M002 (line 15)
