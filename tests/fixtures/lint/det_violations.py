"""Seeded determinism violations for the lint fixture tests.

Never imported -- the lint engine reads it as an AST only.  Line numbers
are asserted exactly in tests/test_analysis.py; append, don't reorder.
"""
import random
import time
from datetime import datetime
from time import perf_counter

import numpy as np


def ok_seeded(seed: int) -> float:
    rng = np.random.default_rng(seed)        # allowed: seeded constructor
    return float(rng.standard_normal())


def bad_wall_clock() -> float:
    t0 = time.time()                         # D001 (line 20)
    t1 = perf_counter()                      # D001 (line 21)
    stamp = datetime.now()                   # D001 (line 22)
    return t0 + t1 + stamp.timestamp()


def bad_rng() -> float:
    a = np.random.rand()                     # D002 (line 27)
    b = random.random()                      # D002 (line 28)
    np.random.seed(0)                        # D002 (line 29)
    return a + b


def suppressed_wall_clock() -> float:
    return time.time()  # lint: ignore[D001] -- fixture suppression demo
