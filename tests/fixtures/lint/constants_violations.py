"""Seeded constant-duplication violations (never imported; AST fixture).

Line numbers are asserted exactly in tests/test_analysis.py.
"""

S3_BANDWIDTH_COPY = 65e6                     # C001 (line 6): s3 bandwidth


def lambda_bill(gb_s: float) -> float:
    return gb_s * 1.66667e-5                 # C001 (line 10): LAMBDA_GB_S


def innocuous() -> float:
    return 10e9 + 0.3                        # 1-sig knobs: not distinctive
