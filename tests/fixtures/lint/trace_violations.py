"""Seeded trace-discipline violations (never imported; AST fixture).

Line numbers are asserted exactly in tests/test_analysis.py.
"""


def untraced_mutation(ctx, dt) -> None:
    ctx.clock += dt                          # T001 (line 8): no rec anywhere
    ctx.breakdown["comm"] = 0.0              # same function: one finding


def traced_mutation(ctx, dt) -> None:
    ctx.clock += dt                          # ok: recorder referenced below
    if ctx.rec is not None:
        ctx.rec.meter("comm", dt)


def suppressed_mutation(res) -> None:
    res.sim_time = 0.0  # lint: ignore[T001] -- numeric no-op demo
