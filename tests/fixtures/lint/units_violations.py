"""Seeded unit-hygiene violations (never imported; AST fixture only).

Line numbers are asserted exactly in tests/test_analysis.py.
"""


def bill(duration_seconds: float) -> float:  # U001 (line 7): _seconds
    cost_dollars = duration_seconds * 0.1    # U001 (line 8): _dollars
    return cost_dollars


def mixed(total_s: float, p50_ms: float, payload_bytes: float) -> float:
    bad = total_s + p50_ms                   # U002 (line 13): _s + _ms
    worse = payload_bytes - total_s          # U002 (line 14): _bytes - _s
    fine = total_s + total_s                 # same unit: not flagged
    converted = total_s + p50_ms / 1e3       # rhs is a BinOp: not flagged
    return bad + worse + fine + converted


def suppressed(total_s: float, p50_ms: float) -> float:
    return total_s + p50_ms  # lint: ignore[U002] -- fixture suppression demo
