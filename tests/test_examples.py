"""End-to-end example integration: elastic checkpoint/resume of the LM
driver (the 15-minute-Lambda contract, deliverable (b))."""
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
ENV = {**os.environ, "PYTHONPATH": str(ROOT / "src")}


def _run(args, timeout=600):
    return subprocess.run([sys.executable, *args], env=ENV, cwd=ROOT,
                          capture_output=True, text=True, timeout=timeout)


def test_train_lm_elastic_resume(tmp_path):
    ck = str(tmp_path / "ck")
    base = ["examples/train_lm.py", "--preset", "tiny", "--batch", "4",
            "--seq", "64", "--ckpt-dir", ck, "--ckpt-every", "10"]
    r1 = _run(base + ["--steps", "20"])
    assert r1.returncode == 0, r1.stdout + r1.stderr
    assert "done: final loss" in r1.stdout
    # resume with a DIFFERENT worker count (elastic data resharding)
    r2 = _run(base + ["--steps", "30", "--num-workers", "2"])
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "resumed from step 20" in r2.stdout
    assert "elastic: now 2 workers" in r2.stdout
    # loss after resume continues from the trained model (well below init ~7.6)
    last = [ln for ln in r2.stdout.splitlines() if ln.startswith("done")][0]
    assert float(last.split()[-1]) < 6.0
