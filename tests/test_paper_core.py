"""Paper-core behaviour: algorithms, channels, sync protocols, FaaS runtime
semantics (lifetime/checkpoint, stragglers, DynamoDB limits), analytical
model vs emulator."""
import numpy as np
import pytest

from repro.core.algorithms import make_algorithm
from repro.core.analytical import (
    TABLE6, Workload, estimate_epochs, faas_time, iaas_time, q1_fast_hybrid,
    q2_hot_data,
)
from repro.core.channels import CHANNEL_SPECS, StorageChannel
from repro.core.mlmodels import make_study_model, model_bytes
from repro.core.runtimes import FaaSRuntime, IaaSRuntime
from repro.data.synthetic import make_dataset, partition, train_val_split


@pytest.fixture(scope="module")
def higgs():
    ds = make_dataset("higgs", rows=30_000)
    return train_val_split(ds)


def test_admm_converges_faster_than_ga(higgs):
    """Paper Fig 7a: for LR on Higgs, ADMM reaches a lower loss than GA-SGD
    in the same number of communication rounds."""
    tr, va = higgs
    model = make_study_model("lr", tr)
    ga = FaaSRuntime(workers=10).train(
        model, make_algorithm("ga_sgd", lr=0.3, batch_size=1024), tr, va,
        max_epochs=5)
    admm = FaaSRuntime(workers=10).train(
        model, make_algorithm("admm", lr=0.1, local_epochs=10), tr, va,
        max_epochs=5)
    assert admm.final_loss < ga.final_loss


def test_ma_reduces_comm_rounds(higgs):
    """MA-SGD syncs once per epoch; GA-SGD once per batch."""
    tr, va = higgs
    model = make_study_model("lr", tr)
    ga = FaaSRuntime(workers=5).train(
        model, make_algorithm("ga_sgd", lr=0.3, batch_size=512), tr, va,
        max_epochs=2)
    ma = FaaSRuntime(workers=5).train(
        model, make_algorithm("ma_sgd", lr=0.3, batch_size=512), tr, va,
        max_epochs=2)
    assert ma.rounds < ga.rounds
    assert ma.breakdown["comm"] < ga.breakdown["comm"]


def test_faas_identical_numerics_to_iaas(higgs):
    """Paper principle 1: same algorithm both sides -> identical loss curves
    (only time/cost differ)."""
    tr, va = higgs
    model = make_study_model("lr", tr)
    kw = dict(max_epochs=3)
    f = FaaSRuntime(workers=4).train(
        model, make_algorithm("ga_sgd", lr=0.2, batch_size=2048), tr, va, **kw)
    i = IaaSRuntime(workers=4).train(
        model, make_algorithm("ga_sgd", lr=0.2, batch_size=2048), tr, va, **kw)
    np.testing.assert_allclose([l for _, l in f.history],
                               [l for _, l in i.history], rtol=1e-6)
    assert f.sim_time != i.sim_time


def test_faas_startup_beats_iaas(higgs):
    tr, va = higgs
    model = make_study_model("lr", tr)
    f = FaaSRuntime(workers=10).train(
        model, make_algorithm("admm", local_epochs=2), tr, va, max_epochs=1)
    i = IaaSRuntime(workers=10).train(
        model, make_algorithm("admm", local_epochs=2), tr, va, max_epochs=1)
    assert f.breakdown["startup"] < i.breakdown["startup"]


def test_dynamodb_rejects_large_models():
    ds = make_dataset("cifar10", rows=2000)
    tr, va = train_val_split(ds)
    mn = make_study_model("mobilenet", tr)          # 12 MB > 400 KB limit
    r = FaaSRuntime(workers=4, channel="dynamodb").train(
        mn, make_algorithm("ga_sgd", lr=0.05, batch_size=512), tr, va,
        max_epochs=1)
    assert "dynamodb" in r.error


def test_lifetime_checkpointing_kicks_in(higgs):
    """With a tiny lifetime the runtime must checkpoint + re-invoke and still
    produce the same numerics as an uninterrupted run."""
    tr, va = higgs
    model = make_study_model("lr", tr)
    algo = lambda: make_algorithm("ga_sgd", lr=0.3, batch_size=1024)  # noqa
    uninterrupted = FaaSRuntime(workers=4).train(model, algo(), tr, va,
                                                 max_epochs=2)
    interrupted = FaaSRuntime(workers=4, lifetime=25.0).train(
        model, algo(), tr, va, max_epochs=2)
    assert interrupted.breakdown["checkpoint"] > 0
    assert interrupted.sim_time > uninterrupted.sim_time
    np.testing.assert_allclose(interrupted.final_loss,
                               uninterrupted.final_loss, rtol=1e-6)


def test_straggler_mitigation(higgs):
    tr, va = higgs
    model = make_study_model("lr", tr)
    algo = lambda: make_algorithm("ma_sgd", lr=0.3, batch_size=1024)  # noqa
    slow = FaaSRuntime(workers=8, straggler=5.0).train(
        model, algo(), tr, va, max_epochs=2)
    mitigated = FaaSRuntime(workers=8, straggler=5.0,
                            backup_invocations=True).train(
        model, algo(), tr, va, max_epochs=2)
    assert mitigated.breakdown["compute"] < slow.breakdown["compute"]


def test_asp_runs_more_rounds_less_stable(higgs):
    tr, va = higgs
    model = make_study_model("lr", tr)
    bsp = FaaSRuntime(workers=6).train(
        model, make_algorithm("ga_sgd", lr=0.3, batch_size=4096), tr, va,
        max_epochs=3)
    asp = FaaSRuntime(workers=6, sync="asp").train(
        model, make_algorithm("ga_sgd", lr=0.3, batch_size=4096), tr, va,
        max_epochs=3)
    assert asp.rounds >= bsp.rounds  # w updates per epoch vs 1 sync'd


def test_kmeans_em(higgs):
    tr, va = higgs
    km = make_study_model("kmeans", tr, k=5)
    r = FaaSRuntime(workers=4).train(km, make_algorithm("kmeans_em"), tr, va,
                                     max_epochs=4)
    losses = [l for _, l in r.history]
    assert losses[-1] <= losses[0]  # EM monotone (up to eval subsampling)


def test_channel_specs_table6():
    assert CHANNEL_SPECS["s3"].bandwidth == 65e6
    assert CHANNEL_SPECS["s3"].latency == 8e-2
    assert CHANNEL_SPECS["memcached"].bandwidth == 630e6
    assert CHANNEL_SPECS["memcached"].startup > 100   # the 2-minute startup
    assert CHANNEL_SPECS["dynamodb"].max_item == 400_000


def test_analytical_model_regimes():
    """The paper's headline: FaaS wins for small models/quick convergence;
    loses when the per-round communication m dominates."""
    # tiny model, few epochs (LR-like): FaaS faster
    small = Workload(s_bytes=1e9, m_bytes=1e3, R=10, C=30.0)
    assert faas_time(small, 10) < iaas_time(small, 10)
    # big model, many rounds (ResNet-like): IaaS faster
    big = Workload(s_bytes=1e9, m_bytes=100e6, R=200, C=300.0)
    assert faas_time(big, 10) > iaas_time(big, 10)


def test_analytical_matches_emulator_shape(higgs):
    """Emulated FaaS runtime within 2x of the closed-form model (same
    constants, same round counts)."""
    tr, va = higgs
    model = make_study_model("lr", tr)
    algo = make_algorithm("ga_sgd", lr=0.3, batch_size=1024)
    r = FaaSRuntime(workers=5).train(model, algo, tr, va, max_epochs=3)
    rounds = r.rounds
    wl = Workload(s_bytes=tr.nbytes, m_bytes=model_bytes(model.init(
        __import__("jax").random.key(0))), R=rounds, C=0.001,
        f=lambda w: 1.0)
    t_model = faas_time(wl, 5)
    assert 0.5 < r.sim_time / t_model < 2.0


def test_what_if_q1_q2():
    wl = Workload(s_bytes=4e9, m_bytes=12e6, R=50, C=120.0)
    q1 = q1_fast_hybrid(wl, 10)
    assert q1["hybrid_10GBps"] < q1["hybrid_now"]
    q2 = q2_hot_data(wl, 10)
    assert q2["iaas_hot"] < q2["faas_hot"]  # paper Fig 15


def test_epoch_estimator(higgs):
    tr, va = higgs
    model = make_study_model("lr", tr)
    algo = make_algorithm("ma_sgd", lr=0.3, batch_size=1024)
    ep = estimate_epochs(model, algo, tr, target_loss=0.55, max_epochs=20)
    assert 1 <= ep <= 20
