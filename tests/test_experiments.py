"""Declarative experiment API (DESIGN.md §10): spec round-trips, the
spec-hash result cache, parity between ``run_experiment`` and the legacy
runtime entry points, the sweep grid, and the ``python -m repro`` CLI."""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.platform import CommSpec, FailureSpec, FleetSpec
from repro.experiments import (
    PRESETS, ExperimentSpec, get_preset, run_experiment, sweep,
)

ROOT = Path(__file__).resolve().parents[1]
ENV = {**os.environ, "PYTHONPATH": str(ROOT / "src")}

QUICK = dict(rows=3_000, max_epochs=1)


def _spec(**kw):
    return ExperimentSpec(**{**QUICK, "fleet": FleetSpec(workers=2), **kw})


# ---------------------------------------------------------- serialization ---

def test_spec_json_round_trip_equality():
    spec = ExperimentSpec(
        name="rt", platform="iaas", sync="ssp:2",
        fleet=FleetSpec(workers=4, instance=("c5.large", "c5.large",
                                             "t2.medium", "t2.medium"),
                        straggler=3.0),
        failure=FailureSpec(spot=True, inject=((1, 140.0), (2, 150.0))),
        comm=CommSpec(ckpt_channel="s3"),
        algorithm="admm", algo_args={"lr": 0.1, "local_epochs": 5},
        target_loss=0.4)
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    # lists (the JSON form of tuples) normalize back to tuples
    d = json.loads(spec.to_json())
    assert isinstance(d["fleet"]["instance"], list)
    back = ExperimentSpec.from_dict(d)
    assert back.fleet.instance == spec.fleet.instance
    assert back.failure.inject == spec.failure.inject


def test_spec_hash_ignores_name_but_not_content():
    a = _spec(name="a")
    assert a.spec_hash() == _spec(name="b").spec_hash()
    assert a.spec_hash() != a.with_(seed=1).spec_hash()
    assert a.spec_hash() != a.with_(**{"fleet.straggler": 2.0}).spec_hash()


def test_spec_rejects_unknown_fields_and_platforms():
    with pytest.raises(KeyError):
        ExperimentSpec.from_dict({"platfrom": "faas"})
    with pytest.raises(ValueError):
        ExperimentSpec(platform="paas")
    with pytest.raises(KeyError):
        _spec().with_(**{"fleet.wrokers": 3})


def test_spec_hash_survives_schema_growth():
    """The cache key diffs against the field defaults, so adding a spec
    field in a later PR must not orphan the record cache: an all-default
    spec canonicalizes to the empty dict, and only specs that USE a new
    field hash differently."""
    import hashlib
    from repro.experiments.spec import HASH_SCHEMA
    assert ExperimentSpec().spec_hash() == \
        hashlib.sha256(f"{HASH_SCHEMA}{{}}".encode()).hexdigest()[:16]
    d = ExperimentSpec(name="x", platform="iaas").to_dict()
    d.pop("platform_args")       # a record written before the field existed
    assert ExperimentSpec.from_dict(d).spec_hash() == \
        ExperimentSpec(name="x", platform="iaas").spec_hash()
    pod = ExperimentSpec(platform="pod")
    assert pod.spec_hash() != \
        pod.with_(platform_args={"mfu": 0.5}).spec_hash()


def test_sync_spec_canonicalizes():
    assert _spec(sync="ssp").sync == "ssp:3"
    assert _spec(sync="asp").sync == "asp"


# ------------------------------------------------------------------ cache ---

def test_run_experiment_cache_hit_miss_and_force(tmp_path):
    spec = _spec(name="c1")
    r1 = run_experiment(spec, cache_dir=tmp_path)
    assert not r1.cached and Path(r1.path).exists()
    r2 = run_experiment(spec, cache_dir=tmp_path)
    assert r2.cached and r2.result == r1.result
    # different content -> miss; renamed spec -> still a hit
    r3 = run_experiment(spec.with_(seed=5), cache_dir=tmp_path)
    assert not r3.cached
    r4 = run_experiment(spec.with_(name="renamed"), cache_dir=tmp_path)
    assert r4.cached and r4.spec.name == "renamed"
    r5 = run_experiment(spec, cache_dir=tmp_path, force=True)
    assert not r5.cached and r5.result == r1.result


def test_record_schema_is_stable(tmp_path):
    rec = run_experiment(_spec(name="s"), cache_dir=tmp_path)
    d = json.loads(Path(rec.path).read_text())
    assert d["schema"] == "repro.experiment/v2"
    assert set(d) == {"schema", "name", "spec_hash", "spec", "result"}
    for key in ("system", "algorithm", "workers", "rounds", "sim_time_s",
                "cost_usd", "final_loss", "converged", "preemptions",
                "max_staleness", "breakdown", "error", "history"):
        assert key in d["result"], key
    # the record alone is enough to re-run the trial
    again = run_experiment(ExperimentSpec.from_dict(d["spec"]))
    assert again.result["history"] == d["result"]["history"]


# ----------------------------------------------------------------- parity ---

def test_run_experiment_parity_with_legacy_faas_train():
    """Identical loss history and cost to a hand-written
    FaaSRuntime(...).train(...) call for the same seed (byte-identical)."""
    from repro.core.algorithms import make_algorithm
    from repro.core.mlmodels import make_study_model
    from repro.core.runtimes import FaaSRuntime
    from repro.data.synthetic import make_dataset, train_val_split

    spec = ExperimentSpec(platform="faas", sync="ssp:2", rows=4_000,
                          max_epochs=2, seed=3,
                          fleet=FleetSpec(workers=3, straggler=4.0),
                          algo_args={"lr": 0.2, "batch_size": 1024})
    rec = run_experiment(spec)

    ds = make_dataset("higgs", rows=4_000, seed=0)
    tr, va = train_val_split(ds)
    model = make_study_model("lr", tr)
    algo = make_algorithm("ga_sgd", lr=0.2, batch_size=1024)
    legacy = FaaSRuntime(workers=3, straggler=4.0, sync="ssp:2",
                         seed=3).train(model, algo, tr, va, max_epochs=2)

    assert [l for _, l in rec.history] == [float(l) for _, l in legacy.history]
    assert [t for t, _ in rec.history] == [float(t) for t, _ in legacy.history]
    assert rec.result["cost_usd"] == legacy.cost   # v2: full precision
    assert rec.result["rounds"] == legacy.rounds


def test_run_experiment_parity_iaas_spot():
    from repro.core.runtimes import IaaSRuntime, _T_IAAS, interp_startup
    t0 = interp_startup(_T_IAAS, 2)
    spec = _spec(platform="iaas",
                 failure=FailureSpec(spot=True, inject=((0, t0 + 1.0),)))
    rec = run_experiment(spec)
    model, algo, tr, va = spec.build_workload()
    legacy = IaaSRuntime(workers=2, spot=True,
                         preempt_at=((0, t0 + 1.0),)).train(
        model, algo, tr, va, max_epochs=1)
    assert rec.result["preemptions"] == legacy.preemptions == 1
    assert [l for _, l in rec.history] == [float(l) for _, l in legacy.history]
    assert rec.result["system"] == "iaas-spot"


# ------------------------------------------------------------------ sweep ---

def test_sweep_2x2_grid_dedupes_through_cache(tmp_path):
    base = _spec(name="grid")
    grid = {"fleet.workers": [2, 3], "sync": ["bsp", "asp"]}
    recs = sweep(base, grid, cache_dir=tmp_path)
    assert len(recs) == 4
    assert sorted(r.spec.name for r in recs) == [
        "grid[workers=2,sync=asp]", "grid[workers=2,sync=bsp]",
        "grid[workers=3,sync=asp]", "grid[workers=3,sync=bsp]"]
    assert len({r.spec_hash for r in recs}) == 4
    assert not any(r.cached for r in recs)
    # identical sweep -> pure cache hits, identical results
    again = sweep(base, grid, cache_dir=tmp_path, max_workers=4)
    assert all(r.cached for r in again)
    assert [r.result for r in again] == [r.result for r in recs]


def test_sweep_duplicate_points_run_once(tmp_path):
    recs = sweep(_spec(name="dup"), {"seed": [0, 0]}, cache_dir=tmp_path)
    assert len(recs) == 2
    assert recs[0].result == recs[1].result
    assert len(list(tmp_path.glob("*.json"))) == 1


# ---------------------------------------------------------------- presets ---

def test_presets_build_valid_specs():
    assert set(PRESETS) == {"fig10_breakdown", "fig10_trace", "fig11_end2end",
                            "fig8_sync", "spot_vs_ondemand", "spot_trace",
                            "hetero_fleet", "faas_vs_pod", "pod_local_sgd",
                            "comm_axis", "elastic_axis"}
    for name, preset in PRESETS.items():
        specs = preset.build(True)
        assert specs, name
        for s in specs:
            assert ExperimentSpec.from_json(s.to_json()) == s
    with pytest.raises(KeyError):
        get_preset("fig99")


# ------------------------------------------------------------ pod platform --

def test_pod_spec_round_trips_and_builds():
    from repro.core.runtimes import PodPlatform
    spec = ExperimentSpec(platform="pod", sync="local:8",
                          model="smollm_360m", dataset="tokens",
                          platform_args={"chips_per_pod": 8, "mfu": 0.5},
                          fleet=FleetSpec(workers=2))
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    rt = spec.build_runtime()
    assert isinstance(rt, PodPlatform)
    assert rt.pods == 2 and rt.chips_per_pod == 8 and rt.mfu == 0.5
    assert spec.sync == "local:8"
    assert spec.spec_hash() != spec.with_(
        **{"platform_args": {"chips_per_pod": 4}}).spec_hash()


def test_platform_args_rejected_off_pod():
    with pytest.raises(ValueError, match="platform_args"):
        ExperimentSpec(platform="faas", platform_args={"mfu": 0.5})


def test_platform_args_unknown_keys_rejected_at_spec_time():
    # keys that would collide with spec-derived constructor args (or be
    # silently ignored, like pods=) must fail at construction, not build
    for bad in ({"pods": 16}, {"seed": 1}, {"sync": "bsp"}, {"mfuu": 0.5}):
        with pytest.raises(KeyError, match="platform_args"):
            ExperimentSpec(platform="pod", platform_args=bad)
    ExperimentSpec(platform="pod", platform_args={"mfu": 0.5})  # fine


def test_workload_dataset_pairing_rejected_at_spec_time():
    # sweeps must reject bad points at expansion, not crash mid-batch
    with pytest.raises(ValueError, match="tokens"):
        ExperimentSpec(model="smollm_360m")            # dataset left "higgs"
    with pytest.raises(ValueError, match="stand-in"):
        ExperimentSpec(model="lr", dataset="tokens")
    ExperimentSpec(model="smollm_360m", dataset="tokens")  # fine


# -------------------------------------------------------------------- CLI ---

def _cli(*args, timeout=600):
    return subprocess.run([sys.executable, "-m", "repro", *args],
                          env=ENV, cwd=ROOT, capture_output=True, text=True,
                          timeout=timeout)


def test_cli_list_smoke():
    r = _cli("list")
    assert r.returncode == 0, r.stderr
    for name in PRESETS:
        assert name in r.stdout


def test_cli_run_fig8_sync_quick(tmp_path):
    out = tmp_path / "records.json"
    r = _cli("run", "fig8_sync", "--quick", "--set", "rows=3000",
             "--set", "max_epochs=1", "--cache", str(tmp_path / "cache"),
             "--out", str(out))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "fig8_higgs_bsp" in r.stdout
    records = json.loads(out.read_text())
    assert len(records) == 3
    assert all(rec["schema"] == "repro.experiment/v2" for rec in records)


def test_cli_sweep_2x2(tmp_path):
    r = _cli("sweep", "fig8_sync", "--grid", "fleet.workers=2,3",
             "--grid", "sync=bsp,asp", "--set", "rows=3000",
             "--set", "max_epochs=1", "--cache", str(tmp_path),
             "--out", str(tmp_path / "sweep.json"))
    assert r.returncode == 0, r.stdout + r.stderr
    records = json.loads((tmp_path / "sweep.json").read_text())
    assert len(records) == 4
    workers = {rec["spec"]["fleet"]["workers"] for rec in records}
    syncs = {rec["spec"]["sync"] for rec in records}
    assert workers == {2, 3} and syncs == {"bsp", "asp"}


def test_cli_unknown_preset_errors():
    r = _cli("run", "fig99_nope")
    assert r.returncode != 0
    assert "fig10_breakdown" in r.stderr   # helpful listing


def test_cli_rerun_from_record_file(tmp_path):
    """README promise: any cached record (or --out file) re-runs as-is."""
    cache = tmp_path / "cache"
    rec = run_experiment(_spec(name="replay"), cache_dir=cache)
    r = _cli("run", rec.path, "--no-cache")
    assert r.returncode == 0, r.stdout + r.stderr
    out = tmp_path / "records.json"         # --out list-of-records form
    (tmp_path / "list.json").write_text(json.dumps([rec.to_dict()]))
    r = _cli("run", str(tmp_path / "list.json"), "--no-cache",
             "--out", str(out))
    assert r.returncode == 0, r.stdout + r.stderr
    assert json.loads(out.read_text())[0]["spec_hash"] == rec.spec_hash


def test_spot_spec_defaults_to_preemption_risk():
    """FailureSpec(spot=True) must arm the 2/worker-hour spot rate, like
    the legacy IaaSRuntime(spot=True) path; on-demand specs stay safe."""
    from repro.core.engine import PoissonPreemptions
    from repro.core.runtimes import FaaSRuntime, IaaSRuntime

    spot = ExperimentSpec(platform="iaas", failure=FailureSpec(spot=True))
    assert isinstance(spot.build_runtime().failure_process(),
                      PoissonPreemptions)
    assert spot.build_runtime().preempt_rate == 2.0
    ondemand = ExperimentSpec(platform="iaas")
    assert type(ondemand.build_runtime().failure_process()).__name__ == \
        "FailureProcess"
    assert FaaSRuntime(workers=2).preempt_rate == 0.0
