"""Metered checkpoint subsystem (DESIGN.md §17): spec grammar round-trip,
transport-routed sharded save/restore with exact metering, trace-driven
spot preemptions, derived restart times, and the elastic-join restore
cost.  Registry constructors under test: ``make_ckpt`` /
``make_ckpt_transport`` (checkpoint transports) and ``make_failure``
(failure processes)."""
import numpy as np
import pytest

from repro.core.algorithms import make_algorithm
from repro.core.ckpt import (
    CKPT_TRANSPORTS, CheckpointSpec, Checkpointer, ckpt_transport_constants,
    list_ckpts, make_ckpt, make_ckpt_transport, shard_sizes,
)
from repro.core.comm.transports import ChannelItemTooLarge, xfer_seconds
from repro.core.failures import (
    TracePreemptions, list_failures, load_trace, make_failure, resolve_trace,
    trace_fixtures,
)
from repro.core.platform import FailureSpec
from repro.core.runtimes import FaaSRuntime, IaaSRuntime, PodPlatform
from repro.data.synthetic import make_dataset, train_val_split


@pytest.fixture(scope="module")
def higgs():
    ds = make_dataset("higgs", rows=20_000)
    return train_val_split(ds)


def _ga(**kw):
    return make_algorithm("ga_sgd", **{"lr": 0.2, "batch_size": 2048, **kw})


def _lr(tr):
    from repro.core.mlmodels import make_study_model
    return make_study_model("lr", tr)


# ---------------------------------------------------- spec grammar (R002) ----

def test_ckpt_spec_parse_name_roundtrip():
    for text in ("s3:every=5:sharded", "local:every=1", "dynamodb:sharded",
                 "every=3", "every=2:sharded", "memcached", ""):
        spec = make_ckpt(text)
        assert make_ckpt(spec.name) == spec        # name -> parse round-trip
    assert CheckpointSpec().name == ""             # default elides (h5)
    s = make_ckpt("s3:every=5:sharded")
    assert (s.transport, s.every, s.sharded) == ("s3", 5, True)
    assert make_ckpt("every=4").transport is None  # platform-default store
    assert make_ckpt(None) == CheckpointSpec()
    assert make_ckpt({"transport": "redis", "every": 2}).name == "redis:every=2"


def test_ckpt_spec_rejects_bad_grammar():
    with pytest.raises(KeyError):
        make_ckpt("carrier-pigeon:every=2")
    with pytest.raises(ValueError):
        make_ckpt("s3:sometimes")
    with pytest.raises(ValueError):
        CheckpointSpec(every=-1)


def test_ckpt_registry_and_transport_constructor():
    names = set(list_ckpts())
    assert {"s3", "dynamodb", "memcached", "redis", "local"} <= names
    local = make_ckpt_transport("local")
    assert local.spec.name == "local" and local.spec.put_cost == 0.0
    assert ckpt_transport_constants("local").bandwidth == local.spec.bandwidth
    # platform defaults (vmps) resolve through the comm registry fallback
    assert ckpt_transport_constants("vmps").bandwidth > 0
    with pytest.raises(KeyError):
        make_ckpt_transport("carrier-pigeon")


# ------------------------------------------------------- sharding layout ----

def test_shard_sizes_partition_the_model():
    mb = 1_000_003
    for k in (1, 2, 7, 32):
        sizes = shard_sizes(mb, k)
        assert sum(sizes) == 4 * (mb // 4)      # fp32 words, nothing lost
        assert len(sizes) <= k
        assert min(sizes) > 0


def test_dynamodb_feasibility_is_spec_time():
    """A 1 MB model overflows DynamoDB's 400 KB items unsharded; splitting
    it over 4 workers makes every shard feasible -- checked eagerly at
    validate(), the checkpoint mirror of Table 1's N/A cells."""
    big = 1_000_000
    with pytest.raises(ChannelItemTooLarge):
        make_ckpt("dynamodb:every=2").validate(model_bytes=big, workers=4)
    make_ckpt("dynamodb:every=2:sharded").validate(model_bytes=big, workers=4)
    # lazily-estimated model bytes (callable) work the same way
    with pytest.raises(ChannelItemTooLarge):
        make_ckpt("dynamodb").validate(model_bytes=lambda: big, workers=4)


# ----------------------------------------- metered save/restore, exactly ----

@pytest.mark.parametrize("name", sorted(CKPT_TRANSPORTS))
def test_roundtrip_meters_exactly_per_transport(name):
    """save()+restore() through EVERY registered transport: wire bytes,
    transfer seconds and request $ must equal the closed-form per-shard
    arithmetic (xfer_seconds over shard_sizes) to the last bit."""
    mbytes, workers = 200_000, 4        # 50 KB shards: feasible everywhere
    spec = CheckpointSpec(transport=name, every=1, sharded=True)
    spec.validate(model_bytes=mbytes, workers=workers)
    store = make_ckpt_transport(name)
    ck = Checkpointer(spec=spec, store=store, mbytes=mbytes,
                      shards=spec.shards(workers))
    dt_put = ck.save("ckpt/fleet")
    dt_get = ck.restore("ckpt/fleet")
    sizes = shard_sizes(mbytes, workers)
    ch = CKPT_TRANSPORTS[name]
    expect = sum(xfer_seconds(ch, s) for s in sizes)
    assert dt_put == expect and dt_get == expect
    assert ck.time_s == dt_put + dt_get
    assert ck.wire_bytes == 2 * sum(sizes)
    usd = 0.0                           # replicate accumulation order (ULP)
    for _ in sizes:
        usd += ch.put_cost
    for _ in sizes:
        usd += ch.get_cost
    assert ck.op_usd == usd
    assert (ck.puts, ck.gets) == (len(sizes), len(sizes))
    # the spec's closed-form restore matches the metered one bit-exactly
    assert dt_get == spec.restore_seconds(mbytes, ch, workers)


def test_single_shard_uses_seed_key_layout():
    """shards=1 keeps the seed engine's one-key layout (parity contract)."""
    ck = Checkpointer(spec=CheckpointSpec(), store=make_ckpt_transport("s3"),
                      mbytes=4_000)
    assert [k for k, _ in ck._blobs("ckpt/3")] == ["ckpt/3"]
    ck4 = Checkpointer(spec=CheckpointSpec(sharded=True),
                       store=make_ckpt_transport("s3"), mbytes=4_000, shards=4)
    assert [k for k, _ in ck4._blobs("ckpt/fleet")] == [
        f"ckpt/fleet/s{j}" for j in range(4)]


# ------------------------------------------------- failure registry (§17) ----

def test_failure_registry_and_trace_fixtures():
    assert set(list_failures()) == {"poisson", "inject", "trace"}
    assert {"spot_burst", "spot_ramp", "spot_sparse"} <= set(trace_fixtures())
    assert isinstance(make_failure("trace:spot_burst", workers=8),
                      TracePreemptions)
    p = make_failure("poisson:2.0", workers=4, seed=7)
    assert p.next_preemption(0, 0.0, 1e9) > 0.0
    inj = make_failure("inject:1@5.0,3@9.0", workers=4)
    assert inj.at == ((1, 5.0), (3, 9.0))
    with pytest.raises(KeyError):
        make_failure("solar-flare:1", workers=4)
    with pytest.raises(ValueError):
        make_failure("trace:", workers=4)


def test_trace_replay_is_deterministic(tmp_path):
    """Same trace -> same kill schedule, no RNG consumed; unassigned events
    round-robin over the fleet; both file formats parse identically."""
    a = make_failure("trace:spot_burst", workers=8)
    b = make_failure("trace:spot_burst", workers=8)
    assert a.at == b.at and len(a.at) > 0
    events = load_trace(resolve_trace("spot_burst"))
    assert all(t1 <= t2 for (t1, _), (t2, _) in zip(events, events[1:]))
    # round-robin assignment for worker-less events
    rr = TracePreemptions(((10.0, None), (20.0, None), (30.0, None)), 2)
    assert rr.at == ((0, 10.0), (1, 20.0), (0, 30.0))
    # JSON pair format == whitespace format
    txt = tmp_path / "t.txt"
    txt.write_text("5.0 1\n9.5\n# comment\n")
    jsn = tmp_path / "t.json"
    jsn.write_text("[[5.0, 1], 9.5]")
    assert load_trace(txt) == load_trace(jsn) == ((5.0, 1), (9.5, None))


def test_empty_trace_matches_no_failure_run(tmp_path, higgs):
    """An empty trace consumes no randomness: the run is byte-identical to
    the same spot fleet with no failure process at all."""
    tr, va = higgs
    model = _lr(tr)
    empty = tmp_path / "empty.txt"
    empty.write_text("# recorded nothing\n")
    base = IaaSRuntime(workers=4, failure=FailureSpec(spot=True, rate=0.0)
                       ).train(model, _ga(), tr, va, max_epochs=2)
    traced = IaaSRuntime(workers=4,
                         failure=FailureSpec(spot=True, trace=str(empty))
                         ).train(model, _ga(), tr, va, max_epochs=2)
    assert traced.preemptions == 0
    assert traced.sim_time == base.sim_time
    assert traced.cost == base.cost
    assert traced.history == base.history


def test_trace_spot_run_meters_checkpoints(higgs):
    """A recorded-trace spot run with a checkpoint cadence: preemptions
    fire, the ckpt meters land in RunResult, and restarts pay the derived
    (startup + metered restore) price."""
    tr, va = higgs
    model = _lr(tr)
    kw = dict(max_epochs=3)
    fail = FailureSpec(spot=True, trace="spot_burst")
    run = IaaSRuntime(workers=8, failure=fail, ckpt="s3:every=2").train(
        model, _ga(), tr, va, **kw)
    assert run.preemptions > 0
    assert run.ckpt_bytes > 0 and run.ckpt_time > 0 and run.ckpt_cost > 0
    assert run.breakdown.get("checkpoint", 0.0) > 0.0
    assert run.breakdown.get("restart", 0.0) > 0.0
    d = run.to_dict()
    assert d["ckpt_bytes"] == run.ckpt_bytes
    # determinism: the replay is RNG-free, so a rerun is byte-identical
    rerun = IaaSRuntime(workers=8, failure=fail, ckpt="s3:every=2").train(
        model, _ga(), tr, va, **kw)
    assert rerun.sim_time == run.sim_time and rerun.cost == run.cost
    # numerics are failure-transparent (resume restores exact state)
    clean = IaaSRuntime(workers=8).train(model, _ga(), tr, va, **kw)
    np.testing.assert_allclose([l for _, l in clean.history],
                               [l for _, l in run.history], rtol=1e-6)


# ------------------------------------------------------- derived restart ----

def test_restart_time_is_derived_from_model_bytes():
    """restart_time(model_bytes) = platform cold start + the metered
    restore of the model's ACTUAL byte size through the platform's
    checkpoint store -- on all three platforms, matching the analytical
    planner's closed form."""
    from repro.core.analytical import restart_seconds
    mb = 100_000_000
    for p, rt in (("faas", FaaSRuntime(workers=4)),
                  ("iaas", IaaSRuntime(workers=4)),
                  ("pod", PodPlatform(pods=2, chips_per_pod=2))):
        bare = rt.restart_time()
        loaded = rt.restart_time(mb)
        ch = rt.ckpt_channel_spec()
        assert loaded == bare + rt.ckpt.restore_seconds(mb, ch, rt.workers)
        assert loaded > bare > 0
        assert restart_seconds(p) == bare
    # an explicit transport redirects the restore term
    slow = IaaSRuntime(workers=4, ckpt="s3")
    fast = IaaSRuntime(workers=4, ckpt="local")
    assert slow.restart_time(mb) > fast.restart_time(mb)
    assert fast.restart_time() == slow.restart_time()   # bare term identical
    from repro.core.analytical import restart_seconds as rs
    assert rs("iaas", mb, ckpt="local") == fast.restart_time(mb)


# -------------------------------------------------- elastic join restore ----

def test_elastic_join_pays_metered_restore(higgs):
    """Scale-up joiners pull the published model through the checkpoint
    transport: one fleet save + one restore per joiner, all metered."""
    tr, va = higgs
    model = _lr(tr)
    run = IaaSRuntime(workers=2, scaling="schedule:2@0,6@2").train(
        model, _ga(), tr, va, max_epochs=4)
    assert run.workers == 6
    import jax
    from repro.core.mlmodels import model_bytes
    mb = model_bytes(model.init(jax.random.key(0)))
    added = 4
    sizes = shard_sizes(mb, 1)
    assert run.ckpt_bytes == (1 + added) * sum(sizes)   # 1 save + 4 pulls
    ch = IaaSRuntime(workers=2).ckpt_channel_spec()
    expect = (1 + added) * sum(xfer_seconds(ch, s) for s in sizes)
    assert run.ckpt_time == expect
    assert run.breakdown.get("resize", 0.0) >= expect   # lands on resize


# ---------------------------------------------------- spec-level wiring ----

def test_experiment_spec_ckpt_and_trace_fields():
    """ExperimentSpec grows ckpt= and failure.trace= (h5, since re-keyed
    to h6 by the trace= field): grammar strings coerce, defaults elide
    from the hash, bad traces fail eagerly."""
    from repro.experiments.spec import HASH_SCHEMA, ExperimentSpec
    assert HASH_SCHEMA == "h6"
    base = ExperimentSpec(platform="iaas", model="lr", dataset="higgs",
                          rows=5_000, algorithm="ga_sgd", max_epochs=1)
    spec = base.with_(ckpt="s3:every=2:sharded",
                      failure=FailureSpec(spot=True, trace="spot_burst"))
    assert spec.ckpt == CheckpointSpec("s3", 2, True)
    assert spec.spec_hash() != base.spec_hash()
    rt = spec.build_runtime()
    assert rt.ckpt == spec.ckpt and rt.failure.trace == "spot_burst"
    with pytest.raises(FileNotFoundError):
        base.with_(failure=FailureSpec(trace="no_such_trace_anywhere"))
    with pytest.raises(ChannelItemTooLarge):
        ExperimentSpec(platform="iaas", model="mobilenet", dataset="cifar10",
                       rows=2_000, algorithm="ga_sgd", max_epochs=1,
                       ckpt="dynamodb:every=1")
