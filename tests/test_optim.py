"""Optimizers: convergence, 8-bit state fidelity, grad clip."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.optim import (
    clip_by_global_norm, dequantize_blockwise, make_optimizer,
    quantize_blockwise,
)


def _quadratic_params():
    return {"w": jnp.array([3.0, -2.0, 5.0]), "b": jnp.array([[1.0, -1.0]])}


def _run(opt_name, steps=300, lr=0.05):
    cfg = TrainConfig(optimizer=opt_name, learning_rate=lr, weight_decay=0.0,
                      grad_clip=1e9)
    opt = make_optimizer(cfg)
    params = _quadratic_params()
    state = opt.init(params)

    def loss(p):
        return sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(p))

    for _ in range(steps):
        grads = jax.grad(loss)(params)
        params, state, _ = opt.update(grads, state, params)
    return float(loss(params))


@pytest.mark.parametrize("name", ["sgd", "adamw", "adamw8bit"])
def test_optimizers_minimize_quadratic(name):
    assert _run(name) < 1e-2


def test_adamw8bit_tracks_fp32():
    """8-bit moment quantization stays close to exact AdamW on a short run."""
    cfg32 = TrainConfig(optimizer="adamw", learning_rate=0.01,
                        weight_decay=0.0)
    cfg8 = dataclasses.replace(cfg32, optimizer="adamw8bit")
    o32, o8 = make_optimizer(cfg32), make_optimizer(cfg8)
    p32 = p8 = {"w": jnp.linspace(-1, 1, 512)}
    s32, s8 = o32.init(p32), o8.init(p8)
    rng = np.random.default_rng(0)
    for _ in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(512) * 0.1, jnp.float32)}
        p32, s32, _ = o32.update(g, s32, p32)
        p8, s8, _ = o8.update(g, s8, p8)
    err = float(jnp.max(jnp.abs(p32["w"] - p8["w"])))
    assert err < 1.5e-2, err  # ~1% of param scale after 50 steps


def test_grad_clip():
    g = {"a": jnp.full((100,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 100.0) < 1e-3
    total = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    assert abs(total - 1.0) < 1e-4


def test_quantize_shapes_and_dtype():
    for shape in [(256,), (3, 512), (5, 7), (2, 3, 256)]:
        x = jnp.ones(shape)
        q, s = quantize_blockwise(x)
        assert q.shape == x.shape and q.dtype == jnp.int8
        xd = dequantize_blockwise(q, s)
        assert xd.shape == x.shape
        np.testing.assert_allclose(xd, x, rtol=2e-2)
