"""Pallas kernels vs. pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quant8.ops import dequantize8, int8_roundtrip, quantize8
from repro.kernels.quant8.ref import quantize8_ref
from repro.kernels.topk_ef.ops import topk_ef
from repro.kernels.ssd_scan.kernel import ssd_scan_kernel
from repro.kernels.ssd_scan.ops import ssd_scan_fused
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.ssm import ssd_scan as ssd_jnp

RNG = np.random.default_rng(7)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


# ------------------------------------------------------------ flash ----------

@pytest.mark.parametrize("b,sq,sk,h,m,d,causal,dtype", [
    (2, 256, 256, 4, 2, 64, True, jnp.float32),
    (1, 512, 512, 2, 2, 128, False, jnp.float32),
    (2, 128, 128, 3, 1, 32, True, jnp.float32),
    (1, 256, 256, 8, 4, 64, True, jnp.bfloat16),
    (1, 384, 384, 2, 1, 128, True, jnp.float32),
])
def test_flash_attention(b, sq, sk, h, m, d, causal, dtype):
    q, k, v = (_arr((b, sq, h, d), dtype), _arr((b, sk, m, d), dtype),
               _arr((b, sk, m, d), dtype))
    o = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                        interpret=True)
    g = h // m
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, 1).reshape(b * h, sk, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, 1).reshape(b * h, sk, d)
    ref = attention_ref(qf, kf, vf, causal=causal, sm_scale=d ** -0.5)
    ref = ref.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_blocks_irrelevant():
    """Block-shape sweep: numerics must not depend on tiling."""
    q, k, v = _arr((1, 512, 2, 64)), _arr((1, 512, 2, 64)), _arr((1, 512, 2, 64))
    outs = [flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                            interpret=True)
            for bq, bk in [(64, 64), (128, 256), (512, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------ decode ---------

@pytest.mark.parametrize("b,h,m,d,S,length,dtype", [
    (2, 8, 2, 64, 2048, 1500, jnp.float32),
    (1, 4, 4, 128, 1024, 1024, jnp.float32),
    (3, 6, 2, 32, 512, 100, jnp.float32),
    (2, 4, 1, 64, 768, 700, jnp.bfloat16),
])
def test_decode_attention(b, h, m, d, S, length, dtype):
    q = _arr((b, h, d), dtype)
    k = _arr((b, S, m, d), dtype)
    v = _arr((b, S, m, d), dtype)
    o = decode_attention(q, k, v, length, block_k=256, interpret=True)
    g = h // m
    qf = q.reshape(b, m, g, d).reshape(b * m, g, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * m, S, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * m, S, d)
    ref = decode_attention_ref(qf, kf, vf, length, sm_scale=d ** -0.5)
    ref = ref.reshape(b, m, g, d).reshape(b, h, d)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


# ------------------------------------------------------------ ssd ------------

@pytest.mark.parametrize("bh,s,p,n,chunk", [
    (4, 256, 64, 32, 64), (2, 128, 32, 16, 32), (3, 96, 16, 8, 32),
    (1, 64, 128, 64, 16),
])
def test_ssd_kernel_vs_recurrence(bh, s, p, n, chunk):
    x = _arr((bh, s, p))
    dt = jnp.abs(_arr((bh, s), scale=0.2))
    a = -jnp.abs(_arr((bh,))) - 0.5
    B, C = _arr((bh, s, n)), _arr((bh, s, n))
    y, st = ssd_scan_kernel(x, dt, a, B, C, chunk=chunk, interpret=True)
    yr, sr = ssd_scan_ref(x, dt, a, B, C)
    np.testing.assert_allclose(y, yr, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(st, sr, atol=1e-3, rtol=1e-3)


def test_ssd_fused_matches_model_path():
    b, s, h, p, n = 2, 128, 4, 32, 16
    x = _arr((b, s, h, p))
    dt = jnp.abs(_arr((b, s, h), scale=0.2))
    a_log = _arr((h,), scale=0.3)
    B, C = _arr((b, s, n)), _arr((b, s, n))
    yk, stk = ssd_scan_fused(x, dt, a_log, B, C, chunk=32, interpret=True)
    yj, stj = ssd_jnp(x, dt, a_log, B, C, 32)
    np.testing.assert_allclose(yk, yj, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(stk, stj, atol=1e-3, rtol=1e-3)


# ------------------------------------------------------------ quant8 ---------

@pytest.mark.parametrize("shape", [(1000,), (33, 70), (4, 256), (7, 13, 11)])
def test_quant8_roundtrip(shape):
    x = _arr(shape, scale=3.0)
    q, s = quantize8(x, interpret=True)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % 256
    xf = jnp.concatenate([flat, jnp.zeros((pad,))]).reshape(-1, 256)
    qr, _ = quantize8_ref(xf)
    assert jnp.array_equal(q, qr)
    xd = dequantize8(q, s, shape, interpret=True)
    # blockwise max-abs scaling: error bounded by scale/2 per element
    assert float(jnp.max(jnp.abs(xd - x))) <= float(jnp.max(s)) * 0.51


def test_quant_block_is_the_codec_wire_constant():
    """codecs.QUANT_BLOCK (plain int, no jax import) must equal the
    kernel's BLOCK -- int8_wire_floats meters what the silicon ships."""
    from repro.core.comm.codecs import QUANT_BLOCK
    from repro.kernels.quant8.kernel import BLOCK
    assert QUANT_BLOCK == BLOCK


@pytest.mark.parametrize("shape", [(256,), (1000,), (33, 70), (7, 13, 11),
                                   (300 * 256 + 17,)])
def test_quant8_ef_kernel_vs_ref_bitwise(shape):
    """The fused EF kernel and the straight-line oracle agree bit-for-bit
    through the same padded-tile plumbing (both fuse identically under
    jit -- see quant8/ref.py on FMA contraction)."""
    x = _arr(shape, scale=3.0)
    qk, sk, dk, ek = int8_roundtrip(x, interpret=True, backend="kernel")
    qr, sr, dr, er = int8_roundtrip(x, backend="ref")
    assert jnp.array_equal(qk, qr)
    assert jnp.array_equal(sk, sr)
    assert jnp.array_equal(dk, dr)
    assert jnp.array_equal(ek, er)
    assert qk.shape == (-(-x.size // 256), 256) and sk.shape == (qk.shape[0], 1)
    # residual == x - deq to the last ulp; deq/err keep the input's shape
    assert dk.shape == ek.shape == x.shape
    np.testing.assert_allclose(np.asarray(ek), np.asarray(x - dk), atol=1e-6)


# ------------------------------------------------------------ topk_ef --------

@pytest.mark.parametrize("shape,k", [
    ((1000,), 50), ((33, 70), 100), ((4, 256), 1), ((512,), 512),
    ((7, 13, 11), 13),
])
def test_topk_ef_kernel_vs_ref(shape, k):
    """Kernel vs oracle parity incl. k=1 and k=n edges; kept + residual
    reconstructs x bitwise (disjoint supports, no float error)."""
    x = _arr(shape, scale=2.0)
    ok, rk = topk_ef(x, k, interpret=True, backend="kernel")
    orf, rrf = topk_ef(x, k, backend="ref")
    assert jnp.array_equal(ok, orf)
    assert jnp.array_equal(rk, rrf)
    assert jnp.array_equal(ok + rk, x)
    assert not bool(jnp.any((ok != 0) & (rk != 0)))
    # gaussian draws have no magnitude ties: exactly k survive
    assert int(jnp.count_nonzero(ok)) == k


def test_topk_ef_residual_carry_three_rounds():
    """EF loop: each round's kept + residual equals its input bitwise, and
    the filtered mass is deferred, not lost -- with no new gradient the
    carried residual drains to zero in ceil(n/k) further rounds."""
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.standard_normal(640), jnp.float32)
    res = jnp.zeros_like(g)
    for _ in range(3):
        x = g + res
        out, res = topk_ef(x, 64, interpret=True)
        assert jnp.array_equal(out + res, x)
        assert not bool(jnp.any((out != 0) & (res != 0)))
    for _ in range(10):
        _, res = topk_ef(res, 64, interpret=True)
    assert float(jnp.max(jnp.abs(res))) == 0.0


# ------------------------------------------------------------ calibration ----

def test_measured_mfu_snapshot_consistency():
    """The committed BENCH_kernels.json measurement, the in-code fallback
    constant, and the resolve knob all agree."""
    from repro.core.calibration import MEASURED_MFU, measured_mfu, resolve_mfu
    m = measured_mfu()
    assert 0.0 < m <= 1.0
    assert abs(m - MEASURED_MFU) < 0.005
    assert resolve_mfu("measured") == m
    assert resolve_mfu(0.4) == 0.4
    with pytest.raises(ValueError):
        resolve_mfu("vibes")
