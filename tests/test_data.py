"""Data pipeline: determinism, paper-exact dims, partitioning."""
import numpy as np

from repro.data.synthetic import DATASETS, make_dataset, partition, train_val_split
from repro.data.tokens import TokenStream


def test_dims_match_paper():
    assert make_dataset("higgs", rows=100).d == 28
    assert make_dataset("rcv1", rows=50).d == 47_236
    assert make_dataset("cifar10", rows=50).d == 3072
    assert make_dataset("yfcc100m", rows=50).d == 4096
    assert make_dataset("criteo", rows=50).d == 1_000_000


def test_deterministic():
    a = make_dataset("higgs", rows=100, seed=3)
    b = make_dataset("higgs", rows=100, seed=3)
    np.testing.assert_array_equal(a.x, b.x)
    np.testing.assert_array_equal(a.y, b.y)


def test_labels_balanced_enough():
    ds = make_dataset("higgs", rows=5000)
    pos = (ds.y > 0).mean()
    assert 0.25 < pos < 0.75
    y = make_dataset("yfcc100m", rows=5000).y
    assert 0.01 < (y > 0).mean() < 0.25  # rare positives like 'animal' tags


def test_partition_covers_all_rows():
    ds = make_dataset("higgs", rows=1003)
    parts = partition(ds, 7)
    assert sum(p.n for p in parts) == 1003
    np.testing.assert_array_equal(np.concatenate([p.x for p in parts]), ds.x)


def test_split_disjoint():
    ds = make_dataset("cifar10", rows=500)
    tr, va = train_val_split(ds)
    assert tr.n + va.n == 500


def test_token_stream_batch_shapes():
    ts = TokenStream(1000, seed=0)
    b = ts.batch(4, 16)
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()
    assert b["tokens"].max() < 1000


def test_token_stream_worker_disjoint():
    a = TokenStream(1000, seed=0, worker=0, num_workers=2).batch(4, 8)
    b = TokenStream(1000, seed=0, worker=1, num_workers=2).batch(4, 8)
    assert not np.array_equal(a["tokens"], b["tokens"])
