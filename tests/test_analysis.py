"""repro.analysis (DESIGN.md §15): the lint engine and its checkers.

Covers: exact finding codes/lines on the seeded fixture files under
tests/fixtures/lint/, suppression comments, a zero-findings run on the
live tree (the merge gate), the ``--format json`` schema, registry-checker
mechanics, and the spec-hash drift contract -- including the acceptance
scenario where an ExperimentSpec field is added WITHOUT bumping
HASH_SCHEMA (exercised on a mutated copy of the real source).
"""
import json
import shutil
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (
    CHECKERS, Finding, LintEngine, ModuleCache, make_checker, run_lint,
    select_checkers, write_manifest)
from repro.analysis.checkers import RegistryChecker
from repro.analysis.manifest import HASHED_SPECS, check_manifest

ROOT = Path(__file__).resolve().parents[1]
FIXTURES = ROOT / "tests" / "fixtures" / "lint"


def lint_fixture(name: str):
    findings, _ = run_lint(paths=[FIXTURES / name])
    return {(f.code, f.line) for f in findings}, findings


# ------------------------------------------------- fixtures: exact findings --

def test_determinism_fixture_exact_codes_and_lines():
    got, findings = lint_fixture("det_violations.py")
    assert got == {("D001", 20), ("D001", 21), ("D001", 22),
                   ("D002", 27), ("D002", 28), ("D002", 29)}
    # the suppressed time.time() on line 34 must NOT be reported
    assert all(f.line != 34 for f in findings)
    assert all(f.checker == "determinism" for f in findings)


def test_units_fixture_exact_codes_and_lines():
    got, findings = lint_fixture("units_violations.py")
    assert got == {("U001", 7), ("U001", 8), ("U002", 13), ("U002", 14)}
    assert all(f.line != 21 for f in findings)   # suppressed U002


def test_metering_fixture_exact_codes_and_lines():
    got, _ = lint_fixture("metering_violations.py")
    # steal_the_books also violates trace discipline (T001, first write)
    assert got == {("M001", 8), ("M001", 9), ("M001", 10), ("M001", 11),
                   ("M002", 15), ("T001", 8)}


def test_trace_fixture_exact_codes_and_lines():
    findings, _ = run_lint(paths=[FIXTURES / "trace_violations.py"],
                           select=["trace"])
    got = {(f.code, f.line) for f in findings}
    # one finding per offending function (anchored at its first write);
    # the rec-referencing and the suppressed functions stay silent
    assert got == {("T001", 8)}
    assert all(f.checker == "trace" for f in findings)


def test_constants_fixture_exact_codes_and_lines():
    got, findings = lint_fixture("constants_violations.py")
    assert got == {("C001", 6), ("C001", 10)}
    # the finding names the owning symbol and home module
    by_line = {f.line: f.message for f in findings}
    assert "LAMBDA_GB_S" in by_line[10]
    assert "cost.py" in by_line[10]


def test_finding_render_format():
    f = Finding(file="a/b.py", line=7, code="D001", message="no clocks")
    assert f.render() == "a/b.py:7 D001 no clocks"


def test_syntax_error_becomes_e999(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def oops(:\n")
    findings, _ = run_lint(paths=[bad])
    assert [f.code for f in findings] == ["E999"]


# ----------------------------------------------------- the merge gate -------

def test_live_tree_is_clean():
    """The acceptance bar: `python -m repro lint` exits 0 on this tree."""
    findings, n_files = run_lint()
    assert findings == [], "\n".join(f.render() for f in findings)
    assert n_files > 50          # it really scanned src/repro + benchmarks


def test_cli_lint_clean_tree_and_json_schema():
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "lint", "--format", "json"],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    data = json.loads(proc.stdout)
    assert data["schema"] == "repro.lint/v1"
    assert data["findings"] == []
    assert data["summary"] == {"total": 0, "by_code": {}}
    assert data["files"] > 50


def test_cli_lint_fixture_exits_nonzero_with_file_line_code():
    rel = "tests/fixtures/lint/det_violations.py"
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "lint", rel],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode == 1
    assert f"{rel}:20 D001 " in proc.stdout


def test_cli_lint_unknown_checker_errors():
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "lint", "--select", "nonsense"],
        cwd=ROOT, capture_output=True, text=True)
    assert proc.returncode != 0
    assert "unknown checker" in (proc.stdout + proc.stderr)


# ------------------------------------------------- the checker registry -----

def test_checker_registry_round_trip():
    for name in CHECKERS:
        checker = make_checker(name)
        assert checker.name == name
        assert checker.codes and checker.description
    with pytest.raises(KeyError):
        make_checker("bogus")


def test_select_checkers_skips_tree_level_on_explicit_paths():
    names = {c.name for c in select_checkers(paths_given=True)}
    assert "spec_hash" not in names and "registry" not in names
    # ... unless selected by name
    assert {c.name for c in select_checkers(["spec_hash"],
                                            paths_given=True)} == {"spec_hash"}


def test_selected_checkers_share_one_parse_per_file():
    cache = ModuleCache(files=[FIXTURES / "units_violations.py"],
                        force_all=True)
    LintEngine([make_checker("units"), make_checker("determinism"),
                make_checker("metering")], cache).run()
    assert len(cache._parsed) == 1


# ---------------------------------------------------- registry checker ------

def test_registry_names_all_non_empty_and_listed():
    checker = RegistryChecker()
    listing = checker._cli_list_output()
    for registry in checker.TABLE:
        names = checker._names(registry)
        assert names, registry
        for name in names:
            assert name.partition(":")[0] in listing, (registry, name)


def test_registry_checker_r001_r002_mechanics(monkeypatch):
    cache = ModuleCache()
    checker = RegistryChecker()
    # a name the CLI listing does not print -> R001
    monkeypatch.setattr(RegistryChecker, "_cli_list_output",
                        staticmethod(lambda: ""))
    codes = {f.code for f in checker.run(cache)}
    assert "R001" in codes
    # a registry whose required test identifiers nothing references -> R002
    monkeypatch.setattr(
        RegistryChecker, "_cli_list_output",
        staticmethod(lambda: " ".join(
            n for r in checker.TABLE for n in checker._names(r))))
    monkeypatch.setitem(checker.TABLE, "sync",
                        ("src/repro/core/sync.py", "SYNC_GRAMMARS",
                         {"identifier_no_test_ever_uses"}))
    findings = list(checker.run(ModuleCache()))
    assert {f.code for f in findings} == {"R002"}
    assert any(f.file == "src/repro/core/sync.py" for f in findings)


# ---------------------------------------------------- spec-hash drift -------

SPEC_REL = HASHED_SPECS["ExperimentSpec"][0]


def _spec_playground(tmp_path: Path) -> tuple:
    """A throwaway tree holding copies of the real hashed-spec sources,
    plus a manifest freshly written against them."""
    root = tmp_path / "tree"
    for cls, (rel, _salt) in HASHED_SPECS.items():
        dst = root / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copy(ROOT / rel, dst)
    manifest = tmp_path / "spec_manifest.json"
    write_manifest(ModuleCache(root=root), manifest)
    return root, manifest


def _mutate(root: Path, old: str, new: str, rel: str = SPEC_REL) -> None:
    path = root / rel
    source = path.read_text()
    assert old in source, f"mutation anchor {old!r} vanished from {rel}"
    path.write_text(source.replace(old, new))


def test_spec_hash_clean_after_write_manifest(tmp_path):
    root, manifest = _spec_playground(tmp_path)
    assert list(check_manifest(ModuleCache(root=root), manifest)) == []


def test_spec_hash_field_added_without_salt_bump_fails(tmp_path):
    """The acceptance scenario: grow ExperimentSpec, forget HASH_SCHEMA."""
    root, manifest = _spec_playground(tmp_path)
    _mutate(root, "    max_epochs: int",
            "    sneaky_new_knob: float = 0.0\n    max_epochs: int")
    findings = list(check_manifest(ModuleCache(root=root), manifest))
    assert [f.code for f in findings] == ["H001"]
    assert findings[0].file == SPEC_REL
    assert "sneaky_new_knob" in findings[0].message
    assert "HASH_SCHEMA" in findings[0].message
    # --write-manifest refuses to paper over the unbumped change
    with pytest.raises(ValueError, match="refusing"):
        write_manifest(ModuleCache(root=root), manifest)


def test_spec_hash_default_change_also_fails(tmp_path):
    root, manifest = _spec_playground(tmp_path)
    _mutate(root, "    max_epochs: int = 3", "    max_epochs: int = 4")
    findings = list(check_manifest(ModuleCache(root=root), manifest))
    assert [f.code for f in findings] == ["H001"]


def test_spec_hash_salt_bump_then_regenerate_goes_green(tmp_path):
    root, manifest = _spec_playground(tmp_path)
    _mutate(root, "    max_epochs: int",
            "    sneaky_new_knob: float = 0.0\n    max_epochs: int")
    _mutate(root, 'HASH_SCHEMA = "', 'HASH_SCHEMA = "bumped-')
    cache = ModuleCache(root=root)
    findings = list(check_manifest(cache, manifest))
    assert [f.code for f in findings] == ["H002"]   # stale manifest
    write_manifest(cache, manifest)                 # now allowed
    assert list(check_manifest(ModuleCache(root=root), manifest)) == []


def test_spec_hash_missing_manifest_is_h003(tmp_path):
    root, _ = _spec_playground(tmp_path)
    missing = tmp_path / "nowhere.json"
    codes = [f.code for f in check_manifest(ModuleCache(root=root), missing)]
    assert codes == ["H003"] * len(HASHED_SPECS)


def test_committed_manifest_matches_the_live_tree():
    """The repo's own manifest is in sync (the CI gate relies on it)."""
    assert list(check_manifest(ModuleCache())) == []
