"""Assigned architecture configs: exact published values (the 10-arch table)."""
import pytest

from repro.configs import ARCH_IDS, get_arch, get_reduced

EXPECT = {
    "grok-1-314b": dict(num_layers=64, d_model=6144, num_heads=48,
                        num_kv_heads=8, d_ff=32768, vocab_size=131072,
                        num_experts=8, experts_per_token=2),
    "deepseek-v2-lite-16b": dict(num_layers=27, d_model=2048, num_heads=16,
                                 d_ff=1408, vocab_size=102400, num_experts=64,
                                 experts_per_token=6, num_shared_experts=2,
                                 kv_lora_rank=512, use_mla=True),
    "hubert-xlarge": dict(num_layers=48, d_model=1280, num_heads=16,
                          d_ff=5120, vocab_size=504, is_encoder=True),
    "phi3-medium-14b": dict(num_layers=40, d_model=5120, num_heads=40,
                            num_kv_heads=10, d_ff=17920, vocab_size=100352),
    "llama3-405b": dict(num_layers=126, d_model=16384, num_heads=128,
                        num_kv_heads=8, d_ff=53248, vocab_size=128256),
    "stablelm-3b": dict(num_layers=32, d_model=2560, num_heads=32,
                        num_kv_heads=32, d_ff=6912, vocab_size=50304),
    "smollm-360m": dict(num_layers=32, d_model=960, num_heads=15,
                        num_kv_heads=5, d_ff=2560, vocab_size=49152),
    "zamba2-2.7b": dict(num_layers=54, d_model=2560, num_heads=32,
                        d_ff=10240, vocab_size=32000, ssm_state=64,
                        attn_every=6),
    "mamba2-370m": dict(num_layers=48, d_model=1024, vocab_size=50280,
                        ssm_state=128),
    "llama-3.2-vision-90b": dict(num_layers=100, d_model=8192, num_heads=64,
                                 num_kv_heads=8, d_ff=28672,
                                 vocab_size=128256, cross_attn_every=5),
}


@pytest.mark.parametrize("name", ARCH_IDS)
def test_exact_config(name):
    cfg = get_arch(name).model
    for k, v in EXPECT[name].items():
        assert getattr(cfg, k) == v, f"{name}.{k}: {getattr(cfg, k)} != {v}"


@pytest.mark.parametrize("name", ARCH_IDS)
def test_reduced_same_family(name):
    full, red = get_arch(name).model, get_reduced(name).model
    assert red.family == full.family
    assert red.use_mla == full.use_mla
    assert bool(red.num_experts) == bool(full.num_experts)
    assert red.is_encoder == full.is_encoder
    assert red.num_layers <= 4


def test_param_counts_match_names():
    """Full-config parameter counts are within 15% of the advertised sizes."""
    import re
    from repro.distributed.roofline import active_params
    targets = {"grok-1-314b": 314e9, "llama3-405b": 405e9,
               "deepseek-v2-lite-16b": 16e9, "phi3-medium-14b": 14e9,
               "smollm-360m": 360e6, "mamba2-370m": 370e6,
               "zamba2-2.7b": 2.7e9, "llama-3.2-vision-90b": 90e9}
    for name, target in targets.items():
        total, active = active_params(get_arch(name))
        assert abs(total - target) / target < 0.15, (name, total, target)


def test_shape_skips():
    assert "decode_32k" not in get_arch("hubert-xlarge").shapes()
    assert "long_500k" not in get_arch("llama3-405b").shapes()
    assert "long_500k" in get_arch("mamba2-370m").shapes()
    assert "long_500k" in get_arch("zamba2-2.7b").shapes()
