"""Checkpointing: atomic roundtrip, retention, elastic resume, preemption."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data.tokens import TokenStream


def _tree():
    return {"a": {"b": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "c": [jnp.ones((4,), jnp.bfloat16), jnp.int32(7)],
            "step": jnp.int32(3)}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 3, t, {"note": "x"})
    loaded, meta = ckpt.load_latest(tmp_path)
    assert meta["step"] == 3 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_structure_preserved(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 1, t)
    loaded, _ = ckpt.load_latest(tmp_path)
    assert jax.tree.structure(jax.tree.map(lambda x: 0, t)) == \
        jax.tree.structure(jax.tree.map(lambda x: 0, loaded))


def test_retention_and_latest(tmp_path):
    for s in (1, 5, 9, 12):
        ckpt.save(tmp_path, s, {"x": jnp.float32(s)})
    assert ckpt.list_steps(tmp_path) == [1, 5, 9, 12]
    ckpt.retain(tmp_path, keep=2)
    assert ckpt.list_steps(tmp_path) == [9, 12]
    loaded, meta = ckpt.load_latest(tmp_path)
    assert float(loaded["x"]) == 12.0


def test_no_partial_files_on_disk(tmp_path):
    ckpt.save(tmp_path, 2, _tree())
    assert not list(tmp_path.glob(".tmp*"))


def test_preemption_guard():
    g = ckpt.PreemptionGuard(lifetime_s=0.5, margin_s=0.2)
    g.record_step(0.05)
    assert not g.should_checkpoint()
    time.sleep(0.35)
    assert g.should_checkpoint()
    g.renew()
    assert not g.should_checkpoint()


def test_roundtrip_is_bit_exact_and_dtype_preserving(tmp_path):
    """Restore equality must be exact, not approximate: bf16 leaves come
    back as bf16 with identical bit patterns (the uint16 shuttle encoding
    is invisible), ints stay ints."""
    rng = np.random.default_rng(0)
    t = {"w": jnp.asarray(rng.standard_normal((3, 5)), jnp.bfloat16),
         "b": jnp.asarray(rng.standard_normal(7), jnp.float32),
         "n": jnp.int32(-42)}
    ckpt.save(tmp_path, 4, t)
    loaded, _ = ckpt.load(tmp_path, 4)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(loaded)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(
            np.asarray(a).view(np.uint16) if a.dtype == jnp.bfloat16
            else np.asarray(a),
            np.asarray(b).view(np.uint16) if b.dtype == jnp.bfloat16
            else np.asarray(b))


def test_load_specific_step_and_empty_dir(tmp_path):
    ckpt.save(tmp_path, 1, {"x": jnp.float32(1.0)})
    ckpt.save(tmp_path, 2, {"x": jnp.float32(2.0)})
    loaded, meta = ckpt.load(tmp_path, 1)
    assert float(loaded["x"]) == 1.0 and meta["step"] == 1
    empty = tmp_path / "nothing"
    empty.mkdir()
    assert ckpt.load_latest(empty) == (None, None)


def test_restart_cost_is_metered(tmp_path):
    """A lifetime-rotated FaaS run pays for its checkpoints: the rotation
    seconds land in breakdown['checkpoint'], extend sim_time, and (because
    Lambda bills GB-seconds on the re-invoked clocks) raise the $ total over
    the identical uninterrupted run."""
    from repro.core.algorithms import make_algorithm
    from repro.core.mlmodels import make_study_model
    from repro.core.runtimes import FaaSRuntime
    from repro.data.synthetic import make_dataset, train_val_split

    tr, va = train_val_split(make_dataset("higgs", rows=4_000, seed=0))
    model = make_study_model("lr", tr)
    algo = lambda: make_algorithm("ga_sgd", lr=0.2, batch_size=1024)  # noqa
    smooth = FaaSRuntime(workers=2).train(model, algo(), tr, va, max_epochs=2)
    rotated = FaaSRuntime(workers=2, lifetime=20.0).train(
        model, algo(), tr, va, max_epochs=2)
    assert smooth.breakdown["checkpoint"] == 0.0
    assert rotated.breakdown["checkpoint"] > 0.0
    assert rotated.sim_time >= smooth.sim_time + rotated.breakdown["checkpoint"] / 2
    assert rotated.cost > smooth.cost
    np.testing.assert_allclose(rotated.final_loss, smooth.final_loss,
                               rtol=1e-6)


def test_elastic_resume_same_stream(tmp_path):
    """Train 2 workers, checkpoint, resume with 3 workers: the global sample
    order continues without gaps or repeats."""
    streams = [TokenStream(64, seed=5, worker=w, num_workers=2)
               for w in range(2)]
    seen = []
    for _ in range(2):
        for s in streams:
            s.batch(4, 8)
    pos = streams[0].position
    ckpt.save(tmp_path, 0, {"pos": jnp.int32(pos)}, streams[0].state())
    loaded, meta = ckpt.load_latest(tmp_path)
    new = [TokenStream(64) for _ in range(3)]
    for w, s in enumerate(new):
        s.restore(meta, w, 3)
    assert all(s.position == pos for s in new)
    idx = sorted(pos + i * 3 + w for w in range(3) for i in range(4))
    assert idx == list(range(pos, pos + 12))
