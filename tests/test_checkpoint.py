"""Checkpointing: atomic roundtrip, retention, elastic resume, preemption."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.data.tokens import TokenStream


def _tree():
    return {"a": {"b": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "c": [jnp.ones((4,), jnp.bfloat16), jnp.int32(7)],
            "step": jnp.int32(3)}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 3, t, {"note": "x"})
    loaded, meta = ckpt.load_latest(tmp_path)
    assert meta["step"] == 3 and meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_structure_preserved(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 1, t)
    loaded, _ = ckpt.load_latest(tmp_path)
    assert jax.tree.structure(jax.tree.map(lambda x: 0, t)) == \
        jax.tree.structure(jax.tree.map(lambda x: 0, loaded))


def test_retention_and_latest(tmp_path):
    for s in (1, 5, 9, 12):
        ckpt.save(tmp_path, s, {"x": jnp.float32(s)})
    assert ckpt.list_steps(tmp_path) == [1, 5, 9, 12]
    ckpt.retain(tmp_path, keep=2)
    assert ckpt.list_steps(tmp_path) == [9, 12]
    loaded, meta = ckpt.load_latest(tmp_path)
    assert float(loaded["x"]) == 12.0


def test_no_partial_files_on_disk(tmp_path):
    ckpt.save(tmp_path, 2, _tree())
    assert not list(tmp_path.glob(".tmp*"))


def test_preemption_guard():
    g = ckpt.PreemptionGuard(lifetime_s=0.5, margin_s=0.2)
    g.record_step(0.05)
    assert not g.should_checkpoint()
    time.sleep(0.35)
    assert g.should_checkpoint()
    g.renew()
    assert not g.should_checkpoint()


def test_elastic_resume_same_stream(tmp_path):
    """Train 2 workers, checkpoint, resume with 3 workers: the global sample
    order continues without gaps or repeats."""
    streams = [TokenStream(64, seed=5, worker=w, num_workers=2)
               for w in range(2)]
    seen = []
    for _ in range(2):
        for s in streams:
            s.batch(4, 8)
    pos = streams[0].position
    ckpt.save(tmp_path, 0, {"pos": jnp.int32(pos)}, streams[0].state())
    loaded, meta = ckpt.load_latest(tmp_path)
    new = [TokenStream(64) for _ in range(3)]
    for w, s in enumerate(new):
        s.restore(meta, w, 3)
    assert all(s.position == pos for s in new)
    idx = sorted(pos + i * 3 + w for w in range(3) for i in range(4))
    assert idx == list(range(pos, pos + 12))
