"""Discrete-event engine behaviour (DESIGN.md §4, §6, §7): FaaS/IaaS
numerics parity through the shared loop, SSP staleness-bound enforcement,
spot-preemption resume correctness, heterogeneous fleets, and the metering
interface shared by storage channels and VM networks."""
import numpy as np
import pytest

from repro.core.algorithms import make_algorithm
from repro.core.channels import StorageChannel, VMNetwork
from repro.core.engine import (
    FailureProcess, InjectedPreemptions, PoissonPreemptions, StragglerProcess,
)
from repro.core.runtimes import FaaSRuntime, IaaSRuntime
from repro.core.sync import ASP, BSP, SSP, make_sync
from repro.data.synthetic import make_dataset, train_val_split


@pytest.fixture(scope="module")
def higgs():
    ds = make_dataset("higgs", rows=20_000)
    return train_val_split(ds)


@pytest.fixture(scope="module")
def cifar():
    ds = make_dataset("cifar10", rows=1_500)
    return train_val_split(ds)


def _ga(**kw):
    return make_algorithm("ga_sgd", **{"lr": 0.2, "batch_size": 2048, **kw})


# ------------------------------------------------------------- protocols ----

def test_make_sync_parses_specs():
    assert isinstance(make_sync("bsp"), BSP)
    assert isinstance(make_sync("asp"), ASP)
    ssp = make_sync("ssp:7")
    assert isinstance(ssp, SSP) and ssp.staleness == 7
    assert make_sync(ssp) is ssp
    assert isinstance(make_sync(BSP), BSP)       # class form also accepted
    assert isinstance(make_sync(ASP), ASP)
    with pytest.raises(KeyError):
        make_sync("totally-async")


def test_bsp_parity_faas_iaas_through_engine(higgs):
    """Both platforms run the SAME engine loop: identical loss histories,
    different clocks/costs."""
    from repro.core.mlmodels import make_study_model
    tr, va = higgs
    model = make_study_model("lr", tr)
    f = FaaSRuntime(workers=4).train(model, _ga(), tr, va, max_epochs=3)
    i = IaaSRuntime(workers=4).train(model, _ga(), tr, va, max_epochs=3)
    np.testing.assert_allclose([l for _, l in f.history],
                               [l for _, l in i.history], rtol=1e-6)
    assert f.sim_time != i.sim_time and f.cost != i.cost


def test_asp_and_ssp_run_on_iaas(higgs):
    """The event-driven protocols are platform-agnostic: IaaS serves the
    global model from worker 0 over the metered VM network."""
    from repro.core.mlmodels import make_study_model
    tr, va = higgs
    model = make_study_model("lr", tr)
    r = IaaSRuntime(workers=4, sync="asp").train(model, _ga(), tr, va,
                                                 max_epochs=2)
    assert r.rounds > 0 and not r.error
    assert np.isfinite(r.final_loss)
    r = IaaSRuntime(workers=4, sync="ssp:1").train(model, _ga(), tr, va,
                                                   max_epochs=2)
    assert r.rounds > 0 and r.max_staleness <= 1


def test_ssp_enforces_staleness_bound(cifar):
    """With a 10x straggler on a compute-heavy model, ASP drifts well past
    the bound while SSP s=2 clamps every read and meters the waits."""
    from repro.core.mlmodels import make_study_model
    tr, va = cifar
    mn = make_study_model("mobilenet", tr)
    kw = dict(max_epochs=6)
    algo = lambda: make_algorithm("ga_sgd", lr=0.05, batch_size=512)  # noqa
    asp = FaaSRuntime(workers=4, sync="asp", straggler=10.0,
                      channel="memcached").train(mn, algo(), tr, va, **kw)
    ssp = FaaSRuntime(workers=4, sync="ssp:2", straggler=10.0,
                      channel="memcached").train(mn, algo(), tr, va, **kw)
    assert asp.max_staleness > 2
    assert ssp.max_staleness <= 2
    assert ssp.breakdown.get("wait", 0.0) > 0.0
    assert asp.rounds == ssp.rounds      # same total statistical work


# ------------------------------------------------------------------ spot ----

def test_spot_preemption_resume_correctness(higgs):
    """Injected preemptions: numerics identical to the on-demand run, >= 1
    preemption metered, wall-clock strictly worse, spot price discounted."""
    from repro.core.mlmodels import make_study_model
    tr, va = higgs
    model = make_study_model("lr", tr)
    base = IaaSRuntime(workers=4).train(model, _ga(), tr, va, max_epochs=3)
    t0 = base.breakdown["startup"]
    spot = IaaSRuntime(workers=4, spot=True,
                       preempt_at=((0, t0 + 1.0), (2, t0 + 3.0))).train(
        model, _ga(), tr, va, max_epochs=3)
    assert spot.preemptions == 2
    assert spot.breakdown["restart"] > 0
    assert spot.sim_time > base.sim_time
    np.testing.assert_allclose([l for _, l in base.history],
                               [l for _, l in spot.history], rtol=1e-6)
    assert "spot" in spot.system


def test_spot_faas_crash_resume(higgs):
    """The same failure machinery drives FaaS worker crashes."""
    from repro.core.mlmodels import make_study_model
    tr, va = higgs
    model = make_study_model("lr", tr)
    base = FaaSRuntime(workers=4).train(model, _ga(), tr, va, max_epochs=2)
    crashed = FaaSRuntime(workers=4, preempt_at=((1, 2.0),)).train(
        model, _ga(), tr, va, max_epochs=2)
    assert crashed.preemptions == 1
    assert crashed.sim_time > base.sim_time
    np.testing.assert_allclose(base.final_loss, crashed.final_loss, rtol=1e-6)


def test_injected_preemptions_apply_without_spot_flag(higgs):
    """An explicit preempt_at is honored even on an on-demand fleet."""
    from repro.core.mlmodels import make_study_model
    tr, va = higgs
    model = make_study_model("lr", tr)
    from repro.core.runtimes import _T_IAAS, interp_startup
    t0 = interp_startup(_T_IAAS, 4)
    r = IaaSRuntime(workers=4, preempt_at=((1, t0 + 0.1),)).train(
        model, _ga(), tr, va, max_epochs=2)
    assert r.preemptions == 1


def test_poisson_preemptions_terminate_under_extreme_rate(higgs):
    """A preemption rate faster than the restart time must degrade
    throughput, not deadlock the event loop."""
    from repro.core.mlmodels import make_study_model
    tr, va = higgs
    model = make_study_model("lr", tr)
    r = IaaSRuntime(workers=3, spot=True, preempt_rate=120.0, seed=3).train(
        model, _ga(), tr, va, max_epochs=1)
    assert not r.error and np.isfinite(r.final_loss)


def test_failure_process_windows():
    none = FailureProcess()
    assert none.next_preemption(0, 0.0, 1e9) is None
    inj = InjectedPreemptions(((1, 5.0), (1, 9.0), (0, 2.0)))
    assert inj.next_preemption(0, 0.0, 10.0) == 2.0
    assert inj.next_preemption(0, 0.0, 10.0) is None       # consumed
    assert inj.next_preemption(1, 0.0, 6.0) == 5.0
    assert inj.next_preemption(1, 0.0, 6.0) is None        # 9.0 not yet due
    assert inj.next_preemption(1, 0.0, 10.0) == 9.0
    poi = PoissonPreemptions(60.0, workers=1, seed=0)
    hits = sum(poi.next_preemption(0, t, t + 30.0) is not None
               for t in range(0, 36_000, 30))
    assert 0 < hits < 1200     # ~one per minute of exposure, not degenerate


# ---------------------------------------------------------- heterogeneity ---

def test_heterogeneous_lambda_fleet_is_slower(cifar):
    """Mixing 1 GB Lambdas into a 3 GB fleet slows compute-bound rounds."""
    from repro.core.mlmodels import make_study_model
    tr, va = cifar
    mn = make_study_model("mobilenet", tr)
    algo = lambda: make_algorithm("ga_sgd", lr=0.05, batch_size=512)  # noqa
    homo = FaaSRuntime(workers=4).train(mn, algo(), tr, va, max_epochs=2)
    hetero = FaaSRuntime(workers=4, lambda_gb=(3.0, 3.0, 1.0, 1.0)).train(
        mn, algo(), tr, va, max_epochs=2)
    assert hetero.sim_time > homo.sim_time
    np.testing.assert_allclose(homo.final_loss, hetero.final_loss, rtol=1e-6)


def test_heterogeneous_instance_fleet(higgs):
    from repro.core.mlmodels import make_study_model
    tr, va = higgs
    model = make_study_model("lr", tr)
    mixed = ("c5.large", "t2.medium", "t2.medium", "c5.large")
    r = IaaSRuntime(workers=4, instance=mixed).train(model, _ga(), tr, va,
                                                     max_epochs=2)
    cheap = IaaSRuntime(workers=4).train(model, _ga(), tr, va, max_epochs=2)
    assert not r.error
    assert r.cost > cheap.cost        # c5.large bills more per hour


def test_per_worker_config_length_mismatch_raises(higgs):
    from repro.core.mlmodels import make_study_model
    tr, va = higgs
    model = make_study_model("lr", tr)
    with pytest.raises(ValueError):
        FaaSRuntime(workers=4, lambda_gb=(3.0, 1.0)).train(
            model, _ga(), tr, va, max_epochs=1)


# ----------------------------------------------------- platform protocol ----

def test_runtimes_satisfy_platform_protocol():
    from repro.core.platform import CommSpec, FailureSpec, FleetSpec, Platform
    from repro.core.runtimes import PodPlatform
    faas, iaas = FaaSRuntime(workers=2), IaaSRuntime(workers=2)
    assert isinstance(faas, Platform) and isinstance(iaas, Platform)
    assert isinstance(PodPlatform(pods=2), Platform)
    # spec objects compose directly (and win over the flat keywords)
    rt = FaaSRuntime(workers=99, fleet=FleetSpec(workers=3, straggler=2.0),
                     failure=FailureSpec(inject=((0, 5.0),)),
                     comm=CommSpec(channel="redis"))
    assert rt.workers == 3 and rt.channel == "redis"
    assert rt.preempt_at == ((0, 5.0),)
    # legacy flat attributes remain readable views over the specs
    assert IaaSRuntime(workers=2, spot=True).spot is True
    assert IaaSRuntime(workers=2, instance="c5.large").instance == "c5.large"


def test_worker_flops_signature_is_unified(higgs):
    """Satellite: FaaS used to take no model, IaaS required one; both now
    accept an optional model (None = capability estimate)."""
    from repro.core.mlmodels import make_study_model
    tr, _ = higgs
    lr = make_study_model("lr", tr)
    faas, iaas = FaaSRuntime(workers=2), IaaSRuntime(workers=2)
    assert faas.worker_flops() == faas.worker_flops(lr) > 0
    assert iaas.worker_flops() == iaas.worker_flops(lr) > 0
    gpu = IaaSRuntime(workers=2, instance="g3s.xlarge", gpu=True)
    # capability estimate without a model reports the GPU; a convex model
    # falls back to CPU speed (the paper's NN-only GPU rule)
    assert gpu.worker_flops() > gpu.worker_flops(lr)


def test_faas_validate_memory_headroom_boundary():
    """Satellite: the opaque `4 * mbytes * gb_min == 0` clause is gone --
    the rule is now: model fits in 1/3 of the smallest Lambda's memory."""
    rt = FaaSRuntime(workers=2, lambda_gb=1.0)
    headroom = int(1.0 * 1e9 / 3)
    assert rt.validate(0) == ""                    # zero-byte model is fine
    assert rt.validate(headroom) == ""             # exactly at the boundary
    assert "exceeds" in rt.validate(headroom + 1)  # one byte over
    # the SMALLEST worker in a hetero fleet bounds the whole fleet
    hetero = FaaSRuntime(workers=3, lambda_gb=(3.0, 3.0, 1.0))
    assert "exceeds" in hetero.validate(headroom + 1)
    assert FaaSRuntime(workers=3, lambda_gb=3.0).validate(headroom + 1) == ""


def test_faas_rejects_gpu_fleets(higgs):
    """Satellite: FleetSpec.gpu used to be silently ignored on FaaS;
    validate() now rejects it with an actionable message (Lambda has no
    GPUs -- the GPU-FaaS what-if is analytical-only)."""
    from repro.core.mlmodels import make_study_model
    from repro.core.platform import FleetSpec
    rt = FaaSRuntime(fleet=FleetSpec(workers=2, gpu=True))
    msg = rt.validate(1_000)
    assert "no GPU" in msg and "analytical" in msg
    tr, va = higgs
    res = rt.train(make_study_model("lr", tr), _ga(), tr, va, max_epochs=1)
    assert res.error == msg and not res.history
    # the same fleet composes fine with platforms that do have accelerators
    assert IaaSRuntime(fleet=FleetSpec(workers=2, gpu=True)).validate(0) == ""
    # pods are accelerators already: gpu=True there is the same reuse
    # mistake and is rejected the same way
    from repro.core.runtimes import PodPlatform
    assert "gpu" in PodPlatform(fleet=FleetSpec(workers=2, gpu=True)
                                ).validate(0)
    assert PodPlatform(pods=2).validate(10**9) == ""


# -------------------------------------------------------------- metering ----

def test_vmnetwork_shares_channel_metering_interface():
    net = VMNetwork(120e6, 5e-4)
    chan = StorageChannel("s3")
    payload = np.zeros(1_000_000, np.float32)
    for store in (net, chan):
        dt_put = store.put("k", payload)
        got, dt_get = store.get("k")
        assert dt_put > 0 and dt_get > 0
        assert got is payload
        assert store.service_cost(10.0) >= 0.0
    assert net.allreduce_time(4_000_000, 1) == 0.0
    assert net.allreduce_time(4_000_000, 8) > net.allreduce_time(1_000, 8)


def test_straggler_process_backup_cap():
    sp = StragglerProcess(factor=6.0)
    s = sp.speeds(8, seed=0)
    capped = StragglerProcess(factor=6.0, cap_at_median=True).speeds(8, seed=0)
    assert np.max(capped) <= np.median(s) + 1e-12
    assert np.max(s) > 3.0
