"""Multi-device distribution tests.

jax pins the device count at first init, so anything needing >1 device runs
in a SUBPROCESS with REPRO_XLA_FLAGS / XLA_FLAGS set before the jax import
(same mechanism as the dry-run launcher).  These are integration tests of
the real launcher path on reduced configs -- slow-ish (~2 min total).
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import pytest

#: the local-SGD inner step needs partial-manual shard_map (manual over
#: "pod", auto over "data"/"model").  That is ``jax.shard_map`` on jax >=
#: 0.5; the legacy ``jax.experimental.shard_map(auto=...)`` mode hard-aborts
#: in the XLA SPMD partitioner for this model, so these tests require the
#: native API (the full-manual paths are unaffected).
requires_partial_manual_shard_map = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="partial-manual shard_map needs jax>=0.5 (jax.shard_map); the "
           "legacy auto= mode aborts in XLA's SPMD partitioner")

ROOT = Path(__file__).resolve().parents[1]
ENV = {**os.environ,
       "REPRO_XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "PYTHONPATH": str(ROOT / "src")}


def _run(args, timeout=900):
    return subprocess.run([sys.executable, *args], env=ENV, cwd=ROOT,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.parametrize("arch,shape", [
    ("smollm-360m", "train_4k"),
    ("deepseek-v2-lite-16b", "train_4k"),   # MoE + MLA + EP
    ("zamba2-2.7b", "decode_32k"),          # hybrid cache
])
def test_dryrun_reduced_single_pod(arch, shape):
    r = _run(["-m", "repro.launch.dryrun", "--arch", arch, "--shape", shape,
              "--mesh", "2x4", "--reduced", "--no-save"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ok " in r.stdout


def test_dryrun_reduced_multi_pod():
    r = _run(["-m", "repro.launch.dryrun", "--arch", "smollm-360m",
              "--shape", "train_4k", "--mesh", "2x2x2", "--reduced",
              "--no-save"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ok " in r.stdout


@requires_partial_manual_shard_map
def test_local_sgd_no_cross_pod_collectives_in_inner_step():
    """The heart of the MA-SGD-on-pods claim: the inner step's collectives
    must all stay within a pod (replica groups never span pods)."""
    script = r"""
import os
os.environ["XLA_FLAGS"]="--xla_force_host_platform_device_count=8"
import json, jax
from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh
from repro.distributed.local_sgd import build_local_sgd
from repro.distributed.hlo_analysis import analyze_hlo
mesh = make_mesh((2,2,2),("pod","data","model"))
ls = build_local_sgd(get_reduced("smollm-360m"), mesh, ShapeConfig("t",128,8,"train"))
with mesh:
    inner = analyze_hlo(ls.lower_inner().compile().as_text(), pod_size=4)
    outer = analyze_hlo(ls.lower_outer().compile().as_text(), pod_size=4)
print(json.dumps({"inner_cross": inner["cross_pod_bytes"],
                  "inner_total": inner["coll_bytes"],
                  "outer_cross": outer["cross_pod_bytes"]}))
"""
    r = _run(["-c", script])
    assert r.returncode == 0, r.stdout + r.stderr
    out = json.loads(r.stdout.strip().splitlines()[-1])
    # the MA-SGD-on-pods guarantee: ZERO cross-pod bytes in the inner step,
    # while the outer sync does cross pods
    assert out["inner_cross"] == 0, out
    assert out["inner_total"] > 0 and out["outer_cross"] > 0, out


@requires_partial_manual_shard_map
def test_local_sgd_numerics_and_sync():
    """Inner loss decreases; after the outer step all pod replicas agree."""
    script = r"""
import os
os.environ["XLA_FLAGS"]="--xla_force_host_platform_device_count=8"
import json, jax, jax.numpy as jnp
from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh
from repro.distributed.local_sgd import build_local_sgd
from repro.launch.specs import make_batch
from repro.models import build_model
from repro.optim import make_optimizer
mesh = make_mesh((2,2,2),("pod","data","model"))
arch = get_reduced("smollm-360m")
ls = build_local_sgd(arch, mesh, ShapeConfig("t",128,8,"train"))
model = build_model(arch)
params = model.init(jax.random.key(0))
params_st = jax.tree.map(lambda x: jnp.stack([x]*2), params)
opt = make_optimizer(arch.train)
opt_st = jax.tree.map(lambda x: jnp.stack([x]*2), opt.init(params))
batch = make_batch(arch, 8, 128)
with mesh:
    losses = []
    for _ in range(5):
        params_st, opt_st, m = ls.inner_fn(params_st, opt_st, batch)
        losses.append(float(m["loss"][0]))
    out_state = ls.init_outer_fn(params_st)
    params_st, out_state = ls.outer_fn(params_st, out_state)
    leaf = jax.tree.leaves(params_st)[2]
    eq = bool(jnp.allclose(leaf[0], leaf[1], atol=1e-3))
print(json.dumps({"losses": losses, "eq": eq}))
"""
    r = _run(["-c", script])
    assert r.returncode == 0, r.stdout + r.stderr
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["losses"][-1] < out["losses"][0]
    assert out["eq"]


def test_comm_pattern_changes_collectives():
    """allreduce (pure DP) vs scatter_reduce (FSDP): the FSDP lowering must
    contain reduce-scatter or param all-gathers; pure DP must not."""
    script = r"""
import os
os.environ["XLA_FLAGS"]="--xla_force_host_platform_device_count=8"
import dataclasses, json, jax
from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh
from repro.distributed.step import build_train_step
from repro.distributed.hlo_analysis import analyze_hlo
mesh = make_mesh((4,2),("data","model"))
sh = ShapeConfig("t", 64, 16, "train")
out = {}
for pat in ("allreduce", "scatter_reduce"):
    arch = get_reduced("stablelm-3b")
    arch = arch.replace(train=dataclasses.replace(arch.train, comm_pattern=pat))
    step = build_train_step(arch, mesh, sh)
    with mesh:
        c = step.lower().compile()
    r = analyze_hlo(c.as_text())
    out[pat] = {k: v["count"] for k, v in r["coll"].items() if isinstance(v, dict)}
print(json.dumps(out))
"""
    r = _run(["-c", script])
    assert r.returncode == 0, r.stdout + r.stderr
    out = json.loads(r.stdout.strip().splitlines()[-1])
    fsdp = out["scatter_reduce"]
    assert fsdp["reduce-scatter"] + fsdp["all-gather"] > \
        out["allreduce"]["reduce-scatter"] + out["allreduce"]["all-gather"]
