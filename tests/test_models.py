"""Model-zoo correctness: decode-vs-forward consistency (the strongest cache
test), SSD chunked-vs-recurrence, MLA absorbed decode, conv cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.launch.specs import make_batch
from repro.models import build_model
from repro.models.ssm import _conv1d_causal, ssd_scan

DECODE_ARCHS = [n for n in ARCH_IDS if n != "hubert-xlarge"]


def _fp32(arch):
    return arch.replace(model=arch.model.replace(dtype="float32"))


@pytest.mark.parametrize("name", DECODE_ARCHS)
def test_decode_matches_forward(name):
    """Teacher-forced decode through the cache must reproduce the training
    forward logits position by position (fp32).

    MoE archs run with a large capacity factor: capacity-based dispatch
    DROPS overflow tokens under load in the batched forward, while one-token
    decode never overflows -- that (designed) difference is exactly what
    this test would otherwise flag (and did, during development).
    """
    arch = _fp32(get_reduced(name))
    if arch.model.num_experts:
        arch = arch.replace(model=arch.model.replace(capacity_factor=8.0))
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    b, s = 2, 12
    batch = make_batch(arch, b, s, seed=3)
    ref_logits, _ = model.forward(params, batch)          # (b, s, v)

    cache = model.init_cache(b, s)
    if arch.model.family == "vlm":
        cache = model.prime_cross_cache(params, cache, batch["image_embeds"])
    errs = []
    for t in range(s):
        step_logits, cache = model.decode_step(
            params, cache, batch["tokens"][:, t], jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(step_logits - ref_logits[:, t]))))
    assert max(errs) < 2e-2, f"{name}: decode/forward divergence {max(errs)}"


def test_ssd_chunked_matches_recurrence():
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 64, 3, 8, 4
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((b, s, h))) * 0.2, jnp.float32)
    a_log = jnp.asarray(rng.standard_normal(h) * 0.3, jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)

    y16, st16 = ssd_scan(x, dt, a_log, B, C, chunk=16)
    y64, st64 = ssd_scan(x, dt, a_log, B, C, chunk=64)
    np.testing.assert_allclose(y16, y64, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st16, st64, rtol=1e-4, atol=1e-4)

    # exact sequential recurrence
    A = -jnp.exp(a_log)
    st = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        dec = jnp.exp(dt[:, t] * A)[..., None, None]
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt[:, t], B[:, t], x[:, t])
        st = st * dec + upd
        ys.append(jnp.einsum("bn,bhpn->bhp", C[:, t], st))
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y16, y_ref, rtol=1e-4, atol=1e-4)


def test_ssd_init_state_continuation():
    """Scanning [first half] then [second half from carried state] must equal
    one full scan -- the property decode and prefill-chunking rely on."""
    rng = np.random.default_rng(1)
    b, s, h, p, n = 1, 64, 2, 8, 4
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((b, s, h))) * 0.2, jnp.float32)
    a_log = jnp.asarray(rng.standard_normal(h) * 0.3, jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    y_full, st_full = ssd_scan(x, dt, a_log, B, C, chunk=16)
    m = s // 2
    y1, st1 = ssd_scan(x[:, :m], dt[:, :m], a_log, B[:, :m], C[:, :m], 16)
    y2, st2 = ssd_scan(x[:, m:], dt[:, m:], a_log, B[:, m:], C[:, m:], 16,
                       init_state=st1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(st2, st_full, rtol=1e-4, atol=1e-4)


def test_conv_cache_streaming():
    """Streaming 1 token at a time through the conv cache == full conv."""
    rng = np.random.default_rng(2)
    b, s, c, w = 2, 10, 6, 4
    x = jnp.asarray(rng.standard_normal((b, s, c)), jnp.float32)
    wgt = jnp.asarray(rng.standard_normal((w, c)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal(c), jnp.float32)
    full, _ = _conv1d_causal(x, wgt, bias)
    cache = jnp.zeros((b, w - 1, c))
    outs = []
    for t in range(s):
        o, cache = _conv1d_causal(x[:, t:t + 1], wgt, bias, cache)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full,
                               rtol=1e-5, atol=1e-5)


def test_encoder_is_bidirectional():
    """Perturbing a FUTURE frame must change an encoder output at position 0
    (and must NOT for a causal LM)."""
    arch = _fp32(get_reduced("hubert-xlarge"))
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    batch = make_batch(arch, 1, 8, seed=0)
    out1, _ = model.forward(params, batch)
    batch2 = dict(batch)
    batch2["frames"] = batch["frames"].at[:, -1].add(1.0)
    out2, _ = model.forward(params, batch2)
    assert float(jnp.max(jnp.abs(out1[:, 0] - out2[:, 0]))) > 1e-6

    arch = _fp32(get_reduced("smollm-360m"))
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    batch = make_batch(arch, 1, 8, seed=0)
    outa, _ = model.forward(params, batch)
    batchb = dict(batch)
    batchb["tokens"] = batch["tokens"].at[:, -1].set(
        (batch["tokens"][:, -1] + 1) % arch.model.vocab_size)
    outb, _ = model.forward(params, batchb)
    np.testing.assert_allclose(outa[:, 0], outb[:, 0], atol=1e-6)


def test_vlm_uses_image():
    arch = _fp32(get_reduced("llama-3.2-vision-90b"))
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    batch = make_batch(arch, 1, 8, seed=0)
    out1, _ = model.forward(params, batch)
    batch2 = dict(batch)
    batch2["image_embeds"] = batch["image_embeds"] * 0.0
    out2, _ = model.forward(params, batch2)
    assert float(jnp.max(jnp.abs(out1 - out2))) > 1e-6


def test_masked_loss_ignores_unmasked():
    arch = _fp32(get_reduced("hubert-xlarge"))
    model = build_model(arch)
    params = model.init(jax.random.key(0))
    batch = make_batch(arch, 2, 8, seed=0)
    loss1, _ = model.loss(params, batch)
    b2 = dict(batch)
    # flip labels outside the mask: loss must not change
    b2["labels"] = jnp.where(batch["mask"], batch["labels"],
                             (batch["labels"] + 7) % arch.model.vocab_size)
    loss2, _ = model.loss(params, b2)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-6)
