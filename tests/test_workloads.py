"""The Workload layer (DESIGN.md §11): study stand-ins and real
architectures behind one protocol, real JAX numerics through the engine on
all three infrastructures, and the analytical model derived from the same
source of truth."""
import jax
import numpy as np
import pytest

from repro.core.algorithms import make_algorithm
from repro.core.analytical import CostInputs, faas_time, iaas_time
from repro.core.analytical import Workload as AnalyticAlias
from repro.core.mlmodels import STUDY_MODELS, StudyModel, make_study_model
from repro.core.runtimes import FaaSRuntime, IaaSRuntime, PodPlatform
from repro.core.workloads import (
    ArchWorkload, Workload, is_arch_workload, list_workloads, make_workload,
    update_vector_bytes,
)


@pytest.fixture(scope="module")
def smollm():
    wl, tr, va = make_workload("smollm_360m", dataset="tokens", rows=128,
                               data_seed=0)
    return wl, tr, va


def _ga(**kw):
    return make_algorithm("ga_sgd", **{"lr": 0.05, "batch_size": 8, **kw})


# ---------------------------------------------------------------- protocol --

def test_both_families_satisfy_the_protocol(smollm):
    from repro.data.synthetic import make_dataset, train_val_split
    tr, _ = train_val_split(make_dataset("higgs", rows=2_000))
    assert isinstance(make_study_model("lr", tr), Workload)
    wl, _, _ = smollm
    assert isinstance(wl, Workload)
    assert wl.convex is False and wl.flops_per_row > 0


def test_registry_names_and_guards():
    names = list_workloads()
    for s in STUDY_MODELS:
        assert s in names
    assert "smollm_360m" in names and "mamba2_370m" in names
    # encoder / VLM archs need non-token inputs: excluded and rejected
    assert "hubert_xlarge" not in names
    assert "llama_3_2_vision_90b" not in names
    assert is_arch_workload("smollm_360m")
    assert not is_arch_workload("lr")
    with pytest.raises(ValueError):
        ArchWorkload("hubert_xlarge")
    with pytest.raises(KeyError):
        ArchWorkload("gpt17_800t")
    with pytest.raises(ValueError, match="tokens"):
        make_workload("smollm_360m", dataset="higgs")   # arch needs tokens
    with pytest.raises(KeyError):
        make_workload("not_a_model")


def test_study_path_is_the_legacy_construction():
    """make_workload with a study name must build the exact objects the
    legacy path built (dataset -> split -> model-on-train)."""
    from repro.data.synthetic import make_dataset, train_val_split
    wl, tr, va = make_workload("lr", dataset="higgs", rows=2_000,
                               data_seed=3, val_frac=0.2)
    ds = make_dataset("higgs", rows=2_000, seed=3)
    tr2, va2 = train_val_split(ds, val_frac=0.2)
    assert isinstance(wl, StudyModel)
    np.testing.assert_array_equal(tr.x, tr2.x)
    np.testing.assert_array_equal(va.y, va2.y)
    p = wl.init(jax.random.key(0))
    assert wl.eval_loss(p, va) == make_study_model("lr", tr2).eval_loss(p, va2)


# ---------------------------------------------------------- real numerics ---

def test_arch_workload_runs_genuine_fwd_bwd(smollm):
    wl, tr, va = smollm
    assert tr.x.dtype == np.int32 and tr.x.shape[1] == wl.seq_len
    params = wl.init(jax.random.key(0))
    b = {"x": tr.x[:8], "y": tr.y[:8]}
    loss, grads = wl.grad(params, b)
    gnorm = sum(float(jax.numpy.sum(jax.numpy.abs(g.astype(jax.numpy.float32))))
                for g in jax.tree.leaves(grads))
    assert float(loss) > 0 and gnorm > 0
    assert wl.flops_per_row == 6.0 * wl.n_params * wl.seq_len
    assert update_vector_bytes(wl, params) == wl.n_params * 4


def test_real_workload_identical_numerics_on_all_three_platforms(smollm):
    """The acceptance run, tier-1 sized: a real smollm-360m-config workload
    through the engine on FaaS, IaaS and pods -- the loss history is
    platform-independent (statistical vs system efficiency split), and
    LocalSGD(H=4) on pods cuts metered comm seconds >= 4x vs BSP while
    tracking the H=1 history at the averaging boundaries."""
    wl, tr, va = smollm
    algo = _ga()
    runs = {
        "faas": FaaSRuntime(workers=4, sync="bsp", channel="memcached"),
        "iaas": IaaSRuntime(workers=4, sync="bsp"),
        "pod": PodPlatform(pods=4, sync="bsp"),
    }
    hist = {}
    for name, plat in runs.items():
        res = plat.train(wl, algo, tr, va, max_epochs=2)
        assert not res.error, (name, res.error)
        hist[name] = [l for _, l in res.history]
    assert hist["faas"] == hist["iaas"] == hist["pod"]

    r1 = PodPlatform(pods=4, sync="local:1").train(wl, algo, tr, va,
                                                   max_epochs=2)
    r4 = PodPlatform(pods=4, sync="local:4").train(wl, algo, tr, va,
                                                   max_epochs=2)
    assert r1.breakdown["comm"] / r4.breakdown["comm"] >= 4.0 * (1 - 1e-9)
    assert r4.comm_bytes * 4 == r1.comm_bytes
    losses1 = [l for _, l in r1.history]
    # H=4 evals only at averaging boundaries (rounds 4, 8, ... of H=1)
    boundaries = [(i + 1) * 4 - 1 for i in range(len(r4.history))]
    for (t4, l4), rnd in zip(r4.history, boundaries):
        assert abs(l4 - losses1[rnd]) / losses1[rnd] < 0.05


# -------------------------------------------------- analytical derivation ---

def test_workload_name_collision_resolved():
    assert AnalyticAlias is CostInputs
    assert not isinstance(CostInputs(1.0, 1.0, 1.0, 1.0), Workload)


def test_cost_inputs_derive_from_workload(smollm):
    from repro.data.synthetic import make_dataset, train_val_split
    tr, _ = train_val_split(make_dataset("higgs", rows=2_000))
    lr = make_study_model("lr", tr)
    ci = CostInputs.from_workload(lr, tr, R=5)
    assert ci.s_bytes == tr.nbytes
    assert ci.m_bytes == update_vector_bytes(lr) == tr.d * 4
    assert ci.R == 5 and ci.C > 0
    wl, wtr, _ = smollm
    ci2 = CostInputs.from_workload(wl, wtr, R=2)
    assert ci2.m_bytes == wl.n_params * 4
    assert ci2.C == wtr.n * wl.flops_per_row / 5.5e9
    with pytest.raises(ValueError):
        CostInputs.from_workload(lr, tr)           # no R, no estimator args


def test_analytic_crossover_ordering_agrees_with_simulation():
    """Satellite cross-check: for the same workload constants, the analytic
    FaaS/IaaS comparison must order the platforms the same way a simulated
    sweep does at each worker count."""
    from repro.experiments import ExperimentSpec, run_experiment
    base = ExperimentSpec(model="lr", dataset="higgs", rows=3_000,
                          algorithm="ga_sgd",
                          algo_args={"lr": 0.2, "batch_size": 512},
                          max_epochs=2)
    wl, tr, _ = make_workload("lr", dataset="higgs", rows=3_000)
    ci = CostInputs.from_workload(wl, tr, R=base.max_epochs)
    for w in (2, 8):
        sim = {}
        for plat in ("faas", "iaas"):
            rec = run_experiment(base.with_(platform=plat,
                                            **{"fleet.workers": w}))
            assert not rec.result["error"]
            sim[plat] = rec.result["sim_time_s"]
        analytic_faas_wins = faas_time(ci, w) < iaas_time(ci, w)
        sim_faas_wins = sim["faas"] < sim["iaas"]
        assert analytic_faas_wins == sim_faas_wins, (w, ci, sim)
