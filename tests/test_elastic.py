"""Elastic fleet control (DESIGN.md §13): static-parity pinning, scheduled
resizes under scripted preemptions, the cost-cap budget invariant, the
analytic planner's paper crossover, and the spec/CLI surface."""
import json
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.algorithms import make_algorithm
from repro.core.elastic import (
    CostCapPolicy, SchedulePolicy, ScalingPolicy, SMLTPolicy, StaticPolicy,
    Telemetry, build_controller, make_policy, plan, plan_initial_workers,
)
from repro.core.mlmodels import make_study_model
from repro.core.platform import FailureSpec, FleetSpec
from repro.core.runtimes import FaaSRuntime, IaaSRuntime, PodPlatform
from repro.data.synthetic import make_dataset, train_val_split

ROOT = Path(__file__).resolve().parents[1]
ENV = {**os.environ, "PYTHONPATH": str(ROOT / "src")}


@pytest.fixture(scope="module")
def workload():
    ds = make_dataset("higgs", rows=4_000, seed=0)
    tr, va = train_val_split(ds)
    model = make_study_model("lr", tr)
    algo = make_algorithm("ga_sgd", lr=0.2, batch_size=512)
    return model, algo, tr, va


def _hist(res):
    return [(float(t), float(l)) for t, l in res.history]


# ------------------------------------------------------------ (a) parity ----

@pytest.mark.parametrize("make", [
    lambda **kw: FaaSRuntime(workers=3, **kw),
    lambda **kw: IaaSRuntime(workers=3, **kw),
    lambda **kw: PodPlatform(pods=3, **kw),
], ids=["faas", "iaas", "pod"])
def test_static_parity_pinned_on_all_platforms(workload, make):
    """scaling='static' (the default) is byte-identical to a fixed fleet,
    AND an active controller that never resizes (a constant schedule)
    perturbs nothing but the timeline -- the controller only reads."""
    model, algo, tr, va = workload
    base = make().train(model, algo, tr, va, max_epochs=2)
    static = make(scaling="static").train(model, algo, tr, va, max_epochs=2)
    pinned = make(scaling="schedule:3@0").train(model, algo, tr, va,
                                                max_epochs=2)
    assert base.scaling_timeline == [] == static.scaling_timeline
    assert pinned.scaling_timeline == [(0, 3, 0.0, 0.0)]
    for other in (static, pinned):
        assert _hist(other) == _hist(base)
        assert other.sim_time == base.sim_time
        assert other.cost == base.cost
        assert other.comm_bytes == base.comm_bytes
        assert other.breakdown == base.breakdown


def test_static_policy_builds_no_controller():
    assert build_controller("static", FleetSpec()) is None
    assert build_controller(StaticPolicy(), FleetSpec()) is None
    assert build_controller("smlt", FleetSpec()) is not None
    assert isinstance(SchedulePolicy.parse("2@0,8@5"), ScalingPolicy)


# -------------------------------------------- (b) schedule x preemptions ----

def test_schedule_resize_under_injected_preemption(workload):
    """A worker retired by a scale-down takes its scripted spot kill with
    it: a later scale-up mints FRESH worker ids, so the preemption never
    fires -- while the same kill on a fixed fleet does."""
    model, algo, tr, va = workload
    sched = "schedule:4@0,2@1,4@6"

    dry = FaaSRuntime(workers=4, scaling=sched).train(
        model, algo, tr, va, max_epochs=10)
    assert not dry.error
    widths = [w for _r, w, _s, _c in dry.scaling_timeline]
    assert widths[:3] == [4, 2, 4]            # down at round 1, up at round 6
    up = dry.scaling_timeline[2]
    assert up[2] > 0.0 and up[3] > 0.0        # joiner startup billed

    # a kill for worker id 3, scheduled well after its retirement window
    t_kill = dry.sim_time + 1.0
    killed_static = FaaSRuntime(
        workers=4, preempt_at=((3, t_kill),)).train(
        model, algo, tr, va, max_epochs=10)
    assert killed_static.preemptions == 1     # fixed fleet: the kill lands
    killed_elastic = FaaSRuntime(
        workers=4, scaling=sched, preempt_at=((3, t_kill),)).train(
        model, algo, tr, va, max_epochs=10)
    assert killed_elastic.preemptions == 0    # id 3 is gone; ids 4/5 joined
    assert killed_elastic.workers == 4        # ...and the fleet is back to 4
    assert _hist(killed_elastic) == _hist(dry)   # kill truly never fired


def test_resize_budget_rescales_epochs(workload):
    """Scaling 4 -> 2 halves the fleet and re-partitions: rounds-per-epoch
    doubles, and the engine stretches the round budget to keep the epoch
    count instead of silently training less."""
    model, algo, tr, va = workload
    static = FaaSRuntime(workers=4).train(model, algo, tr, va, max_epochs=6)
    shrunk = FaaSRuntime(workers=4, scaling="schedule:2@2").train(
        model, algo, tr, va, max_epochs=6)
    assert shrunk.rounds > static.rounds      # narrower fleet, more rounds
    assert shrunk.scaling_timeline[-1][1] == 2


def test_iaas_spot_retired_worker_not_billed_after_exit(workload):
    """IaaS scale-down folds the retired VMs' usage into the bill exactly
    once: the elastic run must cost less than the same fixed fleet."""
    model, algo, tr, va = workload
    fixed = IaaSRuntime(workers=4).train(model, algo, tr, va, max_epochs=4)
    down = IaaSRuntime(workers=4, scaling="schedule:2@1").train(
        model, algo, tr, va, max_epochs=4)
    assert not down.error
    assert down.cost < fixed.cost


def test_ssp_membership_reconciliation(workload):
    """SSP resizes at eval boundaries: the run completes, the staleness
    bound holds within the new membership, and w(t) is recorded."""
    model, algo, tr, va = workload
    res = FaaSRuntime(workers=4, sync="ssp:2",
                      scaling="schedule:4@0,2@1").train(
        model, algo, tr, va, max_epochs=4)
    assert not res.error and res.rounds > 0
    assert any(w == 2 for _r, w, _s, _c in res.scaling_timeline)
    assert res.max_staleness <= 2


def test_ssp_scale_up_does_not_oscillate(workload):
    """The policy's round counter under SSP must be MONOTONE across a
    scale-up: `done // current_w` regresses after widening (16 rounds at
    w=8 reads as round 2), which would un-apply a schedule entry and
    flip-flop the fleet, re-billing joiner startup every swing."""
    model, algo, tr, va = workload
    res = FaaSRuntime(workers=2, sync="ssp:2",
                      scaling="schedule:2@0,8@5").train(
        model, algo, tr, va, max_epochs=6)
    assert not res.error
    rounds_seq = [r for r, _w, _s, _c in res.scaling_timeline]
    assert rounds_seq == sorted(rounds_seq)
    assert [w for _r, w, _s, _c in res.scaling_timeline] == [2, 8]


def test_resize_skipped_when_transport_item_limit_would_break():
    """A scale-down grows the scatter-reduce chunk: a target width whose
    per-item size exceeds the transport limit (DynamoDB 400 KB) is skipped
    -- the fleet keeps its width -- instead of aborting the run mid-flight
    with ChannelItemTooLarge."""
    from repro.core.platform import CommSpec

    ds = make_dataset("higgs", rows=8_000, seed=0)
    tr, va = train_val_split(ds)
    model = make_study_model("kmeans", tr, k=3_500)   # ~406 KB update:
                                                      # > 400 KB whole,
                                                      # < 400 KB halved
    algo = make_algorithm("kmeans_em")
    res = FaaSRuntime(
        workers=2, scaling="schedule:1@1",
        fleet=FleetSpec(workers=2, min_workers=1),
        comm=CommSpec(channel="dynamodb", pattern="scatter_reduce")).train(
        model, algo, tr, va, max_epochs=3)
    assert not res.error                     # the run survived
    assert res.workers == 2                  # the infeasible shrink was skipped
    assert all(w == 2 for _r, w, _s, _c in res.scaling_timeline)


def test_smlt_survives_sparse_eval_cadence(workload):
    """Under eval_every > 1 some boundaries see no fresh eval; the
    controller must report loss_delta=None there (no signal), not a stale
    0.0 that SMLT would read as a stall and shed the whole fleet on."""
    model, algo, tr, va = workload
    r1 = FaaSRuntime(workers=4, scaling="smlt").train(
        model, algo, tr, va, max_epochs=4, eval_every=1)
    r2 = FaaSRuntime(workers=4, scaling="smlt").train(
        model, algo, tr, va, max_epochs=4, eval_every=2)
    assert max(w for _r, w, _s, _c in r1.scaling_timeline) == \
        max(w for _r, w, _s, _c in r2.scaling_timeline)   # still widens
    assert all(w >= 2 for _r, w, _s, _c in r2.scaling_timeline)


def test_elastic_train_is_repeatable(workload):
    """An elastic run must not leave the platform's fleet at the final
    width: a second train() on the same object reproduces the first."""
    model, algo, tr, va = workload
    rt = FaaSRuntime(workers=4, scaling="schedule:2@3")
    r1 = rt.train(model, algo, tr, va, max_epochs=3)
    r2 = rt.train(model, algo, tr, va, max_epochs=3)
    assert rt.workers == 4
    assert _hist(r1) == _hist(r2)
    assert r1.scaling_timeline == r2.scaling_timeline


def test_schedule_widths_validated_at_spec_time():
    """Every width a schedule names is checked against the comm stack's
    per-item limits eagerly: a round-0 pin to a width whose scatter-reduce
    chunk busts DynamoDB's 400 KB must fail at spec construction, not
    mid-simulation."""
    from repro.experiments import ExperimentSpec
    from repro.core.platform import CommSpec

    kw = dict(model="kmeans", model_args={"k": 3_500},
              algorithm="kmeans_em", rows=8_000,
              comm=CommSpec(channel="dynamodb", pattern="scatter_reduce"),
              fleet=FleetSpec(workers=2, min_workers=1))
    ExperimentSpec(**kw)                                  # w=2 chunks fit
    with pytest.raises(ValueError, match="dynamodb"):
        ExperimentSpec(scaling="schedule:1@0", **kw)      # w=1 busts 400 KB


def test_planner_prices_real_instance_even_off_nic_table():
    """Instances outside the analytic NIC table fall back to t2.medium's
    Table 6 constants for TIME only; the COST keeps the real hourly rate
    (c5.xlarge is ~3.7x t2.medium) and the option says so."""
    opts_cheap = plan("lr_higgs", "fastest", platforms=("iaas",),
                      workers=(10,), instance="t2.medium")
    opts_big = plan("lr_higgs", "fastest", platforms=("iaas",),
                    workers=(10,), instance="c5.xlarge")
    assert opts_big[0].time_s == opts_cheap[0].time_s
    assert opts_big[0].cost_usd > 3 * opts_cheap[0].cost_usd
    assert "approximated" in opts_big[0].note


def test_elastic_rejects_unsupported_pairings():
    with pytest.raises(ValueError, match="homogeneous"):
        build_controller("smlt", FleetSpec(workers=2, lambda_gb=(3.0, 1.0)))
    with pytest.raises(ValueError, match="<workers>@<round>"):
        make_policy("schedule:oops")
    with pytest.raises(KeyError, match="unknown scaling policy"):
        make_policy("warp9")
    with pytest.raises(ValueError, match="spec level"):
        make_policy("plan")
    with pytest.raises(ValueError):
        FleetSpec(workers=4, max_workers=2)


# ------------------------------------------------- (c) cost_cap property ----

def test_cost_cap_stop_is_recorded(workload):
    model, algo, tr, va = workload
    policy = CostCapPolicy(1e-4)              # far below one round's spend
    res = FaaSRuntime(workers=4, scaling=policy).train(
        model, algo, tr, va, max_epochs=6)
    assert res.scaling_timeline[-1][1] == 0   # the stop is in the timeline
    assert res.cost <= 1e-4 + policy.max_round_spend + 1e-12


def test_cost_cap_never_overshoots_by_more_than_one_round(workload):
    """Property: for ANY budget, total $ <= budget + one round's spend
    (the policy only lets a round start while still under budget)."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    model, algo, tr, va = workload

    @settings(max_examples=8, deadline=None)
    @given(budget=st.floats(min_value=1e-5, max_value=2e-2),
           workers=st.integers(min_value=2, max_value=6))
    def prop(budget, workers):
        policy = CostCapPolicy(budget)
        res = FaaSRuntime(workers=workers, scaling=policy,
                          fleet=FleetSpec(workers=workers,
                                          min_workers=1)).train(
            model, algo, tr, va, max_epochs=4)
        assert res.cost <= budget + policy.max_round_spend + 1e-12

    prop()


def test_smlt_widens_then_narrows():
    """Unit-level SMLT contract on hand-built telemetry: improving rate ->
    widen; stalled rate -> step back; decayed loss delta -> narrow."""
    pol = SMLTPolicy(factor=2)

    def tel(rnd, w, delta, rt=1.0):
        return Telemetry(round=rnd, workers=w, loss=1.0, loss_delta=delta,
                         round_time=rt, comm_share=0.2, cost_so_far=0.0,
                         sim_time=10.0, min_workers=1, max_workers=64)

    assert pol.observe(tel(1, 4, 0.10)) == 8        # first signal: widen
    assert pol.observe(tel(2, 8, 0.25)) == 16       # rate improved: widen
    assert pol.observe(tel(3, 16, 0.20)) == 8       # stalled: step back
    assert pol.observe(tel(4, 8, 0.20)) == 8        # hold
    assert pol.observe(tel(5, 8, 0.01)) == 4        # efficiency decayed


# ---------------------------------------------------------- (d) planner -----

def test_planner_reproduces_paper_crossover():
    """The paper's headline: FaaS pays off for fast-converging, comm-light
    LR/Higgs; comm-heavy MobileNet belongs on IaaS -- under BOTH
    objectives."""
    for objective in ("cheapest", "fastest"):
        assert plan("lr_higgs", objective)[0].platform == "faas", objective
        assert plan("mobilenet_cifar10", objective)[0].platform == "iaas", \
            objective


def test_planner_constraints_and_ranking():
    opts = plan("lr_higgs", "cheapest")
    assert all(o.feasible for o in opts if o is opts[0])
    assert opts == sorted(opts, key=lambda o: (not o.feasible, o.cost_usd))
    # unconstrained cheapest is a tiny IaaS fleet (VM-seconds are ~4x
    # cheaper than 3GB-Lambda-seconds) -- the auto-deadline is what asks
    # the paper's question "at a competitive degree of parallelism"
    import math
    assert plan("lr_higgs", "cheapest",
                deadline_s=math.inf)[0].platform == "iaas"
    tight = plan("lr_higgs", "fastest", budget_usd=1e-6)
    assert not tight[0].feasible and "budget" in tight[0].note
    with pytest.raises(KeyError, match="unknown planner workload"):
        plan("gpt17_800t", "cheapest")
    with pytest.raises(ValueError, match="objective"):
        plan("lr_higgs", "best_vibes")


def test_plan_scaling_picks_initial_fleet():
    from repro.experiments import ExperimentSpec
    spec = ExperimentSpec(rows=3_000, max_epochs=2, scaling="plan",
                          fleet=FleetSpec(workers=4, max_workers=25))
    rt = spec.build_runtime()
    assert rt.scaling == "static"             # the run itself is fixed
    assert 1 <= rt.workers <= 25
    with pytest.raises(ValueError, match="faas/iaas"):
        ExperimentSpec(platform="pod", scaling="plan")


# ------------------------------------------------------- spec + CLI layer ---

def test_spec_round_trip_and_hash_with_scaling():
    from repro.experiments import ExperimentSpec
    spec = ExperimentSpec(name="el", rows=3_000, max_epochs=2,
                          scaling="schedule:2@0,6@3",
                          fleet=FleetSpec(workers=4, min_workers=2,
                                          max_workers=8))
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    assert spec.spec_hash() != spec.with_(scaling="static").spec_hash()
    # defaults elide: an all-default spec hashes schema + {} (h6 re-key:
    # the trace= field landed, DESIGN.md §18)
    import hashlib
    from repro.experiments.spec import HASH_SCHEMA
    assert HASH_SCHEMA == "h6"
    assert ExperimentSpec().spec_hash() == \
        hashlib.sha256(f"{HASH_SCHEMA}{{}}".encode()).hexdigest()[:16]


def test_resizeless_protocol_refuses_elastic_policies(workload):
    """Every built-in protocol declares supports_resize; a custom one that
    does not (the base-class default) must be refused up front rather than
    resized mid-flight."""
    from repro.core.sync import SyncProtocol

    class FrozenProto(SyncProtocol):
        name = "frozen"

        def run(self, ctx):               # pragma: no cover - never reached
            raise AssertionError

    model, algo, tr, va = workload
    with pytest.raises(ValueError, match="supports_resize"):
        FaaSRuntime(workers=2, sync=FrozenProto(), scaling="smlt").train(
            model, algo, tr, va, max_epochs=1)


def test_run_experiment_records_scaling_timeline(tmp_path):
    from repro.experiments import ExperimentSpec, run_experiment
    spec = ExperimentSpec(name="tl", rows=3_000, max_epochs=4,
                          scaling="schedule:2@0,4@2",
                          fleet=FleetSpec(workers=2, max_workers=8))
    rec = run_experiment(spec, cache_dir=tmp_path)
    tl = rec.result["scaling_timeline"]
    assert [w for _r, w, _s, _c in tl][:2] == [2, 4]
    d = json.loads(Path(rec.path).read_text())
    assert d["result"]["scaling_timeline"] == tl


def test_cli_plan_smoke():
    out = subprocess.run(
        [sys.executable, "-m", "repro", "plan", "lr_higgs",
         "--objective", "cheapest"],
        cwd=ROOT, env=ENV, capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    pick = out.stdout.splitlines()[2]
    assert "faas" in pick and "<- pick" in pick
    listing = subprocess.run(
        [sys.executable, "-m", "repro", "list"],
        cwd=ROOT, env=ENV, capture_output=True, text=True, timeout=120)
    assert "scaling policies" in listing.stdout
    assert "elastic_axis" in listing.stdout
