"""Shared test fixtures.

NOTE: no XLA_FLAGS / device-count overrides here -- smoke tests and
benchmarks must see the real single CPU device.  Multi-device tests go
through subprocesses (tests/test_distributed.py) that set
REPRO_XLA_FLAGS before any jax import.
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    import numpy as np
    return np.random.default_rng(0)
